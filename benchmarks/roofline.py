"""§Roofline: assemble the per-cell roofline table from dry-run artifacts.

Terms (per chip, seconds):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s ICI)

plus MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE) / 2·N·D (serve)
and the useful-compute ratio MODEL_FLOPS / (chips x HLO_FLOPs).
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _lm_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND
    from repro.models.transformer import lm_param_specs, layer_groups
    from repro.models.params import tree_num_params

    cfg = get_config(arch)
    specs = lm_param_specs(cfg)
    total = tree_num_params(specs)
    n_active = total
    if cfg.moe is not None:
        m = cfg.moe
        L_moe = cfg.n_layers - m.first_k_dense
        routed = L_moe * m.num_experts * 3 * cfg.d_model * m.d_ff_expert
        n_active = total - routed * (1 - m.top_k / m.num_experts)
    sh = SHAPES_BY_KIND["lm"][shape]
    if sh["step"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n_active * tokens
    if sh["step"] == "prefill":
        return 2.0 * n_active * sh["global_batch"] * sh["seq_len"]
    return 2.0 * n_active * sh["global_batch"]  # decode: one token / request


def _gnn_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND

    cfg = get_config(arch)
    sh = SHAPES_BY_KIND["gnn"][shape]
    d = cfg.d_hidden
    if sh["mode"] == "full":
        E, N, F = sh["n_edges"], sh["n_nodes"], sh["d_feat"]
    elif sh["mode"] == "sampled":
        B = sh["batch_nodes"]
        f1, f2 = sh["fanout"]
        N = B * (1 + f1 + f1 * f2)
        E = 2 * (B * f1 + B * f1 * f2)
        F = sh["d_feat"]
    else:
        N = sh["batch"] * sh["n_nodes"]
        E = 2 * sh["batch"] * sh["n_edges"]
        F = sh["d_feat"]
    fwd = cfg.n_layers * (2 * E * d + 2 * N * d * max(F, d))
    return 3.0 * fwd  # train ~ 3x forward


def _recsys_model_flops(shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND

    cfg = get_config("mind")
    sh = SHAPES_BY_KIND["recsys"][shape]
    B = sh["batch"]
    D, K, L = cfg.embed_dim, cfg.n_interests, cfg.hist_len
    routing = cfg.capsule_iters * 2 * B * K * L * D * 2
    mlp = 2 * B * K * (2 * D * cfg.mlp_dim + cfg.mlp_dim * D)
    f = routing + mlp + 2 * B * L * D * D
    if sh["step"] == "train":
        f = 3 * f + 2 * B * cfg.num_sampled_negatives * D * 3
    if sh["step"] == "retrieval":
        f += 2 * sh["n_candidates"] * K * D
    return float(f)


def model_flops(arch: str, shape: str) -> float:
    if arch.startswith("semicore"):
        return 0.0
    from repro.configs import get_config

    kind = get_config(arch).kind
    if kind == "lm":
        return _lm_model_flops(arch, shape)
    if kind == "gnn":
        return _gnn_model_flops(arch, shape)
    return _recsys_model_flops(shape)


def load_table(mesh: str = "single_pod_16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "ok": False})
            continue
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["hlo_flops_per_chip"] * r["chips"]
        roof = r["roofline"]
        bound_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "ok": True,
            "chips": r["chips"], "step": r["step"],
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"], "dominant": roof["dominant"],
            "model_flops": mf,
            "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
            "roofline_fraction": (roof["compute_s"] / bound_s) if bound_s else 0.0,
            "mfu_bound": (mf / r["chips"] / 197e12) / bound_s if bound_s else 0.0,
            "hbm_bytes_per_chip": r["memory"]["argument_bytes"]
            + r["memory"]["temp_bytes"],
        })
    return rows


def print_table(mesh: str = "single_pod_16x16"):
    rows = load_table(mesh)
    hdr = (f"{'arch':<18} {'shape':<14} {'dom':<10} {'compute_s':>10} "
           f"{'memory_s':>10} {'collect_s':>10} {'useful%':>8} {'MFUbound%':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if not r.get("ok"):
            print(f"{r['arch']:<18} {r['shape']:<14} FAILED")
            continue
        print(f"{r['arch']:<18} {r['shape']:<14} {r['dominant']:<10} "
              f"{r['compute_s']:>10.3e} {r['memory_s']:>10.3e} "
              f"{r['collective_s']:>10.3e} {100 * r['useful_ratio']:>7.1f}% "
              f"{100 * r['mfu_bound']:>8.1f}%")
    return rows


if __name__ == "__main__":
    import sys

    print_table(sys.argv[1] if len(sys.argv) > 1 else "single_pod_16x16")
