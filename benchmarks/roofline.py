"""§Roofline: assemble the per-cell roofline table from dry-run artifacts.

Terms (per chip, seconds):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s ICI)

plus MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE) / 2·N·D (serve)
and the useful-compute ratio MODEL_FLOPS / (chips x HLO_FLOPs).

``--superstep`` switches to the decomposition engine's roofline: achieved
bytes/s of the fused superstep — numerator sourced *entirely* from the
telemetry registry (``repro_io_bytes_read_total`` delta around one warm
decompose, no hand math) — against a peak measured by a same-process memcpy
probe.  The superstep is memory-bound by construction (one h-index probe per
touched edge), so achieved/peak is the headroom number.
"""
from __future__ import annotations

import glob
import json
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SUPERSTEP_RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _lm_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND
    from repro.models.transformer import lm_param_specs, layer_groups
    from repro.models.params import tree_num_params

    cfg = get_config(arch)
    specs = lm_param_specs(cfg)
    total = tree_num_params(specs)
    n_active = total
    if cfg.moe is not None:
        m = cfg.moe
        L_moe = cfg.n_layers - m.first_k_dense
        routed = L_moe * m.num_experts * 3 * cfg.d_model * m.d_ff_expert
        n_active = total - routed * (1 - m.top_k / m.num_experts)
    sh = SHAPES_BY_KIND["lm"][shape]
    if sh["step"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n_active * tokens
    if sh["step"] == "prefill":
        return 2.0 * n_active * sh["global_batch"] * sh["seq_len"]
    return 2.0 * n_active * sh["global_batch"]  # decode: one token / request


def _gnn_model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND

    cfg = get_config(arch)
    sh = SHAPES_BY_KIND["gnn"][shape]
    d = cfg.d_hidden
    if sh["mode"] == "full":
        E, N, F = sh["n_edges"], sh["n_nodes"], sh["d_feat"]
    elif sh["mode"] == "sampled":
        B = sh["batch_nodes"]
        f1, f2 = sh["fanout"]
        N = B * (1 + f1 + f1 * f2)
        E = 2 * (B * f1 + B * f1 * f2)
        F = sh["d_feat"]
    else:
        N = sh["batch"] * sh["n_nodes"]
        E = 2 * sh["batch"] * sh["n_edges"]
        F = sh["d_feat"]
    fwd = cfg.n_layers * (2 * E * d + 2 * N * d * max(F, d))
    return 3.0 * fwd  # train ~ 3x forward


def _recsys_model_flops(shape: str) -> float:
    from repro.configs import get_config, SHAPES_BY_KIND

    cfg = get_config("mind")
    sh = SHAPES_BY_KIND["recsys"][shape]
    B = sh["batch"]
    D, K, L = cfg.embed_dim, cfg.n_interests, cfg.hist_len
    routing = cfg.capsule_iters * 2 * B * K * L * D * 2
    mlp = 2 * B * K * (2 * D * cfg.mlp_dim + cfg.mlp_dim * D)
    f = routing + mlp + 2 * B * L * D * D
    if sh["step"] == "train":
        f = 3 * f + 2 * B * cfg.num_sampled_negatives * D * 3
    if sh["step"] == "retrieval":
        f += 2 * sh["n_candidates"] * K * D
    return float(f)


def model_flops(arch: str, shape: str) -> float:
    if arch.startswith("semicore"):
        return 0.0
    from repro.configs import get_config

    kind = get_config(arch).kind
    if kind == "lm":
        return _lm_model_flops(arch, shape)
    if kind == "gnn":
        return _gnn_model_flops(arch, shape)
    return _recsys_model_flops(shape)


def load_table(mesh: str = "single_pod_16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"], "ok": False})
            continue
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["hlo_flops_per_chip"] * r["chips"]
        roof = r["roofline"]
        bound_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "ok": True,
            "chips": r["chips"], "step": r["step"],
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"], "dominant": roof["dominant"],
            "model_flops": mf,
            "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
            "roofline_fraction": (roof["compute_s"] / bound_s) if bound_s else 0.0,
            "mfu_bound": (mf / r["chips"] / 197e12) / bound_s if bound_s else 0.0,
            "hbm_bytes_per_chip": r["memory"]["argument_bytes"]
            + r["memory"]["temp_bytes"],
        })
    return rows


def print_table(mesh: str = "single_pod_16x16"):
    rows = load_table(mesh)
    hdr = (f"{'arch':<18} {'shape':<14} {'dom':<10} {'compute_s':>10} "
           f"{'memory_s':>10} {'collect_s':>10} {'useful%':>8} {'MFUbound%':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if not r.get("ok"):
            print(f"{r['arch']:<18} {r['shape']:<14} FAILED")
            continue
        print(f"{r['arch']:<18} {r['shape']:<14} {r['dominant']:<10} "
              f"{r['compute_s']:>10.3e} {r['memory_s']:>10.3e} "
              f"{r['collective_s']:>10.3e} {100 * r['useful_ratio']:>7.1f}% "
              f"{100 * r['mfu_bound']:>8.1f}%")
    return rows


# ====================================================== superstep roofline
def measured_memcpy_peak(nbytes: int = 1 << 27, repeats: int = 5) -> float:
    """Achievable host copy bandwidth in bytes/s (read + write counted).

    The paper's blocked I/O model charges the superstep for bytes *read*;
    the honest peak for that charge on a host runner is a large memcpy —
    the same streams the fused pass moves, with none of its arithmetic.
    """
    src = np.empty(nbytes // 8, dtype=np.float64)
    src.fill(1.0)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2.0 * nbytes / best  # bytes touched = read + write


def superstep_roofline(quick: bool = False,
                       backends=("numpy", "xla")) -> list[dict]:
    """Achieved-vs-peak bytes/s of the fused superstep, registry-sourced."""
    from repro.core.semicore import decompose
    from repro.graph import chung_lu
    from repro.kernels import fused_superstep as fsk
    from repro.obs import metrics as obs_metrics

    n, m, block_edges = (3_000, 13_000, 512) if quick \
        else (25_000, 110_000, 4096)
    g = chung_lu(n, m, seed=8)
    peak = measured_memcpy_peak(1 << 24 if quick else 1 << 27)
    rows = []
    for backend in backends:
        decompose(g, "semicore*", "batch", block_edges=block_edges,
                  backend=backend)  # warm jit caches out of the measurement
        snap = obs_metrics.get_registry().snapshot()
        t0 = time.perf_counter()
        r = decompose(g, "semicore*", "batch", block_edges=block_edges,
                      backend=backend)
        wall = time.perf_counter() - t0
        delta = obs_metrics.get_registry().delta(snap)
        nbytes = obs_metrics.sum_by_name(delta, "repro_io_bytes_read_total")
        achieved = nbytes / max(wall, 1e-9)
        rows.append({
            "backend": backend,
            "algorithm": "semicore*",
            "graph": {"n": g.n, "m": g.m, "block_edges": block_edges},
            "fused_kernel": backend == "pallas" and fsk.fused_enabled(),
            "wall_seconds": round(wall, 5),
            "bytes_read": int(nbytes),
            "passes": int(obs_metrics.sum_by_name(
                delta, "repro_engine_passes_total")),
            "achieved_bytes_per_s": achieved,
            "peak_bytes_per_s": peak,
            "roofline_fraction": achieved / peak,
            "iterations_check": r.iterations,
        })
    return rows


def print_superstep(quick: bool = False, fused: bool = False) -> list[dict]:
    """``fused`` adds the pallas backend (the one-pallas_call-per-pass
    superstep, DESIGN.md §16) to the sweep and writes a separate JSON so
    the two modes don't clobber each other in CI."""
    backends = ("numpy", "xla", "pallas") if fused else ("numpy", "xla")
    rows = superstep_roofline(quick, backends=backends)
    hdr = (f"{'backend':<8} {'wall_s':>9} {'bytes_read':>12} "
           f"{'achieved GB/s':>14} {'peak GB/s':>10} {'roofline%':>10}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['backend']:<8} {r['wall_seconds']:>9.4f} "
              f"{r['bytes_read']:>12,} "
              f"{r['achieved_bytes_per_s'] / 1e9:>14.3f} "
              f"{r['peak_bytes_per_s'] / 1e9:>10.3f} "
              f"{100 * r['roofline_fraction']:>9.1f}%")
    os.makedirs(SUPERSTEP_RESULTS, exist_ok=True)
    name = "fused_superstep_roofline.json" if fused \
        else "superstep_roofline.json"
    path = os.path.join(SUPERSTEP_RESULTS, name)
    with open(path, "w") as f:
        json.dump({"rows": rows}, f, indent=2)
        f.write("\n")
    if fused:
        # markdown mirror for $GITHUB_STEP_SUMMARY (scripts/ci.sh)
        md = os.path.join(SUPERSTEP_RESULTS, "fused_superstep_roofline.md")
        g = rows[0]["graph"]
        with open(md, "w") as f:
            f.write(f"### Fused-superstep roofline (semicore*, n={g['n']}, "
                    f"m={g['m']}, registry-sourced bytes)\n\n")
            f.write("| backend | fused kernel | warm wall | bytes read | "
                    "achieved GB/s | roofline |\n|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(f"| {r['backend']} | "
                        f"{'yes' if r['fused_kernel'] else '-'} | "
                        f"{r['wall_seconds']:.3f}s | {r['bytes_read']:,} | "
                        f"{r['achieved_bytes_per_s'] / 1e9:.3f} | "
                        f"{100 * r['roofline_fraction']:.1f}% |\n")
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("mesh", nargs="?", default="single_pod_16x16")
    ap.add_argument("--superstep", action="store_true",
                    help="registry-sourced achieved-vs-peak bytes/s of the "
                    "fused superstep")
    ap.add_argument("--fused-superstep", action="store_true",
                    help="like --superstep but includes the pallas backend "
                    "(single-kernel fused superstep)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.superstep or args.fused_superstep:
        print_superstep(quick=args.quick, fused=args.fused_superstep)
    else:
        print_table(args.mesh)
