"""Streaming core service benchmark: sustained updates/s + query QPS.

A mixed workload on a Chung–Lu graph: micro-batches of edge inserts/deletes
ingested through ``CoreService`` interleaved with bursts of read queries
(coreness lookups, k-core membership, top-k) against the committed epoch
view.  Reports updates/s, query QPS, edge-block reads per batch, cache hit
rate, and the cost of a WAL+snapshot recovery vs. a cold decomposition.
Always verifies the streamed ``core`` against ``decompose`` on the final
graph.

  PYTHONPATH=src python benchmarks/bench_stream.py --quick
  REPRO_BENCH_FULL=1 PYTHONPATH=src python benchmarks/bench_stream.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.core import decompose  # noqa: E402
from repro.graph import chung_lu  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs.bench import shared_result  # noqa: E402
from repro.stream import CoreService, mixed_stream  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def query_burst(svc: CoreService, rng, num_queries: int) -> int:
    """A read burst against the current epoch; returns #queries served."""
    kmax = svc.degeneracy()
    served = 1  # the degeneracy lookup above is a served query too
    for _ in range(num_queries // 4):
        svc.coreness(int(rng.integers(svc.bg.n)))
        svc.in_kcore(int(rng.integers(svc.bg.n)), max(kmax - 1, 1))
        svc.top_k(100)
        svc.kcore_members(max(kmax - 1, 1))
        served += 4
    return served


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI smoke runs")
    args = ap.parse_args()
    full = os.environ.get("REPRO_BENCH_FULL") == "1" and not args.quick

    if full:  # the ISSUE acceptance workload
        n, m, num_updates, batch = 30_000, 200_000, 10_000, 200
    elif args.quick:
        n, m, num_updates, batch = 3_000, 12_000, 600, 100
    else:
        n, m, num_updates, batch = 10_000, 60_000, 3_000, 150
    queries_per_batch = 200

    g = chung_lu(n, m, seed=1)
    ops, _ = mixed_stream(g, num_updates, seed=2)
    rng = np.random.default_rng(3)

    with tempfile.TemporaryDirectory() as tmp:
        svc = CoreService(
            g,
            wal_path=os.path.join(tmp, "wal.jsonl"),
            snapshot_dir=os.path.join(tmp, "snaps"),
        )
        # telemetry baseline *after* construction: the delta below is pure
        # workload cost (initial decompose + WAL truncate excluded)
        obs_snap = obs_metrics.get_registry().snapshot()
        num_batches = -(-len(ops) // batch)
        snapshot_at = max((2 * num_batches) // 3, 1)  # leaves a WAL tail
        update_s = query_s = 0.0
        queries = 0
        for b, i in enumerate(range(0, len(ops), batch)):
            t0 = time.perf_counter()
            svc.ingest(ops[i : i + batch])
            update_s += time.perf_counter() - t0
            if b + 1 == snapshot_at:
                svc.snapshot()
            t0 = time.perf_counter()
            queries += query_burst(svc, rng, queries_per_batch)
            query_s += time.perf_counter() - t0

        # workload numbers now come from the telemetry registry: the ingest
        # latency histogram supplies the percentiles and the service
        # counters supply the served-query and io totals.  The delta is taken
        # *before* the correctness-gate decompose below so it covers exactly
        # the streamed workload.
        delta = obs_metrics.get_registry().delta(obs_snap)

        # correctness gate: the stream must equal a fresh decomposition
        final = svc.bg.materialize()
        ref = decompose(final, "semicore*", "batch")
        assert np.array_equal(svc.maintainer.core, ref.core), "stream != decompose"

        log = svc.batch_log
        stats = svc.service_stats()
        applied = stats["updates_applied"]
        cache_total = stats["cache_hits"] + stats["cache_misses"]
        s = obs_metrics.sum_by_name
        ingest_hist = obs_metrics.get_registry().get(
            "repro_service_ingest_seconds")
        queries_served = int(s(delta, "repro_service_queries_total"))
        io_reads = int(s(delta, "repro_io_edge_block_reads_total"))
        nt_reads = int(s(delta, "repro_io_node_table_reads_total"))
        if obs_metrics.obs_enabled():  # registry must reconcile exactly
            assert queries_served == queries, (queries_served, queries)
            assert io_reads == sum(x.edge_block_reads for x in log), io_reads
            assert nt_reads == sum(x.node_table_reads for x in log), nt_reads
        else:  # silent registry: fall back to the hand-tracked numbers
            queries_served = queries
            io_reads = sum(x.edge_block_reads for x in log)
            nt_reads = sum(x.node_table_reads for x in log)
        rows = {
            "n": n, "m": m, "num_updates": num_updates, "batch": batch,
            "epochs": svc.epoch,
            "updates_per_s": applied / update_s,
            "query_qps": queries_served / query_s,
            "edge_block_reads_per_batch": io_reads / max(len(log), 1),
            "node_table_reads_per_batch": nt_reads / max(len(log), 1),
            "node_computations_per_update": float(
                sum(x.node_computations for x in log) / max(applied, 1)
            ),
            "p50_batch_ms": ingest_hist.quantile(0.50) * 1e3,
            "p99_batch_ms": ingest_hist.quantile(0.99) * 1e3,
            "cache_hit_rate": stats["cache_hits"] / max(cache_total, 1),
            "degeneracy": stats["degeneracy"],
            "obs": shared_result("stream/mixed-workload",
                                 update_s + query_s, delta),
        }

        # recovery cost vs a cold decomposition of the final graph
        svc.close()
        t0 = time.perf_counter()
        _, rec = CoreService.recover(
            wal_path=os.path.join(tmp, "wal.jsonl"),
            snapshot_dir=os.path.join(tmp, "snaps"),
        )
        rows["recovery_s"] = time.perf_counter() - t0
        rows["recovery_replayed_updates"] = rec.replayed_updates
        rows["recovery_settle_computations"] = rec.settle_node_computations
        rows["cold_decompose_computations"] = ref.node_computations

    print("name,us_per_call,derived")
    print(f"stream/ingest,{update_s / max(applied, 1) * 1e6:.1f},"
          f"updates_per_s={rows['updates_per_s']:.0f};"
          f"io_blocks_per_batch={rows['edge_block_reads_per_batch']:.1f};"
          f"p99_batch_ms={rows['p99_batch_ms']:.1f}")
    print(f"stream/query,{query_s / max(queries, 1) * 1e6:.1f},"
          f"qps={rows['query_qps']:.0f};"
          f"cache_hit_rate={rows['cache_hit_rate']:.3f}")
    print(f"stream/recovery,{rows['recovery_s'] * 1e6:.1f},"
          f"settle_comp={rows['recovery_settle_computations']};"
          f"cold_comp={rows['cold_decompose_computations']}")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "stream.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# verified: streamed core == decompose(final) on n={n}, "
          f"m={final.m}, {num_updates} updates", file=sys.stderr)


if __name__ == "__main__":
    main()
