"""Streaming core service benchmark: sustained updates/s + query QPS.

A mixed workload on a Chung–Lu graph: micro-batches of edge inserts/deletes
ingested through ``CoreService`` interleaved with bursts of read queries
(coreness lookups, k-core membership, top-k) against the committed epoch
view.  Reports updates/s, query QPS, edge-block reads per batch, cache hit
rate, and the cost of a WAL+snapshot recovery vs. a cold decomposition.
Always verifies the streamed ``core`` against ``decompose`` on the final
graph.

  PYTHONPATH=src python benchmarks/bench_stream.py --quick
  REPRO_BENCH_FULL=1 PYTHONPATH=src python benchmarks/bench_stream.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.core import CoreMaintainer, decompose  # noqa: E402
from repro.graph import chung_lu  # noqa: E402
from repro.graph.updates import BufferedGraph  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs.bench import shared_result  # noqa: E402
from repro.runtime import Settings  # noqa: E402
from repro.stream import (CoreService, CoreWriter, Overloaded,  # noqa: E402
                          UpdateBatch, mixed_stream)

RESULTS = os.path.join(os.path.dirname(__file__), "results")

#: maint-scaling cell (PR 10 acceptance): sustained updates/s, parallel
#: grouped settle vs the serial oracle, as a function of micro-batch size.
#: The sizes are fixed — the parallel win needs a graph large enough that
#: the serial warm settle's full-graph passes dominate, so --quick only
#: trims repeats, never the graph.
MAINT_CELL = dict(n=10_000, m=60_000, seed=3)
MAINT_BATCH_SIZES = (16, 64, 128)
#: the gate: at batch size 64 on xla the parallel settle must sustain at
#: least this multiple of the serial oracle's updates/s (ISSUE acceptance
#: asks >= 2x; measured ~2.7x on the reference runner).
MAINT_GATE_BATCH = 64
MAINT_GATE_SPEEDUP = 2.0


def _maint_batches(n, live, rng, bsz, count):
    """Deterministic mixed micro-batches over a shared live-edge set."""
    out = []
    for _ in range(count):
        ops = []
        for _ in range(bsz):
            if rng.random() < 0.5 and live:
                e = sorted(live)[int(rng.integers(len(live)))]
                live.discard(e)
                ops.append(("-",) + e)
            else:
                while True:
                    u, v = int(rng.integers(n)), int(rng.integers(n))
                    e = (min(u, v), max(u, v))
                    if u != v and e not in live:
                        break
                live.add(e)
                ops.append(("+",) + e)
        out.append(UpdateBatch.from_wire(ops))
    return out


def run_maint_scaling(quick: bool) -> tuple[dict, int]:
    """Sustained-updates/s-vs-batch-size cell + the >=2x trajectory gate.

    For each batch size, the same deterministic update stream is applied
    twice from the same initial graph — once through the parallel grouped
    settle, once through the serial oracle (``parallel_maint=False``) —
    and the two must land bit-identical (core and cnt).  Reports medians,
    p99 settle latency and the parallel/serial updates-per-second ratio;
    returns exit code 1 if the gate batch size misses the speedup floor.
    The ratio is same-machine, so the gate is machine-speed independent.
    """
    n, m = MAINT_CELL["n"], MAINT_CELL["m"]
    warmup, repeats = (2, 6) if quick else (2, 10)
    rows = []
    failures = 0
    for bsz in MAINT_BATCH_SIZES:
        walls = {}
        state = {}
        for parallel in (True, False):
            g = chung_lu(n, m, seed=MAINT_CELL["seed"])
            live = set(map(tuple, np.sort(g.edge_list(), axis=1)))
            batches = _maint_batches(
                n, live, np.random.default_rng(5), bsz, warmup + repeats)
            mt = CoreMaintainer(
                BufferedGraph(g),
                settings=Settings(backend="xla", parallel_maint=parallel))
            times = []
            for i, batch in enumerate(batches):
                t0 = time.perf_counter()
                mt.apply(batch)
                dt = time.perf_counter() - t0
                if i >= warmup:  # skip jit/compile warmup batches
                    times.append(dt)
            walls[parallel] = np.asarray(times)
            state[parallel] = (mt.core.copy(), mt.cnt.copy())
        # the differential contract, re-checked inside the bench
        assert np.array_equal(state[True][0], state[False][0]), \
            f"parallel core != serial core at batch={bsz}"
        assert np.array_equal(state[True][1], state[False][1]), \
            f"parallel cnt != serial cnt at batch={bsz}"
        par_med = float(np.median(walls[True]))
        ser_med = float(np.median(walls[False]))
        row = {
            "batch": bsz,
            "parallel_updates_per_s": bsz / par_med,
            "serial_updates_per_s": bsz / ser_med,
            "speedup": ser_med / par_med,
            "parallel_p50_ms": par_med * 1e3,
            "parallel_p99_ms": float(np.percentile(walls[True], 99)) * 1e3,
            "serial_p50_ms": ser_med * 1e3,
            "serial_p99_ms": float(np.percentile(walls[False], 99)) * 1e3,
        }
        gated = bsz == MAINT_GATE_BATCH
        row["gated"] = gated
        if gated and row["speedup"] < MAINT_GATE_SPEEDUP:
            failures += 1
        rows.append(row)
    return {"cell": dict(MAINT_CELL), "rows": rows,
            "gate_batch": MAINT_GATE_BATCH,
            "gate_speedup": MAINT_GATE_SPEEDUP}, failures


def run_overload(quick: bool) -> dict:
    """Admission-control cell (DESIGN.md §17): oversized bursts against a
    budgeted writer.  Bursts cycle through the three admission stages —
    under the soft budget (apply now), between soft and hard (bounded-
    staleness deferral) and over the hard budget (typed ``Overloaded``
    shed) — and the cell reports accepted-updates/s, the shed rate and the
    p99 admission latency.  Ends with the usual correctness gate: after a
    draining snapshot the streamed ``core`` must equal a fresh decompose.
    """
    if quick:
        n, m, budget, bursts = 3_000, 12_000, 240, 45
    else:
        n, m, budget, bursts = 10_000, 60_000, 400, 90
    # stage-0 / stage-1 / shed burst sizes, cycled in that order
    sizes = [budget // 3, (budget * 4) // 5, (budget * 3) // 2]
    g = chung_lu(n, m, seed=1)
    ops, _ = mixed_stream(g, sum(sizes) * (bursts // 3 + 1), seed=2)

    with tempfile.TemporaryDirectory() as tmp:
        w = CoreWriter(
            g,
            wal_path=os.path.join(tmp, "wal.jsonl"),
            snapshot_dir=os.path.join(tmp, "snaps"),
            admission_budget=budget,
            admission_soft_ratio=0.5,
            admission_max_defer=4,
        )
        walls = []
        offered = accepted_updates = deferred_batches = 0
        pos = 0
        ingest_s = 0.0
        for b in range(bursts):
            size = sizes[b % len(sizes)]
            chunk = ops[pos : pos + size]
            pos += size
            offered += len(chunk)
            t0 = time.perf_counter()
            try:
                stats = w.ingest(chunk)
                wall = time.perf_counter() - t0
                accepted_updates += len(chunk)
                deferred_batches += stats.deferred
            except Overloaded:
                wall = time.perf_counter() - t0
            walls.append(wall)
            ingest_s += wall
        w.snapshot()  # drain the pending pool: epoch catches the WAL tip
        assert w.epoch == w._wal_tip
        health = w.health()
        assert health["status"] == "ok", health

        final = w.bg.materialize()
        ref = decompose(final, "semicore*", "batch")
        assert np.array_equal(w.maintainer.core, ref.core), \
            "overloaded stream != decompose"

        adm = w.admission.state()
        wq = np.asarray(walls)
        shed = adm["rejected_updates"]
        row = {
            "n": n, "m": m, "budget": budget, "bursts": bursts,
            "burst_sizes": sizes,
            "offered_updates": offered,
            "accepted_updates": accepted_updates,
            "accepted_updates_per_s": accepted_updates / ingest_s,
            "shed_updates": shed,
            "shed_batches": adm["rejected_batches"],
            "shed_rate": shed / max(offered, 1),
            "deferred_batches": deferred_batches,
            "coalesced_updates": adm["coalesced"],
            "admission_p50_ms": float(np.percentile(wq, 50) * 1e3),
            "admission_p99_ms": float(np.percentile(wq, 99) * 1e3),
            "final_epoch": int(w.epoch),
        }
        w.close()
    return row


def query_burst(svc: CoreService, rng, num_queries: int) -> int:
    """A read burst against the current epoch; returns #queries served."""
    kmax = svc.degeneracy()
    served = 1  # the degeneracy lookup above is a served query too
    for _ in range(num_queries // 4):
        svc.coreness(int(rng.integers(svc.bg.n)))
        svc.in_kcore(int(rng.integers(svc.bg.n)), max(kmax - 1, 1))
        svc.top_k(100)
        svc.kcore_members(max(kmax - 1, 1))
        served += 4
    return served


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI smoke runs")
    ap.add_argument("--overload", action="store_true",
                    help="admission-backpressure cell only: oversized "
                         "bursts against a budgeted writer")
    ap.add_argument("--maint-scaling", action="store_true",
                    help="sustained updates/s vs batch size: parallel "
                         "grouped settle vs serial oracle (gated)")
    args = ap.parse_args()
    full = os.environ.get("REPRO_BENCH_FULL") == "1" and not args.quick

    if args.maint_scaling:
        cell, failures = run_maint_scaling(quick=args.quick or not full)
        print("name,us_per_call,derived")
        for row in cell["rows"]:
            print(f"stream/maint_scaling/batch={row['batch']},"
                  f"{row['parallel_p50_ms'] * 1e3:.1f},"
                  f"updates_per_s={row['parallel_updates_per_s']:.0f};"
                  f"serial_updates_per_s={row['serial_updates_per_s']:.0f};"
                  f"speedup={row['speedup']:.2f};"
                  f"p99_settle_ms={row['parallel_p99_ms']:.1f}"
                  f"{';GATED' if row['gated'] else ''}")
        os.makedirs(RESULTS, exist_ok=True)
        path = os.path.join(RESULTS, "stream.json")
        merged = {}
        if os.path.exists(path):  # ride alongside the mixed-workload rows
            with open(path) as f:
                merged = json.load(f)
        merged["maint_scaling"] = cell
        with open(path, "w") as f:
            json.dump(merged, f, indent=1)
        if failures:
            gated = next(r for r in cell["rows"] if r["gated"])
            print(f"TRAJECTORY FAIL: parallel settle speedup "
                  f"{gated['speedup']:.2f}x at batch={gated['batch']} is "
                  f"below the {MAINT_GATE_SPEEDUP:.1f}x floor",
                  file=sys.stderr)
            sys.exit(1)
        print("# verified: parallel settle bit-identical to serial oracle "
              "at every batch size; gate passed", file=sys.stderr)
        return

    if args.overload:
        row = run_overload(quick=args.quick or not full)
        print("name,us_per_call,derived")
        print(f"stream/overload,{row['admission_p50_ms'] * 1e3:.1f},"
              f"accepted_per_s={row['accepted_updates_per_s']:.0f};"
              f"shed_rate={row['shed_rate']:.3f};"
              f"p99_admission_ms={row['admission_p99_ms']:.2f}")
        os.makedirs(RESULTS, exist_ok=True)
        path = os.path.join(RESULTS, "stream.json")
        merged = {}
        if os.path.exists(path):  # ride alongside the mixed-workload rows
            with open(path) as f:
                merged = json.load(f)
        merged["overload"] = row
        with open(path, "w") as f:
            json.dump(merged, f, indent=1)
        print(f"# verified: overloaded stream == decompose(final) with "
              f"{row['shed_batches']} shed and {row['deferred_batches']} "
              f"deferred batches", file=sys.stderr)
        return

    if full:  # the ISSUE acceptance workload
        n, m, num_updates, batch = 30_000, 200_000, 10_000, 200
    elif args.quick:
        n, m, num_updates, batch = 3_000, 12_000, 600, 100
    else:
        n, m, num_updates, batch = 10_000, 60_000, 3_000, 150
    queries_per_batch = 200

    g = chung_lu(n, m, seed=1)
    ops, _ = mixed_stream(g, num_updates, seed=2)
    rng = np.random.default_rng(3)

    with tempfile.TemporaryDirectory() as tmp:
        svc = CoreService(
            g,
            wal_path=os.path.join(tmp, "wal.jsonl"),
            snapshot_dir=os.path.join(tmp, "snaps"),
        )
        # telemetry baseline *after* construction: the delta below is pure
        # workload cost (initial decompose + WAL truncate excluded)
        obs_snap = obs_metrics.get_registry().snapshot()
        num_batches = -(-len(ops) // batch)
        snapshot_at = max((2 * num_batches) // 3, 1)  # leaves a WAL tail
        update_s = query_s = 0.0
        queries = 0
        for b, i in enumerate(range(0, len(ops), batch)):
            t0 = time.perf_counter()
            svc.ingest(ops[i : i + batch])
            update_s += time.perf_counter() - t0
            if b + 1 == snapshot_at:
                svc.snapshot()
            t0 = time.perf_counter()
            queries += query_burst(svc, rng, queries_per_batch)
            query_s += time.perf_counter() - t0

        # workload numbers now come from the telemetry registry: the ingest
        # latency histogram supplies the percentiles and the service
        # counters supply the served-query and io totals.  The delta is taken
        # *before* the correctness-gate decompose below so it covers exactly
        # the streamed workload.
        delta = obs_metrics.get_registry().delta(obs_snap)

        # correctness gate: the stream must equal a fresh decomposition
        final = svc.bg.materialize()
        ref = decompose(final, "semicore*", "batch")
        assert np.array_equal(svc.maintainer.core, ref.core), "stream != decompose"

        log = svc.batch_log
        stats = svc.service_stats()
        applied = stats["updates_applied"]
        cache_total = stats["cache_hits"] + stats["cache_misses"]
        s = obs_metrics.sum_by_name
        ingest_hist = obs_metrics.get_registry().get(
            "repro_service_ingest_seconds")
        queries_served = int(s(delta, "repro_service_queries_total"))
        io_reads = int(s(delta, "repro_io_edge_block_reads_total"))
        nt_reads = int(s(delta, "repro_io_node_table_reads_total"))
        if obs_metrics.obs_enabled():  # registry must reconcile exactly
            assert queries_served == queries, (queries_served, queries)
            assert io_reads == sum(x.edge_block_reads for x in log), io_reads
            assert nt_reads == sum(x.node_table_reads for x in log), nt_reads
        else:  # silent registry: fall back to the hand-tracked numbers
            queries_served = queries
            io_reads = sum(x.edge_block_reads for x in log)
            nt_reads = sum(x.node_table_reads for x in log)
        rows = {
            "n": n, "m": m, "num_updates": num_updates, "batch": batch,
            "epochs": svc.epoch,
            "updates_per_s": applied / update_s,
            "query_qps": queries_served / query_s,
            "edge_block_reads_per_batch": io_reads / max(len(log), 1),
            "node_table_reads_per_batch": nt_reads / max(len(log), 1),
            "node_computations_per_update": float(
                sum(x.node_computations for x in log) / max(applied, 1)
            ),
            "p50_batch_ms": ingest_hist.quantile(0.50) * 1e3,
            "p99_batch_ms": ingest_hist.quantile(0.99) * 1e3,
            "cache_hit_rate": stats["cache_hits"] / max(cache_total, 1),
            "degeneracy": stats["degeneracy"],
            "obs": shared_result("stream/mixed-workload",
                                 update_s + query_s, delta),
        }

        # recovery cost vs a cold decomposition of the final graph
        svc.close()
        t0 = time.perf_counter()
        _, rec = CoreService.recover(
            wal_path=os.path.join(tmp, "wal.jsonl"),
            snapshot_dir=os.path.join(tmp, "snaps"),
        )
        rows["recovery_s"] = time.perf_counter() - t0
        rows["recovery_replayed_updates"] = rec.replayed_updates
        rows["recovery_settle_computations"] = rec.settle_node_computations
        rows["cold_decompose_computations"] = ref.node_computations

    print("name,us_per_call,derived")
    print(f"stream/ingest,{update_s / max(applied, 1) * 1e6:.1f},"
          f"updates_per_s={rows['updates_per_s']:.0f};"
          f"io_blocks_per_batch={rows['edge_block_reads_per_batch']:.1f};"
          f"p99_batch_ms={rows['p99_batch_ms']:.1f}")
    print(f"stream/query,{query_s / max(queries, 1) * 1e6:.1f},"
          f"qps={rows['query_qps']:.0f};"
          f"cache_hit_rate={rows['cache_hit_rate']:.3f}")
    print(f"stream/recovery,{rows['recovery_s'] * 1e6:.1f},"
          f"settle_comp={rows['recovery_settle_computations']};"
          f"cold_comp={rows['cold_decompose_computations']}")

    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "stream.json")
    if os.path.exists(path):  # keep the overload / maint_scaling cells
        with open(path) as f:
            prior = json.load(f)
        for key in ("overload", "maint_scaling"):
            if key in prior:
                rows.setdefault(key, prior[key])
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# verified: streamed core == decompose(final) on n={n}, "
          f"m={final.m}, {num_updates} updates", file=sys.stderr)


if __name__ == "__main__":
    main()
