"""§Perf hillclimb C: the most collective-bound cell (GNN full-graph
aggregation at ogb_products scale).

Baseline: edges sharded over all axes, node state replicated; XLA lowers
``segment_sum`` into per-device partials + an all-reduce of the full (N, d)
message matrix (~2·N·d·4 B per chip per layer).

Optimized (the paper's layout, one level up): edges are *pre-partitioned by
destination stripe* (the contiguous node-range ownership of the decomposition
engine), so each device's partial lands only in its own stripe — no reduction
at all; the combine is a stripe all-gather (~1·N·d·4 B): predicted 2x less
ICI traffic, plus an (N,d)-sized scatter removed from the memory term.

Run (writes benchmarks/results/perf_gnn_hillclimb.json):
    PYTHONPATH=src python benchmarks/perf_gnn_hillclimb.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.compat.jaxshims import shard_map  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.launch.dryrun import _metrics, ICI_BW, HBM_BW  # noqa: E402

N = 2_449_029            # ogb_products nodes
D = 128                  # graphsage hidden
CHIPS = 256
E = 61_859_328           # padded directed edges
E_LOC = E // CHIPS
N_STRIPE = -(-N // CHIPS)


def run():
    mesh = make_production_mesh()
    axes = tuple(mesh.axis_names)
    sds = jax.ShapeDtypeStruct
    h = sds((N, D), jnp.float32)
    src = sds((E,), jnp.int32)
    dst = sds((E,), jnp.int32)

    # ---------------- baseline: auto-SPMD segment_sum + implicit all-reduce
    def baseline(h, src, dst):
        return jax.ops.segment_sum(jnp.take(h, src, axis=0), dst,
                                   num_segments=N)

    fb = jax.jit(
        baseline,
        in_shardings=(NamedSharding(mesh, P()),
                      NamedSharding(mesh, P(axes)),
                      NamedSharding(mesh, P(axes))),
        out_shardings=NamedSharding(mesh, P()),
    )
    with use_mesh(mesh):
        mb = _metrics(fb.lower(h, src, dst).compile())

    # ------------- optimized: dst-striped edges -> local partial + all-gather
    def striped(h, src, dst, stripe_lo):
        lo = stripe_lo[0]  # 1-D edge arrays arrive pre-sliced per device
        local = jax.ops.segment_sum(
            jnp.take(h, src, axis=0), dst - lo, num_segments=N_STRIPE)
        out = jax.lax.all_gather(local, axes, tiled=True)  # (CHIPS*N_STRIPE, D)
        return out[:N]

    fs = jax.jit(shard_map(
        striped, mesh=mesh,
        in_specs=(P(), P(axes), P(axes), P(axes)),
        out_specs=P(), check_vma=False,
    ), in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P(axes)),
                     NamedSharding(mesh, P(axes)), NamedSharding(mesh, P(axes))),
       out_shardings=NamedSharding(mesh, P()))
    stripe_lo = sds((CHIPS,), jnp.int32)
    with use_mesh(mesh):
        ms = _metrics(fs.lower(h, src, dst, stripe_lo).compile())

    rows = {}
    for name, m in [("baseline_allreduce", mb), ("striped_allgather", ms)]:
        rows[name] = {
            "bytes_per_chip": m["bytes"], "memory_s": m["bytes"] / HBM_BW,
            "collective_bytes": m["coll"], "collective_s": m["coll"]["total"] / ICI_BW,
        }
        print(f"{name}: HBM bytes %.3e (%.4f s)  ICI %.3e B (%.4f s)" % (
            m["bytes"], m["bytes"] / HBM_BW,
            m["coll"]["total"], m["coll"]["total"] / ICI_BW))
    out_path = os.path.join(os.path.dirname(__file__), "results",
                            "perf_gnn_hillclimb.json")
    json.dump(rows, open(out_path, "w"), indent=1)


if __name__ == "__main__":
    run()
