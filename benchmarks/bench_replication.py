"""CQRS replication benchmark: a single writer under sustained ingest with
K read replicas tailing the WAL and serving queries concurrently.

One ``CoreWriter`` ingests micro-batches (WAL append -> apply -> publish,
snapshot+rotation every few batches); K ``CoreReplica``s poll the WAL on
staggered cadences, replay newly durable batches into their own epoch-view
chains, and serve read bursts between syncs.  A late replica joins mid-run
to exercise the snapshot+tail catch-up protocol, and the periodic rotations
exercise the tailers' re-seek path.

Reports sustained writer updates/s, replica query p50/p99, and the observed
replica-lag distribution (sampled before every sync) into
``results/replication.json``.  Always verifies that every replica is
bit-identical to the writer at the final epoch — same ``core``/``cnt``,
same watermarked query replies.

  PYTHONPATH=src python benchmarks/bench_replication.py --smoke
  REPRO_BENCH_FULL=1 PYTHONPATH=src python benchmarks/bench_replication.py
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.core import decompose  # noqa: E402
from repro.faults import FaultPlan, FaultRule, inject  # noqa: E402
from repro.graph import chung_lu  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs.bench import shared_result  # noqa: E402
from repro.stream import CoreReplica, CoreService, mixed_stream  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def replica_burst(rep: CoreReplica, rng, num_queries: int) -> list:
    """A read burst against the replica's committed view; per-query walls."""
    walls = []
    kmax = max(int(rep.degeneracy()) - 1, 1)
    n = rep.bg.n
    for _ in range(num_queries // 4):
        for call in (
            lambda: rep.coreness(int(rng.integers(n))),
            lambda: rep.in_kcore(int(rng.integers(n)), kmax),
            lambda: rep.top_k(100),
            lambda: rep.kcore_members(kmax),
        ):
            t0 = time.perf_counter()
            call()
            walls.append(time.perf_counter() - t0)
    return walls


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 writer + 2 replicas, bounded-lag assertion (CI)")
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--wal-append-latency-ms", type=float, default=0.0,
                    help="inject this much latency into every WAL append "
                         "(seeded FaultPlan); the lag and bit-identity "
                         "gates must hold with slow appends too")
    args = ap.parse_args()
    full = os.environ.get("REPRO_BENCH_FULL") == "1" and not args.smoke

    if full:
        n, m, num_updates, batch, replicas = 30_000, 200_000, 10_000, 200, 4
        snapshot_every, queries_per_burst = 12, 400
    elif args.smoke:
        n, m, num_updates, batch, replicas = 2_000, 8_000, 600, 60, 2
        snapshot_every, queries_per_burst = 4, 80
    else:
        n, m, num_updates, batch, replicas = 10_000, 60_000, 3_000, 150, 3
        snapshot_every, queries_per_burst = 6, 200
    if args.replicas is not None:
        replicas = args.replicas
    # replica r syncs every (r + 2) batches: staggered cadences make the lag
    # distribution non-trivial and bound it by the slowest cadence.
    cadences = [r + 2 for r in range(replicas)]

    g = chung_lu(n, m, seed=1)
    ops, _ = mixed_stream(g, num_updates, seed=2)
    chunks = [ops[i:i + batch] for i in range(0, len(ops), batch)]
    rng = np.random.default_rng(3)

    plan = None
    fault_ctx = contextlib.nullcontext()
    if args.wal_append_latency_ms > 0:
        plan = FaultPlan([FaultRule("wal.append", "latency", every=1,
                                    arg=args.wal_append_latency_ms / 1e3)])
        fault_ctx = inject(plan)

    with fault_ctx, tempfile.TemporaryDirectory() as tmp:
        wal = os.path.join(tmp, "wal.jsonl")
        snaps = os.path.join(tmp, "snaps")
        writer = CoreService(g, wal_path=wal, snapshot_dir=snaps,
                             snapshot_every=snapshot_every)
        writer.snapshot()  # epoch-0 snapshot so replicas can bootstrap
        obs_snap = obs_metrics.get_registry().snapshot()

        t0 = time.perf_counter()
        reps = [CoreReplica(snapshot_dir=snaps, wal_path=wal, replica_id=r)
                for r in range(replicas)]
        bootstrap_s = time.perf_counter() - t0

        late_at = len(chunks) // 2  # joins mid-run: snapshot+tail catch-up
        lag_samples: list[int] = []
        query_walls: list[float] = []
        update_s = sync_s = query_s = 0.0
        for b, chunk in enumerate(chunks):
            t0 = time.perf_counter()
            writer.ingest(chunk)
            update_s += time.perf_counter() - t0
            if b == late_at:
                t0 = time.perf_counter()
                reps.append(CoreReplica(snapshot_dir=snaps, wal_path=wal,
                                        replica_id=len(reps)))
                cadences.append(2)
                sync_s += time.perf_counter() - t0
            for rep, cadence in zip(reps, cadences):
                lag_samples.append(rep.lag(writer.epoch))
                if (b + 1) % cadence == 0:
                    t0 = time.perf_counter()
                    rep.sync()
                    sync_s += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    query_walls.extend(
                        replica_burst(rep, rng, queries_per_burst))
                    query_s += time.perf_counter() - t0

        # drain every replica to the writer's tip
        t0 = time.perf_counter()
        for rep in reps:
            rep.sync()
        sync_s += time.perf_counter() - t0
        delta = obs_metrics.get_registry().delta(obs_snap)

        # correctness gates ------------------------------------------------
        final = writer.bg.materialize()
        ref = decompose(final, "semicore*", "batch")
        np.testing.assert_array_equal(writer.maintainer.core, ref.core)
        all_nodes = np.arange(n)
        wm_core = writer.coreness(all_nodes)
        for rep in reps:
            assert rep.epoch == writer.epoch, (rep.epoch, writer.epoch)
            assert rep.lag(writer.epoch) == 0
            np.testing.assert_array_equal(rep.maintainer.core,
                                          writer.maintainer.core)
            np.testing.assert_array_equal(rep.maintainer.cnt,
                                          writer.maintainer.cnt)
            r_core = rep.coreness(all_nodes)  # bit-identical watermarked reply
            np.testing.assert_array_equal(r_core, wm_core)
            assert r_core.epoch == wm_core.epoch == writer.epoch
            np.testing.assert_array_equal(rep.top_k(100), writer.top_k(100))
            assert int(rep.degeneracy()) == int(writer.degeneracy())
        if args.smoke:  # bounded lag: never worse than the slowest cadence
            assert max(lag_samples) <= max(cadences) + 1, max(lag_samples)

        applied = sum(
            s.num_applied_deletes + s.num_applied_inserts
            for s in writer.batch_log)
        qw = np.asarray(query_walls)
        lags = np.asarray(lag_samples)
        s = obs_metrics.sum_by_name
        rows = {
            "n": n, "m": m, "num_updates": num_updates, "batch": batch,
            "replicas": len(reps), "cadences": cadences,
            "epochs": writer.epoch,
            "writer_updates_per_s": applied / update_s,
            "writer_rotations": writer.wal.rotations,
            "replica_bootstrap_s": bootstrap_s,
            "replica_sync_s_total": sync_s,
            "replica_batches_applied": int(
                s(delta, "repro_replica_batches_applied_total")),
            "replica_rotations_detected": sum(
                r.tailer.rotations_detected for r in reps),
            "replica_bootstraps": sum(r.bootstraps for r in reps),
            "queries_served": len(qw),
            "query_qps": len(qw) / query_s if query_s else 0.0,
            "query_p50_us": float(np.percentile(qw, 50) * 1e6),
            "query_p99_us": float(np.percentile(qw, 99) * 1e6),
            "lag_samples": len(lags),
            "lag_mean": float(lags.mean()),
            "lag_p50": float(np.percentile(lags, 50)),
            "lag_p95": float(np.percentile(lags, 95)),
            "lag_max": int(lags.max()),
            "obs": shared_result("replication/writer+replicas",
                                 update_s + sync_s + query_s, delta),
        }
        rows["wal_append_latency_ms"] = args.wal_append_latency_ms
        rows["faults_injected_total"] = plan.total_injected if plan else 0
        rows["faults_injected"] = (
            {f"{op}/{kind}": cnt for (op, kind), cnt in plan.injected.items()}
            if plan else {})
        if plan is not None:  # every append was slowed, and all were counted
            assert plan.total_injected == writer.wal.appends, \
                (plan.total_injected, writer.wal.appends)
        writer.close()

    print("name,us_per_call,derived")
    print(f"replication/ingest,{update_s / max(applied, 1) * 1e6:.1f},"
          f"updates_per_s={rows['writer_updates_per_s']:.0f};"
          f"rotations={rows['writer_rotations']}")
    print(f"replication/query,{qw.mean() * 1e6:.1f},"
          f"qps={rows['query_qps']:.0f};p50_us={rows['query_p50_us']:.1f};"
          f"p99_us={rows['query_p99_us']:.1f}")
    print(f"replication/lag,{rows['lag_mean']:.2f},"
          f"p95={rows['lag_p95']:.1f};max={rows['lag_max']};"
          f"bootstraps={rows['replica_bootstraps']}")
    if plan is not None:
        print(f"replication/faults,{rows['faults_injected_total']},"
              f"wal_append_latency_ms={args.wal_append_latency_ms:g}")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "replication.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# verified: {len(reps)} replicas bit-identical to the writer at "
          f"epoch {rows['epochs']} (core, cnt, watermarked replies) under "
          f"{num_updates} streamed updates", file=sys.stderr)


if __name__ == "__main__":
    main()
