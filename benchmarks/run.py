"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON artifacts under
benchmarks/results/.  Set REPRO_BENCH_FULL=1 for the full-size suite.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def _save(name: str, obj):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def main() -> None:
    full = os.environ.get("REPRO_BENCH_FULL") == "1"
    from benchmarks import paper_figs

    print("name,us_per_call,derived")

    # ---- Fig. 9: decomposition across datasets -----------------------------
    datasets = ("dblp-sim", "youtube-sim", "wiki-sim", "cpt-sim", "lj-sim",
                "orkut-sim") if full else ("dblp-sim", "youtube-sim", "cpt-sim")
    rows = paper_figs.bench_decomposition(datasets, run_emcore=True)
    _save("fig9_decomposition", rows)
    for r in rows:
        _emit(f"fig9/{r['dataset']}/semicore_star", r["semicore_star_s"] * 1e6,
              f"io={r['semicore_star_io_blocks']};iters={r['semicore_star_iters']};"
              f"mem={r['semicore_star_mem_bytes']}")
        _emit(f"fig9/{r['dataset']}/semicore", r["semicore_s"] * 1e6,
              f"io={r['semicore_io_blocks']}")
        _emit(f"fig9/{r['dataset']}/imcore", r["imcore_s"] * 1e6,
              f"mem={r['imcore_mem_bytes']}")
        if "emcore_s" in r:
            _emit(f"fig9/{r['dataset']}/emcore", r["emcore_s"] * 1e6,
                  f"io={r['emcore_io_blocks']};mem={r['emcore_mem_bytes']};"
                  f"overbudget={r['emcore_over_budget_rounds']}")

    # ---- Fig. 3: convergence profile ---------------------------------------
    conv = paper_figs.bench_convergence(("twitter-sim",) if not full
                                        else ("twitter-sim", "uk-sim"))
    _save("fig3_convergence", conv)
    for r in conv:
        _emit(f"fig3/{r['dataset']}", 0.0,
              f"iters={r['iterations']};first={r['first_iter_updates']};"
              f"late={r['late_iter_updates']}")

    # ---- Fig. 10: maintenance ----------------------------------------------
    maint = paper_figs.bench_maintenance(
        "lj-sim" if full else "dblp-sim", num_edges=100 if full else 40)
    _save("fig10_maintenance", maint)
    for k in ("delete_star", "semiinsert", "semiinsert_star"):
        _emit(f"fig10/{k}", maint[f"{k}_avg_s"] * 1e6,
              f"io={maint[f'{k}_avg_io']:.1f};"
              f"comp={maint[f'{k}_avg_computations']:.1f}")

    # ---- Fig. 11/12: scalability -------------------------------------------
    scal = paper_figs.bench_scalability(
        "twitter-sim" if full else "dblp-sim",
        fracs=(0.2, 0.6, 1.0) if not full else (0.2, 0.4, 0.6, 0.8, 1.0))
    _save("fig11_scalability", scal)
    for r in scal:
        _emit(f"fig11/{r['mode']}/{int(r['frac'] * 100)}pct",
              r["semicore_star_s"] * 1e6,
              f"n={r['n']};m={r['m']};basic_s={r['semicore_s']:.3f}")

    # ---- §Roofline tables (from dry-run artifacts, if present) -------------
    try:
        from benchmarks.roofline import load_table
        for mesh, name in [("single_pod_16x16", "roofline_single_pod"),
                           ("multi_pod_2x16x16", "roofline_multi_pod")]:
            table = load_table(mesh)
            if not table:
                continue
            _save(name, table)
            for t in table:
                if t.get("ok"):
                    _emit(f"roofline[{mesh}]/{t['arch']}/{t['shape']}", 0.0,
                          f"dom={t['dominant']};useful={t['useful_ratio']:.3f}")
    except Exception as e:  # dry-run not yet executed
        print(f"# roofline skipped: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
