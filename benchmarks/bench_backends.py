"""Backend matrix benchmark: one superstep core, four compute substrates.

Runs every batch-schedule algorithm on every compute backend (DESIGN.md §11,
§13) over the same graphs and records pass counts, wall time (cold = first
call including jit compiles, warm = steady state on the device-resident
caches), jit trace counts, planner I/O, and the pallas backend's kernel-block
skip counts to ``benchmarks/results/backends.json``.  All backends must
converge through identical passes to the identical core array — the script
asserts it.

Two graphs: the PR 3 comparison cell (n=4k, the history in CHANGES.md) and a
``large`` ≥200k-directed-edge cell (numpy vs xla vs pallas vs shard) where
the device-resident speedup-vs-numpy is the headline number.

Perf-trajectory gate (scripts/ci.sh):

    python benchmarks/bench_backends.py --emit-trajectory   # refresh baseline
    python benchmarks/bench_backends.py --check-trajectory  # CI regression gate
    python benchmarks/bench_backends.py --summary           # markdown table

``--emit-trajectory`` measures the trajectory cell (warm walls best-of-3,
cold walls, jit-trace counts, numpy-normalized ratios) and writes/updates the
section for the current device count in ``BENCH_backends.json`` at the repo
root — the committed baseline.  ``--check-trajectory`` re-measures and fails
on a warm-wall regression beyond the tolerance band or on *any* jit-trace
count increase (the O(passes)-retrace regression), replacing the old one-off
"xla ≤ 40× numpy + 2s" smoke hack.  Warm walls are compared as ratios to the
same run's numpy wall, so the gate is machine-speed independent; the band is
``ratio <= 1.5 × baseline_ratio + 1.0`` per backend (summed over the three
algorithms to damp small-cell noise).

Usage:
    PYTHONPATH=src python benchmarks/bench_backends.py [--quick]
    REPRO_BACKEND=shard PYTHONPATH=src python benchmarks/bench_backends.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import resident  # noqa: E402
from repro.core.imcore import imcore_bz  # noqa: E402
from repro.core.semicore import decompose  # noqa: E402
from repro.graph import chung_lu  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.obs.bench import shared_result  # noqa: E402

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS = os.path.join(os.path.dirname(__file__), "results")
TRAJECTORY_BASELINE = os.path.join(REPO_ROOT, "BENCH_backends.json")
TRAJECTORY_CURRENT = os.path.join(RESULTS, "BENCH_backends_current.json")
ALGORITHMS = ("semicore", "semicore+", "semicore*")
BACKENDS = ("numpy", "xla", "pallas", "shard")

# trajectory gate: per-backend warm-wall ratio vs numpy (summed over the
# three algorithms) may grow at most BAND x the committed baseline ratio
# plus FLOOR; jit-trace counts may never grow at all.  The large cell rides
# along with fewer warm repeats (walls are seconds, not milliseconds) and
# without shard (the small cell already gates it; the full --bench matrix
# still records it) — its job is gating the pallas fused-superstep ratio at
# a size where per-kernel overheads can't hide.
TRAJECTORY_CELL = dict(n=1200, m=4800, seed=6, block_edges=128)
TRAJECTORY_LARGE_CELL = dict(n=25_000, m=110_000, seed=8, block_edges=4096)
TRAJECTORY_LARGE_BACKENDS = ("numpy", "xla", "pallas")
TRAJECTORY_WALL_BAND = 1.5
TRAJECTORY_RATIO_FLOOR = 1.0
TRAJECTORY_WARM_REPEATS = 3


def _timed(g, algo, backend, block_edges, warm_repeats: int = 1):
    """(cold_s, warm_s, jit_traces, result, obs_delta) for one config.

    ``obs_delta`` is the telemetry-registry delta around the *last* warm run
    (one full decompose), the registry-sourced mirror of the DecompResult
    accounting — reconciled loudly by the callers.
    """
    t0 = resident.trace_count()
    w0 = time.perf_counter()
    r = decompose(g, algo, "batch", block_edges=block_edges, backend=backend)
    cold = time.perf_counter() - w0
    traces = resident.trace_count() - t0
    warm = float("inf")
    delta = {}
    for i in range(max(1, warm_repeats)):
        snap = obs_metrics.get_registry().snapshot()
        w1 = time.perf_counter()
        r2 = decompose(g, algo, "batch", block_edges=block_edges,
                       backend=backend)
        wall = time.perf_counter() - w1
        if wall < warm or not delta:
            delta = obs_metrics.get_registry().delta(snap)
        warm = min(warm, wall)
        assert np.array_equal(r.core, r2.core)
    return cold, warm, traces, r, delta


def _reconcile(delta: dict, r, where) -> dict:
    """Registry-sourced I/O numbers for one decompose, asserted == DecompResult.

    This is the migration contract: benches now *source* their io columns
    from the metrics registry, and the old hand-tracked DecompResult numbers
    become the cross-check instead of the source.  Under ``REPRO_OBS=0`` the
    registry is silent, so the DecompResult numbers are used directly.
    """
    if not obs_metrics.obs_enabled():
        return {
            "edge_block_reads": r.edge_block_reads,
            "node_table_reads": r.node_table_reads,
            "iterations": r.iterations,
            "kernel_blocks_active": r.kernel_blocks_active,
            "kernel_blocks_skipped": r.kernel_blocks_skipped,
        }
    s = obs_metrics.sum_by_name
    out = {
        "edge_block_reads": int(s(delta, "repro_io_edge_block_reads_total")),
        "node_table_reads": int(s(delta, "repro_io_node_table_reads_total")),
        "iterations": int(s(delta, "repro_engine_passes_total")),
        "kernel_blocks_active": int(
            s(delta, "repro_kernel_blocks_active_total")),
        "kernel_blocks_skipped": int(
            s(delta, "repro_kernel_blocks_skipped_total")),
    }
    assert out["edge_block_reads"] == r.edge_block_reads, \
        (where, out["edge_block_reads"], r.edge_block_reads)
    assert out["node_table_reads"] == r.node_table_reads, \
        (where, out["node_table_reads"], r.node_table_reads)
    assert out["iterations"] == r.iterations, \
        (where, out["iterations"], r.iterations)
    assert out["kernel_blocks_active"] == r.kernel_blocks_active, \
        (where, out["kernel_blocks_active"], r.kernel_blocks_active)
    assert out["kernel_blocks_skipped"] == r.kernel_blocks_skipped, \
        (where, out["kernel_blocks_skipped"], r.kernel_blocks_skipped)
    return out


def smoke() -> None:
    """CI backend-matrix smoke: decompose under the REPRO_BACKEND env default
    and check against the BZ oracle (scripts/ci.sh runs one per backend).
    Wall-clock regressions are gated separately by --check-trajectory."""
    backend = os.environ.get("REPRO_BACKEND", "numpy")
    g = chung_lu(400, 1600, seed=3)
    expect = imcore_bz(g)
    wall = 0.0
    for algo in ALGORITHMS:
        rn = decompose(g, algo, "batch", block_edges=64, backend="numpy")
        assert np.array_equal(rn.core, expect), ("numpy", algo)
        r = decompose(g, algo, "batch", block_edges=64)  # backend from env
        t0 = time.perf_counter()
        r = decompose(g, algo, "batch", block_edges=64)  # warm: jits cached
        wall += time.perf_counter() - t0
        assert np.array_equal(r.core, expect), (backend, algo)
        assert r.backend == backend, (r.backend, backend)
        # identical passes + planner trace is the layer's core invariant
        assert r.iterations == rn.iterations, (backend, algo)
        assert r.edge_block_reads == rn.edge_block_reads, (backend, algo)
    skipped = r.kernel_blocks_skipped  # last run: semicore*
    print(f"backend smoke OK: backend={backend} kmax={r.kmax} "
          f"iters={r.iterations} io_blocks={r.edge_block_reads} "
          f"kernel_blocks_skipped={skipped} num_shards={r.num_shards} "
          f"wall={wall:.3f}s")
    if backend == "pallas":
        assert skipped > 0, "SemiCore* frontier shrinkage must skip blocks"
    if backend == "shard":
        import jax

        assert r.num_shards == len(jax.devices()), r.num_shards


def _bench_graph(g, block_edges, backends, label):
    rows = []
    cores: dict = {}
    warm_numpy: dict = {}
    for backend in backends:
        for algo in ALGORITHMS:
            cold, warm, traces, r, delta = _timed(g, algo, backend,
                                                  block_edges)
            cores.setdefault(algo, r.core)
            assert np.array_equal(r.core, cores[algo]), (backend, algo)
            if backend == "numpy":
                warm_numpy[algo] = warm
            # io columns come from the telemetry registry, cross-checked
            # against the DecompResult accounting they mirror
            rec = _reconcile(delta, r, (label, backend, algo))
            row = {
                "backend": backend,
                "algorithm": algo,
                "wall_seconds": round(warm, 4),
                "wall_seconds_cold": round(cold, 4),
                "jit_traces": traces,
                "speedup_vs_numpy": round(warm_numpy[algo] / warm, 2),
                "iterations": rec["iterations"],
                "node_computations": r.node_computations,
                "edge_block_reads": rec["edge_block_reads"],
                "node_table_reads": rec["node_table_reads"],
                "kernel_blocks_active": rec["kernel_blocks_active"],
                "kernel_blocks_skipped": rec["kernel_blocks_skipped"],
                "num_shards": r.num_shards,
                "shard_pad_edges": r.shard_pad_edges,
            }
            rows.append(row)
            print(f"[{label}] {backend:>6} {algo:<10} warm={warm:7.3f}s "
                  f"cold={cold:7.3f}s traces={traces} "
                  f"passes={r.iterations:<3} io={r.edge_block_reads:<5} "
                  f"skipped={r.kernel_blocks_skipped}")
    # identical passes across backends is the layer's core invariant
    by_algo: dict = {}
    for row in rows:
        by_algo.setdefault(row["algorithm"], set()).add(
            (row["iterations"], row["edge_block_reads"],
             row["node_table_reads"]))
    assert all(len(v) == 1 for v in by_algo.values()), by_algo
    return rows


# ============================================================= trajectory
def _trajectory_rows(cell, backends, warm_repeats, label) -> list[dict]:
    g = chung_lu(cell["n"], cell["m"], seed=cell["seed"])
    rows = []
    warm_numpy: dict = {}
    for backend in backends:
        for algo in ALGORITHMS:
            cold, warm, traces, r, delta = _timed(
                g, algo, backend, cell["block_edges"],
                warm_repeats=warm_repeats)
            if backend == "numpy":
                warm_numpy[algo] = warm
            # keep the committed BENCH_backends.json schema byte-compatible:
            # iterations are registry-sourced but the row keys are unchanged
            rec = _reconcile(delta, r, (label, backend, algo))
            rows.append({
                "backend": backend,
                "algorithm": algo,
                "wall_seconds": round(warm, 4),
                "wall_seconds_cold": round(cold, 4),
                "jit_traces": traces,
                "ratio_vs_numpy": round(warm / warm_numpy[algo], 3),
                "speedup_vs_numpy": round(warm_numpy[algo] / warm, 3),
                "iterations": rec["iterations"],
                "num_shards": r.num_shards,
            })
            print(f"[{label}] {backend:>6} {algo:<10} warm={warm:7.3f}s "
                  f"cold={cold:7.3f}s traces={traces}")
    return rows


def _measure_trajectory() -> dict:
    """One trajectory section: the 4-backend × 3-algorithm matrix on the
    trajectory cell (warm walls best-of-N, numpy-normalized ratios) plus the
    3-backend matrix on the large cell (single warm repeat)."""
    import jax

    rows = _trajectory_rows(TRAJECTORY_CELL, BACKENDS,
                            TRAJECTORY_WARM_REPEATS, "traj")
    large_rows = _trajectory_rows(TRAJECTORY_LARGE_CELL,
                                  TRAJECTORY_LARGE_BACKENDS, 1, "traj-large")
    return {
        "device_count": len(jax.devices()),
        "python": f"{sys.version_info[0]}.{sys.version_info[1]}",
        "rows": rows,
        "large_rows": large_rows,
    }


def _backend_aggregate(rows):
    """{backend: (sum warm, sum numpy warm, sum traces)} over the algos."""
    numpy_wall = {r["algorithm"]: r["wall_seconds"] for r in rows
                  if r["backend"] == "numpy"}
    agg: dict = {}
    for r in rows:
        w, nw, t = agg.get(r["backend"], (0.0, 0.0, 0))
        agg[r["backend"]] = (w + r["wall_seconds"],
                             nw + numpy_wall[r["algorithm"]],
                             t + r["jit_traces"])
    return agg


def emit_trajectory() -> None:
    """Measure and write/update this device count's baseline section in the
    repo-root ``BENCH_backends.json`` (commit the result)."""
    section = _measure_trajectory()
    data = {"schema": 1, "cell": TRAJECTORY_CELL, "device_counts": {}}
    if os.path.exists(TRAJECTORY_BASELINE):
        with open(TRAJECTORY_BASELINE) as f:
            data = json.load(f)
    data["cell"] = TRAJECTORY_CELL
    data["large_cell"] = TRAJECTORY_LARGE_CELL
    data.setdefault("device_counts", {})[str(section["device_count"])] = \
        section
    with open(TRAJECTORY_BASELINE, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    print(f"wrote {TRAJECTORY_BASELINE} "
          f"(device_count={section['device_count']})")


def check_trajectory() -> int:
    """Measure fresh, write the candidate next to the other CI artifacts,
    and gate against the committed baseline."""
    section = _measure_trajectory()
    os.makedirs(RESULTS, exist_ok=True)
    with open(TRAJECTORY_CURRENT, "w") as f:
        json.dump({"schema": 1, "cell": TRAJECTORY_CELL,
                   "large_cell": TRAJECTORY_LARGE_CELL,
                   "device_counts": {str(section["device_count"]): section}},
                  f, indent=2)
        f.write("\n")
    if not os.path.exists(TRAJECTORY_BASELINE):
        print("WARN: no committed BENCH_backends.json baseline; "
              "run --emit-trajectory and commit it", file=sys.stderr)
        return 0
    with open(TRAJECTORY_BASELINE) as f:
        baseline = json.load(f)
    base = baseline.get("device_counts", {}).get(
        str(section["device_count"]))
    if base is None:
        print(f"WARN: baseline has no section for device_count="
              f"{section['device_count']}; skipping the gate",
              file=sys.stderr)
        return 0
    failures = []
    for key, tag in (("rows", "gate"), ("large_rows", "gate-large")):
        if key not in base:
            print(f"WARN: baseline has no {key!r} section; skipping "
                  "(re-emit the baseline to gate it)", file=sys.stderr)
            continue
        cand_agg = _backend_aggregate(section[key])
        base_agg = _backend_aggregate(base[key])
        for backend, (w, nw, traces) in sorted(cand_agg.items()):
            if backend not in base_agg:
                continue
            bw, bnw, btraces = base_agg[backend]
            if traces > btraces:
                failures.append(
                    f"{tag}/{backend}: jit traces grew {btraces} -> "
                    f"{traces} (O(passes)-retrace regression)")
            if backend == "numpy":
                continue  # numpy is the normalizer
            ratio = w / max(nw, 1e-9)
            base_ratio = bw / max(bnw, 1e-9)
            limit = TRAJECTORY_WALL_BAND * base_ratio \
                + TRAJECTORY_RATIO_FLOOR
            status = "ok" if ratio <= limit else "FAIL"
            print(f"[{tag}] {backend:>6} warm-vs-numpy ratio {ratio:6.2f} "
                  f"(baseline {base_ratio:6.2f}, limit {limit:6.2f}) "
                  f"{status}")
            if ratio > limit:
                failures.append(
                    f"{tag}/{backend}: warm-wall ratio {ratio:.2f} exceeds "
                    f"{TRAJECTORY_WALL_BAND}x baseline {base_ratio:.2f} + "
                    f"{TRAJECTORY_RATIO_FLOOR}")
    if failures:
        print("perf-trajectory gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("perf-trajectory gate OK "
          f"(device_count={section['device_count']})")
    return 0


def summary() -> None:
    """Render the backend × algorithm wall-clock table as GitHub-flavored
    markdown (for $GITHUB_STEP_SUMMARY) from the freshest trajectory file."""
    path = TRAJECTORY_CURRENT if os.path.exists(TRAJECTORY_CURRENT) \
        else TRAJECTORY_BASELINE
    if not os.path.exists(path):
        print("(no trajectory data)")
        return
    with open(path) as f:
        data = json.load(f)
    for dc, section in sorted(data.get("device_counts", {}).items()):
        sources = [(data.get("cell", {}), section.get("rows", []))]
        if section.get("large_rows"):
            sources.append((data.get("large_cell", {}),
                            section["large_rows"]))
        for cell, sec_rows in sources:
            print(f"### Backend × algorithm warm wall-clock "
                  f"({dc} device(s), python {section.get('python', '?')}, "
                  f"n={cell.get('n', '?')} cell)\n")
            print("| backend | " + " | ".join(ALGORITHMS) +
                  " | jit traces | speedup vs numpy |")
            print("|---|" + "---|" * (len(ALGORITHMS) + 2))
            by_backend: dict = {}
            for r in sec_rows:
                by_backend.setdefault(r["backend"], {})[r["algorithm"]] = r
            numpy_total = sum(r["wall_seconds"]
                              for r in by_backend.get("numpy", {}).values())
            for backend in BACKENDS:
                rows = by_backend.get(backend)
                if not rows:
                    continue
                walls = " | ".join(
                    f"{rows[a]['wall_seconds']:.3f}s" if a in rows else "-"
                    for a in ALGORITHMS)
                traces = sum(r["jit_traces"] for r in rows.values())
                total_w = sum(r["wall_seconds"] for r in rows.values())
                speed = numpy_total / max(total_w, 1e-9)
                print(f"| {backend} | {walls} | {traces} | {speed:.2f}x |")
            print()


# ================================================================= obs cell
OBS_CELL = dict(n=25_000, m=110_000, seed=8, block_edges=4096)
OBS_OVERHEAD_BAND = 0.05      # instrumented warm wall <= 1.05x base ...
OBS_OVERHEAD_FLOOR_S = 0.05   # ... plus an absolute floor for tiny walls
OBS_WARM_REPEATS = 3


def obs_cell(quick: bool = False) -> int:
    """CI observability leg: the large bench cell with tracing on.

    Writes three artifacts to ``benchmarks/results/``:

    * ``superstep_trace.json`` — Chrome-trace (Perfetto-loadable) timeline of
      every superstep/chunk/prologue span of the instrumented runs;
    * ``metrics.prom`` — the full registry in Prometheus text exposition;
    * ``obs_summary.md`` — markdown summary for ``$GITHUB_STEP_SUMMARY``.

    Gate: the instrumented warm wall must stay within
    ``(1 + OBS_OVERHEAD_BAND) x`` the ``REPRO_OBS=0`` wall (+ an absolute
    floor so sub-100ms cells don't flake).  Returns a process exit code.
    """
    cell = dict(OBS_CELL)
    if quick:
        cell.update(n=3_000, m=13_000, block_edges=512)
    g = chung_lu(cell["n"], cell["m"], seed=cell["seed"])
    algo, backend = "semicore*", "xla"

    def warm_wall(repeats: int = OBS_WARM_REPEATS) -> float:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = decompose(g, algo, "batch",
                          block_edges=cell["block_edges"], backend=backend)
            best = min(best, time.perf_counter() - t0)
        return best

    decompose(g, algo, "batch", block_edges=cell["block_edges"],
              backend=backend)  # jit warm-up, outside both measurements

    prev = os.environ.get(obs_metrics.OBS_ENV_VAR)
    os.environ[obs_metrics.OBS_ENV_VAR] = "0"
    try:
        base = warm_wall()
    finally:
        if prev is None:
            os.environ.pop(obs_metrics.OBS_ENV_VAR, None)
        else:
            os.environ[obs_metrics.OBS_ENV_VAR] = prev

    obs_trace.clear_trace()
    obs_trace.start_trace()
    snap = obs_metrics.get_registry().snapshot()
    instrumented = warm_wall()
    delta = obs_metrics.get_registry().delta(snap)
    obs_trace.stop_trace()

    os.makedirs(RESULTS, exist_ok=True)
    trace_path = os.path.join(RESULTS, "superstep_trace.json")
    obs_trace.get_collector().save(trace_path)
    n_events = len(obs_trace.get_collector().events)
    obs_trace.clear_trace()
    prom_path = os.path.join(RESULTS, "metrics.prom")
    with open(prom_path, "w") as f:
        f.write(obs_metrics.get_registry().to_prometheus())

    result = shared_result(f"backends/obs-cell[{backend}/{algo}]",
                           instrumented, delta,
                           extra={"wall_seconds_base": round(base, 4),
                                  "trace_events": n_events,
                                  "cell": cell})
    limit = (1.0 + OBS_OVERHEAD_BAND) * base + OBS_OVERHEAD_FLOOR_S
    ok = instrumented <= limit
    overhead_pct = 100.0 * (instrumented - base) / max(base, 1e-9)

    md_path = os.path.join(RESULTS, "obs_summary.md")
    s = obs_metrics.sum_by_name
    with open(md_path, "w") as f:
        f.write("### Telemetry cell (instrumented superstep, "
                f"{backend}/{algo}, n={cell['n']})\n\n")
        f.write("| metric | value |\n|---|---|\n")
        f.write(f"| warm wall (REPRO_OBS=0) | {base:.3f}s |\n")
        f.write(f"| warm wall (instrumented + tracing) | "
                f"{instrumented:.3f}s |\n")
        f.write(f"| instrumentation overhead | {overhead_pct:.1f}% "
                f"(limit {100 * OBS_OVERHEAD_BAND:.0f}% + "
                f"{OBS_OVERHEAD_FLOOR_S:.2f}s floor) |\n")
        f.write(f"| passes | "
                f"{int(s(delta, 'repro_engine_passes_total'))} |\n")
        f.write(f"| edge-block reads | "
                f"{int(s(delta, 'repro_io_edge_block_reads_total'))} |\n")
        f.write(f"| bytes read | "
                f"{int(s(delta, 'repro_io_bytes_read_total')):,} |\n")
        f.write(f"| trace events | {n_events} |\n")
        f.write(f"| gate | {'ok' if ok else 'FAIL'} |\n")
    with open(os.path.join(RESULTS, "obs_cell.json"), "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")

    print(f"[obs] base={base:.3f}s instrumented={instrumented:.3f}s "
          f"({overhead_pct:+.1f}%, limit {limit:.3f}s) "
          f"events={n_events} -> {trace_path}")
    if not ok:
        print(f"obs overhead gate FAILED: {instrumented:.3f}s > "
              f"{limit:.3f}s", file=sys.stderr)
        return 1
    print("obs overhead gate OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graphs, skip the large cell")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: REPRO_BACKEND env decides the backend")
    ap.add_argument("--emit-trajectory", action="store_true",
                    help="refresh this device count's committed baseline "
                    "section in BENCH_backends.json")
    ap.add_argument("--check-trajectory", action="store_true",
                    help="CI gate: fail on warm-wall or jit-trace regression "
                    "vs the committed baseline")
    ap.add_argument("--summary", action="store_true",
                    help="markdown wall-clock table (for "
                    "$GITHUB_STEP_SUMMARY)")
    ap.add_argument("--obs-cell", action="store_true",
                    help="CI observability leg: traced large cell + "
                    "Prometheus/Chrome-trace artifacts + overhead gate")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    if args.emit_trajectory:
        emit_trajectory()
        return
    if args.check_trajectory:
        raise SystemExit(check_trajectory())
    if args.summary:
        summary()
        return
    if args.obs_cell:
        raise SystemExit(obs_cell(quick=args.quick))

    n, m = (800, 3200) if args.quick else (4000, 16000)
    block_edges = 256
    g = chung_lu(n, m, seed=6)
    result = {
        "graph": {"n": g.n, "m": g.m, "block_edges": block_edges,
                  "num_blocks": -(-g.num_directed // block_edges)},
        "runs": _bench_graph(g, block_edges, BACKENDS, "small"),
        "identical_passes_across_backends": True,
    }
    if not args.quick:
        # >= 200k directed edges: the host reference vs the device-resident
        # xla loop, the fused single-kernel pallas superstep (DESIGN.md §16,
        # still interpret-emulated on CPU), and the on-mesh shard loop
        gl = chung_lu(25_000, 110_000, seed=8)
        assert gl.num_directed >= 200_000
        result["large"] = {
            "graph": {"n": gl.n, "m": gl.m, "block_edges": 4096,
                      "num_blocks": -(-gl.num_directed // 4096)},
            "runs": _bench_graph(gl, 4096,
                                 ("numpy", "xla", "pallas", "shard"),
                                 "large"),
        }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "backends.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
