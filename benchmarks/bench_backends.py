"""Backend matrix benchmark: one superstep core, three compute substrates.

Runs every batch-schedule algorithm on every compute backend (DESIGN.md §11)
over the same graph and records pass counts, wall time, planner I/O, and the
pallas backend's kernel-block skip counts to ``benchmarks/results/backends.json``.
All backends must converge through identical passes to the identical core
array — the script asserts it.

Usage:
    PYTHONPATH=src python benchmarks/bench_backends.py [--quick]
    REPRO_BACKEND=pallas PYTHONPATH=src python benchmarks/bench_backends.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.imcore import imcore_bz  # noqa: E402
from repro.core.semicore import decompose  # noqa: E402
from repro.graph import chung_lu  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")
ALGORITHMS = ("semicore", "semicore+", "semicore*")
BACKENDS = ("numpy", "xla", "pallas")


def smoke() -> None:
    """CI backend-matrix smoke: decompose under the REPRO_BACKEND env default
    and check against the BZ oracle (scripts/ci.sh runs one per backend)."""
    backend = os.environ.get("REPRO_BACKEND", "numpy")
    g = chung_lu(400, 1600, seed=3)
    expect = imcore_bz(g)
    for algo in ALGORITHMS:
        r = decompose(g, algo, "batch", block_edges=64)  # backend from env
        assert np.array_equal(r.core, expect), (backend, algo)
        assert r.backend == backend, (r.backend, backend)
    skipped = r.kernel_blocks_skipped  # last run: semicore*
    print(f"backend smoke OK: backend={backend} kmax={r.kmax} "
          f"iters={r.iterations} io_blocks={r.edge_block_reads} "
          f"kernel_blocks_skipped={skipped}")
    if backend == "pallas":
        assert skipped > 0, "SemiCore* frontier shrinkage must skip blocks"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small graph")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: REPRO_BACKEND env decides the backend")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return

    n, m = (800, 3200) if args.quick else (4000, 16000)
    block_edges = 256
    g = chung_lu(n, m, seed=6)
    result = {
        "graph": {"n": g.n, "m": g.m, "block_edges": block_edges,
                  "num_blocks": -(-g.num_directed // block_edges)},
        "runs": [],
    }
    cores: dict = {}
    for backend in BACKENDS:
        for algo in ALGORITHMS:
            t0 = time.perf_counter()
            r = decompose(g, algo, "batch", block_edges=block_edges,
                          backend=backend)
            wall = time.perf_counter() - t0
            cores.setdefault(algo, r.core)
            assert np.array_equal(r.core, cores[algo]), (backend, algo)
            row = {
                "backend": backend,
                "algorithm": algo,
                "wall_seconds": round(wall, 4),
                "iterations": r.iterations,
                "node_computations": r.node_computations,
                "edge_block_reads": r.edge_block_reads,
                "node_table_reads": r.node_table_reads,
                "kernel_blocks_active": r.kernel_blocks_active,
                "kernel_blocks_skipped": r.kernel_blocks_skipped,
            }
            result["runs"].append(row)
            print(f"{backend:>6} {algo:<10} {wall:7.3f}s  passes={r.iterations:<3} "
                  f"io={r.edge_block_reads:<5} skipped={r.kernel_blocks_skipped}")
    # identical passes across backends is the refactor's core invariant
    by_algo: dict = {}
    for row in result["runs"]:
        by_algo.setdefault(row["algorithm"], set()).add(
            (row["iterations"], row["edge_block_reads"]))
    assert all(len(v) == 1 for v in by_algo.values()), by_algo
    result["identical_passes_across_backends"] = True
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "backends.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
