"""Backend matrix benchmark: one superstep core, three compute substrates.

Runs every batch-schedule algorithm on every compute backend (DESIGN.md §11)
over the same graphs and records pass counts, wall time (cold = first call
including jit compiles, warm = steady state on the device-resident caches),
jit trace counts, planner I/O, and the pallas backend's kernel-block skip
counts to ``benchmarks/results/backends.json``.  All backends must converge
through identical passes to the identical core array — the script asserts it.

Two graphs: the PR 3 comparison cell (n=4k, the history in CHANGES.md) and a
``large`` ≥200k-directed-edge cell (numpy vs xla) where the device-resident
speedup-vs-numpy is the headline number.

Usage:
    PYTHONPATH=src python benchmarks/bench_backends.py [--quick]
    REPRO_BACKEND=pallas PYTHONPATH=src python benchmarks/bench_backends.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import resident  # noqa: E402
from repro.core.imcore import imcore_bz  # noqa: E402
from repro.core.semicore import decompose  # noqa: E402
from repro.graph import chung_lu  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")
ALGORITHMS = ("semicore", "semicore+", "semicore*")
BACKENDS = ("numpy", "xla", "pallas")

# smoke gate: the device-resident xla loop must stay within a loose constant
# factor of numpy wall-clock (compile excluded via one warmup run); the
# additive floor absorbs CI scheduling noise on a tiny graph
SMOKE_WALL_FACTOR = 40.0
SMOKE_WALL_FLOOR_S = 2.0


def _timed(g, algo, backend, block_edges):
    """(cold_seconds, warm_seconds, jit_traces, result) for one config."""
    t0 = resident.trace_count()
    w0 = time.perf_counter()
    r = decompose(g, algo, "batch", block_edges=block_edges, backend=backend)
    cold = time.perf_counter() - w0
    traces = resident.trace_count() - t0
    w1 = time.perf_counter()
    r2 = decompose(g, algo, "batch", block_edges=block_edges, backend=backend)
    warm = time.perf_counter() - w1
    assert np.array_equal(r.core, r2.core)
    return cold, warm, traces, r


def smoke() -> None:
    """CI backend-matrix smoke: decompose under the REPRO_BACKEND env default,
    check against the BZ oracle, and gate the device-resident wall-clock
    (scripts/ci.sh runs one per backend)."""
    backend = os.environ.get("REPRO_BACKEND", "numpy")
    g = chung_lu(400, 1600, seed=3)
    expect = imcore_bz(g)
    numpy_wall = 0.0
    wall = 0.0
    for algo in ALGORITHMS:
        t0 = time.perf_counter()
        rn = decompose(g, algo, "batch", block_edges=64, backend="numpy")
        numpy_wall += time.perf_counter() - t0
        assert np.array_equal(rn.core, expect), ("numpy", algo)
        r = decompose(g, algo, "batch", block_edges=64)  # backend from env
        t0 = time.perf_counter()
        r = decompose(g, algo, "batch", block_edges=64)  # warm: jits cached
        wall += time.perf_counter() - t0
        assert np.array_equal(r.core, expect), (backend, algo)
        assert r.backend == backend, (r.backend, backend)
    skipped = r.kernel_blocks_skipped  # last run: semicore*
    print(f"backend smoke OK: backend={backend} kmax={r.kmax} "
          f"iters={r.iterations} io_blocks={r.edge_block_reads} "
          f"kernel_blocks_skipped={skipped} wall={wall:.3f}s "
          f"(numpy {numpy_wall:.3f}s)")
    if backend == "pallas":
        assert skipped > 0, "SemiCore* frontier shrinkage must skip blocks"
    if backend == "xla" and resident.resident_enabled():
        # the device-resident sanity gate: within a loose multiple of numpy.
        # Not applied to the REPRO_DEVICE_RESIDENT=0 legacy leg, whose
        # per-pass loop is exactness-checked but expected to be slow.
        limit = SMOKE_WALL_FACTOR * numpy_wall + SMOKE_WALL_FLOOR_S
        assert wall <= limit, (
            f"xla wall {wall:.3f}s exceeds {limit:.3f}s "
            f"({SMOKE_WALL_FACTOR}x numpy + {SMOKE_WALL_FLOOR_S}s)")


def _bench_graph(g, block_edges, backends, label):
    rows = []
    cores: dict = {}
    warm_numpy: dict = {}
    for backend in backends:
        for algo in ALGORITHMS:
            cold, warm, traces, r = _timed(g, algo, backend, block_edges)
            cores.setdefault(algo, r.core)
            assert np.array_equal(r.core, cores[algo]), (backend, algo)
            if backend == "numpy":
                warm_numpy[algo] = warm
            row = {
                "backend": backend,
                "algorithm": algo,
                "wall_seconds": round(warm, 4),
                "wall_seconds_cold": round(cold, 4),
                "jit_traces": traces,
                "speedup_vs_numpy": round(warm_numpy[algo] / warm, 2),
                "iterations": r.iterations,
                "node_computations": r.node_computations,
                "edge_block_reads": r.edge_block_reads,
                "node_table_reads": r.node_table_reads,
                "kernel_blocks_active": r.kernel_blocks_active,
                "kernel_blocks_skipped": r.kernel_blocks_skipped,
            }
            rows.append(row)
            print(f"[{label}] {backend:>6} {algo:<10} warm={warm:7.3f}s "
                  f"cold={cold:7.3f}s traces={traces} "
                  f"passes={r.iterations:<3} io={r.edge_block_reads:<5} "
                  f"skipped={r.kernel_blocks_skipped}")
    # identical passes across backends is the layer's core invariant
    by_algo: dict = {}
    for row in rows:
        by_algo.setdefault(row["algorithm"], set()).add(
            (row["iterations"], row["edge_block_reads"],
             row["node_table_reads"]))
    assert all(len(v) == 1 for v in by_algo.values()), by_algo
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graphs, skip the large cell")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: REPRO_BACKEND env decides the backend")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return

    n, m = (800, 3200) if args.quick else (4000, 16000)
    block_edges = 256
    g = chung_lu(n, m, seed=6)
    result = {
        "graph": {"n": g.n, "m": g.m, "block_edges": block_edges,
                  "num_blocks": -(-g.num_directed // block_edges)},
        "runs": _bench_graph(g, block_edges, BACKENDS, "small"),
        "identical_passes_across_backends": True,
    }
    if not args.quick:
        # >= 200k directed edges: the interpret-mode pallas kernels pay a
        # Python-free but still emulated per-block cost, so the large cell
        # compares the host reference against the device-resident xla loop
        gl = chung_lu(25_000, 110_000, seed=8)
        assert gl.num_directed >= 200_000
        result["large"] = {
            "graph": {"n": gl.n, "m": gl.m, "block_edges": 4096,
                      "num_blocks": -(-gl.num_directed // 4096)},
            "runs": _bench_graph(gl, 4096, ("numpy", "xla"), "large"),
        }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "backends.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
