"""Out-of-core benchmark: external-memory build throughput + buffer pool.

Three measurements (JSON artifact: ``benchmarks/results/outofcore.json``):

1. **Build** — stream a ≥10M-edge synthetic web (R-MAT chunks) through
   ``build_csr`` into on-disk node/edge tables, recording wall time, edge
   throughput, and peak memory (tracemalloc tracks numpy allocations; the
   point is O(n) + O(chunk), never O(m)).
2. **Fidelity** — memmap-load the disk build and decompose it; the core
   array must be bit-identical to decomposing an in-memory ``from_edges``
   build of the same stream (``--quick`` only shrinks the graph, the check
   always runs).
3. **Pool sweep** — a skip-heavy SemiCore* run per ``pool_blocks`` setting:
   block reads must decrease monotonically as the pool grows (LRU inclusion).

Usage:
    PYTHONPATH=src python benchmarks/bench_outofcore.py [--quick] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time
import tracemalloc

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.semicore import decompose  # noqa: E402
from repro.graph import CSRGraph, build_csr, chung_lu, rmat_chunks  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs.bench import shared_result  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def bench_build(scale: int, edge_factor: int, chunk_edges: int, workdir: str) -> dict:
    """Out-of-core build of an R-MAT stream; peak memory + throughput."""
    out = os.path.join(workdir, "graph")
    tracemalloc.start()
    t0 = time.perf_counter()
    stats = build_csr(
        rmat_chunks(scale, edge_factor, seed=7, chunk_edges=chunk_edges),
        out,
        n=1 << scale,
        chunk_edges=chunk_edges,
        tmp_dir=workdir,
    )
    build_s = time.perf_counter() - t0
    _, peak_bytes = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    raw = (1 << scale) * edge_factor
    return {
        "n": stats.n,
        "m": stats.m,
        "edges_ingested": stats.edges_ingested,
        "runs": stats.runs,
        "merge_rounds": stats.merge_rounds,
        "chunk_edges": chunk_edges,
        "build_seconds": round(build_s, 3),
        "edges_per_second": round(raw / build_s),
        "peak_traced_bytes": peak_bytes,
        "node_state_bytes": stats.node_state_bytes,
        # the O(n) + O(chunk) contract, with headroom for numpy temporaries:
        # sort/unique/scatter stages each hold a small constant number of
        # int64 views of one chunk (measured ~26x8 bytes/chunk edge)
        "memory_bound_bytes": stats.node_state_bytes + 32 * 8 * chunk_edges,
        "within_bound": peak_bytes <= stats.node_state_bytes + 32 * 8 * chunk_edges,
        # what an in-memory from_edges would hold just for the raw edge array
        "inmemory_edge_array_bytes": stats.edges_ingested * 16,
        "graph_dir": out,
    }


def bench_fidelity(graph_dir: str, scale: int, edge_factor: int,
                   chunk_edges: int) -> dict:
    """decompose(memmap build) must equal decompose(in-memory build)."""
    g_disk = CSRGraph.load(graph_dir, mmap=True)
    edges = np.concatenate(
        list(rmat_chunks(scale, edge_factor, seed=7, chunk_edges=chunk_edges))
    )
    g_mem = CSRGraph.from_edges(1 << scale, edges)
    del edges
    assert np.array_equal(np.asarray(g_disk.indptr), g_mem.indptr)
    assert np.array_equal(np.asarray(g_disk.adj), g_mem.adj)
    t0 = time.perf_counter()
    r_disk = decompose(g_disk, "semicore*", "batch")
    t_disk = time.perf_counter() - t0
    r_mem = decompose(g_mem, "semicore*", "batch")
    identical = bool(np.array_equal(r_disk.core, r_mem.core))
    assert identical, "memmap decomposition diverged from in-memory build"
    return {
        "kmax": r_disk.kmax,
        "iterations": r_disk.iterations,
        "decompose_seconds_memmap": round(t_disk, 3),
        "edge_block_reads": r_disk.edge_block_reads,
        "bit_identical_to_inmemory": identical,
    }


def bench_pool_sweep(quick: bool) -> dict:
    """Skip-heavy SemiCore* (seq): block reads vs pool size, monotone."""
    n, m = (1200, 5000) if quick else (4000, 16000)
    g = chung_lu(n, m, seed=6)
    block_edges = 32
    pools = [1, 16, 64, 256, 1024]
    rows = []
    core0 = None
    s = obs_metrics.sum_by_name
    for pool in pools:
        snap = obs_metrics.get_registry().snapshot()
        t0 = time.perf_counter()
        r = decompose(g, "semicore*", "seq", block_edges=block_edges,
                      pool_blocks=pool)
        wall = time.perf_counter() - t0
        delta = obs_metrics.get_registry().delta(snap)
        if core0 is None:
            core0 = r.core
        else:
            assert np.array_equal(r.core, core0)
        # reads come from the telemetry registry, cross-checked against the
        # DecompResult; hits/evictions exist only in the registry — the
        # reader's paper accounting never needed them until the pool sweep
        reads = int(s(delta, "repro_io_edge_block_reads_total"))
        if obs_metrics.obs_enabled():
            assert reads == r.edge_block_reads, (pool, reads,
                                                 r.edge_block_reads)
        else:
            reads = r.edge_block_reads
        rows.append({
            "pool_blocks": pool,
            "edge_block_reads": reads,
            "pool_hits": int(s(delta, "repro_io_edge_block_pool_hits_total")),
            "evictions": int(s(delta, "repro_io_edge_block_evictions_total")),
            "obs": shared_result(f"outofcore/pool-sweep[pool={pool}]",
                                 wall, delta),
        })
    reads = [row["edge_block_reads"] for row in rows]
    monotone = all(a >= b for a, b in zip(reads, reads[1:]))
    assert monotone, f"pool sweep not monotone: {reads}"
    return {
        "graph": {"n": g.n, "m": g.m, "block_edges": block_edges,
                  "num_blocks": -(-g.num_directed // block_edges)},
        "sweep": rows,
        "monotone_decreasing": monotone,
        "reads_reduction": round(1 - reads[-1] / reads[0], 4),
    }


def smoke(workdir: str) -> None:
    """CI smoke: ~1M-edge chunked build == in-memory build, end to end."""
    scale, ef, chunk = 16, 16, 1 << 17  # 2^16 nodes, ~1M raw edges
    out = os.path.join(workdir, "smoke")
    build_csr(rmat_chunks(scale, ef, seed=7, chunk_edges=chunk), out,
              n=1 << scale, chunk_edges=chunk, tmp_dir=workdir)
    g_disk = CSRGraph.load(out, mmap=True)
    edges = np.concatenate(list(rmat_chunks(scale, ef, seed=7, chunk_edges=chunk)))
    g_mem = CSRGraph.from_edges(1 << scale, edges)
    assert np.array_equal(np.asarray(g_disk.indptr), g_mem.indptr)
    assert np.array_equal(np.asarray(g_disk.adj), g_mem.adj)
    r_disk = decompose(g_disk, "semicore*", "batch")
    r_mem = decompose(g_mem, "semicore*", "batch")
    assert np.array_equal(r_disk.core, r_mem.core)
    print(f"out-of-core smoke OK: n={g_disk.n:,} m={g_disk.m:,} "
          f"kmax={r_disk.kmax} (disk == memory)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graph (CI-friendly); skips the 10M-edge build")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke only: ~1M-edge disk-vs-memory check")
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="bench_ooc_")
    try:
        if args.smoke:
            smoke(workdir)
            return
        if args.quick:
            scale, ef, chunk = 16, 16, 1 << 17
        else:
            # 2M nodes, 16.8M raw edges, 1M-edge chunks: scratch is 1/16 of
            # the stream, so the O(chunk) bound is visibly decoupled from m
            scale, ef, chunk = 21, 8, 1 << 20
        result = {"mode": "quick" if args.quick else "full"}
        print(f"building 2^{scale} x {ef} R-MAT out of core ...")
        result["build"] = bench_build(scale, ef, chunk, workdir)
        b = result["build"]
        print(f"  n={b['n']:,} m={b['m']:,} in {b['build_seconds']}s "
              f"({b['edges_per_second']:,} edges/s), peak "
              f"{b['peak_traced_bytes']/1e6:.1f} MB "
              f"(bound {b['memory_bound_bytes']/1e6:.1f} MB)")
        print("checking memmap decomposition == in-memory build ...")
        result["fidelity"] = bench_fidelity(b.pop("graph_dir"), scale, ef, chunk)
        print(f"  kmax={result['fidelity']['kmax']} bit-identical: "
              f"{result['fidelity']['bit_identical_to_inmemory']}")
        print("pool sweep (skip-heavy SemiCore*, seq) ...")
        result["pool_sweep"] = bench_pool_sweep(args.quick)
        for row in result["pool_sweep"]["sweep"]:
            print(f"  pool={row['pool_blocks']:>5}  reads={row['edge_block_reads']}")
        os.makedirs(RESULTS, exist_ok=True)
        path = os.path.join(RESULTS, "outofcore.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {path}")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
