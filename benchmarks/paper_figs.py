"""Benchmarks mirroring the paper's tables/figures on the synthetic suite.

Fig. 9  -> bench_decomposition : time / memory / I/O, all algorithms
Fig. 3  -> bench_convergence   : per-iteration update counts collapse
Fig. 10 -> bench_maintenance   : per-op insert/delete cost vs recompute
Fig. 11/12 -> bench_scalability: vary |V| / |E| 20%..100%
"""
from __future__ import annotations

import time

import numpy as np

from repro.graph import make_dataset, CSRGraph
from repro.core.imcore import imcore_peel
from repro.core.emcore import emcore
from repro.core.semicore import HostEngine, decompose
from repro.core.maintenance import CoreMaintainer
from repro.core.update import Delete, Insert, UpdateBatch

BLOCK = 4096


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def bench_decomposition(datasets=("dblp-sim", "youtube-sim", "wiki-sim",
                                  "cpt-sim", "lj-sim", "orkut-sim"),
                        run_emcore=True):
    rows = []
    for name in datasets:
        g = make_dataset(name)
        expect, t_im = _time(lambda: imcore_peel(g))
        base = {
            "dataset": name, "n": g.n, "m": g.m,
            "kmax": int(expect.max()),
            "imcore_s": t_im,
            # IMCore holds the whole CSR + per-node state in memory
            "imcore_mem_bytes": g.num_directed * 4 + (g.n + 1) * 8 + g.n * 16,
        }
        for algo in ("semicore", "semicore+", "semicore*"):
            r, t = _time(lambda a=algo: decompose(g, a, "batch", BLOCK))
            assert np.array_equal(r.core, expect), (name, algo)
            key = algo.replace("*", "_star").replace("+", "_plus")
            base[f"{key}_s"] = t
            base[f"{key}_io_blocks"] = r.edge_block_reads
            base[f"{key}_iters"] = r.iterations
            base[f"{key}_computations"] = r.node_computations
            base[f"{key}_mem_bytes"] = r.memory_bytes
        if run_emcore:
            r, t = _time(lambda: emcore(g, num_partitions=16,
                                        memory_budget_edges=g.num_directed // 4,
                                        block_edges=BLOCK))
            assert np.array_equal(r.core, expect), (name, "emcore")
            base["emcore_s"] = t
            base["emcore_io_blocks"] = r.read_blocks + r.write_blocks
            base["emcore_write_blocks"] = r.write_blocks
            base["emcore_mem_bytes"] = r.peak_memory_bytes
            base["emcore_over_budget_rounds"] = r.over_budget_rounds
        rows.append(base)
    return rows


def bench_convergence(datasets=("twitter-sim", "uk-sim")):
    """Fig. 3: number of nodes whose core changes, per iteration."""
    rows = []
    for name in datasets:
        g = make_dataset(name)
        r = decompose(g, "semicore", "batch", BLOCK)
        rows.append({
            "dataset": name, "iterations": r.iterations,
            "updates_per_iter": r.updates_per_iter,
            "first_iter_updates": r.updates_per_iter[0],
            "late_iter_updates": int(np.mean(r.updates_per_iter[-5:])),
        })
    return rows


def bench_maintenance(dataset="lj-sim", num_edges=100, seed=7):
    """Fig. 10: avg per-op cost of SemiDelete*/SemiInsert/SemiInsert*."""
    g = make_dataset(dataset)
    rng = np.random.default_rng(seed)
    e = g.edge_list()
    picks = e[rng.choice(len(e), size=num_edges, replace=False)]

    full = decompose(g, "semicore*", "batch", BLOCK)
    m = CoreMaintainer(g, block_edges=BLOCK)

    out = {"dataset": dataset, "num_ops": num_edges,
           "full_decompose_io_blocks": full.edge_block_reads}
    # deletions
    t0 = time.perf_counter()
    io = comp = 0
    for u, v in picks:
        s = m.apply(UpdateBatch((Delete(int(u), int(v)),)))
        io += s.edge_block_reads
        comp += s.node_computations
    out["delete_star_avg_s"] = (time.perf_counter() - t0) / num_edges
    out["delete_star_avg_io"] = io / num_edges
    out["delete_star_avg_computations"] = comp / num_edges

    # insertions (reinsert the same edges), both algorithms
    for algo in ("semiinsert", "semiinsert*"):
        m2 = CoreMaintainer(m.bg.materialize(), block_edges=BLOCK,
                            state=(m.core, m.cnt))
        t0 = time.perf_counter()
        io = comp = 0
        for u, v in picks:
            s = m2.apply(UpdateBatch((Insert(int(u), int(v)),)),
                         insert_algorithm=algo)
            io += s.edge_block_reads
            comp += s.node_computations
        key = algo.replace("*", "_star")
        out[f"{key}_avg_s"] = (time.perf_counter() - t0) / num_edges
        out[f"{key}_avg_io"] = io / num_edges
        out[f"{key}_avg_computations"] = comp / num_edges
    # correctness of the final state
    final = m2.bg.materialize()
    assert np.array_equal(m2.core, imcore_peel(final))
    return out


def bench_scalability(dataset="twitter-sim", fracs=(0.2, 0.4, 0.6, 0.8, 1.0)):
    """Fig. 11/12: decomposition + maintenance cost vs |V| and |E| samples."""
    g = make_dataset(dataset)
    rows = []
    for frac in fracs:
        for mode in ("nodes", "edges"):
            sub = g.sample_nodes(frac, seed=1) if mode == "nodes" else \
                g.sample_edges(frac, seed=1)
            rec = {"dataset": dataset, "mode": mode, "frac": frac,
                   "n": sub.n, "m": sub.m}
            for algo in ("semicore", "semicore*"):
                r, t = _time(lambda a=algo: decompose(sub, a, "batch", BLOCK))
                key = algo.replace("*", "_star")
                rec[f"{key}_s"] = t
                rec[f"{key}_io_blocks"] = r.edge_block_reads
            m = CoreMaintainer(sub, block_edges=BLOCK)
            e = sub.edge_list()
            if len(e):
                u, v = e[len(e) // 2]
                _, t = _time(lambda: m.apply(
                    UpdateBatch((Delete(int(u), int(v)),))))
                rec["delete_s"] = t
                _, t = _time(lambda: m.apply(
                    UpdateBatch((Insert(int(u), int(v)),))))
                rec["insert_star_s"] = t
            rows.append(rec)
    return rows
