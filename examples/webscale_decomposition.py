"""Web-scale-style decomposition: on-disk graph, pluggable compute backend,
SPMD engine, checkpoint/restart.

The end-to-end driver for the paper's workload: builds an RMAT web-crawl-like
graph, stores it as the on-disk node/edge tables, decomposes it with the
semi-external host engine on the chosen compute backend (DESIGN.md §11),
cross-checks the distributed engine, checkpoints mid-run, and proves a warm
restart converges to the same fixpoint (monotone upper bounds = free crash
consistency).

    PYTHONPATH=src python examples/webscale_decomposition.py [--backend numpy|xla|pallas]

``--backend pallas`` demonstrates the paper's block skipping at the kernel
layer end to end: SemiCore*'s shrinking frontier drives the block-activity
mask of ``segment_sum_active``, so untouched edge blocks issue no DMA (on
this CPU container the kernels run in Pallas interpret mode, so the graph is
scaled down to keep the demo quick; the TPU lowering is the deploy target).
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.graph import rmat, CSRGraph
from repro.core import imcore_peel, decompose
from repro.core.distributed import distributed_decompose, shard_graph, build_decompose_fn
from repro.train import save, restore

parser = argparse.ArgumentParser()
parser.add_argument("--backend", default="numpy",
                    choices=["numpy", "xla", "pallas"],
                    help="batch-schedule compute backend (DESIGN.md §11)")
args = parser.parse_args()

workdir = tempfile.mkdtemp(prefix="webscale_")

# 1) build + store the graph on disk (the paper's edge/node tables).
# Interpret-mode pallas pays a Python-level cost per kernel block, so the
# pallas demo uses a smaller crawl + coarser blocks.
if args.backend == "pallas":
    scale, edge_factor, block_edges = 13, 8, 512
else:
    scale, edge_factor, block_edges = 17, 12, 4096
g = rmat(scale, edge_factor, seed=3)
g.save(os.path.join(workdir, "graph"))
g = CSRGraph.load(os.path.join(workdir, "graph"), mmap=True)  # edges on disk
print(f"graph: n={g.n:,} 2m={g.num_directed:,} (memmapped from disk)")

# 2) host OOC engine (the faithful semi-external reproduction) on the
#    selected compute backend.  Device backends run the fixpoint
#    device-resident (DESIGN.md §12): the edge table uploads once, ~8 fused
#    passes execute per host round-trip, and jit compiles stay O(1) per
#    decompose — resident.trace_count() below proves it
from repro.core import resident
traces0 = resident.trace_count()
t0 = time.time()
r = decompose(g, "semicore*", "batch", block_edges=block_edges,
              backend=args.backend)
print(f"SemiCore* (OOC host, backend={r.backend}): kmax={r.kmax} "
      f"iters={r.iterations} I/O={r.edge_block_reads} blocks in "
      f"{time.time() - t0:.2f}s; node-state memory {r.memory_bytes / 1e6:.1f} MB")
if args.backend != "numpy" and resident.resident_enabled():
    print(f"  device-resident: {resident.trace_count() - traces0} jit "
          f"trace(s) for {r.iterations} passes "
          f"(~{-(-r.iterations // resident.chunk_len())} host round-trips)")
if args.backend == "pallas":
    total = r.kernel_blocks_active + r.kernel_blocks_skipped
    print(f"  kernel layer: {r.kernel_blocks_skipped}/{total} edge-block DMAs "
          f"skipped by the frontier activity mask (SemiCore* I/O saving)")
expect = imcore_peel(g)
assert np.array_equal(r.core, expect)

# 3) SPMD engine + mid-run checkpoint/restart
core, iters = distributed_decompose(g)
assert np.array_equal(core, expect)
print(f"SPMD engine: {iters} supersteps — matches IMCore")

# simulate a crash: run a budgeted prefix, checkpoint, restart warm
import jax
from jax.sharding import Mesh
mesh = Mesh(np.array(jax.devices()).reshape(-1), ("shard",))
sg = shard_graph(g, 1)
fn = build_decompose_fn(mesh, sg.n, sg.num_probes, max_supersteps=max(2, iters // 2))
partial_core, done = fn(sg.deg.astype(np.int32), sg.dst, sg.rows,
                        sg.edge_mask, sg.owned_ids, sg.owned_mask)
save(workdir, int(done), {"core": np.asarray(partial_core)})
print(f"checkpointed after {int(done)} supersteps (upper bounds still valid)")

(state, step) = restore(workdir, {"core": np.zeros(g.n, np.int32)})
core2, extra = distributed_decompose(g, core0=state["core"])
assert np.array_equal(core2, expect)
print(f"warm restart finished in {extra} further supersteps — exact result")
