"""Web-scale-style decomposition: on-disk graph, pluggable compute backend,
sharded mesh execution, checkpoint/restart.

The end-to-end driver for the paper's workload: builds an RMAT web-crawl-like
graph, stores it as the on-disk node/edge tables, decomposes it with the
semi-external host engine on the chosen compute backend (DESIGN.md §11),
cross-checks the sharded mesh backend, checkpoints mid-run, and proves a warm
restart converges to the same fixpoint (monotone upper bounds = free crash
consistency).

    PYTHONPATH=src python examples/webscale_decomposition.py \
        [--backend numpy|xla|pallas|shard] [--num-shards N]

``--backend pallas`` demonstrates the paper's block skipping at the kernel
layer end to end: SemiCore*'s shrinking frontier drives the block-activity
mask of ``segment_sum_active``, so untouched edge blocks issue no DMA (on
this CPU container the kernels run in Pallas interpret mode, so the graph is
scaled down to keep the demo quick; the TPU lowering is the deploy target).

``--backend shard`` runs the whole fixpoint on a device mesh (DESIGN.md §13):
per-device contiguous edge shards, replicated O(n) core, one all_gather of
owned slices per superstep — with the exact numpy pass/I-O trace.  Force
more host devices to see a real mesh on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/webscale_decomposition.py \
        --backend shard --num-shards 8
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.graph import rmat, CSRGraph
from repro.core import imcore_peel, decompose
from repro.core.distributed import distributed_decompose
from repro.core.engine import ShardedBackend
from repro.train import save, restore

parser = argparse.ArgumentParser()
parser.add_argument("--backend", default="numpy",
                    choices=["numpy", "xla", "pallas", "shard"],
                    help="batch-schedule compute backend (DESIGN.md §11/§13)")
parser.add_argument("--num-shards", type=int, default=None,
                    help="mesh width for --backend shard "
                    "(CoreGraphConfig.num_shards; default: all devices)")
args = parser.parse_args()

workdir = tempfile.mkdtemp(prefix="webscale_")

# 1) build + store the graph on disk (the paper's edge/node tables).
# Interpret-mode pallas pays a Python-level cost per kernel block, so the
# pallas demo uses a smaller crawl + coarser blocks.
if args.backend == "pallas":
    scale, edge_factor, block_edges = 13, 8, 512
else:
    scale, edge_factor, block_edges = 17, 12, 4096
g = rmat(scale, edge_factor, seed=3)
g.save(os.path.join(workdir, "graph"))
g = CSRGraph.load(os.path.join(workdir, "graph"), mmap=True)  # edges on disk
print(f"graph: n={g.n:,} 2m={g.num_directed:,} (memmapped from disk)")

# 2) host OOC engine (the faithful semi-external reproduction) on the
#    selected compute backend.  Device backends run the fixpoint
#    device-resident (DESIGN.md §12): the edge table uploads once, ~8 fused
#    passes execute per host round-trip, and jit compiles stay O(1) per
#    decompose — resident.trace_count() below proves it.  The shard backend
#    keeps the same contract with the edge table cut over the mesh (§13).
from repro.core import resident
backend = (ShardedBackend(num_shards=args.num_shards)
           if args.backend == "shard" else args.backend)
traces0 = resident.trace_count()
t0 = time.time()
r = decompose(g, "semicore*", "batch", block_edges=block_edges,
              backend=backend)
print(f"SemiCore* (OOC host, backend={r.backend}): kmax={r.kmax} "
      f"iters={r.iterations} I/O={r.edge_block_reads} blocks in "
      f"{time.time() - t0:.2f}s; node-state memory {r.memory_bytes / 1e6:.1f} MB")
if args.backend != "numpy" and resident.resident_enabled():
    print(f"  device-resident: {resident.trace_count() - traces0} jit "
          f"trace(s) for {r.iterations} passes "
          f"(~{-(-r.iterations // resident.chunk_len())} host round-trips)")
if args.backend == "pallas":
    total = r.kernel_blocks_active + r.kernel_blocks_skipped
    print(f"  kernel layer: {r.kernel_blocks_skipped}/{total} edge-block DMAs "
          f"skipped by the frontier activity mask (SemiCore* I/O saving)")
if args.backend == "shard":
    print(f"  mesh: {r.num_shards} shard(s), rectangular-layout padding "
          f"{r.shard_pad_edges} edge slots "
          f"({100.0 * r.shard_pad_edges / max(1, g.num_directed):.1f}% "
          f"of 2m — minimax-balanced contiguous cuts)")
expect = imcore_peel(g)
assert np.array_equal(r.core, expect)

# 3) sharded mesh engine + mid-run checkpoint/restart
core, iters = distributed_decompose(g)
assert np.array_equal(core, expect)
print(f"shard engine: {iters} supersteps — matches IMCore")

# simulate a crash: run a budgeted prefix (chunk-granular), checkpoint the
# intermediate state — any superstep's core is a valid upper bound — and
# restart warm from it
budget = max(2, iters // 2)
partial_core, done = distributed_decompose(g, max_supersteps=budget)
save(workdir, int(done), {"core": np.asarray(partial_core, dtype=np.int32)})
print(f"checkpointed after {int(done)} supersteps (upper bounds still valid)")

(state, step) = restore(workdir, {"core": np.zeros(g.n, np.int32)})
core2, extra = distributed_decompose(g, core0=state["core"])
assert np.array_equal(core2, expect)
print(f"warm restart finished in {extra} further supersteps — exact result")
