"""Web-scale-style decomposition: on-disk graph, SPMD engine, checkpoint/restart.

The end-to-end driver for the paper's workload: builds an RMAT web-crawl-like
graph, stores it as the on-disk node/edge tables, decomposes it with the
distributed engine, checkpoints mid-run, and proves a warm restart converges
to the same fixpoint (monotone upper bounds = free crash consistency).

    PYTHONPATH=src python examples/webscale_decomposition.py
"""
import os
import tempfile
import time

import numpy as np

from repro.graph import rmat, CSRGraph
from repro.core import imcore_peel, decompose
from repro.core.distributed import distributed_decompose, shard_graph, build_decompose_fn
from repro.train import save, restore

workdir = tempfile.mkdtemp(prefix="webscale_")

# 1) build + store the graph on disk (the paper's edge/node tables)
g = rmat(17, 12, seed=3)   # 131k nodes, ~1.4M directed edges, heavy skew
g.save(os.path.join(workdir, "graph"))
g = CSRGraph.load(os.path.join(workdir, "graph"), mmap=True)  # edges on disk
print(f"graph: n={g.n:,} 2m={g.num_directed:,} (memmapped from disk)")

# 2) host OOC engine (the faithful semi-external reproduction)
t0 = time.time()
r = decompose(g, "semicore*", "batch")
print(f"SemiCore* (OOC host): kmax={r.kmax} iters={r.iterations} "
      f"I/O={r.edge_block_reads} blocks in {time.time() - t0:.2f}s; "
      f"node-state memory {r.memory_bytes / 1e6:.1f} MB")

# 3) SPMD engine + mid-run checkpoint/restart
expect = imcore_peel(g)
core, iters = distributed_decompose(g)
assert np.array_equal(core, expect)
print(f"SPMD engine: {iters} supersteps — matches IMCore")

# simulate a crash: run a budgeted prefix, checkpoint, restart warm
import jax
from jax.sharding import Mesh
mesh = Mesh(np.array(jax.devices()).reshape(-1), ("shard",))
sg = shard_graph(g, 1)
fn = build_decompose_fn(mesh, sg.n, sg.num_probes, max_supersteps=max(2, iters // 2))
partial_core, done = fn(sg.deg.astype(np.int32), sg.dst, sg.rows,
                        sg.edge_mask, sg.owned_ids, sg.owned_mask)
save(workdir, int(done), {"core": np.asarray(partial_core)})
print(f"checkpointed after {int(done)} supersteps (upper bounds still valid)")

(state, step) = restore(workdir, {"core": np.zeros(g.n, np.int32)})
core2, extra = distributed_decompose(g, core0=state["core"])
assert np.array_equal(core2, expect)
print(f"warm restart finished in {extra} further supersteps — exact result")
