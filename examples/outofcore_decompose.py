"""Out-of-core ingestion end to end: stream -> disk tables -> decomposition.

The paper's pipeline at laptop scale: a power-law edge *stream* (never an
edge array) is built into on-disk node/edge tables by the external-memory
builder — sorted runs, cascaded k-way merge, streaming symmetrized scatter,
peak memory O(n) + O(chunk) — then memmap-loaded and decomposed with
SemiCore*, with and without a degree-descending relabel and with a buffer
pool against the paper's single block buffer.

    PYTHONPATH=src python examples/outofcore_decompose.py
"""
import os
import tempfile
import time

import numpy as np

from repro.core import decompose
from repro.graph import CSRGraph, build_csr, powerlaw_chunks

workdir = tempfile.mkdtemp(prefix="ooc_")
N, M, CHUNK = 200_000, 2_000_000, 1 << 18

# 1) ingest the stream out of core (16 chunks; no full edge list anywhere)
t0 = time.time()
stats = build_csr(
    powerlaw_chunks(N, M, gamma=2.2, seed=4, chunk_edges=CHUNK),
    os.path.join(workdir, "graph"),
    n=N,
    chunk_edges=CHUNK,
)
print(f"built n={stats.n:,} m={stats.m:,} from {stats.chunks} chunks "
      f"({stats.runs} runs, {stats.merge_rounds} merge rounds) "
      f"in {time.time() - t0:.1f}s; node state {stats.node_state_bytes / 1e6:.1f} MB")

# 2) memmap-load the edge table and decompose semi-externally
g = CSRGraph.load(os.path.join(workdir, "graph"), mmap=True)
r = decompose(g, "semicore*", "batch")
print(f"SemiCore*: kmax={r.kmax} iters={r.iterations} "
      f"I/O={r.edge_block_reads} blocks; node-state {r.memory_bytes / 1e6:.1f} MB")

# 3) the same stream with the paper's ordering lever: degree-descending ids
stats2 = build_csr(
    powerlaw_chunks(N, M, gamma=2.2, seed=4, chunk_edges=CHUNK),
    os.path.join(workdir, "graph_deg"),
    n=N,
    chunk_edges=CHUNK,
    relabel="degree",
)
g2 = CSRGraph.load(os.path.join(workdir, "graph_deg"), mmap=True)
r2 = decompose(g2, "semicore*", "batch")
assert np.array_equal(np.sort(r2.core), np.sort(r.core))
assert np.array_equal(r2.core[stats2.perm], r.core)  # same cores, permuted ids
print(f"degree-relabeled: node-table reads {r.node_table_reads} -> "
      f"{r2.node_table_reads}, edge blocks {r.edge_block_reads} -> "
      f"{r2.edge_block_reads}")

# 4) single block buffer (the paper's model) vs an LRU buffer pool sized to
#    the edge table (only compulsory misses survive a covering pool)
num_blocks = -(-g.num_directed // 512)
for pool in (1, num_blocks // 4, num_blocks):
    rp = decompose(g, "semicore*", "seq", block_edges=512, pool_blocks=pool)
    print(f"pool_blocks={pool:>5}: edge block reads {rp.edge_block_reads}")
