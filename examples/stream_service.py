"""Streaming core-graph service end to end (§V as a long-lived process).

A CoreService ingests a live insert/delete stream in micro-batches while
serving coreness / k-core / top-k queries from epoch-versioned snapshots,
then "crashes" and recovers from its write-ahead log + node-state snapshot
without recomputing the decomposition from scratch.

    PYTHONPATH=src python examples/stream_service.py
"""
import os
import tempfile
import time

import numpy as np

from repro.core import decompose
from repro.graph import chung_lu
from repro.stream import CoreService, mixed_stream

n, m, num_updates, batch = 10_000, 60_000, 1_000, 100
g = chung_lu(n, m, seed=1)
stream, _ = mixed_stream(g, num_updates, seed=0)

tmp = tempfile.mkdtemp(prefix="core_stream_")
svc = CoreService(g, wal_path=os.path.join(tmp, "wal.jsonl"),
                  snapshot_dir=os.path.join(tmp, "snaps"),
                  snapshot_every=4)
print(f"service up: n={n}, m={m}, degeneracy={svc.degeneracy()}, epoch 0")

t0 = time.time()
for i in range(0, num_updates, batch):
    s = svc.ingest(stream[i : i + batch])
    top = svc.top_k(3)
    print(f"epoch {s.epoch:>2}: +{s.num_applied_inserts}/-{s.num_applied_deletes} "
          f"edges, {s.num_changed} cores changed, {s.edge_block_reads} block "
          f"I/Os, top-3 {top.tolist()} (core {svc.coreness(top).tolist()})")
rate = svc.service_stats()["updates_applied"] / (time.time() - t0)
print(f"sustained {rate:.0f} updates/s; cache hit rate "
      f"{svc.cache.hits / max(svc.cache.hits + svc.cache.misses, 1):.2f}")

svc.close()  # --- crash here: everything below rebuilds from disk ----------
t0 = time.time()
svc2, rec = CoreService.recover(wal_path=os.path.join(tmp, "wal.jsonl"),
                                snapshot_dir=os.path.join(tmp, "snaps"))
print(f"recovered epoch {rec.recovered_epoch} from snapshot@"
      f"{rec.snapshot_epoch} + {rec.replayed_batches} WAL batches in "
      f"{time.time() - t0:.2f}s (settle: {rec.settle_node_computations} "
      f"node computations)")

ref = decompose(svc2.bg.materialize(), "semicore*", "batch")
assert np.array_equal(svc2.maintainer.core, ref.core)
assert np.array_equal(svc2.maintainer.core, svc.maintainer.core)
print(f"recovered state exact (== full decompose, {ref.node_computations} "
      f"computations avoided per restart)")
