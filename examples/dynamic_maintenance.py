"""Dynamic graphs: maintain core numbers under an edge-update stream (§V).

Compares SemiInsert vs SemiInsert* and both against full recomputation,
reproducing the qualitative claims of Fig. 10.

    PYTHONPATH=src python examples/dynamic_maintenance.py
"""
import time

import numpy as np

from repro.graph import chung_lu
from repro.core import CoreMaintainer, decompose, imcore_bz
from repro.core.update import Insert, UpdateBatch
from repro.runtime import Settings

g = chung_lu(30_000, 200_000, seed=1)
full = decompose(g, "semicore*", "batch")
print(f"initial decomposition: kmax={full.kmax}, I/O={full.edge_block_reads} blocks")

rng = np.random.default_rng(0)
edges = g.edge_list()
picks = edges[rng.choice(len(edges), 100, replace=False)]

# the SemiInsert-vs-SemiInsert* comparison needs the paper's per-edge
# path, so pin the serial oracle (parallel_maint=False)
m = CoreMaintainer(g, settings=Settings(parallel_maint=False))
for algo in ("semiinsert", "semiinsert*"):
    m2 = CoreMaintainer(m.bg.materialize(), state=(m.core, m.cnt),
                        settings=Settings(parallel_maint=False))
    io = comp = 0
    t0 = time.time()
    for u, v in picks:
        m2.apply(UpdateBatch.from_pairs(deletes=[(int(u), int(v))]))
    for u, v in picks:
        s = m2.apply(UpdateBatch((Insert(int(u), int(v)),)),
                     insert_algorithm=algo)
        io += s.edge_block_reads
        comp += s.node_computations
    dt = (time.time() - t0) / 200
    print(f"{algo:<12} avg {dt * 1e3:.2f} ms/op, {io / 100:.1f} I/Os and "
          f"{comp / 100:.1f} computations per insertion")
    assert np.array_equal(m2.core, imcore_bz(m2.bg.materialize()))
print(f"(one full recomputation costs {full.edge_block_reads} I/Os — "
      f"maintenance is orders of magnitude cheaper per update)")

# the parallel grouped settle (DESIGN.md §18) takes the whole micro-batch
# in one call: independent groups fixpoint concurrently on device
m3 = CoreMaintainer(m.bg.materialize(), state=(m.core, m.cnt))
batch = UpdateBatch.from_pairs(deletes=picks)
t0 = time.time()
s = m3.apply(batch)
print(f"parallel     {len(batch)} deletes in one apply(): "
      f"{(time.time() - t0) * 1e3:.1f} ms total, {s.groups} groups "
      f"(largest {s.largest_group} nodes), {s.settle_passes} settle passes")
assert np.array_equal(m3.core, imcore_bz(m3.bg.materialize()))
