"""Dynamic graphs: maintain core numbers under an edge-update stream (§V).

Compares SemiInsert vs SemiInsert* and both against full recomputation,
reproducing the qualitative claims of Fig. 10.

    PYTHONPATH=src python examples/dynamic_maintenance.py
"""
import time

import numpy as np

from repro.graph import chung_lu
from repro.core import CoreMaintainer, decompose, imcore_bz

g = chung_lu(30_000, 200_000, seed=1)
full = decompose(g, "semicore*", "batch")
print(f"initial decomposition: kmax={full.kmax}, I/O={full.edge_block_reads} blocks")

rng = np.random.default_rng(0)
edges = g.edge_list()
picks = edges[rng.choice(len(edges), 100, replace=False)]

m = CoreMaintainer(g)
for algo in ("semiinsert", "semiinsert*"):
    m2 = CoreMaintainer(m.bg.materialize(), state=(m.core, m.cnt))
    io = comp = 0
    t0 = time.time()
    for u, v in picks:
        m2.delete_edge(int(u), int(v))
    for u, v in picks:
        s = m2.insert_edge(int(u), int(v), algorithm=algo)
        io += s.edge_block_reads
        comp += s.node_computations
    dt = (time.time() - t0) / 200
    print(f"{algo:<12} avg {dt * 1e3:.2f} ms/op, {io / 100:.1f} I/Os and "
          f"{comp / 100:.1f} computations per insertion")
    assert np.array_equal(m2.core, imcore_bz(m2.bg.materialize()))
print(f"(one full recomputation costs {full.edge_block_reads} I/Os — "
      f"maintenance is orders of magnitude cheaper per update)")
