"""Quickstart: core decomposition with the paper's three semi-external
algorithms on the paper's own running example (Fig. 1) + a synthetic graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.graph import paper_example_graph, chung_lu
from repro.core import decompose, imcore_bz, CoreMaintainer
from repro.core.update import Delete, Insert, UpdateBatch

# --- the paper's Fig. 1 graph -----------------------------------------------
g = paper_example_graph()
print("Fig. 1 graph:", g.n, "nodes,", g.m, "edges")
for algo in ("semicore", "semicore+", "semicore*"):
    r = decompose(g, algo, schedule="seq", block_edges=16)
    print(f"  {algo:<10} cores={r.core.tolist()} iters={r.iterations} "
          f"computations={r.node_computations}")
# SemiCore:36, SemiCore+:23, SemiCore*:11 — exactly Examples 4.1/4.2/4.3.

# --- a power-law graph, all engines agree ------------------------------------
g = chung_lu(50_000, 400_000, seed=0)
ref = imcore_bz(g)
r = decompose(g, "semicore*", schedule="batch")
assert np.array_equal(r.core, ref)
print(f"\nchung_lu(50k, 400k): kmax={r.kmax} iters={r.iterations} "
      f"I/O={r.edge_block_reads} blocks  memory={r.memory_bytes / 1e6:.1f} MB "
      f"(vs in-memory CSR {(g.num_directed * 4 + g.n * 24) / 1e6:.1f} MB)")

# --- maintain under updates ---------------------------------------------------
m = CoreMaintainer(g)
e = g.edge_list()[12345]
s = m.apply(UpdateBatch((Delete(int(e[0]), int(e[1])),)))
print(f"delete edge: {s.node_computations} computations, "
      f"{s.edge_block_reads} I/Os, {s.num_changed} cores changed")
s = m.apply(UpdateBatch((Insert(int(e[0]), int(e[1])),)))
print(f"insert edge: {s.node_computations} computations, "
      f"{s.edge_block_reads} I/Os, {s.num_changed} cores changed")
print("cores back to original:", np.array_equal(m.core, ref))

# a whole micro-batch settles in one call — deletes and inserts interleave
# in submission order, and stats report the independent groups settled
picks = g.edge_list()[:4]
batch = UpdateBatch.from_pairs(deletes=picks[:2], inserts=picks[:2])
s = m.apply(batch)
print(f"batch of {len(batch)} ops: algorithm={s.algorithm} "
      f"groups={s.groups} noops={s.num_noops}")
