"""End-to-end driver: train GraphSAGE with the real neighbor sampler, using
the paper's core decomposition as a locality-improving preprocessing step
(degeneracy-order relabeling), with checkpoint/resume.

    PYTHONPATH=src python examples/train_graphsage.py [steps]
"""
import sys
import tempfile

from repro.train import TrainLoop

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
ckpt = tempfile.mkdtemp(prefix="sage_ckpt_")

loop = TrainLoop("graphsage-reddit", shape="full_graph_sm", reduced=True,
                 checkpoint_dir=ckpt, checkpoint_every=50, log_every=25)
out = loop.run(steps, resume=False)
print(f"trained {steps} steps: loss {out['losses'][0]:.3f} -> "
      f"{out['final_loss']:.3f} at {out['steps_per_s']:.1f} steps/s")

# crash/resume: a second loop picks up from the checkpoint
loop2 = TrainLoop("graphsage-reddit", shape="full_graph_sm", reduced=True,
                  checkpoint_dir=ckpt, log_every=0)
out2 = loop2.run(20)
print(f"resumed +20 steps: final loss {out2['final_loss']:.3f}")
