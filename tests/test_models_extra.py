"""Deeper model-level correctness: MoE dispatch vs dense reference, serving
engine decode-vs-prefill consistency, GNN invariances."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MoEConfig, GNNConfig
from repro.models.params import tree_init
from repro.models import moe as moe_m
from repro.models import transformer as tfm
from repro.models import gnn as gnn_m


def _dense_moe_reference(p, cfg, x):
    """Per-token loop over selected experts — no capacity, no dropping."""
    m = cfg.moe
    B, S, E = x.shape
    xt = np.asarray(x.reshape(-1, E), np.float32)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    topk = np.argsort(-probs, axis=-1)[:, : m.top_k]
    out = np.zeros_like(xt)
    wg, wu, wd = (np.asarray(p["w_gate"]), np.asarray(p["w_up"]),
                  np.asarray(p["w_down"]))
    for t in range(xt.shape[0]):
        ps = probs[t, topk[t]]
        ps = ps / ps.sum()
        for e, g in zip(topk[t], ps):
            h = xt[t] @ wg[e]
            h = (h / (1 + np.exp(-h))) * (xt[t] @ wu[e])
            out[t] += g * (h @ wd[e])
    return out.reshape(B, S, E)


def test_moe_dispatch_matches_dense_reference_when_no_drops():
    cfg = LMConfig("t", n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32,
                   vocab=64, dtype=jnp.float32,
                   moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                                 capacity_factor=8.0))  # no drops
    specs = moe_m.moe_param_specs(cfg, 1)
    params = jax.tree.map(lambda s: s, tree_init(specs, jax.random.PRNGKey(1)))
    p1 = jax.tree.map(lambda a: a[0], params)  # layer 0
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 16))
    got = moe_m.moe_apply(p1, cfg, x)
    want = _dense_moe_reference(p1, cfg, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0, dropped fraction stays small for uniform routing."""
    cfg = LMConfig("t", n_layers=1, d_model=8, n_heads=2, n_kv=2, d_ff=16,
                   vocab=64, dtype=jnp.float32,
                   moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=8,
                                 capacity_factor=1.0))
    specs = moe_m.moe_param_specs(cfg, 1)
    params = jax.tree.map(lambda a: a[0], tree_init(specs, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 8))
    out = moe_m.moe_apply(params, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


def test_serve_engine_greedy_matches_prefill():
    from repro.serve import ServeEngine

    cfg = LMConfig("t", n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64,
                   vocab=97, d_head=8, dtype=jnp.float32, qk_norm=True)
    params = tree_init(tfm.lm_param_specs(cfg), jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 97))
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=32)
    logits = eng.prefill(prompts)
    full = tfm.serve_prefill(params, cfg, jnp.asarray(prompts))
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
    toks = eng.generate(prompts, steps=4)
    assert toks.shape == (2, 4) and (toks >= 0).all() and (toks < 97).all()


def test_flash_decode_kernel_matches_transformer_decode_attention():
    """The Pallas long-context kernel equals the model's decode attention."""
    from repro.kernels import flash_decode
    from repro.models.layers import decode_attention

    rng = np.random.default_rng(5)
    B, H, Hkv, d, T = 2, 8, 2, 32, 256
    q = rng.normal(size=(B, 1, H, d)).astype(np.float32)
    k = rng.normal(size=(B, T, Hkv, d)).astype(np.float32)
    v = rng.normal(size=(B, T, Hkv, d)).astype(np.float32)
    length = 200
    want = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            jnp.int32(length))
    for b in range(B):
        got = flash_decode(
            jnp.asarray(q[b, 0].reshape(Hkv, H // Hkv, d).reshape(H, d)),
            jnp.asarray(k[b].transpose(1, 0, 2)),
            jnp.asarray(v[b].transpose(1, 0, 2)),
            jnp.int32(length), block_kv=64)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want[b, 0]), rtol=2e-4, atol=2e-4)


def test_egnn_translation_invariance():
    """E(n): translating all coordinates leaves per-node energies unchanged."""
    cfg = GNNConfig("e", arch="egnn", n_layers=2, d_hidden=16)
    specs = gnn_m.egnn_param_specs(cfg, 8)
    params = tree_init(specs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n, e = 12, 40
    x = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    e1, _ = gnn_m.egnn_forward(params, cfg, x, pos, src, dst, n)
    e2, _ = gnn_m.egnn_forward(params, cfg, x, pos + 5.0, src, dst, n)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4,
                               atol=1e-4)


def test_gcn_isolated_nodes_finite():
    cfg = GNNConfig("g", arch="gcn", n_layers=2, d_hidden=8, num_classes=3)
    params = tree_init(gnn_m.gcn_param_specs(cfg, 4), jax.random.PRNGKey(0))
    x = jnp.ones((6, 4))
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([1, 0], jnp.int32)  # nodes 2..5 isolated
    out = gnn_m.gcn_forward(params, cfg, x, src, dst, 6)
    assert np.isfinite(np.asarray(out)).all()
