"""Differential battery: every algorithm × schedule × storage backing × compute
backend must agree with the in-memory BZ oracle (Algorithm 1) on seeded graph
families.

Backings:
  * ``inmem``    — numpy arrays straight from the generator;
  * ``memmap``   — the CSR saved to disk and reopened with ``np.memmap``
                   (the true out-of-core edge table);
  * ``buffered`` — a ``BufferedGraph`` whose base CSR *differs* from the
                   target graph (edges missing + decoys present) and whose
                   update buffer patches it back — so merged neighbor reads,
                   not just passthrough, are what the engine consumes.

Backends (batch schedule; DESIGN.md §11, §13): ``numpy`` — the historical
host loops, whose traces must stay bit-identical; ``xla`` — jit'd
binary-search h-index on the device-resident fixpoint; ``pallas-interpret``
— block-skipping kernels through the Pallas interpreter; ``shard`` — the
on-mesh sharded fixpoint (one shard per visible device, so the CI 8-device
matrix leg runs this sweep over a real 8-way mesh).
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.imcore import imcore_bz
from repro.core.semicore import decompose
from repro.graph import (
    BufferedGraph,
    CSRGraph,
    chung_lu,
    erdos_renyi,
    paper_example_graph,
)

ALGORITHMS = ["semicore", "semicore+", "semicore*"]
SCHEDULES = ["seq", "batch"]
BACKINGS = ["inmem", "memmap", "buffered"]
BACKENDS = ["numpy", "xla", "pallas-interpret", "shard"]


# ----------------------------------------------------------- graph families
def _star(n=41):
    e = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n, dtype=np.int64)], 1)
    return CSRGraph.from_edges(n, e)


def _clique(n=13):
    ij = np.array([(i, j) for i in range(n) for j in range(i + 1, n)], np.int64)
    return CSRGraph.from_edges(n, ij)


def _disconnected():
    """Two cliques of different core number joined by nothing."""
    a = np.array([(i, j) for i in range(6) for j in range(i + 1, 6)], np.int64)
    b = 6 + np.array([(i, j) for i in range(4) for j in range(i + 1, 4)], np.int64)
    return CSRGraph.from_edges(10, np.concatenate([a, b]))


def _isolated():
    """A path embedded in a larger id space: nodes 0, 5, 9 have no edges."""
    e = np.array([(1, 2), (2, 3), (3, 4), (4, 6), (6, 7), (7, 8)], np.int64)
    return CSRGraph.from_edges(10, e)


def _empty():
    return CSRGraph.from_edges(7, np.zeros((0, 2), np.int64))


FAMILIES = {
    "erdos_renyi": lambda: erdos_renyi(200, 700, seed=7),
    "powerlaw": lambda: chung_lu(250, 900, gamma=2.3, seed=11),
    "star": _star,
    "clique": _clique,
    "disconnected": _disconnected,
    "isolated": _isolated,
    "empty": _empty,
}


# ----------------------------------------------------------------- backings
def _buffered_backing(g: CSRGraph) -> BufferedGraph:
    """A BufferedGraph whose merged view equals ``g`` but whose base doesn't."""
    e = g.edge_list()
    rng = np.random.default_rng(g.n * 1000 + g.m)
    hold_out = rng.random(len(e)) < 0.3 if len(e) else np.zeros(0, bool)
    base_edges = e[~hold_out]
    # decoy edges absent from g, to be deleted through the buffer
    present = set(map(tuple, e))
    decoys = []
    for _ in range(200):
        u, v = int(rng.integers(g.n)), int(rng.integers(g.n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key not in present and key not in decoys:
            decoys.append(key)
        if len(decoys) >= 5:
            break
    if decoys:
        base_edges = np.concatenate([base_edges, np.array(decoys, np.int64)])
    bg = BufferedGraph(CSRGraph.from_edges(g.n, base_edges), buffer_capacity=1 << 30)
    for u, v in decoys:
        assert bg.delete_edge(int(u), int(v))
    for u, v in e[hold_out]:
        assert bg.insert_edge(int(u), int(v))
    return bg


def _with_backing(g: CSRGraph, backing: str, tmpdir: str):
    if backing == "inmem":
        return g
    if backing == "memmap":
        path = os.path.join(tmpdir, "g")
        g.save(path)
        return CSRGraph.load(path, mmap=True)
    if backing == "buffered":
        if g.n == 0:
            return BufferedGraph(g)
        return _buffered_backing(g)
    raise ValueError(backing)


# -------------------------------------------------------------------- tests
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("backing", BACKINGS)
def test_differential_matches_bz_oracle(family, algorithm, schedule, backing, tmp_path):
    g = FAMILIES[family]()
    expect = imcore_bz(g)
    target = _with_backing(g, backing, str(tmp_path))
    r = decompose(target, algorithm, schedule, block_edges=64)
    np.testing.assert_array_equal(
        r.core, expect, err_msg=f"{family}/{algorithm}/{schedule}/{backing}"
    )
    if r.cnt is not None:  # semicore*: cnt must be exact Eq. 2 at fixpoint
        for v in range(g.n):
            assert r.cnt[v] == int((r.core[g.neighbors(v)] >= r.core[v]).sum())


@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_differential_pooled_reader_same_fixpoint(algorithm, schedule):
    """pool_blocks only changes I/O accounting, never the decomposition."""
    g = chung_lu(300, 1200, seed=5)
    expect = imcore_bz(g)
    for pool in (1, 4, 32):
        r = decompose(g, algorithm, schedule, block_edges=32, pool_blocks=pool)
        np.testing.assert_array_equal(r.core, expect, err_msg=f"pool={pool}")


# -------------------------------------------------------- compute backends
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_differential_matches_bz_oracle(family, algorithm, backend):
    """backend × algorithm × batch schedule vs the BZ oracle, plus exact
    pass-for-pass agreement (core, cnt, I/O trace) with the numpy backend."""
    g = FAMILIES[family]()
    expect = imcore_bz(g)
    ref = decompose(g, algorithm, "batch", block_edges=64, backend="numpy")
    r = decompose(g, algorithm, "batch", block_edges=64, backend=backend)
    np.testing.assert_array_equal(
        r.core, expect, err_msg=f"{family}/{algorithm}/{backend}"
    )
    if r.cnt is not None:  # semicore*: cnt must be exact Eq. 2 at fixpoint
        np.testing.assert_array_equal(r.cnt, ref.cnt)
    # exact integer ops => identical passes => identical planner accounting
    assert r.iterations == ref.iterations
    assert r.node_computations == ref.node_computations
    assert r.edge_block_reads == ref.edge_block_reads
    assert r.node_table_reads == ref.node_table_reads
    assert r.backend == backend.split("-")[0]


def test_numpy_backend_preserves_paper_traces():
    """pool=1 Fig. 2/4/5 traces are unchanged under the numpy backend: the
    exact node computations, iterations, and block I/O of the paper's
    running example, seq and batch schedules alike."""
    # (algorithm, schedule) -> (comps, iters, edge_block_reads, node_reads)
    pinned = {
        ("semicore", "seq"): (36, 4, 1, 4),
        ("semicore+", "seq"): (23, 4, 1, 4),
        ("semicore*", "seq"): (11, 3, 1, 3),
        ("semicore", "batch"): (36, 4, 4, 4),
        ("semicore+", "batch"): (26, 4, 4, 4),
        ("semicore*", "batch"): (11, 3, 3, 3),
    }
    for (algo, sched), (comps, iters, ebr, ntr) in pinned.items():
        r = decompose(paper_example_graph(), algo, sched, block_edges=64,
                      pool_blocks=1, backend="numpy")
        np.testing.assert_array_equal(r.core, [3, 3, 3, 3, 2, 2, 2, 2, 1])
        assert r.node_computations == comps, (algo, sched)
        assert r.iterations == iters, (algo, sched)
        assert r.edge_block_reads == ebr, (algo, sched)
        assert r.node_table_reads == ntr, (algo, sched)


def test_pallas_backend_skips_blocks_on_shrinking_frontier():
    """SemiCore* frontier shrinkage must reach the kernel layer: inactive
    edge blocks are skipped (no DMA), and the skip count is reported."""
    g = chung_lu(250, 900, gamma=2.3, seed=11)
    star = decompose(g, "semicore*", "batch", block_edges=64, backend="pallas")
    assert star.kernel_blocks_skipped > 0
    # per-pass blocks partition into active + skipped
    nb = -(-g.num_directed // 64)
    assert star.kernel_blocks_active + star.kernel_blocks_skipped == \
        nb * star.iterations
    # full-frontier SemiCore never skips: every pass touches every block
    basic = decompose(g, "semicore", "batch", block_edges=64, backend="pallas")
    assert basic.kernel_blocks_skipped == 0
    assert basic.kernel_blocks_active == nb * basic.iterations


def test_seq_schedule_rejects_non_numpy_backends():
    g = paper_example_graph()
    with pytest.raises(ValueError, match="seq"):
        decompose(g, "semicore*", "seq", backend="xla")


# ------------------------------------------------------ property harness
@st.composite
def random_graph(draw):
    n = draw(st.integers(2, 40))
    max_e = min(n * (n - 1) // 2, 120)
    num_e = draw(st.integers(0, max_e))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=num_e,
            max_size=num_e,
        )
    )
    return n, edges


@given(random_graph(), st.sampled_from(ALGORITHMS), st.sampled_from(SCHEDULES))
@settings(max_examples=40, deadline=None)
def test_property_differential_all_backings(ng, algorithm, schedule):
    n, edges = ng
    g = CSRGraph.from_edges(n, np.array(edges, np.int64).reshape(-1, 2))
    expect = imcore_bz(g)
    with tempfile.TemporaryDirectory() as td:
        for backing in BACKINGS:
            target = _with_backing(g, backing, td)
            r = decompose(target, algorithm, schedule, block_edges=16)
            np.testing.assert_array_equal(
                r.core, expect, err_msg=f"{algorithm}/{schedule}/{backing}"
            )
