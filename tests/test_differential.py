"""Differential battery: every algorithm × schedule × storage backing must
agree with the in-memory BZ oracle (Algorithm 1) on seeded graph families.

Backings:
  * ``inmem``    — numpy arrays straight from the generator;
  * ``memmap``   — the CSR saved to disk and reopened with ``np.memmap``
                   (the true out-of-core edge table);
  * ``buffered`` — a ``BufferedGraph`` whose base CSR *differs* from the
                   target graph (edges missing + decoys present) and whose
                   update buffer patches it back — so merged neighbor reads,
                   not just passthrough, are what the engine consumes.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.imcore import imcore_bz
from repro.core.semicore import decompose
from repro.graph import BufferedGraph, CSRGraph, chung_lu, erdos_renyi

ALGORITHMS = ["semicore", "semicore+", "semicore*"]
SCHEDULES = ["seq", "batch"]
BACKINGS = ["inmem", "memmap", "buffered"]


# ----------------------------------------------------------- graph families
def _star(n=41):
    e = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n, dtype=np.int64)], 1)
    return CSRGraph.from_edges(n, e)


def _clique(n=13):
    ij = np.array([(i, j) for i in range(n) for j in range(i + 1, n)], np.int64)
    return CSRGraph.from_edges(n, ij)


def _disconnected():
    """Two cliques of different core number joined by nothing."""
    a = np.array([(i, j) for i in range(6) for j in range(i + 1, 6)], np.int64)
    b = 6 + np.array([(i, j) for i in range(4) for j in range(i + 1, 4)], np.int64)
    return CSRGraph.from_edges(10, np.concatenate([a, b]))


def _isolated():
    """A path embedded in a larger id space: nodes 0, 5, 9 have no edges."""
    e = np.array([(1, 2), (2, 3), (3, 4), (4, 6), (6, 7), (7, 8)], np.int64)
    return CSRGraph.from_edges(10, e)


def _empty():
    return CSRGraph.from_edges(7, np.zeros((0, 2), np.int64))


FAMILIES = {
    "erdos_renyi": lambda: erdos_renyi(200, 700, seed=7),
    "powerlaw": lambda: chung_lu(250, 900, gamma=2.3, seed=11),
    "star": _star,
    "clique": _clique,
    "disconnected": _disconnected,
    "isolated": _isolated,
    "empty": _empty,
}


# ----------------------------------------------------------------- backings
def _buffered_backing(g: CSRGraph) -> BufferedGraph:
    """A BufferedGraph whose merged view equals ``g`` but whose base doesn't."""
    e = g.edge_list()
    rng = np.random.default_rng(g.n * 1000 + g.m)
    hold_out = rng.random(len(e)) < 0.3 if len(e) else np.zeros(0, bool)
    base_edges = e[~hold_out]
    # decoy edges absent from g, to be deleted through the buffer
    present = set(map(tuple, e))
    decoys = []
    for _ in range(200):
        u, v = int(rng.integers(g.n)), int(rng.integers(g.n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key not in present and key not in decoys:
            decoys.append(key)
        if len(decoys) >= 5:
            break
    if decoys:
        base_edges = np.concatenate([base_edges, np.array(decoys, np.int64)])
    bg = BufferedGraph(CSRGraph.from_edges(g.n, base_edges), buffer_capacity=1 << 30)
    for u, v in decoys:
        assert bg.delete_edge(int(u), int(v))
    for u, v in e[hold_out]:
        assert bg.insert_edge(int(u), int(v))
    return bg


def _with_backing(g: CSRGraph, backing: str, tmpdir: str):
    if backing == "inmem":
        return g
    if backing == "memmap":
        path = os.path.join(tmpdir, "g")
        g.save(path)
        return CSRGraph.load(path, mmap=True)
    if backing == "buffered":
        if g.n == 0:
            return BufferedGraph(g)
        return _buffered_backing(g)
    raise ValueError(backing)


# -------------------------------------------------------------------- tests
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("backing", BACKINGS)
def test_differential_matches_bz_oracle(family, algorithm, schedule, backing, tmp_path):
    g = FAMILIES[family]()
    expect = imcore_bz(g)
    target = _with_backing(g, backing, str(tmp_path))
    r = decompose(target, algorithm, schedule, block_edges=64)
    np.testing.assert_array_equal(
        r.core, expect, err_msg=f"{family}/{algorithm}/{schedule}/{backing}"
    )
    if r.cnt is not None:  # semicore*: cnt must be exact Eq. 2 at fixpoint
        for v in range(g.n):
            assert r.cnt[v] == int((r.core[g.neighbors(v)] >= r.core[v]).sum())


@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_differential_pooled_reader_same_fixpoint(algorithm, schedule):
    """pool_blocks only changes I/O accounting, never the decomposition."""
    g = chung_lu(300, 1200, seed=5)
    expect = imcore_bz(g)
    for pool in (1, 4, 32):
        r = decompose(g, algorithm, schedule, block_edges=32, pool_blocks=pool)
        np.testing.assert_array_equal(r.core, expect, err_msg=f"pool={pool}")


# ------------------------------------------------------ property harness
@st.composite
def random_graph(draw):
    n = draw(st.integers(2, 40))
    max_e = min(n * (n - 1) // 2, 120)
    num_e = draw(st.integers(0, max_e))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=num_e,
            max_size=num_e,
        )
    )
    return n, edges


@given(random_graph(), st.sampled_from(ALGORITHMS), st.sampled_from(SCHEDULES))
@settings(max_examples=40, deadline=None)
def test_property_differential_all_backings(ng, algorithm, schedule):
    n, edges = ng
    g = CSRGraph.from_edges(n, np.array(edges, np.int64).reshape(-1, 2))
    expect = imcore_bz(g)
    with tempfile.TemporaryDirectory() as td:
        for backing in BACKINGS:
            target = _with_backing(g, backing, td)
            r = decompose(target, algorithm, schedule, block_edges=16)
            np.testing.assert_array_equal(
                r.core, expect, err_msg=f"{algorithm}/{schedule}/{backing}"
            )
