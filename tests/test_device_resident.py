"""Device-resident fixpoint (core/resident.py, DESIGN.md §12).

Pins the three properties the refactor claims:

* **compile count** — jit traces per decompose are O(1), independent of the
  pass count (the PR 3 path retraced O(passes) times);
* **trace parity** — the resident path reproduces the numpy backend's
  paper-pinned Fig. 2/4/5 traces and the per-pass (legacy) path's
  kernel-block report bit-for-bit;
* **structure residency** — the uploaded edge table is version-keyed: reused
  across runs and no-op batches, rebuilt exactly once per structural change,
  and dropped on unbind for one-shot runs (the decompose memory guarantee).
"""
import numpy as np
import pytest

from repro.core import resident
from repro.core.engine import PallasBackend, XLABackend, run_batch, warm_settle
from repro.core.imcore import imcore_bz
from repro.core.maintenance import CoreMaintainer
from repro.core.semicore import HostEngine, decompose
from repro.graph import BufferedGraph, chung_lu, paper_example_graph
from repro.stream.service import CoreService


# ------------------------------------------------------------ compile count
def test_compile_count_independent_of_pass_count():
    """A ~26-pass decompose must cost O(1) jit traces (chunk fn (+ warm-path
    prologue), never one per pass), and a re-run with warm caches zero."""
    g = chung_lu(4000, 16000, seed=6)
    before = resident.trace_count()
    r1 = decompose(g, "semicore*", "batch", block_edges=256, backend="xla")
    first = resident.trace_count() - before
    assert r1.iterations >= 20  # far more passes than allowed traces
    assert first <= 2, f"{first} traces for {r1.iterations} passes"
    before = resident.trace_count()
    r2 = decompose(g, "semicore*", "batch", block_edges=256, backend="xla")
    assert resident.trace_count() - before == 0
    np.testing.assert_array_equal(r1.core, r2.core)


def test_compile_count_pallas_interpret():
    g = chung_lu(250, 900, gamma=2.3, seed=11)
    decompose(g, "semicore*", "batch", block_edges=64,
              backend="pallas-interpret")  # prime the jit cache
    before = resident.trace_count()
    r = decompose(g, "semicore*", "batch", block_edges=64,
                  backend="pallas-interpret")
    assert resident.trace_count() - before == 0
    assert r.iterations > 2


# -------------------------------------------------------------- trace parity
@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
def test_resident_pins_paper_example_batch_traces(backend):
    """The device-resident path must walk the paper's running example through
    the exact batch-schedule traces the numpy backend pins (Figs. 2/4/5)."""
    pinned = {
        "semicore": (36, 4, 4, 4),
        "semicore+": (26, 4, 4, 4),
        "semicore*": (11, 3, 3, 3),
    }
    for algo, (comps, iters, ebr, ntr) in pinned.items():
        r = decompose(paper_example_graph(), algo, "batch", block_edges=64,
                      pool_blocks=1, backend=backend)
        np.testing.assert_array_equal(r.core, [3, 3, 3, 3, 2, 2, 2, 2, 1])
        assert r.node_computations == comps, algo
        assert r.iterations == iters, algo
        assert r.edge_block_reads == ebr, algo
        assert r.node_table_reads == ntr, algo


def test_resident_kernel_block_report_matches_per_pass_path(monkeypatch):
    """The replayed pallas kernel-block activity must equal what the per-pass
    (legacy) path's begin_pass accounting reports."""
    g = chung_lu(250, 900, gamma=2.3, seed=11)
    res = decompose(g, "semicore*", "batch", block_edges=64,
                    backend="pallas-interpret")
    monkeypatch.setenv(resident.RESIDENT_ENV_VAR, "0")
    leg = decompose(g, "semicore*", "batch", block_edges=64,
                    backend="pallas-interpret")
    assert res.kernel_blocks_active == leg.kernel_blocks_active
    assert res.kernel_blocks_skipped == leg.kernel_blocks_skipped
    assert res.kernel_blocks_skipped > 0
    np.testing.assert_array_equal(res.core, leg.core)
    assert res.iterations == leg.iterations
    assert res.edge_block_reads == leg.edge_block_reads


def test_edgeless_graph_kernel_blocks_match_legacy(monkeypatch):
    """An edgeless table has no kernel blocks: the resident replay must not
    charge the padding block the legacy begin_pass guard skips."""
    from repro.graph import CSRGraph

    g = CSRGraph.from_edges(5, np.zeros((0, 2), np.int64))
    r = decompose(g, "semicore", "batch", block_edges=64,
                  backend="pallas-interpret")
    monkeypatch.setenv(resident.RESIDENT_ENV_VAR, "0")
    leg = decompose(g, "semicore", "batch", block_edges=64,
                    backend="pallas-interpret")
    assert (r.kernel_blocks_active, r.kernel_blocks_skipped) == \
        (leg.kernel_blocks_active, leg.kernel_blocks_skipped) == (0, 0)
    assert r.iterations == leg.iterations == 1


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
def test_legacy_per_pass_path_still_matches_numpy(monkeypatch, backend):
    """REPRO_DEVICE_RESIDENT=0 keeps the PR 3 per-pass loop alive and exact."""
    monkeypatch.setenv(resident.RESIDENT_ENV_VAR, "0")
    g = chung_lu(250, 900, gamma=2.3, seed=11)
    for algo in ("semicore", "semicore+", "semicore*"):
        ref = decompose(g, algo, "batch", block_edges=64, backend="numpy")
        r = decompose(g, algo, "batch", block_edges=64, backend=backend)
        np.testing.assert_array_equal(r.core, ref.core)
        assert r.iterations == ref.iterations
        assert r.edge_block_reads == ref.edge_block_reads
        assert r.node_table_reads == ref.node_table_reads


@pytest.mark.parametrize("chunk", ["1", "3", "32"])
def test_chunk_size_does_not_change_traces(monkeypatch, chunk):
    """The chunk length is pure scheduling: any value walks the same passes
    and replays the same accounting."""
    monkeypatch.setenv(resident.CHUNK_ENV_VAR, chunk)
    g = chung_lu(400, 1600, seed=3)
    ref = decompose(g, "semicore*", "batch", block_edges=64, backend="numpy")
    r = decompose(g, "semicore*", "batch", block_edges=64, backend="xla")
    np.testing.assert_array_equal(r.core, ref.core)
    np.testing.assert_array_equal(r.cnt, ref.cnt)
    assert r.iterations == ref.iterations
    assert r.edge_block_reads == ref.edge_block_reads
    assert r.updates_per_iter == ref.updates_per_iter
    assert r.computations_per_iter == ref.computations_per_iter


def test_superstep_chunk_parameter_threads_through():
    """The CoreGraphConfig.superstep_chunk knob reaches the resident runner
    through decompose and CoreMaintainer, overriding the env default."""
    g = chung_lu(300, 1200, seed=4)
    ref = decompose(g, "semicore*", "batch", block_edges=64, backend="numpy")
    r = decompose(g, "semicore*", "batch", block_edges=64, backend="xla",
                  superstep_chunk=2)
    np.testing.assert_array_equal(r.core, ref.core)
    assert r.iterations == ref.iterations
    assert r.edge_block_reads == ref.edge_block_reads
    m = CoreMaintainer(g, block_edges=64, backend="xla", superstep_chunk=2)
    e = g.edge_list()
    m.apply_batch([tuple(map(int, e[0]))], [(0, 250)])
    np.testing.assert_array_equal(m.core, imcore_bz(m.bg.materialize()))


# ------------------------------------------------------- warm settle parity
def test_warm_settle_resident_matches_numpy_settle():
    """The device-resident warm settle (exact-cnt prologue + SemiCore*
    passes, all on device) must match the numpy settle state-for-state and
    charge-for-charge."""
    g = chung_lu(300, 1200, seed=5)
    core0 = decompose(g, "semicore*", "batch", backend="numpy").core
    e = g.edge_list()

    def perturbed():
        bg = BufferedGraph(g)
        for i in range(6):
            assert bg.delete_edge(*map(int, e[i * 11]))
        ins = [(1, 250), (2, 251), (3, 252)]
        ni = sum(bg.insert_edge(u, v) for u, v in ins)
        return bg, ni

    bg_np, ni = perturbed()
    eng_np = HostEngine(bg_np, block_edges=64)
    r_np = warm_settle(eng_np, core0, ni, "numpy")
    bg_x, ni_x = perturbed()
    assert ni_x == ni
    eng_x = HostEngine(bg_x, block_edges=64)
    r_x = warm_settle(eng_x, core0, ni, "xla")
    np.testing.assert_array_equal(r_x.core, r_np.core)
    np.testing.assert_array_equal(r_x.cnt, r_np.cnt)
    assert r_x.iterations == r_np.iterations
    assert r_x.edge_block_reads == r_np.edge_block_reads
    assert r_x.node_table_reads == r_np.node_table_reads
    np.testing.assert_array_equal(r_x.core, imcore_bz(bg_x.materialize()))


# -------------------------------------------------------- structure caching
def test_structure_cache_reused_across_runs_and_invalidated_on_change():
    g = chung_lu(200, 800, seed=1)
    bg = BufferedGraph(g)
    eng = HostEngine(bg, block_edges=64)
    be = XLABackend()
    be.retain_structure = True
    r1 = run_batch(eng, "semicore*", be)
    r2 = run_batch(eng, "semicore+", be)
    assert be.structure_builds == 1  # second run re-uploaded nothing
    np.testing.assert_array_equal(r1.core, r2.core)
    u, v = map(int, g.edge_list()[0])
    assert bg.delete_edge(u, v)  # version bump
    r3 = run_batch(eng, "semicore*", be)
    assert be.structure_builds == 2
    np.testing.assert_array_equal(r3.core, imcore_bz(bg.materialize()))


@pytest.mark.parametrize("cls", [XLABackend,
                                 lambda: PallasBackend(interpret=True)])
def test_one_shot_run_drops_structure_on_unbind(cls):
    """decompose's memory guarantee: without retain_structure, no O(m)
    edge-table copy (host or device) survives the result."""
    be = cls()
    eng = HostEngine(chung_lu(150, 500, seed=2), block_edges=64)
    run_batch(eng, "semicore*", be)
    assert be._resident is None


def test_caller_supplied_backend_instance_is_not_mutated():
    """CoreMaintainer only retains structure on backends it created itself;
    a caller-supplied instance keeps its one-shot unbind guarantee."""
    g = chung_lu(150, 500, seed=3)
    be = XLABackend()
    m = CoreMaintainer(g, block_edges=64, backend=be)
    assert not be.retain_structure
    assert be._resident is None  # the initial decompose dropped it
    assert m.backend is be


def test_maintainer_rebuilds_structure_only_on_structural_change():
    g = chung_lu(200, 800, seed=7)
    m = CoreMaintainer(g, block_edges=64, backend="xla")
    assert m.backend.retain_structure
    assert m.backend.structure_builds == 1  # the initial decompose
    # a batch of pure no-ops applies nothing: no settle, no rebuild
    non_edge = next((u, v) for u in range(3) for v in range(100, 200)
                    if not m.bg.base.has_edge(u, v))
    s = m.apply_batch([non_edge], [])
    assert s.num_noops == 1 and s.num_deletes == 0
    assert m.backend.structure_builds == 1
    # a real batch bumps the version: exactly one rebuild for the settle
    e = m.bg.base.edge_list()
    s = m.apply_batch([tuple(map(int, e[3]))], [])
    assert s.num_deletes == 1
    assert m.backend.structure_builds == 2
    np.testing.assert_array_equal(m.core, imcore_bz(m.bg.materialize()))


# ------------------------------------------------------------- service path
def test_core_service_on_device_backend_stays_exact():
    g = chung_lu(220, 900, seed=9)
    svc = CoreService(g, block_edges=64, backend="xla")
    e = g.edge_list()
    svc.ingest([("-", *map(int, e[0])), ("-", *map(int, e[7])),
                ("+", 0, 100)])
    svc.ingest([("+", 2, 150), ("-", *map(int, e[21]))])
    np.testing.assert_array_equal(
        svc.maintainer.core, imcore_bz(svc.bg.materialize()))
    stats = svc.service_stats()
    assert stats["backend"] == "xla"
    assert stats["backend_structure_builds"] >= 1
