"""SPMD decomposition engine: correctness on 1 device + 8 virtual devices."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.graph import paper_example_graph, chung_lu, rmat
from repro.core.imcore import imcore_peel
from repro.core.distributed import distributed_decompose, shard_graph


def test_single_device_matches_oracle():
    for g in [paper_example_graph(), chung_lu(2000, 8000, seed=1), rmat(9, 8, seed=2)]:
        expect = imcore_peel(g)
        core, iters = distributed_decompose(g)
        np.testing.assert_array_equal(core, expect)
        assert 0 < iters < g.n


def test_warm_restart_from_upper_bound():
    """Monotone convergence: any upper-bound state is a valid warm start."""
    g = chung_lu(1000, 4000, seed=5)
    expect = imcore_peel(g)
    core0 = np.minimum(g.degrees(), expect + 2).astype(np.int32)  # valid UB
    core, _ = distributed_decompose(g, core0=core0)
    np.testing.assert_array_equal(core, expect)


def test_shard_balance():
    g = chung_lu(5000, 40000, seed=3)
    sg = shard_graph(g, 8)
    per_shard = sg.edge_mask.sum(axis=1)
    assert per_shard.max() <= 1.6 * per_shard.mean()  # balanced cuts
    assert per_shard.sum() == g.num_directed
    assert sg.owned_mask.sum() == g.n


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.graph import chung_lu, rmat
from repro.core.imcore import imcore_peel
from repro.core.distributed import distributed_decompose

assert len(jax.devices()) == 8
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
for g in [chung_lu(3000, 15000, seed=7), rmat(10, 6, seed=8)]:
    expect = imcore_peel(g)
    core, iters = distributed_decompose(g, mesh=mesh)
    assert np.array_equal(core, expect), "multi-device mismatch"
    assert iters > 0
print("MULTIDEV_OK")
"""


@pytest.mark.slow
def test_multi_device_8way():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-2000:]
