"""Test bootstrap: make ``repro`` importable without PYTHONPATH tweaks and
gate the optional ``hypothesis`` dev dependency behind a deterministic
fallback (hermetic images cannot reach an index; see repro.compat)."""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.compat import install_hypothesis_fallback

install_hypothesis_fallback()
