"""Guard: the committed dry-run artifacts cover every cell on both meshes."""
import glob
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "../benchmarks/results/dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun)")


def _cells():
    from repro.configs import get_config, shape_names, ARCH_IDS
    cells = [(a, s) for a in ARCH_IDS for s in shape_names(get_config(a))]
    cells.append(("semicore-webscale", "decompose"))
    return cells


@pytest.mark.parametrize("mesh", ["single_pod_16x16", "multi_pod_2x16x16"])
def test_all_cells_compiled_ok(mesh):
    cells = _cells()
    assert len(cells) == 41  # 40 assigned + the paper's own workload
    for arch, shape in cells:
        path = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}.json")
        assert os.path.exists(path), f"missing {arch}/{shape} on {mesh}"
        rec = json.load(open(path))
        assert rec.get("ok"), f"{arch}/{shape}/{mesh}: {rec.get('error')}"
        r = rec["roofline"]
        assert r["compute_s"] >= 0 and r["dominant"] in (
            "compute", "memory", "collective")


def test_clueweb_cell_reproduces_paper_memory_bound():
    """The paper's headline: Clueweb decomposition under ~4.2 GB of node
    state; per chip the replicated core array is n x 4 B = 3.9 GB."""
    path = os.path.join(RESULTS,
                        "semicore-webscale__decompose__single_pod_16x16.json")
    rec = json.load(open(path))
    assert rec["ok"]
    n = 978_408_098
    mm = rec["memory_model"]
    assert mm["args_bytes_per_chip"] >= n * 4       # replicated core state
    assert mm["fits_16GB_hbm"]                      # the paper's bound, per chip
