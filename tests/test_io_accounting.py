"""I/O-accounting invariants of the blocked edge table + buffer pool.

Pins the external-memory cost model: sequential scans cost exactly
``ceil(2m/B)``, the algorithm ladder reads monotonically fewer blocks, the
paper's exact Fig. 2/4/5 traces survive ``pool_blocks=1``, and the LRU pool
both zeroes on ``reset_io`` and never reads more as it grows (inclusion
property of LRU).
"""
import numpy as np
import pytest

from repro.core.imcore import imcore_bz
from repro.core.semicore import HostEngine, decompose
from repro.graph import BlockReader, CSRGraph, chung_lu, erdos_renyi, paper_example_graph

EXPECTED_CORES = np.array([3, 3, 3, 3, 2, 2, 2, 2, 1])


# ------------------------------------------------------------ full scans
@pytest.mark.parametrize("pool_blocks", [1, 2, 8])
@pytest.mark.parametrize("block_edges", [16, 64, 4096])
def test_sequential_full_scan_costs_ceil_2m_over_B(block_edges, pool_blocks):
    """One cold pass over all adjacency lists reads every block exactly once
    (compulsory misses only — no pool size can beat ceil(2m/B))."""
    g = erdos_renyi(300, 1100, seed=2)
    reader = BlockReader(g, block_edges, pool_blocks=pool_blocks)
    for v in range(g.n):
        reader.load_neighbors(v)
    assert reader.reads == -(-g.num_directed // block_edges)


def test_semicore_seq_per_pass_scan_cost():
    """Every SemiCore pass is one sequential full scan (seed invariant)."""
    g = erdos_renyi(400, 1600, seed=1)
    r = HostEngine(g, block_edges=64).semicore("seq")
    assert r.edge_block_reads == r.iterations * -(-g.num_directed // 64)


# ---------------------------------------------------- algorithm ladder
@pytest.mark.parametrize("seed", [0, 3, 9])
def test_block_read_ladder_star_leq_plus_leq_basic(seed):
    g = erdos_renyi(600, 2400, seed=seed)
    basic = HostEngine(g, block_edges=64).semicore("seq")
    plus = HostEngine(g, block_edges=64).semicore_plus("seq")
    star = HostEngine(g, block_edges=64).semicore_star("seq")
    expect = imcore_bz(g)
    for r in (basic, plus, star):
        np.testing.assert_array_equal(r.core, expect)
    assert star.edge_block_reads <= plus.edge_block_reads <= basic.edge_block_reads


# ------------------------------------------------- paper traces, pooled
def test_pool_blocks_1_reproduces_paper_traces():
    """pool_blocks=1 must leave the Fig. 2/4/5 traces bit-identical, node
    computations and block I/O alike."""
    for algo, comps, iters in (
        ("semicore", 36, 4),
        ("semicore+", 23, None),
        ("semicore*", 11, 3),
    ):
        default_eng = HostEngine(paper_example_graph(), block_edges=8)
        pooled_eng = HostEngine(paper_example_graph(), block_edges=8, pool_blocks=1)
        runs = {}
        for name, eng in (("default", default_eng), ("pool1", pooled_eng)):
            r = {
                "semicore": eng.semicore,
                "semicore+": eng.semicore_plus,
                "semicore*": eng.semicore_star,
            }[algo]("seq")
            np.testing.assert_array_equal(r.core, EXPECTED_CORES)
            assert r.node_computations == comps
            if iters is not None:
                assert r.iterations == iters
            runs[name] = r
        assert runs["default"].edge_block_reads == runs["pool1"].edge_block_reads
        assert runs["default"].node_table_reads == runs["pool1"].node_table_reads


# --------------------------------------------------------------- reset_io
def test_reset_io_zeroes_pool_state():
    g = erdos_renyi(100, 400, seed=4)
    reader = BlockReader(g, 32, pool_blocks=4)
    for v in range(g.n):
        reader.load_neighbors(v)
    assert reader.reads > 0 and len(reader.resident_blocks) > 0
    reader.reset_io()
    assert reader.reads == 0
    assert reader.node_table_reads == 0
    assert reader.hits == 0
    assert reader.resident_blocks == ()
    # the pool is actually cold, not just the counters: the next access pays
    reader.load_neighbors(0)
    assert reader.reads >= 1


def test_invalidate_drops_residency_but_keeps_counters():
    g = erdos_renyi(100, 400, seed=4)
    reader = BlockReader(g, 32, pool_blocks=4)
    reader.load_neighbors(0)
    before = reader.reads
    reader.invalidate()
    assert reader.reads == before and reader.resident_blocks == ()


# ------------------------------------------------------- pool monotonicity
@pytest.mark.parametrize("schedule", ["seq", "batch"])
def test_pool_growth_monotonically_reduces_reads(schedule):
    """On a skip-heavy SemiCore* run, block reads are non-increasing in
    pool_blocks (LRU inclusion property), and the fixpoint is unchanged."""
    g = chung_lu(2500, 10000, seed=6)
    num_blocks = -(-g.num_directed // 32)
    expect = None
    reads = []
    for pool in (1, 128, 256, 512, 1024):
        r = decompose(g, "semicore*", schedule, block_edges=32, pool_blocks=pool)
        if expect is None:
            expect = r.core
        else:
            np.testing.assert_array_equal(r.core, expect)
        reads.append(r.edge_block_reads)
    assert all(a >= b for a, b in zip(reads, reads[1:])), reads
    assert reads[-1] < reads[0]  # the pool must actually help
    # pool >= every block: only compulsory misses remain
    assert reads[-1] == num_blocks


def test_pool_hits_accounted():
    g = paper_example_graph()
    reader = BlockReader(g, 4, pool_blocks=2)
    reader.load_neighbors(0)
    reader.load_neighbors(0)
    assert reader.hits >= 1
    assert reader.bytes_read == reader.reads * 4 * 4 + reader.node_table_reads * 4 * 4
