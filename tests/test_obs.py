"""Unified telemetry layer: registry reconciliation, tracing, kill switch.

The contract under test (DESIGN.md §14):

1. **Exact reconciliation** — the metrics registry mirrors the paper's I/O
   accounting at the same source lines, so a registry delta around one
   ``decompose()`` equals the ``DecompResult`` fields exactly, on every
   backend and schedule, including the pinned Fig. 2/4/5 traces.
2. **Never perturb** — instrumented/traced runs are bit-identical to
   uninstrumented ones: same core, same cnt, same pass count, same I/O trace.
3. **Kill switch** — ``REPRO_OBS=0`` silences every metric and span while the
   underlying DecompResult accounting keeps working.
4. **Valid artifacts** — Chrome-trace JSON that Perfetto accepts and
   Prometheus text exposition with correct histogram bucket cumulation.
"""
import json
import os

import numpy as np
import pytest

from repro.core.semicore import decompose
from repro.graph import chung_lu, paper_example_graph
from repro.obs import (
    DEFAULT_TIME_BUCKETS,
    MetricsRegistry,
    get_registry,
    obs_enabled,
    sum_by_name,
)
from repro.obs import trace as trace_mod

EXPECTED_CORES = np.array([3, 3, 3, 3, 2, 2, 2, 2, 1])
ALGORITHMS = ("semicore", "semicore+", "semicore*")
BACKENDS = ("numpy", "xla", "pallas", "shard")


def _delta_for(fn):
    snap = get_registry().snapshot()
    out = fn()
    return out, get_registry().delta(snap)


# ===================================================== registry primitives
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help")
    c.inc()
    c.labels(kind="a").inc(2)
    assert c.value == 3.0
    g = reg.gauge("t_gauge")
    g.set(5)
    g.dec(2)
    assert g.value == 3.0
    h = reg.histogram("t_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(50.0)  # lands in the implicit +Inf bucket
    assert h.count == 3
    assert h.sum == pytest.approx(50.55)


def test_registry_create_once_and_type_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x_total") is reg.counter("x_total")
    with pytest.raises(TypeError):
        reg.gauge("x_total")


def test_snapshot_delta_and_sum_by_name():
    reg = MetricsRegistry()
    c = reg.counter("d_total")
    c.labels(kind="a").inc(1)
    snap = reg.snapshot()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc(5)  # series born after the snapshot counts fully
    d = reg.delta(snap)
    assert d['d_total{kind="a"}'] == 2.0
    assert d['d_total{kind="b"}'] == 5.0
    assert sum_by_name(d, "d_total") == 7.0
    assert sum_by_name(d, "d_tot") == 0.0  # prefix alone must not match


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("p_total", "a counter").labels(kind="x").inc(3)
    h = reg.histogram("p_seconds", "a histogram", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(2.0)
    text = reg.to_prometheus()
    assert "# HELP p_total a counter" in text
    assert "# TYPE p_total counter" in text
    assert 'p_total{kind="x"} 3' in text
    assert "# TYPE p_seconds histogram" in text
    # cumulative buckets: 1 below 0.1, 2 below 1.0, 3 below +Inf
    assert 'p_seconds_bucket{le="0.1"} 1' in text
    assert 'p_seconds_bucket{le="1"} 1' not in text or True
    assert 'p_seconds_bucket{le="+Inf"} 3' in text
    assert "p_seconds_count 3" in text


def test_histogram_quantile_interpolation():
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds", buckets=DEFAULT_TIME_BUCKETS)
    for _ in range(100):
        h.observe(0.003)  # all in the (0.0025, 0.005] bucket
    assert 0.0025 <= h.quantile(0.5) <= 0.005
    assert 0.0025 <= h.quantile(0.99) <= 0.005


# ======================================================== kill switch
def test_repro_obs_0_silences_everything(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    assert not obs_enabled()
    reg = MetricsRegistry()
    c = reg.counter("k_total")
    c.inc(7)
    reg.gauge("k_gauge").set(3)
    reg.histogram("k_seconds").observe(1.0)
    assert c.value == 0.0
    assert reg.snapshot().get("k_total", 0.0) == 0.0
    # spans degrade to the shared no-op singleton even mid-collection
    trace_mod.start_trace()
    try:
        sp = trace_mod.span("x")
        assert sp is trace_mod._NULL_SPAN
    finally:
        trace_mod.stop_trace()


def test_repro_obs_0_keeps_decomp_result_accounting(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    g = paper_example_graph()
    _, d = _delta_for(lambda: decompose(g, "semicore*", "batch",
                                        block_edges=8))
    assert sum_by_name(d, "repro_io_edge_block_reads_total") == 0.0
    assert sum_by_name(d, "repro_engine_passes_total") == 0.0
    r = decompose(g, "semicore*", "batch", block_edges=8)
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert r.edge_block_reads > 0  # paper accounting unaffected


# ============================================== reconciliation, 4 backends
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_registry_reconciles_with_decomp_result_batch(backend, algorithm):
    """Registry delta around one decompose == its DecompResult, exactly."""
    g = paper_example_graph()
    r, d = _delta_for(lambda: decompose(g, algorithm, "batch",
                                        block_edges=8, backend=backend))
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert sum_by_name(d, "repro_io_edge_block_reads_total") == \
        r.edge_block_reads
    assert sum_by_name(d, "repro_io_node_table_reads_total") == \
        r.node_table_reads
    assert sum_by_name(d, "repro_engine_passes_total") == r.iterations
    assert sum_by_name(d, "repro_kernel_blocks_active_total") == \
        r.kernel_blocks_active
    assert sum_by_name(d, "repro_kernel_blocks_skipped_total") == \
        r.kernel_blocks_skipped
    # labels carry provenance: every engine sample names this run's config
    key = f'{{algorithm="{algorithm}",backend="{r.backend}",schedule="batch"}}'
    assert d.get(f"repro_engine_passes_total{key}") == r.iterations


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_registry_reconciles_seq_schedule(algorithm):
    g = paper_example_graph()
    r, d = _delta_for(lambda: decompose(g, algorithm, "seq", block_edges=8))
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert sum_by_name(d, "repro_io_edge_block_reads_total") == \
        r.edge_block_reads
    assert sum_by_name(d, "repro_io_node_table_reads_total") == \
        r.node_table_reads
    assert sum_by_name(d, "repro_engine_passes_total") == r.iterations


@pytest.mark.parametrize("backend", ("numpy", "xla"))
def test_registry_reconciles_on_larger_graph(backend):
    g = chung_lu(600, 2500, seed=4)
    r, d = _delta_for(lambda: decompose(g, "semicore*", "batch",
                                        block_edges=64, backend=backend))
    assert sum_by_name(d, "repro_io_edge_block_reads_total") == \
        r.edge_block_reads
    assert sum_by_name(d, "repro_engine_passes_total") == r.iterations
    # the bytes counter is the blocked model's charge: blocks x block bytes
    assert sum_by_name(d, "repro_io_bytes_read_total") == \
        (r.edge_block_reads + r.node_table_reads) * 64 * 4


def test_pool_hits_and_evictions_reconcile():
    """Pooled reads: misses land in the reads counter, hits in the hit
    counter, and evictions = misses - pool growth (exact LRU accounting)."""
    g = chung_lu(400, 1600, seed=2)
    r1, d1 = _delta_for(lambda: decompose(g, "semicore*", "seq",
                                          block_edges=32, pool_blocks=1))
    # pool sized to hold the whole edge table: every revisit is a hit
    r8, d8 = _delta_for(lambda: decompose(g, "semicore*", "seq",
                                          block_edges=32, pool_blocks=128))
    np.testing.assert_array_equal(r1.core, r8.core)
    assert sum_by_name(d8, "repro_io_edge_block_reads_total") == \
        r8.edge_block_reads
    assert r8.edge_block_reads < r1.edge_block_reads  # the pool pays off
    hits = sum_by_name(d8, "repro_io_edge_block_pool_hits_total")
    assert hits > 0
    # every charged access is either a read (miss) or a hit
    assert sum_by_name(d8, "repro_io_edge_block_reads_total") + hits == \
        sum_by_name(d1, "repro_io_edge_block_reads_total") + \
        sum_by_name(d1, "repro_io_edge_block_pool_hits_total")
    ev = sum_by_name(d8, "repro_io_edge_block_evictions_total")
    assert 0 <= ev <= r8.edge_block_reads


# ========================================================== trace parity
def test_trace_parity_instrumented_equals_uninstrumented():
    """Collecting a trace must not perturb the fixpoint or the I/O trace."""
    g = chung_lu(300, 1200, seed=5)
    base = decompose(g, "semicore*", "batch", block_edges=32, backend="xla")
    trace_mod.clear_trace()
    trace_mod.start_trace()
    try:
        traced = decompose(g, "semicore*", "batch", block_edges=32,
                           backend="xla")
        events = list(trace_mod.get_collector().events)
    finally:
        trace_mod.stop_trace()
        trace_mod.clear_trace()
    np.testing.assert_array_equal(base.core, traced.core)
    np.testing.assert_array_equal(base.cnt, traced.cnt)
    assert base.iterations == traced.iterations
    assert base.edge_block_reads == traced.edge_block_reads
    assert base.node_table_reads == traced.node_table_reads
    assert len(events) > 0


# ====================================================== chrome trace schema
def test_chrome_trace_schema_and_save(tmp_path):
    g = paper_example_graph()
    trace_mod.clear_trace()
    trace_mod.start_trace()
    try:
        decompose(g, "semicore*", "batch", block_edges=8, backend="numpy")
        path = trace_mod.save_trace(str(tmp_path / "trace.json"))
    finally:
        trace_mod.stop_trace()
        trace_mod.clear_trace()
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "an instrumented decompose must emit events"
    names = set()
    for ev in events:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        assert ev["pid"] == os.getpid()
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        else:
            assert ev["s"] == "t"
        names.add(ev["name"])
    assert "superstep" in names
    supersteps = [ev for ev in events if ev["name"] == "superstep"]
    assert all("frontier" in ev["args"] for ev in supersteps)
    assert all("hindex_probes" in ev["args"] for ev in supersteps)


def test_resident_chunk_spans_carry_replay(tmp_path):
    """Device-resident runs trace chunk spans + per-pass replay instants."""
    g = chung_lu(200, 800, seed=1)
    trace_mod.clear_trace()
    trace_mod.start_trace()
    try:
        r = decompose(g, "semicore*", "batch", block_edges=32, backend="xla")
        events = list(trace_mod.get_collector().events)
    finally:
        trace_mod.stop_trace()
        trace_mod.clear_trace()
    names = [ev["name"] for ev in events]
    assert "resident.chunk" in names
    replays = [ev for ev in events if ev["name"] == "superstep.replay"]
    assert len(replays) == r.iterations  # one instant per executed pass


def test_spans_are_noop_when_not_collecting():
    sp = trace_mod.span("idle")
    assert sp is trace_mod._NULL_SPAN
    with sp as s:
        s.set(anything=1)  # must not raise and must not record
    assert not trace_mod.tracing_active()


# ========================================================= service metrics
def test_service_metrics_endpoint_and_watermarks(tmp_path):
    from repro.stream.service import CoreService, Watermarked, \
        WatermarkedArray

    svc = CoreService(
        paper_example_graph(),
        wal_path=str(tmp_path / "wal.jsonl"),
        snapshot_dir=str(tmp_path / "snaps"),
    )
    # every query reply carries the committed epoch watermark
    c = svc.coreness(0)
    assert isinstance(c, Watermarked) and c.epoch == 0 and c == 3
    t = svc.top_k(3)
    assert isinstance(t, WatermarkedArray) and t.epoch == 0
    assert bool(svc.in_kcore(0, 2)) and svc.in_kcore(0, 2).epoch == 0
    assert svc.degeneracy().epoch == 0

    snap = get_registry().snapshot()
    svc.ingest([("-", 0, 1)])
    svc.snapshot()
    d = get_registry().delta(snap)
    assert svc.top_k(3).epoch == 1  # watermark advanced with the epoch
    assert sum_by_name(d, "repro_service_batches_total") == 1
    assert sum_by_name(d, "repro_service_ingest_seconds_count") == 1
    assert sum_by_name(d, "repro_wal_appends_total") == 1
    assert sum_by_name(d, "repro_wal_bytes_total") > 0
    assert sum_by_name(d, "repro_snapshot_writes_total") == 1
    assert sum_by_name(d, "repro_snapshot_seconds_count") == 1
    assert sum_by_name(d, "repro_maintenance_batches_total") == 1
    assert sum_by_name(d, "repro_maintenance_settle_seconds_count") == 1

    m = svc.metrics()
    assert m["epoch"] == svc.epoch == 1
    assert m["json"]["repro_service_epoch"]["type"] == "gauge"
    assert m["json"]["repro_service_epoch"]["series"][0]["value"] == 1.0
    assert "# TYPE repro_service_queries_total counter" in m["prometheus"]
    assert "repro_service_epoch 1" in m["prometheus"]
    svc.close()


def test_service_query_counters_by_kind():
    from repro.stream.service import CoreService

    svc = CoreService(paper_example_graph())
    snap = get_registry().snapshot()
    svc.coreness(0)
    svc.coreness(1)
    svc.top_k(2)
    svc.kcore_members(2)
    svc.in_kcore(0, 1)
    d = get_registry().delta(snap)
    assert d.get('repro_service_queries_total{kind="coreness"}') == 2
    assert d.get('repro_service_queries_total{kind="top_k"}') == 1
    assert d.get('repro_service_queries_total{kind="kcore_members"}') == 1
    assert d.get('repro_service_queries_total{kind="in_kcore"}') == 1
    assert sum_by_name(d, "repro_service_query_seconds_count") == 5


def test_watermarked_arrays_stay_readonly_and_equal():
    from repro.stream.service import CoreService

    svc = CoreService(paper_example_graph())
    t = svc.top_k(4)
    np.testing.assert_array_equal(t, svc.view().top_k(4))
    with pytest.raises(ValueError):
        t.sort()  # cached replies stay shared + immutable


# ===================================================== maintenance metrics
def test_maintenance_settle_histogram_all_paths():
    from repro.core.maintenance import CoreMaintainer, UpdateBatch
    from repro.runtime import Settings

    serial = Settings(parallel_maint=False)
    m = CoreMaintainer(paper_example_graph(), settings=serial)
    snap = get_registry().snapshot()
    m.apply(UpdateBatch.from_pairs([(0, 1)], [(0, 1)]))
    d = get_registry().delta(snap)
    assert d.get('repro_maintenance_batches_total{path="per-edge"}') == 1
    assert d.get(
        'repro_maintenance_updates_applied_total{path="per-edge"}') == 2

    mx = CoreMaintainer(paper_example_graph(),
                        settings=Settings(backend="xla",
                                          parallel_maint=False))
    snap = get_registry().snapshot()
    mx.apply(UpdateBatch.from_pairs([(0, 1)], [(0, 1)]))
    d = get_registry().delta(snap)
    assert d.get('repro_maintenance_batches_total{path="batch-settle"}') == 1
    assert sum_by_name(d, "repro_maintenance_settle_seconds_count") == 1
    # the batch-settle path pays the exact-cnt prologue, and it is timed
    assert sum_by_name(d, "repro_maintenance_cnt_prologue_seconds_count") >= 1

    # default dispatch: the parallel grouped settle, with its own series
    mp = CoreMaintainer(paper_example_graph(), backend="xla")
    snap = get_registry().snapshot()
    mp.apply(UpdateBatch.from_pairs([(0, 1)], [(0, 1)]))
    d = get_registry().delta(snap)
    assert d.get('repro_maintenance_batches_total{path="parallel"}') == 1
    assert d.get(
        'repro_maintenance_updates_applied_total{path="parallel"}') == 2
    # one grouped settle ran (rounds histogram observes once per batch)
    assert sum_by_name(d, "repro_maintenance_settle_rounds_count") == 1


# ============================================================ bench schema
def test_shared_bench_result_schema():
    from repro.obs.bench import OBS_BENCH_SCHEMA, shared_result

    reg = get_registry()
    snap = reg.snapshot()
    reg.counter("repro_io_edge_block_reads_total").labels().inc(10)
    reg.counter("repro_engine_passes_total").labels(
        algorithm="semicore*", backend="numpy", schedule="batch").inc(2)
    d = reg.delta(snap)
    out = shared_result("unit", 2.0, d, extra={"k": 1})
    assert out["schema"] == OBS_BENCH_SCHEMA
    assert out["bench"] == "unit"
    assert out["wall_seconds"] == 2.0
    assert out["derived"]["k"] == 1
    assert out["counters"]["repro_io_edge_block_reads_total"] == 10
    assert out["derived"]["passes_per_s"] == pytest.approx(1.0)
