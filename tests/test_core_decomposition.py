"""Core decomposition: faithfulness to the paper + correctness vs IMCore."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import CSRGraph, paper_example_graph, chung_lu, rmat, erdos_renyi
from repro.core.imcore import imcore_bz, imcore_peel
from repro.core.semicore import HostEngine, decompose

EXPECTED_CORES = np.array([3, 3, 3, 3, 2, 2, 2, 2, 1])


def test_paper_example_graph_shape():
    g = paper_example_graph()
    assert g.n == 9 and g.m == 15
    np.testing.assert_array_equal(g.degrees(), [3, 3, 4, 6, 3, 5, 3, 2, 1])


def test_imcore_on_paper_example():
    g = paper_example_graph()
    np.testing.assert_array_equal(imcore_bz(g), EXPECTED_CORES)
    np.testing.assert_array_equal(imcore_peel(g), EXPECTED_CORES)


# ---------------------------------------------------------------- Fig. 2/4/5
def test_semicore_seq_matches_fig2():
    """Algorithm 3 on Fig. 1: 4 iterations x 9 nodes = 36 computations."""
    r = HostEngine(paper_example_graph()).semicore("seq")
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert r.iterations == 4
    assert r.node_computations == 36


def test_semicore_plus_seq_matches_fig4():
    """Algorithm 4 on Fig. 1: 23 node computations (Example 4.2)."""
    r = HostEngine(paper_example_graph()).semicore_plus("seq")
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert r.node_computations == 23


def test_semicore_star_seq_matches_fig5():
    """Algorithm 5 on Fig. 1: 3 iterations, 11 node computations (Example 4.3)."""
    r = HostEngine(paper_example_graph()).semicore_star("seq")
    np.testing.assert_array_equal(r.core, EXPECTED_CORES)
    assert r.iterations == 3
    assert r.node_computations == 11
    # Example 4.3: after convergence cnt(v5)=4? -- check invariant instead:
    # cnt(v) must equal |{u in nbr(v): core(u) >= core(v)}| >= core(v)
    g = paper_example_graph()
    for v in range(g.n):
        exact = int((r.core[g.neighbors(v)] >= r.core[v]).sum())
        assert r.cnt[v] == exact
        assert r.cnt[v] >= r.core[v]


def test_semicore_star_fewer_computations_than_plus_than_basic():
    g = chung_lu(2000, 8000, seed=3)
    basic = HostEngine(g).semicore("seq")
    plus = HostEngine(g).semicore_plus("seq")
    star = HostEngine(g).semicore_star("seq")
    assert star.node_computations <= plus.node_computations <= basic.node_computations
    assert star.edge_block_reads <= basic.edge_block_reads


# ------------------------------------------------------------- correctness
@pytest.mark.parametrize("algorithm", ["semicore", "semicore+", "semicore*"])
@pytest.mark.parametrize("schedule", ["seq", "batch"])
def test_algorithms_match_oracle_random(algorithm, schedule):
    for seed in range(3):
        g = erdos_renyi(300, 900, seed=seed)
        expect = imcore_peel(g)
        r = decompose(g, algorithm, schedule, block_edges=64)
        np.testing.assert_array_equal(r.core, expect, err_msg=f"{algorithm}/{schedule}")


@pytest.mark.parametrize("gen", [chung_lu, erdos_renyi])
def test_batch_star_on_skewed(gen):
    g = gen(1500, 6000, seed=11)
    expect = imcore_bz(g)
    np.testing.assert_array_equal(imcore_peel(g), expect)
    r = decompose(g, "semicore*", "batch", block_edges=128)
    np.testing.assert_array_equal(r.core, expect)


def test_rmat_all_algorithms_agree():
    g = rmat(9, 8, seed=5)
    expect = imcore_peel(g)
    for algo in ["semicore", "semicore+", "semicore*"]:
        r = decompose(g, algo, "batch")
        np.testing.assert_array_equal(r.core, expect, err_msg=algo)


# ---------------------------------------------------------------- property
@st.composite
def random_graph(draw):
    n = draw(st.integers(2, 60))
    max_e = min(n * (n - 1) // 2, 150)
    num_e = draw(st.integers(0, max_e))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=num_e,
            max_size=num_e,
        )
    )
    return n, edges


@given(random_graph())
@settings(max_examples=120, deadline=None)
def test_property_semicore_star_equals_imcore(ng):
    n, edges = ng
    g = CSRGraph.from_edges(n, np.array(edges, dtype=np.int64).reshape(-1, 2))
    expect = imcore_bz(g)
    for schedule in ("seq", "batch"):
        r = decompose(g, "semicore*", schedule, block_edges=16)
        np.testing.assert_array_equal(r.core, expect)
        # the k-core property: induced subgraph of {core >= k} has min degree >= k
    for k in range(1, int(expect.max()) + 1):
        nodes = np.flatnonzero(expect >= k)
        sub = g.induced_subgraph(nodes)
        if sub.n:
            assert (sub.degrees() >= k).all() or sub.m == 0 and k > 0 and (expect[nodes] >= k).all()


@given(random_graph())
@settings(max_examples=60, deadline=None)
def test_property_kcore_minimum_degree(ng):
    """G_k = induced({v: core(v) >= k}) has min degree >= k (Lemma 2.1)."""
    n, edges = ng
    g = CSRGraph.from_edges(n, np.array(edges, dtype=np.int64).reshape(-1, 2))
    core = imcore_bz(g)
    for k in range(1, int(core.max()) + 1):
        nodes = np.flatnonzero(core >= k)
        sub = g.induced_subgraph(nodes)
        assert sub.n == len(nodes)
        if len(nodes):
            assert sub.degrees().min() >= k


def test_io_accounting_read_only_sequential():
    """SemiCore scans every block once per pass: reads == l * ceil(2m/B)."""
    g = erdos_renyi(400, 1600, seed=1)
    eng = HostEngine(g, block_edges=64)
    r = eng.semicore("seq")
    blocks = -(-g.num_directed // 64)
    assert r.edge_block_reads == r.iterations * blocks
