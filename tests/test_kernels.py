"""Per-kernel shape/dtype sweeps + hypothesis properties vs ref.py oracles."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import segment_sum, embedding_bag, flash_decode
from repro.kernels import ref


# ------------------------------------------------------------------ segsum
@pytest.mark.parametrize("E,D,n", [(64, 8, 10), (512, 128, 100), (1000, 16, 7),
                                   (2048, 1, 2048), (3, 4, 5), (513, 32, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segsum_sweep(E, D, n, dtype):
    rng = np.random.default_rng(E * D + n)
    rows = np.sort(rng.integers(0, n, size=E)).astype(np.int32)
    if dtype == jnp.int32:
        vals = rng.integers(-5, 6, size=(E, D)).astype(np.int32)
    else:
        vals = rng.normal(size=(E, D)).astype(np.float32)
    if D == 1:
        vals = vals[:, 0]
    got = segment_sum(jnp.asarray(vals), jnp.asarray(rows), n, block_edges=128)
    want = ref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(rows), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_segsum_bfloat16():
    rng = np.random.default_rng(0)
    rows = np.sort(rng.integers(0, 50, size=512)).astype(np.int32)
    vals = rng.normal(size=(512, 64)).astype(np.float32)
    got = segment_sum(jnp.asarray(vals, jnp.bfloat16), jnp.asarray(rows), 50,
                      block_edges=128)
    want = ref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(rows), 50)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=2e-2, atol=2e-1
    )


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_segsum_property(data):
    E = data.draw(st.integers(1, 300))
    n = data.draw(st.integers(1, 50))
    D = data.draw(st.sampled_from([1, 3, 8]))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    rows = np.sort(rng.integers(0, n, size=E)).astype(np.int32)
    vals = rng.normal(size=(E, D)).astype(np.float32)
    got = segment_sum(jnp.asarray(vals), jnp.asarray(rows), n, block_edges=64)
    want = ref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(rows), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)
    # linearity: segsum(a+b) == segsum(a) + segsum(b)
    vals2 = rng.normal(size=(E, D)).astype(np.float32)
    lhs = segment_sum(jnp.asarray(vals + vals2), jnp.asarray(rows), n, block_edges=64)
    rhs = got + segment_sum(jnp.asarray(vals2), jnp.asarray(rows), n, block_edges=64)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("N,D,B,L", [(100, 16, 4, 3), (1000, 64, 8, 10),
                                     (37, 128, 16, 5), (10, 8, 1, 1)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(N, D, B, L, mode):
    rng = np.random.default_rng(N + B)
    table = rng.normal(size=(N, D)).astype(np.float32)
    idx = rng.integers(-1, N, size=(B, L)).astype(np.int32)  # -1 = masked
    w = rng.uniform(0.5, 2.0, size=(B, L)).astype(np.float32)
    got = embedding_bag(jnp.asarray(table), jnp.asarray(idx), jnp.asarray(w), mode=mode)
    want = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx), jnp.asarray(w), mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_embedding_bag_unweighted_default():
    rng = np.random.default_rng(3)
    table = rng.normal(size=(50, 32)).astype(np.float32)
    idx = rng.integers(0, 50, size=(6, 4)).astype(np.int32)
    got = embedding_bag(jnp.asarray(table), jnp.asarray(idx))
    want = np.asarray(table)[idx].sum(axis=1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- flash_decode
@pytest.mark.parametrize("Hkv,G,S,d,blk", [(2, 4, 1024, 64, 256), (8, 1, 512, 128, 128),
                                           (1, 8, 2048, 64, 512), (4, 7, 512, 32, 128)])
def test_flash_decode_sweep(Hkv, G, S, d, blk):
    rng = np.random.default_rng(S + d)
    q = rng.normal(size=(Hkv * G, d)).astype(np.float32)
    k = rng.normal(size=(Hkv, S, d)).astype(np.float32)
    v = rng.normal(size=(Hkv, S, d)).astype(np.float32)
    for cache_len in [S, S - 17, blk + 1, 1]:
        got = flash_decode(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           jnp.int32(cache_len), block_kv=blk)
        want = ref.flash_decode_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                    jnp.int32(cache_len))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16():
    rng = np.random.default_rng(9)
    q = rng.normal(size=(8, 64)).astype(np.float32)
    k = rng.normal(size=(2, 512, 64)).astype(np.float32)
    v = rng.normal(size=(2, 512, 64)).astype(np.float32)
    got = flash_decode(jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
                       jnp.asarray(v, jnp.bfloat16), jnp.int32(511), block_kv=128)
    want = ref.flash_decode_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                jnp.int32(511))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_segsum_is_the_localcore_count_primitive():
    """The kernel computes Eq. 1/2 neighbor counts exactly."""
    from repro.graph import paper_example_graph
    g = paper_example_graph()
    src, dst = g.directed_pairs()
    core = np.array([3, 3, 3, 3, 2, 2, 2, 2, 1], np.int32)
    contrib = (core[dst] >= core[src]).astype(np.int32)
    cnt = segment_sum(jnp.asarray(contrib), jnp.asarray(src.astype(np.int32)), g.n,
                      block_edges=64)
    for v in range(g.n):
        exact = int((core[g.neighbors(v)] >= core[v]).sum())
        assert int(cnt[v]) == exact


# ------------------------------------------------------- block-skipping segsum
def test_segsum_active_skips_inactive_blocks_exactly():
    """SemiCore* discipline at the kernel level: skipped blocks contribute 0,
    active blocks match the plain segment sum."""
    from repro.kernels import segment_sum_active
    rng = np.random.default_rng(7)
    E, D, n, BE = 512, 8, 40, 64
    rows = np.sort(rng.integers(0, n, size=E)).astype(np.int32)
    vals = rng.normal(size=(E, D)).astype(np.float32)
    node_active = (rng.random(n) < 0.4)
    got = segment_sum_active(jnp.asarray(vals), jnp.asarray(rows),
                             jnp.asarray(node_active), n, block_edges=BE)
    # reference: zero out whole blocks with no active rows
    blk = rows.reshape(-1, BE)
    blk_act = node_active[blk].any(axis=1)
    masked = vals * np.repeat(blk_act, BE)[:, None]
    want = ref.segment_sum_ref(jnp.asarray(masked), jnp.asarray(rows), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # all-active degenerates to the plain kernel
    got_all = segment_sum_active(jnp.asarray(vals), jnp.asarray(rows),
                                 jnp.ones(n, bool), n, block_edges=BE)
    want_all = ref.segment_sum_ref(jnp.asarray(vals), jnp.asarray(rows), n)
    np.testing.assert_allclose(np.asarray(got_all), np.asarray(want_all),
                               rtol=1e-5, atol=1e-4)


def test_segsum_active_localcore_frontier_semantics():
    """Counts over only-frontier-touching blocks reproduce exact cnt values
    for frontier nodes (the SemiCore* per-superstep contract)."""
    from repro.kernels import segment_sum_active
    from repro.graph import chung_lu
    g = chung_lu(300, 1500, seed=3)
    src, dst = g.directed_pairs()
    core = g.degrees().astype(np.int32)
    frontier = np.zeros(g.n, bool)
    frontier[:50] = True  # contiguous CSR rows -> block skipping is real
    contrib = (core[dst] >= core[src]).astype(np.int32)
    got = segment_sum_active(jnp.asarray(contrib), jnp.asarray(src.astype(np.int32)),
                             jnp.asarray(frontier), g.n, block_edges=128)
    for v in range(50):
        exact = int((core[g.neighbors(v)] >= core[v]).sum())
        blk_lo = int(g.indptr[v]) // 128
        blk_hi = int(g.indptr[v + 1] - 1) // 128
        blocks_active = all(
            frontier[src[b * 128:(b + 1) * 128]].any()
            for b in range(blk_lo, blk_hi + 1))
        if blocks_active:
            assert int(got[v]) == exact
