"""Training runtime: optimizer codecs, checkpoint/restore/resume, train loops."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update, q8_encode,
                         q8_decode)
from repro.train import save, restore, latest_step, CheckpointManager, TrainLoop


def test_q8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    for shape in [(64,), (100, 37), (8, 16, 5)]:
        x = jnp.asarray(rng.normal(size=shape) * rng.uniform(0.01, 10))
        q, s = q8_encode(x)
        y = q8_decode(q, s, shape)
        err = np.abs(np.asarray(y - x)) / (np.abs(np.asarray(x)).max() + 1e-9)
        assert err.max() < 1.0 / 64  # block-absmax int8: < 2 ulp of 1/127


@pytest.mark.parametrize("quant", [False, True])
def test_adamw_descends_quadratic(quant):
    cfg = AdamWConfig(lr=0.05, quantize_moments=quant)
    params = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32, 4)))}
    target = jnp.ones((32, 4))
    state = adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        g = jax.grad(loss_fn)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(loss_fn(params)) < l0 * 0.1


def test_quantized_states_are_smaller():
    params = {"w": jnp.zeros((1024, 1024))}
    plain = adamw_init(params, AdamWConfig())
    quant = adamw_init(params, AdamWConfig(quantize_moments=True))

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    assert nbytes(quant) < nbytes(plain) / 3.5  # ~8x fp32 -> int8 (+scales)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    got, step = restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10.0))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_gc_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(5):
        m.save(s, {"x": jnp.full((4,), s)})
    m.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    (got, step) = m.restore_latest({"x": jnp.zeros((4,))})
    assert step == 4 and float(got["x"][0]) == 4


def test_trainloop_loss_descends_and_resumes(tmp_path):
    loop = TrainLoop("qwen3-0.6b", reduced=True, checkpoint_dir=str(tmp_path),
                     checkpoint_every=10, log_every=0)
    r1 = loop.run(20, resume=False)
    assert r1["losses"][-1] < r1["losses"][0]          # it learns
    # resume continues from the saved step
    loop2 = TrainLoop("qwen3-0.6b", reduced=True, checkpoint_dir=str(tmp_path),
                      checkpoint_every=10, log_every=0)
    r2 = loop2.run(5)
    assert np.isfinite(r2["losses"]).all()
    assert latest_step(str(tmp_path)) >= 23


def test_trainloop_gnn_and_recsys():
    for arch in ["gcn-cora", "mind"]:
        r = TrainLoop(arch, reduced=True, log_every=0).run(8, resume=False)
        assert np.isfinite(r["losses"]).all(), arch
        assert r["losses"][-1] <= r["losses"][0] * 1.5, arch


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint saved unsharded restores under a new sharding (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save(str(tmp_path), 0, tree)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = restore(str(tmp_path), tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
