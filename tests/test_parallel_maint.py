"""Differential battery for the parallel grouped maintenance settle.

The contract under test (DESIGN.md §18): ``CoreMaintainer.apply`` with the
parallel path enabled lands on ``(core, cnt)`` **bit-identical** to the
serial oracle — the paper's per-edge seq maintenance — for every batch
shape and every compute backend, because both are exact algorithms for the
same fixpoint.  The battery runs 7 differential families × 4 backends,
plus adversarial batches, a candidate-bound soundness check, replica
replay parity, and the deprecation-shim equivalences.
"""
import warnings

import numpy as np
import pytest

import repro.core.parallel_maint as pm
from repro.core import CoreMaintainer, Delete, Insert, UpdateBatch
from repro.core.imcore import imcore_bz
from repro.graph import chung_lu, erdos_renyi
from repro.graph.updates import BufferedGraph
from repro.runtime import Settings
from repro.stream import CoreReplica, CoreService, WriteAheadLog

BACKENDS = ["numpy", "xla", "pallas-interpret", "shard"]

# the interpreter-mode pallas substrate is orders of magnitude slower than
# compiled paths; every family shrinks its graph for it.
_SIZES = {"pallas-interpret": (90, 300)}
_DEFAULT_SIZE = (250, 1000)


def _graph(backend, seed):
    n, m = _SIZES.get(backend, _DEFAULT_SIZE)
    return chung_lu(n, m, seed=seed), n


def _live_edges(g):
    return set(map(tuple, np.sort(g.edge_list(), axis=1)))


def _rand_missing(rng, n, live):
    while True:
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u == v:
            continue
        e = (min(u, v), max(u, v))
        if e not in live:
            return e


# --------------------------------------------------------------- families
def _fam_insert_sparse(g, n, rng):
    live = _live_edges(g)
    ops = []
    for _ in range(16):
        e = _rand_missing(rng, n, live)
        live.add(e)
        ops.append(Insert(*e))
    return [ops]


def _fam_delete_sparse(g, n, rng):
    live = sorted(_live_edges(g))
    idx = rng.choice(len(live), 16, replace=False)
    return [[Delete(*live[i]) for i in idx]]


def _fam_mixed(g, n, rng):
    live = _live_edges(g)
    out = []
    for _ in range(2):  # two consecutive batches: state carries over
        ops = []
        for _ in range(16):
            if rng.random() < 0.5 and live:
                e = sorted(live)[int(rng.integers(len(live)))]
                live.discard(e)
                ops.append(Delete(*e))
            else:
                e = _rand_missing(rng, n, live)
                live.add(e)
                ops.append(Insert(*e))
        out.append(ops)
    return out


def _fam_clique_lift(g, n, rng):
    """Complete a clique among low-degree nodes: multi-level rises that
    force saturation re-root rounds."""
    deg = np.zeros(n, dtype=int)
    e = g.edge_list()
    np.add.at(deg, e[:, 0], 1)
    np.add.at(deg, e[:, 1], 1)
    nodes = [int(v) for v in np.argsort(deg)[:7]]
    live = _live_edges(g)
    ops = []
    for i, u in enumerate(nodes):
        for v in nodes[i + 1:]:
            edge = (min(u, v), max(u, v))
            if edge not in live:
                ops.append(Insert(*edge))
                live.add(edge)
    return [ops]


def _fam_hub_churn(g, n, rng):
    """Every op incident to one hub: maximally-overlapping candidate sets
    (one big group, not many independent ones)."""
    deg = np.zeros(n, dtype=int)
    e = g.edge_list()
    np.add.at(deg, e[:, 0], 1)
    np.add.at(deg, e[:, 1], 1)
    hub = int(np.argmax(deg))
    live = _live_edges(g)
    hub_edges = sorted(e for e in live if hub in e)
    ops = [Delete(*e) for e in hub_edges[:6]]
    for e in hub_edges[:6]:
        live.discard(e)
    for _ in range(6):
        while True:
            v = int(rng.integers(n))
            edge = (min(hub, v), max(hub, v))
            if v != hub and edge not in live:
                break
        live.add(edge)
        ops.append(Insert(*edge))
    return [ops]


def _fam_cascade_delete(g, n, rng):
    """Delete edges of max-core nodes: the deepest drop cascades, the whole
    settle mass lands in the delete prefix masks."""
    core = imcore_bz(g)
    kmax = int(core.max())
    top = set(np.flatnonzero(core == kmax).tolist())
    live = sorted(_live_edges(g))
    ops = [Delete(*e) for e in live if e[0] in top or e[1] in top][:16]
    return [ops]


def _fam_reinsert(g, n, rng):
    """Delete edges and re-insert the same edges inside one batch (plus
    fresh inserts): the structural net effect interleaves with genuine
    changes — order-preserving application must still be exact."""
    live = sorted(_live_edges(g))
    idx = rng.choice(len(live), 8, replace=False)
    victims = [live[i] for i in idx]
    ops = [Delete(*e) for e in victims] + [Insert(*e) for e in victims]
    live_set = set(live)
    for _ in range(4):
        e = _rand_missing(rng, n, live_set)
        live_set.add(e)
        ops.append(Insert(*e))
    return [ops]


FAMILIES = {
    "insert_sparse": _fam_insert_sparse,
    "delete_sparse": _fam_delete_sparse,
    "mixed": _fam_mixed,
    "clique_lift": _fam_clique_lift,
    "hub_churn": _fam_hub_churn,
    "cascade_delete": _fam_cascade_delete,
    "reinsert": _fam_reinsert,
}


def _pair(g, backend):
    """(parallel maintainer on ``backend``, serial numpy per-edge oracle)."""
    par = CoreMaintainer(
        BufferedGraph(g),
        settings=Settings(backend=backend, parallel_maint=True))
    ser = CoreMaintainer(
        BufferedGraph(g), settings=Settings(parallel_maint=False))
    return par, ser


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_grouped_settle_matches_serial_oracle(family, backend):
    g, n = _graph(backend, seed=11 + len(family))
    rng = np.random.default_rng(29)
    batches = FAMILIES[family](g, n, rng)
    par, ser = _pair(g, backend)
    for ops in batches:
        sp = par.apply(UpdateBatch(ops))
        ser.apply(UpdateBatch(ops))
        assert sp.algorithm.startswith("parallel(")
        np.testing.assert_array_equal(par.core, ser.core)
        np.testing.assert_array_equal(par.cnt, ser.cnt)
    # and both equal recompute-from-scratch on the final graph
    np.testing.assert_array_equal(par.core, imcore_bz(par.bg.materialize()))


# ----------------------------------------------------------- adversarial
def test_adversarial_net_noop_batch():
    """delete(e) then insert(e) in one batch: the graph round-trips, so the
    settled state must equal the initial decomposition exactly."""
    g = chung_lu(200, 800, seed=5)
    par = CoreMaintainer(BufferedGraph(g), backend="xla")
    core0, cnt0 = par.core.copy(), par.cnt.copy()
    live = sorted(_live_edges(g))[:12]
    ops = [Delete(*e) for e in live] + [Insert(*e) for e in live]
    par.apply(UpdateBatch(ops))
    np.testing.assert_array_equal(par.core, core0)
    np.testing.assert_array_equal(par.cnt, cnt0)


def test_adversarial_duplicate_and_missing_ops():
    """Duplicate inserts, deletes of absent edges, and an empty batch are
    counted as no-ops, never corrupt state."""
    g = chung_lu(200, 800, seed=7)
    par, ser = _pair(g, "xla")
    e = _rand_missing(np.random.default_rng(0), 200, _live_edges(g))
    ops = [Insert(*e), Insert(*e), Delete(199, 198 if e != (198, 199) else 0)]
    sp = par.apply(UpdateBatch(ops))
    ser.apply(UpdateBatch(ops))
    assert sp.num_noops >= 1
    np.testing.assert_array_equal(par.core, ser.core)
    np.testing.assert_array_equal(par.cnt, ser.cnt)
    s_empty = par.apply(UpdateBatch())
    assert s_empty.num_deletes == s_empty.num_inserts == 0
    np.testing.assert_array_equal(par.core, ser.core)


def test_adversarial_isolated_nodes():
    """Edges among previously isolated nodes (degree 0 -> small core)."""
    base = erdos_renyi(60, 150, seed=3)
    # append 6 isolated nodes
    g = type(base).from_edges(base.n + 6, base.edge_list())
    par, ser = _pair(g, "xla")
    iso = list(range(base.n, base.n + 6))
    ops = [Insert(iso[0], iso[1]), Insert(iso[1], iso[2]),
           Insert(iso[2], iso[0]), Insert(iso[3], 0)]
    par.apply(UpdateBatch(ops))
    ser.apply(UpdateBatch(ops))
    np.testing.assert_array_equal(par.core, ser.core)
    np.testing.assert_array_equal(par.cnt, ser.cnt)


def test_group_cap_forces_serial_fallback_and_stays_exact():
    """group_cap=1 marks every insert component heavy: the round falls back
    to the serial warm settle, which must stay exact (and be counted)."""
    g = chung_lu(200, 800, seed=9)
    par = CoreMaintainer(BufferedGraph(g), backend="xla", group_cap=1)
    ser = CoreMaintainer(
        BufferedGraph(g), settings=Settings(parallel_maint=False))
    rng = np.random.default_rng(1)
    live = _live_edges(g)
    ops = []
    for _ in range(8):
        e = _rand_missing(rng, 200, live)
        live.add(e)
        ops.append(Insert(*e))
    sp = par.apply(UpdateBatch(ops))
    ser.apply(UpdateBatch(ops))
    assert sp.fallbacks >= 1
    np.testing.assert_array_equal(par.core, ser.core)
    np.testing.assert_array_equal(par.cnt, ser.cnt)


# ------------------------------------------------- candidate-bound soundness
def test_candidate_bound_covers_every_changed_node(monkeypatch):
    """Soundness of the planner's bounds: every node whose core changed is
    covered by some round's plan — a rise inside a planned candidate set,
    a drop inside a planned delete prefix (``core0 <= c``)."""
    g = chung_lu(300, 1200, seed=21)
    par = CoreMaintainer(BufferedGraph(g), backend="xla")
    core_before = par.core.copy()

    plans = []
    orig_batch, orig_risers = pm.plan_batch, pm.plan_risers

    def rec_batch(*a, **k):
        p = orig_batch(*a, **k)
        plans.append((p, a[1].copy()))  # (plan, round-start core0)
        return p

    def rec_risers(*a, **k):
        p = orig_risers(*a, **k)
        plans.append((p, a[1].copy()))
        return p

    monkeypatch.setattr(pm, "plan_batch", rec_batch)
    monkeypatch.setattr(pm, "plan_risers", rec_risers)

    rng = np.random.default_rng(2)
    live = _live_edges(g)
    ops = []
    for _ in range(24):
        if rng.random() < 0.5 and live:
            e = sorted(live)[int(rng.integers(len(live)))]
            live.discard(e)
            ops.append(Delete(*e))
        else:
            e = _rand_missing(rng, 300, live)
            live.add(e)
            ops.append(Insert(*e))
    stats = par.apply(UpdateBatch(ops))
    assert stats.algorithm.startswith("parallel(")
    assert plans, "parallel path did not plan?"

    covered = np.zeros(300, dtype=bool)
    for plan, core_r in plans:
        for up in plan.updates:
            covered[np.asarray(up.cand, dtype=np.int64)] = True
            if up.prefix_level >= 0:
                covered |= core_r <= up.prefix_level
    changed = par.core != core_before
    stray = np.flatnonzero(changed & ~covered)
    assert stray.size == 0, f"changed outside every plan bound: {stray[:10]}"


# ------------------------------------------------------ replica replay parity
def test_replica_replay_parity_under_parallel_maint(tmp_path):
    """Writer ingests with the parallel settle; a replica replays the op-
    vocabulary WAL through its own maintainer and lands bit-identical."""
    g = chung_lu(400, 1600, seed=17)
    svc = CoreService(
        g, block_edges=128,
        wal_path=str(tmp_path / "wal.jsonl"),
        snapshot_dir=str(tmp_path / "snaps"),
        settings=Settings(backend="xla", parallel_maint=True),
    )
    svc.snapshot()
    rng = np.random.default_rng(4)
    live = _live_edges(g)
    for _ in range(4):
        ops = []
        for _ in range(16):
            if rng.random() < 0.5 and live:
                e = sorted(live)[int(rng.integers(len(live)))]
                live.discard(e)
                ops.append(("-",) + e)
            else:
                e = _rand_missing(rng, 400, live)
                live.add(e)
                ops.append(("+",) + e)
        svc.ingest(ops)
    rep = CoreReplica(
        snapshot_dir=str(tmp_path / "snaps"),
        wal_path=str(tmp_path / "wal.jsonl"), block_edges=128)
    rep.sync()
    assert rep.epoch == svc.epoch
    np.testing.assert_array_equal(rep.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(rep.maintainer.cnt, svc.maintainer.cnt)


# --------------------------------------------------------- deprecation shims
def test_apply_batch_shim_warns_and_matches_apply():
    g = chung_lu(150, 600, seed=8)
    a = CoreMaintainer(BufferedGraph(g), backend="xla")
    b = CoreMaintainer(BufferedGraph(g), backend="xla")
    dels = sorted(_live_edges(g))[:5]
    ins = [(0, 149), (1, 148)]
    with pytest.warns(DeprecationWarning, match="apply_batch.*deprecated"):
        a.apply_batch(dels, ins)
    b.apply(UpdateBatch.from_pairs(dels, ins))
    np.testing.assert_array_equal(a.core, b.core)
    np.testing.assert_array_equal(a.cnt, b.cnt)


def test_wal_append_shim_warns_and_replays_identically(tmp_path):
    new = str(tmp_path / "new.jsonl")
    old = str(tmp_path / "old.jsonl")
    batch = UpdateBatch.from_pairs([(0, 1), (2, 3)], [(4, 5)])
    w = WriteAheadLog(new)
    w.append(1, batch)
    w.close()
    w = WriteAheadLog(old)
    with pytest.warns(DeprecationWarning, match="pass an UpdateBatch"):
        w.append(1, [(0, 1), (2, 3)], [(4, 5)])
    w.close()
    got_new = list(WriteAheadLog.replay(new))
    got_old = list(WriteAheadLog.replay(old))
    assert got_new == got_old == [(1, batch)]


def test_legacy_del_ins_records_still_replay(tmp_path):
    """A pre-op-vocabulary WAL (``del``/``ins`` records) decodes to the
    canonical deletes-then-inserts UpdateBatch."""
    import json

    from repro.stream.integrity import frame_record

    path = str(tmp_path / "legacy.jsonl")
    rec = {"epoch": 3, "del": [[1, 2]], "ins": [[3, 4], [5, 6]]}
    with open(path, "wb") as f:
        f.write(frame_record(json.dumps(rec).encode()))
        f.write(json.dumps({"epoch": 4, "del": [], "ins": [[7, 8]]})
                .encode() + b"\n")  # unframed legacy line
    got = list(WriteAheadLog.replay(path))
    assert got == [
        (3, UpdateBatch.from_pairs([(1, 2)], [(3, 4), (5, 6)])),
        (4, UpdateBatch.from_pairs([], [(7, 8)])),
    ]


# ------------------------------------------------------------- runtime knob
def test_parallel_maint_env_toggle(monkeypatch):
    g = chung_lu(150, 600, seed=10)
    monkeypatch.setenv("REPRO_PARALLEL_MAINT", "0")
    m = CoreMaintainer(BufferedGraph(g), backend="xla")
    s = m.apply(UpdateBatch.from_pairs([], [(0, 149)]))
    assert not s.algorithm.startswith("parallel(")
    monkeypatch.setenv("REPRO_PARALLEL_MAINT", "1")
    s = m.apply(UpdateBatch.from_pairs([(0, 149)], []))
    assert s.algorithm.startswith("parallel(")
