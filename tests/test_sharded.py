"""Sharded on-mesh backend (engine.ShardedBackend + resident.run_sharded,
DESIGN.md §13).

Pins the four properties the fold-in claims:

* **trace parity** — the on-mesh fixpoint walks the numpy backend's exact
  batch passes (paper Fig. 2/4/5 pins, warm-settle charge parity), and the
  walk is *shard-count invariant*: 1/2/8 shards on a forced 8-device host
  produce bit-identical core/cnt/iters/planner-I/O traces;
* **compile count** — jit traces per decompose stay O(1) (one chunk fn),
  independent of pass count;
* **structure residency** — the sharded edge table is version-keyed like the
  flat resident table: reused across runs and no-op batches, re-sharded
  exactly once per structural change;
* **layout hygiene** — contiguous shards are minimax-balanced by edge count
  (the rectangular (S, E) padding bugfix), padding is surfaced on the
  result, and int32 offset overflow fails loudly.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import resident
from repro.core.distributed import (
    balanced_bounds,
    distributed_decompose,
    shard_arrays,
    shard_graph,
)
from repro.core.engine import ShardedBackend, warm_settle
from repro.core.imcore import imcore_bz
from repro.core.maintenance import CoreMaintainer
from repro.core.semicore import HostEngine, decompose
from repro.graph import BufferedGraph, CSRGraph, chung_lu, paper_example_graph
from repro.stream.service import CoreService


# -------------------------------------------------------------- trace parity
def test_shard_pins_paper_example_batch_traces():
    """The on-mesh path must walk the paper's running example through the
    exact batch-schedule traces the numpy backend pins (Figs. 2/4/5)."""
    pinned = {
        "semicore": (36, 4, 4, 4),
        "semicore+": (26, 4, 4, 4),
        "semicore*": (11, 3, 3, 3),
    }
    for algo, (comps, iters, ebr, ntr) in pinned.items():
        r = decompose(paper_example_graph(), algo, "batch", block_edges=64,
                      pool_blocks=1, backend="shard")
        np.testing.assert_array_equal(r.core, [3, 3, 3, 3, 2, 2, 2, 2, 1])
        assert r.node_computations == comps, algo
        assert r.iterations == iters, algo
        assert r.edge_block_reads == ebr, algo
        assert r.node_table_reads == ntr, algo
        assert r.num_shards >= 1


def test_shard_full_history_parity_vs_numpy():
    g = chung_lu(250, 900, gamma=2.3, seed=11)
    for algo in ("semicore", "semicore+", "semicore*"):
        ref = decompose(g, algo, "batch", block_edges=64, backend="numpy")
        r = decompose(g, algo, "batch", block_edges=64, backend="shard")
        np.testing.assert_array_equal(r.core, ref.core)
        if ref.cnt is not None:
            np.testing.assert_array_equal(r.cnt, ref.cnt)
        assert r.iterations == ref.iterations
        assert r.node_computations == ref.node_computations
        assert r.updates_per_iter == ref.updates_per_iter
        assert r.computations_per_iter == ref.computations_per_iter
        assert r.edge_block_reads == ref.edge_block_reads
        assert r.node_table_reads == ref.node_table_reads


def test_warm_settle_shard_matches_numpy_settle():
    """The on-mesh warm settle (exact-cnt prologue on the bound sharded
    structure + SemiCore* passes) must match the numpy settle
    state-for-state and charge-for-charge."""
    g = chung_lu(300, 1200, seed=5)
    core0 = decompose(g, "semicore*", "batch", backend="numpy").core
    e = g.edge_list()

    def perturbed():
        bg = BufferedGraph(g)
        for i in range(6):
            assert bg.delete_edge(*map(int, e[i * 11]))
        ins = [(1, 250), (2, 251), (3, 252)]
        ni = sum(bg.insert_edge(u, v) for u, v in ins)
        return bg, ni

    bg_np, ni = perturbed()
    r_np = warm_settle(HostEngine(bg_np, block_edges=64), core0, ni, "numpy")
    bg_sh, ni_sh = perturbed()
    assert ni_sh == ni
    r_sh = warm_settle(HostEngine(bg_sh, block_edges=64), core0, ni, "shard")
    np.testing.assert_array_equal(r_sh.core, r_np.core)
    np.testing.assert_array_equal(r_sh.cnt, r_np.cnt)
    assert r_sh.iterations == r_np.iterations
    assert r_sh.edge_block_reads == r_np.edge_block_reads
    assert r_sh.node_table_reads == r_np.node_table_reads
    np.testing.assert_array_equal(r_sh.core, imcore_bz(bg_sh.materialize()))


# ------------------------------------------------------ shard-count invariance
_INVARIANCE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert len(jax.devices()) == 8
from repro.graph import chung_lu
from repro.core.imcore import imcore_bz
from repro.core.semicore import decompose
from repro.core.engine import ShardedBackend

g = chung_lu(250, 900, gamma=2.3, seed=11)
expect = imcore_bz(g)
for algo in ("semicore", "semicore+", "semicore*"):
    ref = decompose(g, algo, "batch", block_edges=64, backend="numpy")
    traces = set()
    for S in (1, 2, 8):
        r = decompose(g, algo, "batch", block_edges=64,
                      backend=ShardedBackend(num_shards=S))
        assert np.array_equal(r.core, expect), (algo, S)
        assert r.num_shards == S
        if ref.cnt is not None:
            assert np.array_equal(r.cnt, ref.cnt), (algo, S)
        traces.add((r.iterations, r.node_computations, r.edge_block_reads,
                    r.node_table_reads, tuple(r.updates_per_iter),
                    tuple(r.computations_per_iter)))
    assert traces == {(ref.iterations, ref.node_computations,
                       ref.edge_block_reads, ref.node_table_reads,
                       tuple(ref.updates_per_iter),
                       tuple(ref.computations_per_iter))}, (algo, traces)
# default mesh width = every visible device
r = decompose(g, "semicore*", "batch", block_edges=64, backend="shard")
assert r.num_shards == 8 and np.array_equal(r.core, expect)
print("SHARD_INVARIANCE_OK")
"""


@pytest.mark.slow
def test_shard_count_invariance_under_8_forced_devices():
    """1/2/8 shards must produce the identical core/cnt/iters/planner-I/O
    trace — the mesh cut is pure layout, never scheduling."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _INVARIANCE_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert "SHARD_INVARIANCE_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- compile count
def test_shard_compile_count_independent_of_pass_count():
    g = chung_lu(4000, 16000, seed=6)
    before = resident.trace_count()
    r1 = decompose(g, "semicore*", "batch", block_edges=256, backend="shard")
    first = resident.trace_count() - before
    assert r1.iterations >= 20  # far more passes than allowed traces
    assert first <= 2, f"{first} traces for {r1.iterations} passes"
    before = resident.trace_count()
    r2 = decompose(g, "semicore*", "batch", block_edges=256, backend="shard")
    assert resident.trace_count() - before == 0
    np.testing.assert_array_equal(r1.core, r2.core)


# -------------------------------------------------------- structure caching
def test_shard_structure_cache_reused_across_apply_batch():
    g = chung_lu(200, 800, seed=7)
    m = CoreMaintainer(g, block_edges=64, backend="shard")
    assert m.backend.retain_structure
    assert m.backend.structure_builds == 1  # the initial decompose
    # a batch of pure no-ops applies nothing: no settle, no re-shard
    non_edge = next((u, v) for u in range(3) for v in range(100, 200)
                    if not m.bg.base.has_edge(u, v))
    s = m.apply_batch([non_edge], [])
    assert s.num_noops == 1 and s.num_deletes == 0
    assert m.backend.structure_builds == 1
    # a real batch bumps the version: exactly one re-shard for the settle
    e = m.bg.base.edge_list()
    s = m.apply_batch([tuple(map(int, e[3]))], [(0, 150)])
    assert s.num_deletes == 1
    assert m.backend.structure_builds == 2
    np.testing.assert_array_equal(m.core, imcore_bz(m.bg.materialize()))


def test_shard_one_shot_run_drops_structure_on_unbind():
    be = ShardedBackend()
    from repro.core.engine import run_batch

    eng = HostEngine(chung_lu(150, 500, seed=2), block_edges=64)
    run_batch(eng, "semicore*", be)
    assert be._resident is None


# ------------------------------------------------------------- service path
def test_core_service_on_shard_backend_stays_exact():
    g = chung_lu(220, 900, seed=9)
    svc = CoreService(g, block_edges=64, backend="shard")
    e = g.edge_list()
    svc.ingest([("-", *map(int, e[0])), ("-", *map(int, e[7])),
                ("+", 0, 100)])
    svc.ingest([("+", 2, 150), ("-", *map(int, e[21]))])
    np.testing.assert_array_equal(
        svc.maintainer.core, imcore_bz(svc.bg.materialize()))
    stats = svc.service_stats()
    assert stats["backend"] == "shard"
    assert stats["backend_structure_builds"] >= 1


# ------------------------------------------------------------ layout hygiene
def test_balanced_bounds_is_minimax_optimal():
    """The binary-search cut must match the brute-force minimax optimum for
    contiguous ranges (the (S, E) padding is driven by the heaviest shard)."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        deg = rng.integers(0, 9, size=rng.integers(3, 12))
        seg_ptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
        n = len(deg)
        S = int(rng.integers(1, 5))
        bounds = balanced_bounds(seg_ptr, S)
        assert bounds[0] == 0 and bounds[-1] == n
        assert (np.diff(bounds) >= 0).all()
        got = int((seg_ptr[bounds[1:]] - seg_ptr[bounds[:-1]]).max())
        # brute force over all contiguous S-partitions
        from itertools import combinations

        best = min(
            max(seg_ptr[b] - seg_ptr[a]
                for a, b in zip((0,) + cuts, cuts + (n,)))
            for cuts in combinations(range(1, n), min(S - 1, n - 1))
        ) if S > 1 and n > 1 else int(seg_ptr[-1])
        assert got == best, (deg.tolist(), S, got, best)


def test_shard_graph_balance_and_padding_stats():
    g = chung_lu(5000, 40000, seed=3)
    sg = shard_graph(g, 8)
    per_shard = sg.edge_mask.sum(axis=1)
    np.testing.assert_array_equal(per_shard, sg.per_shard_edges)
    assert per_shard.sum() == g.num_directed
    assert sg.owned_mask.sum() == g.n
    assert per_shard.max() <= 1.6 * per_shard.mean()  # balanced cuts
    assert sg.pad_edges == 8 * sg.dst.shape[1] - g.num_directed
    # local segment offsets must tile each shard's real edge span exactly
    for s in range(8):
        nv = int(sg.owned_mask[s].sum())
        assert sg.lsegptr[s, 0] == 0
        assert sg.lsegptr[s, nv] == per_shard[s]
        assert (np.diff(sg.lsegptr[s]) >= 0).all()
    # padding stats reach the DecompResult
    r = decompose(g, "semicore*", "batch", block_edges=256, backend="shard")
    assert r.num_shards >= 1
    assert r.shard_pad_edges >= 0


def test_skewed_graph_rebalance_beats_naive_split():
    """A hub-heavy graph: minimax cuts keep the rectangular padding at the
    information-theoretic floor (heaviest node's adjacency)."""
    # one hub with 400 edges + a long path
    hub = np.stack([np.zeros(400, np.int64),
                    np.arange(1, 401, dtype=np.int64)], 1)
    path = np.stack([np.arange(401, 800, dtype=np.int64),
                     np.arange(402, 801, dtype=np.int64)], 1)
    g = CSRGraph.from_edges(801, np.concatenate([hub, path]))
    sg = shard_graph(g, 4)
    # the hub shard is unavoidable; every other shard must stay near the mean
    assert sg.per_shard_edges.max() <= g.degrees().max() + \
        -(-g.num_directed // 4)


def test_shard_int32_validation_raises_loudly():
    with pytest.raises(ValueError, match="int32"):
        shard_arrays(np.zeros(0, np.int32), np.zeros(2, np.int64), 1,
                     n=1 << 31)


def test_num_shards_validation_and_env(monkeypatch):
    g = paper_example_graph()
    with pytest.raises(ValueError, match="device"):
        decompose(g, "semicore*", "batch",
                  backend=ShardedBackend(num_shards=4096))
    monkeypatch.setenv("REPRO_NUM_SHARDS", "1")
    monkeypatch.setenv("REPRO_BACKEND", "shard")
    r = decompose(g, "semicore*", "batch", block_edges=64)
    assert r.backend == "shard" and r.num_shards == 1


# --------------------------------------------------------- budgeted prefix
def test_distributed_decompose_budgeted_prefix_and_warm_restart():
    g = chung_lu(1000, 4000, seed=5)
    expect = imcore_bz(g)
    core, iters = distributed_decompose(g)
    np.testing.assert_array_equal(core, expect)
    budget = max(2, iters // 2)
    partial, done = distributed_decompose(g, max_supersteps=budget)
    assert done < iters
    assert (partial >= expect).all()  # any prefix is a valid upper bound
    core2, extra = distributed_decompose(g, core0=partial)
    np.testing.assert_array_equal(core2, expect)
    assert extra <= iters
