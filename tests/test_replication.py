"""CQRS replication (DESIGN.md §15): WAL tailing, rotation, read replicas,
crash-recovery fault-injection matrix, and O(record) replay memory."""
import json
import os
import tracemalloc

import numpy as np
import pytest

from repro.graph import chung_lu
from repro.stream import (CoreReplica, CoreService, UpdateBatch, WalGap,
                          WalTailer, WriteAheadLog, admit_batch,
                          mixed_stream)


def batches(ops, size):
    return [ops[i : i + size] for i in range(0, len(ops), size)]


def make_writer(tmp_path, *, n=800, m=3200, seed=6, snapshot_every=0,
                block_edges=128):
    g = chung_lu(n, m, seed=seed)
    svc = CoreService(
        g, block_edges=block_edges,
        wal_path=str(tmp_path / "wal.jsonl"),
        snapshot_dir=str(tmp_path / "snaps"),
        snapshot_every=snapshot_every,
    )
    return svc, str(tmp_path / "wal.jsonl"), str(tmp_path / "snaps")


def make_replica(wal, snaps, **kw):
    kw.setdefault("block_edges", 128)
    return CoreReplica(snapshot_dir=snaps, wal_path=wal, **kw)


def assert_converged(rep, svc):
    """The replica serves bit-identical state to the writer at its epoch."""
    assert rep.epoch == svc.epoch
    np.testing.assert_array_equal(rep.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(rep.maintainer.cnt, svc.maintainer.cnt)


# ================================================================ WalTailer
def test_tailer_yields_only_new_complete_records(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([(0, 1)], []))
    w.append(2, UpdateBatch.from_pairs([], [(2, 3)]))
    t = WalTailer(wal)
    assert [e for e, _ in t.poll()] == [1, 2]
    assert list(t.poll()) == []  # nothing new
    w.append(3, UpdateBatch.from_pairs([(4, 5)], [(6, 7)]))
    got = list(t.poll())
    assert got == [(3, UpdateBatch.from_pairs([(4, 5)], [(6, 7)]))]
    w.close()


def test_tailer_leaves_inflight_tail_for_next_poll(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    w.close()
    with open(wal, "a") as f:  # writer mid-append: no trailing newline yet
        f.write('{"epoch":2,"del":[],"ins":[[2,')
    t = WalTailer(wal)
    assert [e for e, _ in t.poll()] == [1]
    off = t.offset
    assert list(t.poll()) == []  # partial line is not durable
    with open(wal, "a") as f:  # the append completes
        f.write('3]]}\n')
    assert [e for e, _ in t.poll()] == [2]
    assert t.offset > off


def test_tailer_resumes_from_after_epoch(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 6):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    w.close()
    t = WalTailer(wal, after_epoch=3)
    assert [e for e, _ in t.poll()] == [4, 5]


def test_tailer_detects_rotation_and_reseeks_without_duplicates(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 5):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    t = WalTailer(wal)
    assert [e for e, _ in t.poll()] == [1, 2, 3, 4]
    assert w.rotate(after_epoch=3) == 3  # epochs 1-3 dropped
    w.append(5, UpdateBatch.from_pairs([], [(0, 5)]))
    got = [e for e, _ in t.poll()]
    assert got == [5]  # epoch 4 survived rotation but was already applied
    assert t.rotations_detected == 1
    w.close()


def test_tailer_raises_walgap_when_rotation_outran_it(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 4):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    t = WalTailer(wal)
    assert [e for e, _ in t.poll()] == [1, 2, 3]
    for e in range(4, 8):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    w.rotate(after_epoch=6)  # drops 1..6; tailer needs 4 next
    with pytest.raises(WalGap):
        list(t.poll())
    w.close()


def test_rotate_is_atomic_and_appends_keep_working(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 6):
        w.append(e, UpdateBatch.from_pairs([(e, e + 1)], []))
    w.rotate(after_epoch=4)
    w.append(6, UpdateBatch.from_pairs([], [(9, 10)]))  # handle was reopened onto the new inode
    w.close()
    got = list(WriteAheadLog.replay(wal))
    assert [e for e, _ in got] == [5, 6]
    assert not os.path.exists(wal + WriteAheadLog.ROTATE_TMP_SUFFIX)


def test_stale_rotate_tmp_is_discarded_on_reopen(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    w.close()
    tmp = wal + WriteAheadLog.ROTATE_TMP_SUFFIX
    with open(tmp, "w") as f:  # crash mid-rotation: os.replace never ran
        f.write('{"epoch":1,"del"')
    w2 = WriteAheadLog(wal)
    assert not os.path.exists(tmp)
    assert [e for e, _ in WriteAheadLog.replay(wal)] == [1]
    w2.close()


# ============================================================= WAL bugfixes
def test_replay_is_a_lazy_generator(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 100):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    w.close()
    it = WriteAheadLog.replay(wal)
    assert next(it)[0] == 1  # consuming one record doesn't parse the rest
    it.close()


def test_replay_rejects_mid_log_corruption_but_skips_torn_tail(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    w.append(2, UpdateBatch.from_pairs([], [(0, 2)]))
    w.close()
    with open(wal, "a") as f:
        f.write('{"epoch":3,"del":[[1,')  # torn tail: skipped
    assert [e for e, _ in WriteAheadLog.replay(wal)] == [1, 2]
    with open(wal) as f:
        lines = f.readlines()
    lines[0] = '{"epoch":1,"del":[[corrupt\n'  # mid-log damage: must raise
    with open(wal, "w") as f:
        f.writelines(lines)
    with pytest.raises(json.JSONDecodeError):
        list(WriteAheadLog.replay(wal))


def test_truncate_torn_tail_streams_from_the_end(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    w.close()
    torn = '{"epoch":2,"pad":"' + "x" * 300_000  # torn line > scan chunk
    with open(wal, "a") as f:
        f.write(torn)
    w2 = WriteAheadLog(wal)  # reopen truncates the torn line
    w2.append(2, UpdateBatch.from_pairs([], [(0, 2)]))
    w2.close()
    assert [e for e, _ in WriteAheadLog.replay(wal)] == [1, 2]


def test_replay_and_truncate_memory_is_o_record_not_o_log(tmp_path):
    """Peak replay/recovery memory must track one record, not the log size:
    a ~8 MB log replays within a ~1 MB tracemalloc envelope (readlines or a
    whole-file read would show up as >= the file size)."""
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 2_001):
        w.append(e, UpdateBatch.from_pairs([(i, i + 1) for i in range(300)], [(i, i + 2) for i in range(300)]))
    w.close()
    log_bytes = os.path.getsize(wal)
    assert log_bytes > 8_000_000

    tracemalloc.start()
    count = 0
    for _e, batch in WriteAheadLog.replay(wal):
        count += len(batch)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert count == 2_000 * 600
    assert peak < 1_000_000, f"replay peak {peak} vs log {log_bytes}"

    with open(wal, "a") as f:
        f.write('{"epoch":9999,"del":[[1,')  # torn
    tracemalloc.start()
    WriteAheadLog._truncate_torn_tail(wal)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 1_000_000, f"truncate peak {peak} vs log {log_bytes}"
    assert [e for e, _ in WriteAheadLog.replay(wal)][-1] == 2_000

    tracemalloc.start()
    assert WriteAheadLog.tip_epoch(wal) == 2_000
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 1_000_000, f"tip_epoch peak {peak} vs log {log_bytes}"


def test_tip_epoch_handles_empty_torn_and_blank(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    assert WriteAheadLog.tip_epoch(wal) is None  # missing file
    open(wal, "w").close()
    assert WriteAheadLog.tip_epoch(wal) is None  # empty file
    with open(wal, "w") as f:
        f.write('{"epoch":7,"del":[],"ins":[]}\n')
        f.write("\n")  # blank line
        f.write('{"epoch":8,"del":[')  # torn tail
    assert WriteAheadLog.tip_epoch(wal) == 7
    with open(wal, "w") as f:
        f.write('{"epoch":3,"del":')  # the only line is torn
    assert WriteAheadLog.tip_epoch(wal) is None


def test_wal_stays_bounded_by_rotation_under_snapshots(tmp_path):
    """The unbounded-growth bugfix: with periodic snapshots, WAL records at
    or below the snapshot epoch are dropped, so the log length tracks the
    snapshot interval, not the stream lifetime."""
    svc, wal, _snaps = make_writer(tmp_path, snapshot_every=3)
    g0 = svc.bg.materialize()
    ops, _ = mixed_stream(g0, 360, seed=4)
    for chunk in batches(ops, 30):  # 12 batches, snapshots at 3,6,9,12
        svc.ingest(chunk)
    svc.close()
    records = [e for e, _ in WriteAheadLog.replay(wal)]
    assert records == []  # epoch 12 snapshot just rotated everything out
    assert svc.wal.rotations == 4


# ================================================================= replicas
def test_replica_bootstrap_serves_bit_identical_replies(tmp_path):
    svc, wal, snaps = make_writer(tmp_path)
    ops, _ = mixed_stream(svc.bg.materialize(), 300, seed=3)
    chunks = batches(ops, 50)
    for c in chunks[:3]:
        svc.ingest(c)
    svc.snapshot()
    for c in chunks[3:]:
        svc.ingest(c)  # WAL tail past the snapshot

    rep = make_replica(wal, snaps)
    assert_converged(rep, svc)
    assert rep.last_bootstrap.warm_restart
    assert rep.last_bootstrap.replayed_batches == len(chunks) - 3
    nodes = np.arange(svc.bg.n)
    w_core, r_core = svc.coreness(nodes), rep.coreness(nodes)
    np.testing.assert_array_equal(r_core, w_core)
    assert r_core.epoch == w_core.epoch == svc.epoch
    np.testing.assert_array_equal(rep.top_k(50), svc.top_k(50))
    np.testing.assert_array_equal(rep.kcore_members(2), svc.kcore_members(2))
    assert int(rep.degeneracy()) == int(svc.degeneracy())
    assert rep.in_kcore(int(rep.top_k(1)[0]), int(rep.degeneracy()))


def test_replica_tails_incrementally_under_continuous_ingest(tmp_path):
    svc, wal, snaps = make_writer(tmp_path)
    svc.snapshot()
    rep = make_replica(wal, snaps)
    reader = rep.maintainer.engine.reader
    ops, _ = mixed_stream(svc.bg.materialize(), 400, seed=5)
    for i, chunk in enumerate(batches(ops, 40)):
        svc.ingest(chunk)
        if i % 3 == 2:  # replica trails, then catches up incrementally
            assert rep.lag(svc.epoch) == 3
            assert rep.sync() == 3
            assert_converged(rep, svc)
            assert rep.lag(svc.epoch) == 0
    rep.sync()
    assert_converged(rep, svc)
    assert rep.bootstraps == 1  # pure tailing: never re-bootstrapped
    # tailing replays maintenance, so replica reads edge blocks — but
    # queries stay zero-I/O (served from the committed views)
    io0 = (reader.reads, reader.node_table_reads)
    rep.top_k(10), rep.coreness(0), rep.kcore_members(2)
    assert (reader.reads, reader.node_table_reads) == io0


def test_replica_epoch_view_chain_and_watermarks(tmp_path):
    svc, wal, snaps = make_writer(tmp_path)
    svc.snapshot()
    rep = make_replica(wal, snaps, keep_views=3)
    ops, _ = mixed_stream(svc.bg.materialize(), 200, seed=8)
    per_epoch_core = {}
    for chunk in batches(ops, 40):
        svc.ingest(chunk)
        per_epoch_core[svc.epoch] = svc.view().core.copy()
    rep.sync()
    assert [v.epoch for v in rep.views] == [3, 4, 5]
    for e in (3, 4, 5):  # retained views replay the writer's exact history
        np.testing.assert_array_equal(rep.view_at(e).core, per_epoch_core[e])
    with pytest.raises(KeyError):
        rep.view_at(1)  # evicted from the bounded chain
    assert rep.view().epoch == svc.epoch


def test_replica_lag_metrics_and_stats(tmp_path):
    from repro.obs import metrics as obs

    svc, wal, snaps = make_writer(tmp_path)
    svc.snapshot()
    rep = make_replica(wal, snaps, replica_id=7)
    ops, _ = mixed_stream(svc.bg.materialize(), 120, seed=9)
    for chunk in batches(ops, 40):
        svc.ingest(chunk)
    assert rep.lag() == 3  # probed from the WAL tip, no writer handle needed
    if obs.obs_enabled():
        reg = obs.get_registry()
        assert reg.get("repro_replica_lag").labels(replica="7").value == 3
        assert reg.get("repro_replica_epoch").labels(replica="7").value == 0
    rep.sync()
    assert rep.lag() == 0
    st = rep.replica_stats()
    assert st["epoch"] == svc.epoch == 3
    assert st["lag"] == 0 and st["batches_applied"] == 3
    assert st["bootstraps"] == 1 and st["replica_id"] == 7


def test_replica_rebootstraps_across_rotation_gap(tmp_path):
    svc, wal, snaps = make_writer(tmp_path)
    svc.snapshot()
    rep = make_replica(wal, snaps)
    for seed in (11, 12, 13):  # rotations march past the sleeping replica
        ops, _ = mixed_stream(svc.bg.materialize(), 60, seed=seed)
        svc.ingest(ops)
        svc.snapshot()
    ops, _ = mixed_stream(svc.bg.materialize(), 60, seed=14)
    svc.ingest(ops)
    rep.sync()  # WalGap inside -> snapshot catch-up -> tail the rest
    assert rep.bootstraps == 2
    assert_converged(rep, svc)
    # and the recovered cursor keeps tailing incrementally afterwards
    ops, _ = mixed_stream(svc.bg.materialize(), 40, seed=15)
    svc.ingest(ops)
    assert rep.sync() == 1
    assert_converged(rep, svc)


def test_replica_requires_a_snapshot(tmp_path):
    svc, wal, snaps = make_writer(tmp_path)
    with pytest.raises(RuntimeError, match="snapshot"):
        make_replica(wal, snaps)
    svc.close()


def test_replica_registered_as_serving_surface():
    from repro.serve import available_services, service_factory

    assert "core-replica" in available_services()
    assert service_factory("core-replica") is CoreReplica


# ============================================== crash-recovery fault matrix
def _seeded_writer(tmp_path, *, snapshot_every=0):
    svc, wal, snaps = make_writer(tmp_path, snapshot_every=snapshot_every)
    ops, _ = mixed_stream(svc.bg.materialize(), 240, seed=21)
    chunks = batches(ops, 40)
    for c in chunks[:2]:
        svc.ingest(c)
    svc.snapshot()
    for c in chunks[2:]:
        svc.ingest(c)
    return svc, wal, snaps


def _recover_and_replicate(wal, snaps):
    """The matrix invariant: writer recovery and a fresh replica bootstrap
    must land on the same exact (core, cnt) in every fault cell."""
    svc2, rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                   block_edges=128)
    rep = make_replica(wal, snaps)
    assert_converged(rep, svc2)
    return svc2, rep, rs


def test_fault_kill_between_wal_append_and_apply(tmp_path):
    svc, wal, snaps = _seeded_writer(tmp_path)
    # crash after the WAL append but before apply_batch: the record is
    # durable (and acknowledged by the log) but the state never advanced
    admitted = admit_batch(
        mixed_stream(svc.bg.materialize(), 30, seed=22)[0], n=svc.bg.n)
    svc.wal.append(svc.epoch + 1, admitted.batch)
    svc.close()
    svc2, rep, rs = _recover_and_replicate(wal, snaps)
    assert svc2.epoch == svc.epoch + 1  # the logged batch was replayed
    assert rs.replayed_batches == 5
    # recovery's state is exact: it equals re-applying the batch on the
    # pre-crash writer through the normal ingest path
    svc.maintainer.apply(admitted.batch,
                         insert_algorithm=svc.insert_algorithm)
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(svc2.maintainer.cnt, svc.maintainer.cnt)


def test_fault_mid_snapshot_tmp_write(tmp_path):
    svc, wal, snaps = _seeded_writer(tmp_path)
    svc.close()
    tmp = os.path.join(snaps, ".snap_tmp")  # crash mid-snapshot dump
    os.makedirs(tmp)
    with open(os.path.join(tmp, "core.npy"), "wb") as f:
        f.write(b"\x93NUMPY garbage")
    svc2, rep, _ = _recover_and_replicate(wal, snaps)
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(svc2.maintainer.cnt, svc.maintainer.cnt)
    svc2.snapshot()  # the next snapshot clears the wreckage and publishes
    assert not os.path.exists(tmp)
    rep2 = make_replica(wal, snaps)
    assert_converged(rep2, svc2)


def test_fault_mid_rotation(tmp_path):
    svc, wal, snaps = _seeded_writer(tmp_path)
    svc.close()
    # crash mid-rotation: the filtered temp exists, os.replace never ran —
    # the published WAL is still the full pre-rotation log
    with open(wal + WriteAheadLog.ROTATE_TMP_SUFFIX, "w") as f:
        f.write('{"epoch":3,"del":[],"ins"')
    svc2, rep, rs = _recover_and_replicate(wal, snaps)
    assert rs.replayed_batches == 4
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(svc2.maintainer.cnt, svc.maintainer.cnt)
    assert not os.path.exists(wal + WriteAheadLog.ROTATE_TMP_SUFFIX)


def test_fault_multi_record_torn_tail(tmp_path):
    svc, wal, snaps = _seeded_writer(tmp_path)
    # several durable records land after the snapshot, then the crash tears
    # the last one mid-append: every complete record must replay, the torn
    # one must not
    admitted = admit_batch(
        mixed_stream(svc.bg.materialize(), 30, seed=23)[0], n=svc.bg.n)
    svc.wal.append(svc.epoch + 1, admitted.batch)
    svc.close()
    with open(wal, "a") as f:
        f.write('{"epoch":%d,"del":[[1,2],[3' % (svc.epoch + 2))
    svc2, rep, rs = _recover_and_replicate(wal, snaps)
    assert svc2.epoch == svc.epoch + 1 and rs.replayed_batches == 5
    svc.maintainer.apply(admitted.batch,
                         insert_algorithm=svc.insert_algorithm)
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(svc2.maintainer.cnt, svc.maintainer.cnt)


def test_fault_matrix_replica_converges_under_every_cell(tmp_path):
    """The full matrix in one sweep: each cell seeds a writer, injects its
    fault, and requires writer-recovery == replica-bootstrap == oracle."""
    from repro.core import imcore_bz

    def torn_append(wal_path, epoch):
        with open(wal_path, "a") as f:
            f.write('{"epoch":%d,"del":[[0,' % epoch)

    cells = {
        "append-no-apply": lambda svc, wal, snaps: svc.wal.append(
            svc.epoch + 1, UpdateBatch.from_pairs([], [])),
        "snap-tmp": lambda svc, wal, snaps: os.makedirs(
            os.path.join(snaps, ".snap_tmp")),
        "rotate-tmp": lambda svc, wal, snaps: open(
            wal + WriteAheadLog.ROTATE_TMP_SUFFIX, "w").close(),
        "torn-tail": lambda svc, wal, snaps: torn_append(wal, svc.epoch + 1),
    }
    for name, inject in cells.items():
        d = tmp_path / name
        d.mkdir()
        svc, wal, snaps = _seeded_writer(d)
        inject(svc, wal, snaps)
        svc.close()
        svc2, rep, _ = _recover_and_replicate(wal, snaps)
        oracle = imcore_bz(svc2.bg.materialize())
        np.testing.assert_array_equal(svc2.maintainer.core, oracle,
                                      err_msg=f"cell {name}")
        np.testing.assert_array_equal(rep.maintainer.core, oracle,
                                      err_msg=f"cell {name}")
