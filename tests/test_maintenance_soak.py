"""Maintenance soak: long random interleaved insert/delete sequences must
keep ``(core, cnt)`` identical to a from-scratch SemiCore* recompute at every
step — including across WAL-recovery replays taken mid-sequence — and the
update buffer must honor its bounded-footprint contract (no empty-set
entries accumulating from membership probes, see updates.py).
"""
import os

import numpy as np
import pytest

from repro.core.maintenance import CoreMaintainer
from repro.core.semicore import HostEngine
from repro.graph import BufferedGraph, CSRGraph, erdos_renyi
from repro.stream.service import CoreService


def _scratch_state(n, edges):
    """(core, cnt) of the current edge set via a fresh SemiCore* run."""
    g = CSRGraph.from_edges(n, np.array(sorted(edges), np.int64).reshape(-1, 2))
    r = HostEngine(g, block_edges=16).semicore_star("seq")
    return r.core, r.cnt


def _op_stream(n, edges, steps, rng):
    """Yield ('i'|'d', u, v) ops valid against the evolving edge set."""
    for _ in range(steps):
        if edges and rng.random() < 0.45:
            u, v = sorted(edges)[int(rng.integers(len(edges)))]
            edges.discard((u, v))
            yield "d", u, v
        else:
            while True:
                u, v = int(rng.integers(n)), int(rng.integers(n))
                if u != v and (min(u, v), max(u, v)) not in edges:
                    break
            u, v = min(u, v), max(u, v)
            edges.add((u, v))
            yield "i", u, v


@pytest.mark.parametrize("insert_algorithm", ["semiinsert*", "semiinsert"])
def test_soak_interleaved_updates_match_recompute(insert_algorithm):
    n = 45
    rng = np.random.default_rng(17)
    g = erdos_renyi(n, 110, seed=17)
    edges = set(map(tuple, g.edge_list()))
    m = CoreMaintainer(g, block_edges=16)
    for step, (op, u, v) in enumerate(_op_stream(n, edges, 60, rng)):
        if op == "d":
            m.delete_edge(u, v)
        else:
            m.insert_edge(u, v, algorithm=insert_algorithm)
        core, cnt = _scratch_state(n, edges)
        np.testing.assert_array_equal(m.core, core, err_msg=f"step {step} {op} ({u},{v})")
        np.testing.assert_array_equal(m.cnt, cnt, err_msg=f"step {step} {op} ({u},{v})")


def test_soak_with_wal_recovery_mid_sequence(tmp_path):
    """Stream batches through a durable CoreService; at several cut points,
    recover from snapshot + WAL tail and require the recovered state to equal
    a from-scratch recompute of the current edge set."""
    n = 40
    rng = np.random.default_rng(23)
    g = erdos_renyi(n, 90, seed=23)
    edges = set(map(tuple, g.edge_list()))
    base = CSRGraph.from_edges(n, np.array(sorted(edges), np.int64))
    base_dir = os.path.join(tmp_path, "base")
    base.save(base_dir)

    wal = os.path.join(tmp_path, "wal.jsonl")
    snap = os.path.join(tmp_path, "snaps")
    svc = CoreService(
        base, block_edges=16, wal_path=wal, snapshot_dir=snap, snapshot_every=3
    )
    checkpoints = {2, 5, 9}
    batch = []
    nbatches = 0
    for op, u, v in _op_stream(n, edges, 50, rng):
        batch.append(("+" if op == "i" else "-", u, v))
        if len(batch) == 5:
            svc.ingest(batch)
            batch = []
            nbatches += 1
            if nbatches in checkpoints:
                rec, stats = CoreService.recover(
                    wal_path=wal,
                    snapshot_dir=snap,
                    base_graph=CSRGraph.load(base_dir),
                    block_edges=16,
                )
                core, cnt = _scratch_state(n, edges)
                np.testing.assert_array_equal(
                    rec.maintainer.core, core, err_msg=f"recovery @batch {nbatches}"
                )
                np.testing.assert_array_equal(
                    rec.maintainer.cnt, cnt, err_msg=f"recovery @batch {nbatches}"
                )
                assert stats.recovered_epoch == svc.epoch
                rec.close()
                # live service must agree too (recovery is read-only)
                np.testing.assert_array_equal(svc.maintainer.core, core)
    svc.close()


# ------------------------------------------------ bounded-buffer contract
def test_buffered_graph_rejected_updates_leave_no_empty_entries():
    """Regression (updates.py): membership probes on a defaultdict used to
    materialize an empty set per probed node, so rejected updates grew the
    buffer without bound on long streams."""
    g = erdos_renyi(200, 600, seed=1)
    bg = BufferedGraph(g)
    rng = np.random.default_rng(0)
    rejected = 0
    for _ in range(500):
        u, v = int(rng.integers(200)), int(rng.integers(200))
        if g.has_edge(u, v):
            rejected += not bg.insert_edge(u, v)  # exists -> rejected
        else:
            rejected += not bg.delete_edge(u, v)  # missing -> rejected
    assert rejected == 500  # every op above is a no-op by construction
    assert bg._ins == {} and bg._del == {}
    assert bg._size == 0


def test_buffered_graph_cancelling_updates_clean_up_entries():
    """insert-then-delete (and delete-then-insert) must not strand empty sets."""
    g = erdos_renyi(50, 120, seed=3)
    bg = BufferedGraph(g)
    u, v = 1, 2
    if not g.has_edge(u, v):
        assert bg.insert_edge(u, v) and bg.delete_edge(u, v)
    e = g.edge_list()[0]
    assert bg.delete_edge(int(e[0]), int(e[1]))
    assert bg.insert_edge(int(e[0]), int(e[1]))
    assert bg._ins == {} and bg._del == {} and bg._size == 0
    # merged reads see the unchanged graph
    for w in range(g.n):
        np.testing.assert_array_equal(
            np.sort(bg.merged_neighbors(w, g.neighbors(w))), np.sort(g.neighbors(w))
        )
