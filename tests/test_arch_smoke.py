"""Per-arch smoke tests: reduced configs, one real step on CPU per shape cell.

Asserts output shapes + finiteness for all 10 assigned archs x their 4 shapes
(40 cells, reduced sizes) + the paper's own decompose cell.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, shape_names, ARCH_IDS
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.launch.steps import build_step

CELLS = []
for arch in ARCH_IDS:
    for shape in shape_names(get_config(arch)):
        CELLS.append((arch, shape))


def materialize(avals, cfg, rng):
    """Random concrete inputs from ShapeDtypeStruct trees, domain-aware."""
    def gen(path, s):
        name = path[-1] if path else ""
        shape, dtype = s.shape, s.dtype
        if dtype == jnp.int32:
            hi = 4
            if name in ("tokens", "labels") and cfg.kind == "lm":
                hi = cfg.vocab
            elif name in ("hist_ids", "target_id", "negative_ids",
                          "candidate_ids"):
                hi = cfg.n_items
            elif name == "profile_ids":
                hi = cfg.profile_vocab
            elif name == "z":
                hi = 90
            elif name == "len":
                return jnp.zeros((), jnp.int32)
            elif name in ("src", "dst"):
                hi = gen.num_nodes
            elif name == "labels":
                hi = max(getattr(cfg, "num_classes", 4), 2)
            elif name == "graph_ids":
                n = shape[0]
                g = gen.num_graphs
                return jnp.asarray(np.repeat(np.arange(g), n // g)[:n], jnp.int32)
            return jnp.asarray(rng.integers(0, max(hi, 1), size=shape), jnp.int32)
        if dtype == jnp.bool_:
            return jnp.asarray(rng.random(shape) < 0.9)
        return jnp.asarray(rng.normal(size=shape) * 0.1, dtype)

    gen.num_nodes = None
    gen.num_graphs = 1

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, jax.ShapeDtypeStruct):
            return gen(path, tree)
        return tree

    return walk, gen


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_smoke(arch, shape):
    mesh = make_host_mesh()
    bundle = build_step(arch, shape, mesh, reduced=True)
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(42)
    walk, gen = materialize(None, cfg, rng)

    # find num_nodes for GNN cells (for src/dst ranges)
    if cfg.kind == "gnn":
        from repro.configs import input_specs
        _, av = input_specs(cfg, shape, reduced=True)
        gen.num_nodes = av["num_nodes"]
        if shape == "molecule":
            gen.num_graphs = 4

    args = list(walk(a) for a in bundle.args)
    if bundle.name == "train_step":
        # optimizer state must be *initialized*, not randomized (v >= 0)
        from repro.optim import adamw_init
        args[1] = adamw_init(args[0], bundle.static["opt"])
    args = tuple(args)
    fn = (jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                  out_shardings=bundle.out_shardings,
                  donate_argnums=bundle.donate_argnums)
          if bundle.in_shardings is not None else bundle.fn)
    with use_mesh(mesh):
        out = fn(*args)

    leaves = jax.tree.leaves(out)
    assert leaves, "no outputs"
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.isfinite(arr).all(), f"{arch}/{shape}: non-finite output"

    if bundle.name == "train_step":
        loss = float(np.asarray(leaves[-1]).reshape(-1)[0])
        assert np.isfinite(loss)


def test_semicore_webscale_reduced_cell():
    """The paper's own cell at reduced scale executes end-to-end."""
    from repro.graph import chung_lu
    from repro.core.imcore import imcore_peel
    from repro.core.distributed import distributed_decompose

    g = chung_lu(2000, 16000, seed=0)
    core, iters = distributed_decompose(g)
    np.testing.assert_array_equal(core, imcore_peel(g))
