"""Fused single-kernel Pallas superstep (DESIGN.md §16).

Three layers of evidence that the one-pallas_call-per-pass path is exact:

* a differential battery of ``fused_pass`` / ``fused_hindex`` /
  ``fused_counts`` against the eager jnp oracle (``kernels/ref.py``) on
  block-boundary shapes — empty/all/random frontiers, a single partial tail
  block, n not a multiple of the tile, isolated nodes;
* the paper's Fig. 2/4/5 cells end-to-end through the pallas backend, pinned
  bit-identical to the numpy planner traces;
* kernel_blocks_active/skipped parity against the per-probe
  ``segment_sum_active`` path (``REPRO_PALLAS_FUSED=0``) on all three
  algorithms — the replayed accounting may not notice which kernel ran.
"""
import math

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.graph import paper_example_graph, chung_lu, erdos_renyi  # noqa: E402
from repro.core.semicore import decompose  # noqa: E402
from repro.kernels.fused_superstep import (  # noqa: E402
    build_fused_table, fused_pass, fused_hindex, fused_counts,
    fused_block_edges)
from repro.kernels.ref import fused_superstep_ref  # noqa: E402

EXPECTED_CORES = np.array([3, 3, 3, 3, 2, 2, 2, 2, 1])
ALGORITHMS = ("semicore", "semicore+", "semicore*")


# ------------------------------------------------------------- differential
def _rand_csr(n, m, rng, iso_frac=0.0):
    """Random multigraph CSR; neighbors always point at present nodes."""
    deg = rng.integers(0, max(1, 2 * m // max(n, 1)), size=n)
    if iso_frac:
        deg[rng.random(n) < iso_frac] = 0
    seg_ptr = np.zeros(n + 1, dtype=np.int64)
    seg_ptr[1:] = np.cumsum(deg)
    E = int(seg_ptr[-1])
    pres = np.flatnonzero(deg > 0)
    if len(pres) == 0:
        return seg_ptr, np.zeros(0, np.int32)
    nbr = rng.choice(pres, size=E).astype(np.int32)
    return seg_ptr, nbr


def _one_case(n, m, cbe, rng, iso_frac, frontier_mode, algorithm):
    seg_ptr, nbr = _rand_csr(n, m, rng, iso_frac)
    deg = np.diff(seg_ptr)
    rows = np.repeat(np.arange(n, dtype=np.int32), deg)
    core = np.minimum(deg, rng.integers(0, 12, size=n)).astype(np.int32)
    core = np.where(deg > 0, np.maximum(core, 1), 0).astype(np.int32)
    cnt = rng.integers(0, 8, size=n).astype(np.int32)
    if frontier_mode == "empty":
        active = np.zeros(n, bool)
    elif frontier_mode == "all":
        active = core > 0
    else:
        active = (core > 0) & (rng.random(n) < 0.4)
    cmax = int(core[active].max()) if active.any() else 0
    num_probes = max(1, math.ceil(math.log2(cmax + 2)))

    ft = build_fused_table(seg_ptr, nbr, n, cbe)
    got = fused_pass(jnp.asarray(core), jnp.asarray(cnt), jnp.asarray(active),
                     ft.arrays, dims=ft.dims, num_probes=num_probes,
                     algorithm=algorithm, interpret=True)
    want = fused_superstep_ref(core, cnt, active, nbr, rows, n, algorithm)
    for name, g_, w_ in zip(("core2", "cnt2", "active2", "upd"), got, want):
        if w_ is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(g_), np.asarray(w_),
            err_msg=f"{algorithm}/{frontier_mode} n={n} cbe={cbe} {name}")
    return ft, core, active, cmax, nbr, rows


# (n, m, cbe, iso_frac, frontier): multi-block, single partial tail block,
# n not a multiple of anything, isolated nodes, empty/all/random frontiers
CASES = [
    (50, 200, 16, 0.0, "all"),
    (50, 200, 16, 0.0, "rand"),
    (50, 200, 16, 0.0, "empty"),
    (40, 60, 512, 0.0, "rand"),       # one partial tail block
    (33, 130, 16, 0.3, "rand"),       # isolated nodes, odd n
    (7, 9, 8, 0.0, "all"),            # tiny
]


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_fused_pass_matches_ref(algorithm):
    rng = np.random.default_rng(0)
    for (n, m, cbe, iso, fr) in CASES:
        _one_case(n, m, cbe, rng, iso, fr, algorithm)


def test_fused_hindex_and_counts_match_ref():
    rng = np.random.default_rng(1)
    for (n, m, cbe, iso, fr) in CASES:
        ft, core, active, cmax, nbr, rows = _one_case(
            n, m, cbe, rng, iso, fr, "semicore*")
        num_probes = max(1, math.ceil(math.log2(cmax + 2)))
        h_g, cnth_g = fused_hindex(
            jnp.asarray(core), jnp.asarray(active), ft.arrays, dims=ft.dims,
            num_probes=num_probes, interpret=True)
        want = fused_superstep_ref(core, None, active, nbr, rows, n,
                                   "semicore")
        h_want = np.where(active, np.asarray(want[0]), 0)
        np.testing.assert_array_equal(np.asarray(h_g) * active, h_want)
        # counts at arbitrary thresholds vs a numpy scatter
        thr = np.where(active, rng.integers(0, cmax + 1, size=n), 0)
        want_cnt = np.zeros(n, np.int64)
        np.add.at(want_cnt, rows,
                  (core[nbr] >= thr[rows]).astype(np.int64))
        tp = max(1, math.ceil(math.log2(int(thr.max()) + 2)))
        got_cnt = np.asarray(fused_counts(
            jnp.asarray(core), jnp.asarray(thr), jnp.asarray(active),
            ft.arrays, dims=ft.dims, num_probes=tp, interpret=True))
        np.testing.assert_array_equal(got_cnt[active], want_cnt[active])


def test_adaptive_tile_default(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED_BLOCK_EDGES", raising=False)
    assert fused_block_edges() == 512
    assert fused_block_edges(12_000) == 512
    assert fused_block_edges(26_000) == 2048
    assert fused_block_edges(1_000_000) == 8192
    monkeypatch.setenv("REPRO_FUSED_BLOCK_EDGES", "64")
    assert fused_block_edges(1_000_000) == 64


# ------------------------------------------------------- Fig. 2/4/5 pins
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_paper_example_trace_pins_through_fused_backend(algorithm):
    """Fig. 2/4/5 cells: the fused pallas batch run must walk the numpy
    planner's exact passes — same cores, iterations, planner I/O, and
    per-pass update counts."""
    g = paper_example_graph()
    rn = decompose(g, algorithm, "batch", block_edges=8, backend="numpy")
    rp = decompose(g, algorithm, "batch", block_edges=8, backend="pallas")
    np.testing.assert_array_equal(rp.core, EXPECTED_CORES)
    np.testing.assert_array_equal(rp.core, rn.core)
    assert rp.iterations == rn.iterations
    assert rp.edge_block_reads == rn.edge_block_reads
    assert rp.node_table_reads == rn.node_table_reads
    assert rp.updates_per_iter == rn.updates_per_iter
    if algorithm == "semicore*":
        np.testing.assert_array_equal(rp.cnt, rn.cnt)


# --------------------------------------- accounting parity vs per-probe
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_kernel_block_accounting_parity_vs_per_probe(algorithm, monkeypatch):
    """kernel_blocks_active/skipped replay identically whether the pallas
    backend runs the fused kernel or the PR 3 per-probe dispatch."""
    g = chung_lu(400, 1600, seed=3)
    monkeypatch.setenv("REPRO_PALLAS_FUSED", "0")
    r_probe = decompose(g, algorithm, "batch", block_edges=64,
                        backend="pallas")
    monkeypatch.setenv("REPRO_PALLAS_FUSED", "1")
    r_fused = decompose(g, algorithm, "batch", block_edges=64,
                        backend="pallas")
    np.testing.assert_array_equal(r_fused.core, r_probe.core)
    assert r_fused.iterations == r_probe.iterations
    assert r_fused.edge_block_reads == r_probe.edge_block_reads
    assert r_fused.kernel_blocks_active == r_probe.kernel_blocks_active
    assert r_fused.kernel_blocks_skipped == r_probe.kernel_blocks_skipped
    if algorithm == "semicore*":
        assert r_fused.kernel_blocks_skipped > 0


def test_fused_backend_matches_oracle_random():
    from repro.core.imcore import imcore_peel
    for seed in range(2):
        g = erdos_renyi(300, 900, seed=seed)
        expect = imcore_peel(g)
        for algorithm in ALGORITHMS:
            r = decompose(g, algorithm, "batch", block_edges=64,
                          backend="pallas")
            np.testing.assert_array_equal(r.core, expect,
                                          err_msg=f"{algorithm}/{seed}")
