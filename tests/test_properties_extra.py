"""Additional property-based coverage: EMCore, BufferedGraph, sampler,
degeneracy ordering, q8 codec, and layer invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.graph import CSRGraph, BufferedGraph, chung_lu, NeighborSampler
from repro.core.imcore import imcore_bz, imcore_peel
from repro.core.emcore import emcore
from repro.optim import q8_encode, q8_decode


@st.composite
def small_graph(draw):
    n = draw(st.integers(4, 50))
    e = draw(st.integers(1, min(n * (n - 1) // 2, 120)))
    edges = draw(st.lists(st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                          min_size=e, max_size=e))
    return CSRGraph.from_edges(n, np.array(edges, np.int64).reshape(-1, 2))


@given(small_graph(), st.integers(2, 6), st.integers(4, 64))
@settings(max_examples=60, deadline=None)
def test_property_emcore_matches_oracle(g, parts, budget):
    if g.m == 0:
        return
    r = emcore(g, num_partitions=parts,
               memory_budget_edges=max(budget, 4), block_edges=8)
    np.testing.assert_array_equal(r.core, imcore_bz(g))
    assert r.read_blocks >= 0 and r.peak_memory_edges <= g.num_directed


@given(small_graph())
@settings(max_examples=40, deadline=None)
def test_property_bz_equals_peel(g):
    np.testing.assert_array_equal(imcore_bz(g), imcore_peel(g))


@given(small_graph(), st.lists(st.tuples(st.integers(0, 49), st.integers(0, 49)),
                               max_size=12))
@settings(max_examples=40, deadline=None)
def test_property_buffered_graph_flush_equivalence(g, updates):
    """Buffered merged reads == post-flush CSR reads, update for update."""
    bg = BufferedGraph(g, buffer_capacity=1 << 20)  # never auto-flush
    applied = []
    for (u, v) in updates:
        u, v = u % g.n, v % g.n
        if u == v:
            continue
        if bg.degree(u) and np.isin(v, bg.merged_neighbors(u, g.neighbors(u))):
            if bg.delete_edge(u, v):
                applied.append(("d", u, v))
        else:
            if bg.insert_edge(u, v):
                applied.append(("i", u, v))
    merged = {v: np.sort(bg.merged_neighbors(v, g.neighbors(v)))
              for v in range(g.n)}
    flushed = bg.materialize()
    for v in range(g.n):
        np.testing.assert_array_equal(merged[v], np.sort(flushed.neighbors(v)))


def test_sampler_uniformity():
    """Sampled neighbors come from the true neighbor set, ~uniformly."""
    g = chung_lu(500, 3000, seed=0)
    s = NeighborSampler(g, seed=1)
    v = int(np.argmax(g.degrees()))
    nbrs = set(g.neighbors(v).tolist())
    counts = {}
    for _ in range(200):
        blk = s.sample_hop(np.array([v]), 8)
        for u in blk.neighbors[0]:
            assert int(u) in nbrs
            counts[int(u)] = counts.get(int(u), 0) + 1
    # a high-degree node's sample should touch many distinct neighbors
    assert len(counts) > min(len(nbrs), 8 * 200) * 0.2


def test_degeneracy_order_improves_frontier_locality():
    """Core-ordered relabeling clusters same-core nodes into contiguous id
    ranges — the paper's ordering as a block-locality lever (DESIGN §8)."""
    g = chung_lu(4000, 30000, seed=2)
    core = imcore_peel(g)
    order = np.argsort(-core, kind="stable")
    perm = np.empty(g.n, np.int64)
    perm[order] = np.arange(g.n)
    g2 = g.relabel(perm)
    core2 = imcore_peel(g2)
    np.testing.assert_array_equal(np.sort(core), np.sort(core2))
    # after relabeling, the top-core nodes occupy a contiguous prefix
    kmax = core2.max()
    top = np.flatnonzero(core2 == kmax)
    assert top.max() - top.min() + 1 == len(top)


@given(st.integers(1, 4096), st.floats(0.001, 100.0))
@settings(max_examples=40, deadline=None)
def test_property_q8_bounded_error(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = q8_encode(x)
    y = q8_decode(q, s, (n,))
    blockwise_max = np.abs(np.asarray(x)).max() + 1e-12
    assert float(jnp.abs(y - x).max()) <= blockwise_max / 127.0 + 1e-6


def test_rope_preserves_norm_and_relative_phase():
    from repro.models.layers import rope
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 64)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    def dot_at(i, j):
        qi = rope(q, jnp.full((1, 1), i))
        kj = rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


def test_chunked_attention_equals_full_softmax():
    from repro.models.layers import chunked_attention
    rng = np.random.default_rng(1)
    B, S, H, Hkv, d = 2, 33, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
    got = chunked_attention(q, k, v, chunk=8, causal=True)
    # dense reference
    G = H // Hkv
    qg = np.asarray(q).reshape(B, S, Hkv, G, d)
    s = np.einsum("bshgd,bthd->bhgst", qg, np.asarray(k)) / np.sqrt(d)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhgst,bthd->bshgd", p, np.asarray(v)).reshape(B, S, H, d)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
