"""External-memory CSR builder (graph/build.py): the disk pipeline must be
byte-identical to ``CSRGraph.from_edges`` for every ingest source, chunk
size, and relabel mode — and the decomposition of the memmap-loaded result
must match the in-memory build exactly (DESIGN.md §10)."""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.imcore import imcore_bz
from repro.core.semicore import decompose
from repro.graph import (
    CSRGraph,
    build_csr,
    edge_chunks_from_npy,
    edge_chunks_from_text,
    powerlaw_chunks,
    rmat_chunks,
    uniform_chunks,
)


def _assert_same_layout(out_dir, n, edges):
    """Disk tables == the from_edges layout, byte for byte."""
    g_disk = CSRGraph.load(str(out_dir), mmap=True)
    g_mem = CSRGraph.from_edges(n, edges)
    np.testing.assert_array_equal(np.asarray(g_disk.indptr), g_mem.indptr)
    np.testing.assert_array_equal(np.asarray(g_disk.adj), g_mem.adj)
    return g_disk, g_mem


@pytest.mark.parametrize("chunk_edges", [1024, 4096])  # 1024 = builder floor
def test_build_matches_from_edges_random(tmp_path, chunk_edges):
    rng = np.random.default_rng(3)
    n, e = 400, rng.integers(0, 400, size=(5000, 2), dtype=np.int64)
    # feed deliberately ragged chunks, duplicates, self loops, both orientations
    chunks = [e[i : i + 313] for i in range(0, len(e), 313)]
    stats = build_csr(iter(chunks), str(tmp_path / "g"), n=n, chunk_edges=chunk_edges)
    g_disk, g_mem = _assert_same_layout(tmp_path / "g", n, e)
    assert stats.n == n and stats.m == g_mem.m
    assert stats.edges_ingested == len(e)
    assert stats.runs >= 1 and stats.merge_rounds >= 1
    # decompose the memmapped build == decompose the in-memory build
    r_disk = decompose(g_disk, "semicore*", "batch", block_edges=64)
    r_mem = decompose(g_mem, "semicore*", "batch", block_edges=64)
    np.testing.assert_array_equal(r_disk.core, r_mem.core)
    np.testing.assert_array_equal(r_disk.core, imcore_bz(g_mem))


def test_build_from_npy_shards(tmp_path):
    rng = np.random.default_rng(5)
    n = 300
    parts = [rng.integers(0, n, size=(k, 2), dtype=np.int64) for k in (900, 1300, 1)]
    paths = []
    for i, p in enumerate(parts):
        path = str(tmp_path / f"shard{i}.npy")
        np.save(path, p)
        paths.append(path)
    stats = build_csr(paths, str(tmp_path / "g"), n=n, chunk_edges=1024)
    _assert_same_layout(tmp_path / "g", n, np.concatenate(parts))
    assert stats.edges_ingested == sum(len(p) for p in parts)
    # the shard reader itself must slice, not load
    got = np.concatenate(list(edge_chunks_from_npy(paths, chunk_edges=100)))
    np.testing.assert_array_equal(got, np.concatenate(parts))


def test_build_from_text_edge_list(tmp_path):
    rng = np.random.default_rng(7)
    n, e = 120, rng.integers(0, 120, size=(800, 2), dtype=np.int64)
    path = tmp_path / "edges.txt"
    with open(path, "w") as f:
        f.write("# SNAP-style header\n% konect header\n\n")
        for u, v in e:
            f.write(f"{u}\t{v}\n")
    build_csr(str(path), str(tmp_path / "g"), n=n, chunk_edges=1024)
    _assert_same_layout(tmp_path / "g", n, e)
    got = np.concatenate(list(edge_chunks_from_text(str(path), chunk_edges=97)))
    np.testing.assert_array_equal(got, e)


def test_build_infers_n_and_validates_explicit_n(tmp_path):
    e = np.array([(0, 9), (3, 4), (9, 3)], np.int64)
    stats = build_csr([e], str(tmp_path / "g"))
    assert stats.n == 10
    with pytest.raises(ValueError, match="exceed"):
        build_csr([e], str(tmp_path / "g2"), n=5)


def test_build_empty_and_isolated(tmp_path):
    stats = build_csr(iter([]), str(tmp_path / "empty"))
    g = CSRGraph.load(str(tmp_path / "empty"))
    assert (g.n, g.m, stats.m) == (0, 0, 0)
    # isolated tail nodes exist only via explicit n
    e = np.array([(1, 2)], np.int64)
    build_csr([e], str(tmp_path / "iso"), n=6)
    g = CSRGraph.load(str(tmp_path / "iso"))
    assert g.n == 6 and g.m == 1 and g.degree(5) == 0


def test_build_degree_relabel(tmp_path):
    rng = np.random.default_rng(11)
    n, e = 250, rng.integers(0, 250, size=(3000, 2), dtype=np.int64)
    stats = build_csr([e], str(tmp_path / "g"), n=n, relabel="degree", chunk_edges=1024)
    g = CSRGraph.load(str(tmp_path / "g"))
    deg = g.degrees()
    assert np.all(np.diff(deg) <= 0), "ids must be degree-descending"
    # the relabeled build == from_edges on the permuted edge list
    base = CSRGraph.from_edges(n, e)
    np.testing.assert_array_equal(np.asarray(g.adj), base.relabel(stats.perm).adj)
    # cores are invariant under relabeling: core_new[perm[v]] == core_old[v]
    core_new = decompose(g, "semicore*", "batch").core
    core_old = imcore_bz(base)
    np.testing.assert_array_equal(core_new[stats.perm], core_old)


def test_build_streaming_generators_feed_builder(tmp_path):
    """rmat/powerlaw/uniform chunk streams build the same graph as the
    equivalent concatenated array (and are deterministic in seed)."""
    for name, mk in (
        ("rmat", lambda: rmat_chunks(8, 6, seed=2, chunk_edges=500)),
        ("powerlaw", lambda: powerlaw_chunks(400, 2500, seed=2, chunk_edges=700)),
        ("uniform", lambda: uniform_chunks(300, 2000, seed=2, chunk_edges=611)),
    ):
        e = np.concatenate(list(mk()))
        stats = build_csr(mk(), str(tmp_path / name), chunk_edges=1024)
        _assert_same_layout(tmp_path / name, stats.n, e)


def test_build_peak_scratch_stays_chunk_bounded(tmp_path):
    """Scratch per stage tracks the chunk budget, not m: many small chunks
    through a small chunk_edges must not accumulate."""
    rng = np.random.default_rng(13)
    e = rng.integers(0, 3000, size=(60_000, 2), dtype=np.int64)
    chunk_edges = 2048
    chunks = (e[i : i + 500] for i in range(0, len(e), 500))
    stats = build_csr(chunks, str(tmp_path / "g"), n=3000, chunk_edges=chunk_edges)
    _assert_same_layout(tmp_path / "g", 3000, e)
    assert stats.runs >= 20  # the run budget was actually exercised
    # run formation buffers < chunk + one ingest chunk; merge holds ≤ 2 chunks
    assert stats.peak_scratch_edges <= 4 * chunk_edges
    assert stats.node_state_bytes == 3000 * 24


@st.composite
def chunked_edge_stream(draw):
    n = draw(st.integers(2, 60))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)), max_size=150
        )
    )
    parts = draw(st.integers(1, 7))  # chunk split
    return n, edges, parts


@given(chunked_edge_stream())
@settings(max_examples=30, deadline=None)
def test_property_build_equals_from_edges(params):
    n, edges, parts = params
    import tempfile

    e = np.array(edges, np.int64).reshape(-1, 2)
    split = np.array_split(e, parts)
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "g")
        build_csr(iter(split), out, n=n, chunk_edges=1024)
        g_disk = CSRGraph.load(out, mmap=True)
        g_mem = CSRGraph.from_edges(n, e)
        np.testing.assert_array_equal(np.asarray(g_disk.indptr), g_mem.indptr)
        np.testing.assert_array_equal(np.asarray(g_disk.adj), g_mem.adj)
