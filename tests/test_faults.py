"""Fault-injection harness + durability matrix (DESIGN.md §17).

Covers the seeded ``FaultPlan`` layer, CRC32C WAL/snapshot framing, the
parametrized bit-flip corruption matrix (replay / tailer / tip_epoch /
reopen / rotation-repair boundaries), retry + circuit-breaker recovery,
dir-fsync power-loss regressions, admission backpressure, and the chaos
soak: a writer plus two replicas under a randomized seeded fault schedule
must end bit-identical to the in-memory oracle for every seed.
"""
import errno
import json
import os

import numpy as np
import pytest

from repro.core import imcore_bz
from repro.faults import (CircuitBreaker, FaultInjected, FaultPlan, FaultRule,
                          RetryPolicy, flip_bit, inject, simulate_power_loss)
from repro.graph import chung_lu
from repro.obs.metrics import counter
from repro.stream import (CoreReplica, CoreWriter, CorruptionError,
                          Overloaded, SnapshotStore, UpdateBatch, WalTailer,
                          WriteAheadLog, crc32c, mixed_stream)
from repro.stream.integrity import frame_record, is_framed, unframe


def no_sleep(_seconds):
    return None


def fast_retry(retries=4, **kw):
    kw.setdefault("base_delay", 0.0)
    return RetryPolicy(retries, sleep=no_sleep, **kw)


def batches(ops, size):
    return [ops[i : i + size] for i in range(0, len(ops), size)]


def framed_wal(path, n):
    """A WAL of n framed records, epochs 1..n, one insert each."""
    w = WriteAheadLog(path)
    for e in range(1, n + 1):
        w.append(e, UpdateBatch.from_pairs([], [(0, e)]))
    w.close()


def record_spans(path):
    """[(byte offset, byte length)] of each line in the log."""
    spans, off = [], 0
    with open(path, "rb") as f:
        for line in f:
            spans.append((off, len(line)))
            off += len(line)
    return spans


def flip_record(path, k):
    """Flip one payload bit inside record k (0-based)."""
    off, ln = record_spans(path)[k]
    flip_bit(path, off + ln - 3)  # inside the JSON payload, not the newline
    return off


def make_writer(tmp_path, *, n=300, m=1200, seed=3, **kw):
    g = chung_lu(n, m, seed=seed)
    kw.setdefault("block_edges", 128)
    w = CoreWriter(g, wal_path=str(tmp_path / "wal.log"),
                   snapshot_dir=str(tmp_path / "snaps"), **kw)
    return w, str(tmp_path / "wal.log"), str(tmp_path / "snaps")


def assert_converged(rep, w):
    assert rep.epoch == w.epoch
    np.testing.assert_array_equal(rep.maintainer.core, w.maintainer.core)
    np.testing.assert_array_equal(rep.maintainer.cnt, w.maintainer.cnt)


# ============================================================== FaultPlan
def test_chaos_plan_is_reproducible_from_its_seed():
    rates = {"wal.append": {"io_error": 0.5, "latency": 0.3}}
    ops = ["wal.append"] * 40 + ["wal.fsync"] * 10
    logs = []
    for _ in range(2):
        plan = FaultPlan.chaos(7, rates)
        for op in ops:
            plan.decide(op)
        logs.append(list(plan.log))
    assert logs[0] == logs[1]
    assert logs[0]  # the schedule actually fired at these rates
    other = FaultPlan.chaos(8, rates)
    for op in ops:
        other.decide(op)
    assert list(other.log) != logs[0]


def test_scripted_rule_fires_at_exact_nth_op():
    plan = FaultPlan([FaultRule("wal.append", "io_error", nth=3)])
    fired = [plan.decide("wal.append") for _ in range(5)]
    assert [d is not None for d in fired] == [False, False, True, False, False]
    kind, _arg, count = fired[2]
    assert (kind, count) == ("io_error", 3)
    assert plan.injected[("wal.append", "io_error")] == 1


def test_rule_patterns_fnmatch_and_every():
    plan = FaultPlan([FaultRule("wal.*", "latency", every=2, arg=0.0)])
    hits = [plan.decide("wal.append") is not None for _ in range(4)]
    assert hits == [False, True, False, True]
    assert plan.decide("snapshot.save") is None  # pattern does not match
    assert plan.total_injected == 2


def test_injected_faults_are_visible_in_the_metric(tmp_path):
    fam = counter("repro_faults_injected_total")
    before = fam.value
    plan = FaultPlan([FaultRule("wal.append", "io_error", nth=1)])
    w = WriteAheadLog(str(tmp_path / "wal.log"))
    with inject(plan):
        with pytest.raises(FaultInjected) as ei:
            w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    w.close()
    assert (ei.value.op, ei.value.kind, ei.value.index) == \
        ("wal.append", "io_error", 1)
    assert plan.total_injected == 1
    assert fam.value - before == 1


# ========================================================== CRC32C framing
def test_crc32c_known_answer():
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0


def test_frame_roundtrip_and_flip_detection():
    payload = b'{"epoch":7,"del":[],"ins":[[0,7]]}'
    line = frame_record(payload)
    assert is_framed(line)
    assert unframe(line) == payload
    # any single-bit payload flip fails the checksum
    corrupt = bytearray(line)
    corrupt[-3] ^= 0x10
    with pytest.raises(CorruptionError):
        unframe(bytes(corrupt))
    # a short frame (torn write) fails the length check, not the CRC
    with pytest.raises(CorruptionError) as ei:
        unframe(line[:-8] + b"\n")
    assert "torn" in str(ei.value)


# ================================================ WAL corruption matrix
N_RECORDS = 5


@pytest.mark.parametrize("k", range(N_RECORDS))
def test_bitflip_matrix_replay(tmp_path, k):
    """Interior corruption raises a typed error with its offset; a corrupt
    final record is indistinguishable from a torn tail and is skipped."""
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, N_RECORDS)
    off = flip_record(wal, k)
    if k == N_RECORDS - 1:
        got = [e for e, _ in WriteAheadLog.replay(wal)]
        assert got == list(range(1, N_RECORDS))
    else:
        with pytest.raises(CorruptionError) as ei:
            list(WriteAheadLog.replay(wal))
        assert ei.value.path == wal
        assert ei.value.offset == off


@pytest.mark.parametrize("k", range(N_RECORDS))
def test_bitflip_matrix_tip_epoch(tmp_path, k):
    """The O(record) tail probe never reads interior records: only tail
    corruption is visible to it, and it steps over at most one record."""
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, N_RECORDS)
    flip_record(wal, k)
    if k == N_RECORDS - 1:  # steps over exactly one unacknowledged tail
        assert WriteAheadLog.tip_epoch(wal) == N_RECORDS - 1
    else:  # interior flips are the replay/tailer layers' job to catch
        assert WriteAheadLog.tip_epoch(wal) == N_RECORDS


def test_tip_epoch_raises_on_two_corrupt_tail_records(tmp_path):
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, N_RECORDS)
    flip_record(wal, N_RECORDS - 1)
    flip_record(wal, N_RECORDS - 2)
    with pytest.raises(CorruptionError):
        WriteAheadLog.tip_epoch(wal)


@pytest.mark.parametrize("k", [1, 2, N_RECORDS - 1])
def test_bitflip_matrix_tailer(tmp_path, k):
    """The tailer delivers the intact prefix, then raises without advancing
    its cursor past the corrupt record — every poll re-detects it."""
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, N_RECORDS)
    off = flip_record(wal, k)
    t = WalTailer(wal)
    got = []
    with pytest.raises(CorruptionError):
        for rec in t.poll():
            got.append(rec[0])
    assert got == list(range(1, k + 1))
    assert t.offset == off
    with pytest.raises(CorruptionError):  # cursor did not advance
        list(t.poll())
    assert t.offset == off


def test_corrupt_final_record_truncated_on_reopen(tmp_path):
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, N_RECORDS)
    flip_record(wal, N_RECORDS - 1)
    w = WriteAheadLog(wal)  # reopen drops the unacknowledged corrupt tail
    w.append(N_RECORDS, UpdateBatch.from_pairs([], [(1, 2)]))
    w.close()
    got = [(e, b.inserts) for e, b in WriteAheadLog.replay(wal)]
    assert [e for e, _ in got] == list(range(1, N_RECORDS + 1))
    assert got[-1][1] == [(1, 2)]


def test_rotation_repairs_interior_corruption(tmp_path):
    wal = str(tmp_path / "wal.log")
    fam = counter("repro_wal_repaired_records_total")
    before = fam.value
    framed_wal(wal, N_RECORDS)
    flip_record(wal, 2)
    w = WriteAheadLog(wal)
    w.rotate(0)  # nothing superseded: only the corrupt record is dropped
    w.close()
    assert w.repaired == 1
    assert fam.value - before == 1
    got = [e for e, _ in WriteAheadLog.replay(wal)]
    assert got == [1, 2, 4, 5]  # epoch 3 was unrecoverable


def test_legacy_unframed_wal_still_replays(tmp_path):
    wal = str(tmp_path / "wal.log")
    with open(wal, "w") as f:
        f.write('{"epoch": 1, "del": [], "ins": [[0, 1]]}\n')
        f.write('{"epoch": 2, "del": [[0, 1]], "ins": []}\n')
    w = WriteAheadLog(wal)  # appends framed records after legacy ones
    w.append(3, UpdateBatch.from_pairs([], [(2, 3)]))
    w.close()
    got = [e for e, _ in WriteAheadLog.replay(wal)]
    assert got == [1, 2, 3]
    # the tailer types legacy corruption too (wrapped, cursor pinned)
    with open(wal, "r+") as f:
        f.seek(0)
        f.write('{"epoch" garbage')
    t = WalTailer(wal)
    with pytest.raises(CorruptionError):
        list(t.poll())
    assert t.offset == 0


def test_rotation_reframes_legacy_records(tmp_path):
    wal = str(tmp_path / "wal.log")
    with open(wal, "w") as f:
        f.write('{"epoch": 1, "del": [], "ins": [[0, 1]]}\n')
        f.write('{"epoch": 2, "del": [], "ins": [[2, 3]]}\n')
    w = WriteAheadLog(wal)
    w.rotate(1)
    w.close()
    with open(wal, "rb") as f:
        lines = f.readlines()
    assert len(lines) == 1 and is_framed(lines[0])
    assert [e for e, _ in WriteAheadLog.replay(wal)] == [2]


def test_torn_append_self_heals_for_retry(tmp_path):
    wal = str(tmp_path / "wal.log")
    w = WriteAheadLog(wal)
    w.append(1, UpdateBatch.from_pairs([], [(0, 1)]))
    plan = FaultPlan([FaultRule("wal.append", "torn_write", nth=1, arg=0.5)])
    with inject(plan):
        with pytest.raises(FaultInjected):
            w.append(2, UpdateBatch.from_pairs([], [(2, 3)]))
        w.append(2, UpdateBatch.from_pairs([], [(2, 3)]))  # retry lands on a clean offset
    w.close()
    assert plan.total_injected == 1
    got = [e for e, _ in WriteAheadLog.replay(wal)]
    assert got == [1, 2]  # no torn fragment, no duplicate


# =========================================================== snapshots
def _dummy_store(tmp_path, *, keep, epochs):
    g = chung_lu(60, 200, seed=1)
    store = SnapshotStore(str(tmp_path / "snaps"), keep=keep)
    core = imcore_bz(g)
    cnt = np.ones(g.n, dtype=np.int64)
    for e in epochs:
        store.save(e, g, core + e, cnt)
    return store, g


def test_snapshot_flip_falls_back_to_older(tmp_path):
    fam = counter("repro_snapshot_fallbacks_total")
    before = fam.value
    store, _g = _dummy_store(tmp_path, keep=2, epochs=[1, 2])
    flip_bit(os.path.join(store._dir(2), "core.npy"), -9)
    epoch, _graph, core, _cnt = store.latest()
    assert epoch == 1
    assert store.fallbacks == 1
    assert fam.value - before == 1
    assert core[0] == imcore_bz(chung_lu(60, 200, seed=1))[0] + 1


def test_snapshot_all_corrupt_raises_typed(tmp_path):
    store, _g = _dummy_store(tmp_path, keep=1, epochs=[1])
    flip_bit(os.path.join(store._dir(1), store.MANIFEST), 8)
    with pytest.raises(CorruptionError) as ei:
        store.latest()
    assert ei.value.layer == "snapshot"


def test_snapshot_manifest_tamper_detected(tmp_path):
    """Editing the manifest itself (consistent JSON, wrong self-CRC) fails."""
    store, _g = _dummy_store(tmp_path, keep=1, epochs=[3])
    mpath = os.path.join(store._dir(3), store.MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["epoch"] = 4  # body no longer matches the embedded checksum
    with open(mpath, "w") as f:
        json.dump(manifest, f, sort_keys=True, separators=(",", ":"))
    with pytest.raises(CorruptionError, match="manifest checksum"):
        store.verify(store._dir(3))


def test_legacy_snapshot_without_manifest_loads(tmp_path):
    store, _g = _dummy_store(tmp_path, keep=1, epochs=[5])
    os.remove(os.path.join(store._dir(5), store.MANIFEST))
    epoch, _graph, _core, _cnt = store.latest()
    assert epoch == 5


def test_keep_n_retention_and_rotation_floor(tmp_path):
    store, _g = _dummy_store(tmp_path, keep=2, epochs=[1, 2, 3])
    assert store.latest_epoch() == 3
    assert store.oldest_retained_epoch() == 2  # epoch 1 was GC'd
    assert len(store._names()) == 2


def test_enospc_on_snapshot_save_leaves_store_usable(tmp_path):
    w, _wal, _snaps = make_writer(tmp_path, snapshot_keep=2)
    w.snapshot()
    plan = FaultPlan([FaultRule("snapshot.save", "enospc", nth=1)])
    with inject(plan):
        with pytest.raises(FaultInjected) as ei:
            w.snapshot()
    assert ei.value.errno == errno.ENOSPC
    assert w.snapshots.latest()[0] == 0  # previous snapshot intact
    w.ingest([("+", 0, 1)])
    w.snapshot()  # clean retry succeeds
    assert w.snapshots.latest_epoch() == 1


# ============================================ power loss / dir fsync
def test_snapshot_publish_needs_the_directory_fsync(tmp_path):
    """Satellite regression: with the parent-dir fsync swallowed (lying
    fsync), a power loss un-publishes the snapshot rename; with it honored
    the publish survives."""
    lying = FaultPlan([FaultRule("snapshot.dirsync", "lying_fsync", every=1)],
                      track_durability=True)
    with inject(lying):
        store, _g = _dummy_store(tmp_path, keep=1, epochs=[1])
        simulate_power_loss()
        assert store.latest() is None  # the publish rename was lost
    honest = FaultPlan(track_durability=True)
    with inject(honest):
        g = chung_lu(60, 200, seed=1)
        store.save(2, g, np.zeros(g.n, np.int64), np.zeros(g.n, np.int64))
        simulate_power_loss()
        assert store.latest()[0] == 2


def test_wal_rotation_needs_the_directory_fsync(tmp_path):
    wal = str(tmp_path / "wal.log")
    framed_wal(wal, 4)
    pre = (tmp_path / "wal.log").read_bytes()
    lying = FaultPlan([FaultRule("wal.dirsync", "lying_fsync", every=1)],
                      track_durability=True)
    with inject(lying):
        w = WriteAheadLog(wal, fsync=True)
        w.rotate(2)
        w.close()
        simulate_power_loss()
    # rename not durable: power loss rolls back to the unrotated log
    assert (tmp_path / "wal.log").read_bytes() == pre
    honest = FaultPlan(track_durability=True)
    with inject(honest):
        w = WriteAheadLog(wal, fsync=True)
        w.rotate(2)
        w.close()
        simulate_power_loss()
    got = [e for e, _ in WriteAheadLog.replay(wal)]
    assert got == [3, 4]  # the rotation survived the crash


# ======================================================= retry / breaker
def test_retry_delays_deterministic_and_bounded():
    mk = lambda: RetryPolicy(4, base_delay=0.01, max_delay=0.05, jitter=0.5,
                             seed=9, sleep=no_sleep)
    a, b = list(mk().delays()), list(mk().delays())
    assert a == b and len(a) == 4
    assert all(0 < d <= 0.05 for d in a)
    nojit = RetryPolicy(3, base_delay=0.01, max_delay=1.0, jitter=0.0,
                        sleep=no_sleep)
    assert list(nojit.delays()) == [0.01, 0.02, 0.04]


def test_retry_deadline_stops_early():
    p = RetryPolicy(10, base_delay=0.5, jitter=0.0, deadline=1.0,
                    sleep=no_sleep)
    assert len(list(p.delays())) < 10


def test_retry_call_recovers_then_exhausts():
    retried = counter("repro_retries_total")
    exhausted = counter("repro_retries_exhausted_total")
    r0, e0 = retried.value, exhausted.value
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert fast_retry(4).call(flaky, op="unit") == "ok"
    assert calls["n"] == 3
    assert retried.value - r0 == 2

    def always():
        raise OSError("permanent")

    with pytest.raises(OSError):
        fast_retry(2).call(always, op="unit")
    assert exhausted.value - e0 == 1


def test_retry_only_catches_listed_exceptions():
    def bad():
        raise ValueError("not retryable")

    with pytest.raises(ValueError):
        fast_retry(3).call(bad, op="unit", retry_on=(OSError,))


def test_circuit_breaker_trips_once_then_resets():
    b = CircuitBreaker(trip_after=3)
    assert [b.record_failure() for _ in range(4)] == \
        [False, False, True, False]
    assert b.tripped and b.trips == 1
    b.record_success()
    assert not b.tripped and b.consecutive_failures == 0


# ====================================== BlockReader faults (satellite 2)
def test_block_read_fault_then_retry_keeps_accounting_exact(tmp_path):
    from repro.core.semicore import HostEngine

    g = chung_lu(400, 1600, seed=2)
    clean = HostEngine(g, block_edges=32, pool_blocks=8)
    res_clean = clean.semicore_star("seq")

    plan = FaultPlan([FaultRule("block.read", "io_error", every=13)])
    eng = HostEngine(g, block_edges=32, pool_blocks=8, retry=fast_retry(6))
    with inject(plan):
        res = eng.semicore_star("seq")
    assert plan.total_injected > 0
    np.testing.assert_array_equal(res.core, res_clean.core)
    # a failed fill is never charged: the retried run's misses equal the
    # clean run's exactly; re-touching the span's already-filled prefix on
    # retry books as extra pool hits, never as reads
    a, b = clean.reader, eng.reader
    assert b.reads == a.reads
    assert b.hits >= a.hits
    assert len(b._pool) == len(a._pool)


def test_block_read_without_retry_propagates(tmp_path):
    from repro.core.semicore import HostEngine

    g = chung_lu(100, 400, seed=2)
    eng = HostEngine(g, block_edges=32, pool_blocks=4)
    with inject(FaultPlan([FaultRule("block.read", "io_error", nth=1)])):
        with pytest.raises(FaultInjected):
            eng.semicore_star("seq")


# ===================================================== writer recovery
def test_writer_recover_truncates_at_interior_corruption(tmp_path):
    from repro.stream import CoreService

    w, wal, snaps = make_writer(tmp_path)
    w.snapshot()
    ops, _ = mixed_stream(w.bg.materialize(), 60, seed=4)
    all_batches = batches(ops, 10)
    for b in all_batches:
        w.ingest(b)
    w.wal.close()
    flip_record(wal, 3)  # epoch 4 of 6 becomes unreadable

    w2, _rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                  block_edges=128)
    assert w2.epoch == 3  # the intact prefix, nothing past the corruption
    assert [e for e, _ in WriteAheadLog.replay(wal)] == [1, 2, 3]

    expect, _, _ = make_writer(tmp_path / "expect")
    for b in all_batches[:3]:
        expect.ingest(b)
    np.testing.assert_array_equal(w2.maintainer.core, expect.maintainer.core)
    np.testing.assert_array_equal(w2.maintainer.cnt, expect.maintainer.cnt)
    np.testing.assert_array_equal(
        w2.maintainer.core, imcore_bz(w2.bg.materialize()))


# ==================================================== replica recovery
def test_replica_corruption_bootstraps_then_rotation_unwedges(tmp_path):
    w, wal, snaps = make_writer(tmp_path)
    ops, _ = mixed_stream(w.bg.materialize(), 60, seed=5)
    bs = batches(ops, 10)
    for b in bs[:3]:
        w.ingest(b)
    w.snapshot()  # snapshot at epoch 3
    for b in bs[3:]:
        w.ingest(b)  # epochs 4..6
    # the snapshot's rotation left records 4..6: flip epoch 5 (interior)
    flip_record(wal, 1)

    rep = CoreReplica(snapshot_dir=snaps, wal_path=wal, block_edges=128)
    assert rep.epoch == 4  # bootstrap stops at the intact prefix
    rep.sync()  # re-detects the corruption, falls back to a bootstrap
    assert rep.sync_failures >= 1
    assert rep.bootstraps >= 2
    assert rep.epoch == 4  # pinned before the bad record until repaired

    w.snapshot()  # snapshot at 6 + rotation: the corrupt record is repaired
    assert w.wal.repaired == 1
    rep.sync()
    assert_converged(rep, w)
    assert rep.health()["status"] == "ok"


def test_replica_breaker_trips_transient_polls_to_bootstrap(tmp_path):
    w, wal, snaps = make_writer(tmp_path)
    ops, _ = mixed_stream(w.bg.materialize(), 40, seed=6)
    for b in batches(ops, 10):
        w.ingest(b)
    w.snapshot()
    rep = CoreReplica(snapshot_dir=snaps, wal_path=wal, block_edges=128,
                      breaker_trip_after=2)
    for b in batches(mixed_stream(w.bg.materialize(), 20, seed=7)[0], 10):
        w.ingest(b)

    with inject(FaultPlan([FaultRule("wal.poll", "io_error", every=1)])):
        rep.sync()  # transient failure 1: serve stale, count it
        assert rep.stale_serving and not rep.breaker.tripped
        assert rep.health()["status"] == "degraded"
        rep.sync()  # failure 2 trips the breaker -> bootstrap attempt
        assert rep.breaker.tripped
        # the bootstrap's own catch-up poll hits the same outage: counted,
        # and the replica keeps serving its last good views
        assert rep.bootstrap_failures >= 1
        assert rep.stale_serving
    assert rep.sync_failures == 2
    rep.sync()  # outage over: the pinned cursor drains to the tip
    assert not rep.stale_serving
    assert rep.breaker.consecutive_failures == 0
    assert_converged(rep, w)
    assert rep.health()["status"] == "ok"


def test_replica_survives_total_outage_and_stays_stale(tmp_path):
    w, wal, snaps = make_writer(tmp_path)
    w.snapshot()
    w.ingest([("+", 0, 1)])
    rep = CoreReplica(snapshot_dir=snaps, wal_path=wal, block_edges=128,
                      breaker_trip_after=1)
    before = rep.epoch
    plan = FaultPlan([FaultRule("wal.poll", "io_error", every=1),
                      FaultRule("snapshot.load", "io_error", every=1)])
    with inject(plan):
        rep.sync()  # poll fails, breaker trips, bootstrap fails too
        assert rep.stale_serving
        assert rep.bootstrap_failures >= 1
        assert rep.epoch == before  # still serving the last good views
        assert rep.health()["status"] == "degraded"
    rep.sync()
    assert_converged(rep, w)


# ======================================================== backpressure
def test_admission_defers_then_bounded_staleness_applies(tmp_path):
    w, _wal, _snaps = make_writer(
        tmp_path, admission_budget=64, admission_soft_ratio=0.15,
        admission_max_defer=3)
    ops, _ = mixed_stream(w.bg.materialize(), 96, seed=8)
    stats = [w.ingest(b) for b in batches(ops, 12)]
    flags = [int(s.deferred) for s in stats]
    assert flags == [1, 1, 1, 0, 1, 1, 1, 0]  # max_defer bounds staleness
    soft = w.admission.soft
    for s in stats:  # deferred batches hold a pool above the soft budget;
        if s.deferred:  # every apply drains it to zero (all-or-nothing)
            assert s.pending_updates > soft
        else:
            assert s.pending_updates == 0
    # while deferring, health declares the bounded-stale window
    w2, _, _ = make_writer(tmp_path / "mid", admission_budget=64,
                           admission_soft_ratio=0.15, admission_max_defer=3)
    s = w2.ingest(batches(ops, 12)[0])
    assert s.deferred
    h_mid = w2.health()
    assert h_mid["status"] == "degraded" and h_mid["wal_lag"] > 0
    # drain on snapshot: epoch catches the WAL tip exactly
    w2.snapshot()
    assert w2.epoch == w2._wal_tip
    assert w2.health()["status"] == "ok"


def test_overload_sheds_with_typed_retry_after(tmp_path):
    w, _wal, _snaps = make_writer(tmp_path, admission_budget=20)
    epoch0 = w.epoch
    present = {tuple(e) for e in w.bg.materialize().edge_list().tolist()}
    absent = [(u, v) for u in range(300) for v in range(u + 1, 300)
              if (u, v) not in present][:40]
    big = [("+", u, v) for u, v in absent]
    with pytest.raises(Overloaded) as ei:
        w.ingest(big)
    exc = ei.value
    assert exc.requested == 40 and exc.budget == 20
    assert exc.retry_after_s > 0
    assert w.epoch == epoch0  # shed batches leave no trace in the state
    assert w.admission.rejected_batches == 1
    assert w.admission.rejected_updates == 40
    small = [("+", u, v) for u, v in absent[:2]]
    w.ingest(small)  # within budget: accepted immediately after the shed
    assert w.epoch == epoch0 + 1


def test_backpressure_path_matches_sequential_and_oracle(tmp_path):
    ops, _ = mixed_stream(chung_lu(300, 1200, seed=3), 120, seed=9)
    w, _, _ = make_writer(tmp_path / "bp", admission_budget=48,
                          admission_soft_ratio=0.2, admission_max_defer=2)
    seq, _, _ = make_writer(tmp_path / "seq")
    for b in batches(ops, 12):
        w.ingest(b)
        seq.ingest(b)
    w.snapshot()  # drain any deferred tail
    assert w.epoch == w._wal_tip == seq.epoch
    np.testing.assert_array_equal(w.maintainer.core, seq.maintainer.core)
    np.testing.assert_array_equal(w.maintainer.cnt, seq.maintainer.cnt)
    np.testing.assert_array_equal(
        w.maintainer.core, imcore_bz(w.bg.materialize()))


# ========================================================= chaos soak
CHAOS_SEEDS = (11, 23, 37, 41, 59, 67, 73, 89)

CHAOS_RATES = {
    "wal.append": {"io_error": 0.04, "torn_write": 0.03, "bit_flip": 0.02,
                   "latency": 0.04},
    "wal.fsync": {"lying_fsync": 0.2},
    "wal.poll": {"io_error": 0.08},
    "block.read": {"io_error": 0.01},
    "snapshot.save": {"enospc": 0.15},
    "snapshot.load": {"io_error": 0.1},
}


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_soak_stays_bit_identical_to_oracle(tmp_path, seed):
    """Writer + two replicas under a seeded randomized fault schedule: after
    the storm, every node's (core, cnt) is bit-identical to the in-memory
    oracle and both replicas converge to the writer."""
    fam = counter("repro_faults_injected_total")
    metric_before = fam.value
    g = chung_lu(240, 960, seed=seed)
    ops, _ = mixed_stream(g, 80, seed=seed)
    wal = str(tmp_path / "wal.log")
    snaps = str(tmp_path / "snaps")
    plan = FaultPlan.chaos(seed, CHAOS_RATES)

    w = CoreWriter(g, block_edges=128, wal_path=wal, wal_fsync=True,
                   snapshot_dir=snaps, snapshot_keep=2,
                   retry=fast_retry(6, seed=seed))

    def try_snapshot():
        for _ in range(20):
            try:
                w.snapshot()
                return
            except OSError:
                continue
        pytest.fail("snapshot never succeeded under injected ENOSPC")

    with inject(plan):
        try_snapshot()
        reps = [
            CoreReplica(snapshot_dir=snaps, wal_path=wal, block_edges=128,
                        replica_id=i, retry=fast_retry(4, seed=seed + i),
                        breaker_trip_after=2)
            for i in (1, 2)
        ]
        for i, b in enumerate(batches(ops, 10)):
            for _ in range(50):
                try:
                    w.ingest(b)
                    break
                except OSError:
                    continue
            else:
                pytest.fail("ingest never succeeded under injected faults")
            if (i + 1) % 3 == 0:
                try:
                    w.snapshot()
                except OSError:
                    pass
            for r in reps:
                r.sync()
        try_snapshot()  # final snapshot; rotation repairs corrupt records

    # the storm is over: replicas drain to the writer's tip and match it
    for r in reps:
        for _ in range(30):
            if r.epoch == w.epoch:
                break
            r.sync()
        assert_converged(r, w)
    np.testing.assert_array_equal(
        w.maintainer.core, imcore_bz(w.bg.materialize()))

    # every injected fault is tallied and visible in the metric
    assert plan.total_injected > 0
    assert sum(plan.injected.values()) == plan.total_injected
    assert len(plan.log) == plan.total_injected
    assert fam.value - metric_before == plan.total_injected
