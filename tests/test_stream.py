"""Streaming core service: admission, replay determinism, epoch isolation,
zero-I/O queries, WAL/snapshot crash recovery (warm restart)."""
import os

import numpy as np
import pytest

from repro.core import decompose, imcore_bz
from repro.graph import chung_lu, paper_example_graph
from repro.stream import (CoreService, UpdateBatch, WriteAheadLog,
                          admit_batch, mixed_stream)

make_stream = mixed_stream  # shared generator: repro.stream.workload


def batches(ops, size):
    return [ops[i : i + size] for i in range(0, len(ops), size)]


# ================================================================ admission
def test_admission_coalesces_last_op_wins():
    b = admit_batch([("+", 1, 2), ("-", 2, 1), ("+", 3, 4), ("+", 4, 3)])
    assert b.deletes == [(1, 2)]
    assert b.inserts == [(3, 4)]
    assert b.num_requested == 4
    assert b.num_coalesced == 2
    assert b.num_dropped == 0


def test_admission_drops_out_of_range_node_ids():
    g = paper_example_graph()  # n = 9
    svc = CoreService(g, block_edges=16)
    core0 = svc.view().core.copy()
    s = svc.ingest([("+", 0, 50), ("-", -3, 1), ("+", 0, 8)])
    assert s.num_dropped == 2 and s.num_applied_inserts == 1
    svc.ingest([("-", 0, 8)])  # buffer intact: stream keeps working
    np.testing.assert_array_equal(svc.view().core, core0)


def test_admission_counts_malformed_ops_as_dropped():
    g = paper_example_graph()
    svc = CoreService(g, block_edges=16)
    s = svc.ingest([("+", 3), ("+", "a", "b"), None, ("+", 0, 8)])
    assert s.num_dropped == 3 and s.num_applied_inserts == 1


def test_admission_drops_self_loops_and_orders_deletes_first():
    b = admit_batch([("+", 5, 5), ("+", 0, 9), ("-", 7, 3)])
    assert b.num_dropped == 1
    assert b.deletes == [(3, 7)] and b.inserts == [(0, 9)]


def test_admission_insert_then_delete_of_missing_edge_is_noop():
    """Stream says +e then -e on an absent edge: net nothing must change."""
    g = paper_example_graph()
    svc = CoreService(g, block_edges=16)
    core0 = svc.view().core.copy()
    s = svc.ingest([("+", 0, 8), ("-", 0, 8)])
    assert s.num_applied_inserts == 0 and s.num_applied_deletes == 0
    assert s.num_noops == 1 and s.num_coalesced == 1
    np.testing.assert_array_equal(svc.view().core, core0)


# ==================================================== stream == decompose
def test_stream_matches_full_decompose_exactly():
    g = chung_lu(1500, 6000, seed=3)
    ops, final_edges = make_stream(g, 800, seed=1)
    svc = CoreService(g, block_edges=128)
    for chunk in batches(ops, 80):
        svc.ingest(chunk)
    final = svc.bg.materialize()
    assert {tuple(e) for e in final.edge_list().tolist()} == final_edges
    np.testing.assert_array_equal(svc.maintainer.core, imcore_bz(final))
    r = decompose(final, "semicore*", "batch", block_edges=128)
    np.testing.assert_array_equal(svc.maintainer.core, r.core)
    np.testing.assert_array_equal(svc.maintainer.cnt, r.cnt)


def test_replay_determinism_same_stream_same_result():
    g = chung_lu(600, 2400, seed=5)
    ops, _ = make_stream(g, 300, seed=2)
    runs = []
    for _ in range(2):
        svc = CoreService(chung_lu(600, 2400, seed=5), block_edges=64)
        log = [svc.ingest(c) for c in batches(ops, 50)]
        runs.append((svc.view().core, [s.num_changed for s in log], svc.epoch))
    np.testing.assert_array_equal(runs[0][0], runs[1][0])
    assert runs[0][1] == runs[1][1]
    assert runs[0][2] == runs[1][2] == 6


# ======================================================== epochs + queries
def test_epoch_isolation_of_views():
    g = paper_example_graph()
    svc = CoreService(g, block_edges=16)
    v0 = svc.view()
    np.testing.assert_array_equal(v0.core, [3, 3, 3, 3, 2, 2, 2, 2, 1])
    svc.ingest([("-", 0, 1)])  # drops the 3-core to 2 (Example 5.1)
    v1 = svc.view()
    assert (v0.epoch, v1.epoch) == (0, 1)
    # the pre-batch view is frozen: still answers the old epoch's state
    np.testing.assert_array_equal(v0.core, [3, 3, 3, 3, 2, 2, 2, 2, 1])
    np.testing.assert_array_equal(v1.core, [2, 2, 2, 2, 2, 2, 2, 2, 1])
    assert v0.coreness(0) == 3 and v1.coreness(0) == 2
    with pytest.raises(ValueError):
        v0.core[0] = 99  # views are read-only


def test_queries_are_zero_edge_io_and_cached():
    g = chung_lu(1000, 5000, seed=4)
    svc = CoreService(g, block_edges=64)
    reader = svc.maintainer.engine.reader
    io0 = (reader.reads, reader.node_table_reads)
    top = svc.top_k(10)
    members = svc.kcore_members(2)
    assert svc.degeneracy() == svc.view().core.max()
    assert bool(svc.in_kcore(int(top[0]), svc.degeneracy()))
    # vectorized membership/coreness
    np.testing.assert_array_equal(svc.coreness(top), svc.view().core[top])
    assert (reader.reads, reader.node_table_reads) == io0  # zero edge-table I/O
    # second identical queries hit the epoch cache
    h0 = svc.cache.hits
    np.testing.assert_array_equal(svc.top_k(10), top)
    np.testing.assert_array_equal(svc.kcore_members(2), members)
    assert svc.cache.hits == h0 + 2
    # a new epoch invalidates: same query misses again
    svc.ingest([])
    m0 = svc.cache.misses
    svc.top_k(10)
    assert svc.cache.misses == m0 + 1


def test_top_k_is_sorted_and_deterministic():
    g = chung_lu(500, 2500, seed=9)
    svc = CoreService(g, block_edges=64)
    core = svc.view().core
    full = svc.view().top_k(g.n)
    # sorted by coreness desc, ties by id asc — and a permutation of all nodes
    np.testing.assert_array_equal(np.sort(full), np.arange(g.n))
    c = core[full]
    assert (np.diff(c) <= 0).all()
    for k in (1, 7, 50):
        np.testing.assert_array_equal(svc.view().top_k(k), full[:k])


def test_kcore_members_match_min_degree_property():
    g = chung_lu(400, 1600, seed=8)
    svc = CoreService(g, block_edges=64)
    k = max(svc.degeneracy() - 1, 1)
    members = svc.kcore_members(k)
    sub = g.induced_subgraph(members)
    assert sub.degrees().min() >= k
    assert svc.view().kcore_size(k) == len(members)


# ================================================================ recovery
def test_crash_recovery_from_snapshot_and_wal_tail(tmp_path):
    g = chung_lu(1200, 5000, seed=6)
    wal = str(tmp_path / "wal.jsonl")
    snaps = str(tmp_path / "snaps")
    svc = CoreService(g, block_edges=128, wal_path=wal, snapshot_dir=snaps,
                      snapshot_every=3)
    ops, _ = make_stream(g, 350, seed=3)
    for chunk in batches(ops, 50):  # 7 batches -> snapshots at epochs 3, 6
        svc.ingest(chunk)
    svc.close()  # "crash" after epoch 7: one un-snapshotted batch in the WAL

    svc2, rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                   block_edges=128)
    assert rs.snapshot_epoch == 6 and rs.recovered_epoch == 7
    assert rs.replayed_batches == 1 and rs.warm_restart
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)
    np.testing.assert_array_equal(svc2.maintainer.cnt, svc.maintainer.cnt)
    assert svc2.epoch == svc.epoch
    # the warm settle must beat recomputing the decomposition from scratch
    cold = decompose(svc.bg.materialize(), "semicore*", "batch", block_edges=128)
    assert 0 < rs.settle_node_computations < cold.node_computations
    # and the recovered service keeps serving the stream
    more, _ = make_stream(svc2.bg.materialize(), 40, seed=11)
    svc2.ingest(more)
    np.testing.assert_array_equal(
        svc2.maintainer.core, imcore_bz(svc2.bg.materialize())
    )


def test_recovery_without_tail_uses_snapshot_state_verbatim(tmp_path):
    g = chung_lu(500, 2000, seed=2)
    wal = str(tmp_path / "wal.jsonl")
    snaps = str(tmp_path / "snaps")
    svc = CoreService(g, block_edges=64, wal_path=wal, snapshot_dir=snaps,
                      snapshot_every=2)
    ops, _ = make_stream(g, 80, seed=7)
    for chunk in batches(ops, 40):  # snapshot lands exactly at the last epoch
        svc.ingest(chunk)
    svc.close()
    svc2, rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                   block_edges=64)
    assert not rs.warm_restart and rs.settle_node_computations == 0
    assert svc2.epoch == svc.epoch == 2
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)


def test_recovery_ignores_torn_wal_tail(tmp_path):
    g = chung_lu(400, 1600, seed=1)
    wal = str(tmp_path / "wal.jsonl")
    snaps = str(tmp_path / "snaps")
    svc = CoreService(g, block_edges=64, wal_path=wal, snapshot_dir=snaps,
                      snapshot_every=100)
    svc.snapshot()  # durable state at epoch 0
    ops, _ = make_stream(g, 60, seed=4)
    svc.ingest(ops[:30])
    svc.close()
    with open(wal, "a") as f:  # crash mid-append of batch 2: torn line
        f.write('{"epoch":2,"del":[[1,')
    svc2, rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                   block_edges=64)
    assert rs.recovered_epoch == 1 and rs.replayed_batches == 1
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)


def test_wal_appends_after_torn_tail_do_not_corrupt_next_recovery(tmp_path):
    """Reopening a torn WAL must truncate the partial line first; otherwise
    the next append concatenates onto it and a *second* recovery silently
    drops that acknowledged batch (or refuses to parse the log)."""
    g = chung_lu(300, 1200, seed=3)
    wal = str(tmp_path / "wal.jsonl")
    snaps = str(tmp_path / "snaps")
    svc = CoreService(g, block_edges=64, wal_path=wal, snapshot_dir=snaps)
    svc.snapshot()
    ops, _ = make_stream(g, 60, seed=4)
    svc.ingest(ops[:30])
    svc.close()
    with open(wal, "a") as f:
        f.write('{"epoch":2,"del":[[1,')  # crash mid-append
    svc2, _ = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                  block_edges=64)
    svc2.ingest(ops[30:])  # epoch 2, appended to the reopened WAL
    svc2.close()
    svc3, rs = CoreService.recover(wal_path=wal, snapshot_dir=snaps,
                                   block_edges=64)
    assert rs.recovered_epoch == 2 and rs.replayed_batches == 2
    np.testing.assert_array_equal(svc3.maintainer.core, svc2.maintainer.core)


def test_cached_query_results_are_read_only():
    g = chung_lu(300, 1200, seed=9)
    svc = CoreService(g, block_edges=64)
    top = svc.top_k(5)
    with pytest.raises(ValueError):
        top[0] = -1  # a caller must not be able to poison later cache hits
    with pytest.raises(ValueError):
        svc.kcore_members(1).sort()
    np.testing.assert_array_equal(svc.top_k(5), svc.view().top_k(5))


def test_recovery_from_base_graph_without_snapshot(tmp_path):
    """No snapshot yet: replay the whole WAL onto the base graph, cold-init."""
    g = chung_lu(300, 1200, seed=5)
    wal = str(tmp_path / "wal.jsonl")
    svc = CoreService(g, block_edges=64, wal_path=wal)
    ops, _ = make_stream(g, 100, seed=6)
    for chunk in batches(ops, 25):
        svc.ingest(chunk)
    svc.close()
    svc2, rs = CoreService.recover(wal_path=wal, base_graph=g, block_edges=64)
    assert rs.replayed_batches == 4 and not rs.warm_restart
    np.testing.assert_array_equal(svc2.maintainer.core, svc.maintainer.core)


def test_wal_replay_filters_already_snapshotted_epochs(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    w = WriteAheadLog(wal)
    for e in range(1, 5):
        w.append(e, UpdateBatch.from_pairs([(0, e)], [(e, e + 1)]))
    w.close()
    got = list(WriteAheadLog.replay(wal, after_epoch=2))
    assert [e for e, _ in got] == [3, 4]
    assert got[0][1].deletes == [(0, 3)]
    assert got[0][1].inserts == [(3, 4)]


# ========================================================== integration bits
def test_buffer_flush_during_stream_keeps_state_exact():
    """A tiny buffer forces CSR rewrites mid-stream; flush hooks fire and the
    decomposition stays exact across the storage epoch turnover."""
    g = chung_lu(400, 1600, seed=7)
    from repro.graph import BufferedGraph

    bg = BufferedGraph(g, buffer_capacity=64)
    svc = CoreService(bg, block_edges=64)
    ops, _ = make_stream(g, 300, seed=8)
    for chunk in batches(ops, 60):
        svc.ingest(chunk)
    assert svc._flush_events > 0
    assert sum(s.flushes for s in svc.batch_log) == svc._flush_events
    np.testing.assert_array_equal(
        svc.maintainer.core, imcore_bz(svc.bg.materialize())
    )


def test_service_registry_exposes_core_stream():
    from repro.serve import (CoreService as Exported, available_services,
                             service_factory)

    assert "core-stream" in available_services()
    assert "lm" in available_services()
    assert service_factory("core-stream") is Exported is CoreService
    svc = service_factory("core-stream")(paper_example_graph(), block_edges=16)
    assert svc.degeneracy() == 3


# ================================================= watermark epoch semantics
def _wm(values, epoch):
    from repro.stream import WatermarkedArray

    a = np.asarray(values).view(WatermarkedArray)
    a.epoch = epoch
    return a


def test_watermark_views_and_slices_keep_source_epoch():
    a = _wm([3, 1, 4, 1, 5], epoch=7)
    assert a[1:4].epoch == 7
    assert a[a >= 3].epoch == 7
    assert a.reshape(5, 1).epoch == 7
    assert a.copy().epoch == 7


def test_watermark_derived_arrays_keep_source_epoch():
    """Deriving from one stamped reply keeps its epoch: `core >= k`,
    `core + 1`, reductions via ufunc — all still describe epoch-7 state."""
    a = _wm([3, 1, 4], epoch=7)
    assert (a + 1).epoch == 7
    assert (a >= 3).epoch == 7
    assert (-a).epoch == 7
    assert np.maximum(a, 2).epoch == 7  # plain operand doesn't constrain
    assert (a * np.array([1, 2, 3])).epoch == 7
    assert (2 ** a).epoch == 7  # reflected op keeps the stamp too


def test_watermark_same_epoch_operands_keep_epoch():
    a, b = _wm([1, 2, 3], epoch=4), _wm([4, 5, 6], epoch=4)
    assert (a + b).epoch == 4
    assert (a < b).epoch == 4


def test_watermark_mixed_epochs_drop_to_none():
    """The bugfix pin: combining replies from different epochs must not
    silently inherit one parent's watermark — the result describes no
    single consistent snapshot."""
    a, b = _wm([1, 2, 3], epoch=4), _wm([4, 5, 6], epoch=5)
    assert (a + b).epoch is None
    assert (a == b).epoch is None
    assert np.minimum(a, b).epoch is None


def test_watermark_unstamped_operand_does_not_constrain():
    a = _wm([1, 2, 3], epoch=9)
    from repro.stream import WatermarkedArray

    bare = np.array([7, 8, 9]).view(WatermarkedArray)  # never stamped
    assert bare.epoch is None
    assert (a + bare).epoch == 9
    assert (bare + 1).epoch is None


def test_watermark_inplace_ops_restamp_target():
    a, b = _wm([1, 2, 3], epoch=4), _wm([4, 5, 6], epoch=5)
    a += 1  # in-place with a constant: still epoch-4 data
    assert a.epoch == 4
    a += b  # in-place mix: target no longer describes one epoch
    assert a.epoch is None
    np.testing.assert_array_equal(np.asarray(a), [6, 8, 10])


def test_watermark_service_replies_compose():
    svc = CoreService(paper_example_graph(), block_edges=16)
    svc.ingest([("+", 0, 5)])
    core = svc.coreness(np.arange(svc.bg.n))
    assert core.epoch == svc.epoch == 1
    assert (core >= 2).epoch == 1
    stale = _wm(np.asarray(core).copy(), epoch=0)
    assert (core - stale).epoch is None
