"""Core maintenance: paper Examples 5.1-5.3 + property tests vs recompute."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import CSRGraph, paper_example_graph, chung_lu, erdos_renyi
from repro.core.imcore import imcore_bz
from repro.core.maintenance import CoreMaintainer
from repro.core.semicore import HostEngine
from repro.core.update import UpdateBatch
from repro.runtime import Settings as RuntimeSettings


def fresh_maintainer():
    return CoreMaintainer(paper_example_graph(), block_edges=16)


def test_semidelete_star_example_5_1():
    """Delete (v0,v1): all of v0..v3 drop to core 2; 1 iteration, 4 computations."""
    m = fresh_maintainer()
    s = m.delete_edge(0, 1)
    np.testing.assert_array_equal(m.core, [2, 2, 2, 2, 2, 2, 2, 2, 1])
    assert s.iterations == 1
    assert s.node_computations == 4
    assert s.num_changed == 4


def test_semiinsert_two_phase_example_5_2():
    """After deleting (v0,v1), insert (v4,v6) with Algorithm 7: 12 computations."""
    m = fresh_maintainer()
    m.delete_edge(0, 1)
    s = m.insert_edge(4, 6, algorithm="semiinsert")
    np.testing.assert_array_equal(m.core, [2, 2, 2, 3, 3, 3, 3, 2, 1])
    assert s.node_computations == 12
    assert s.algorithm == "semiinsert"


def test_semiinsert_star_example_5_3():
    """Same update with Algorithm 8: 5 computations, 2 iterations."""
    m = fresh_maintainer()
    m.delete_edge(0, 1)
    s = m.insert_edge(4, 6, algorithm="semiinsert*")
    np.testing.assert_array_equal(m.core, [2, 2, 2, 3, 3, 3, 3, 2, 1])
    assert s.node_computations == 5
    assert s.iterations == 2
    assert s.num_changed == 4


def test_cnt_stays_exact_after_maintenance():
    """cnt must equal Eq. 2 exactly after every op (enables chaining)."""
    m = fresh_maintainer()
    ops = [("del", 0, 1), ("ins", 4, 6), ("del", 3, 5), ("ins", 0, 1), ("ins", 3, 5)]
    for op, a, b in ops:
        if op == "del":
            m.delete_edge(a, b)
        else:
            m.insert_edge(a, b)
        g = m.bg.materialize()
        m.engine.graph = g  # storage rewritten after flush
        m.engine.reader.graph = g
        for v in range(g.n):
            nbr = g.neighbors(v)
            exact = int((m.core[nbr] >= m.core[v]).sum())
            assert m.cnt[v] == exact, (op, a, b, v)
        np.testing.assert_array_equal(m.core, imcore_bz(g), err_msg=f"{op}({a},{b})")


@pytest.mark.parametrize("algorithm", ["semiinsert", "semiinsert*"])
def test_random_update_stream_matches_recompute(algorithm):
    rng = np.random.default_rng(0)
    g = erdos_renyi(200, 600, seed=4)
    m = CoreMaintainer(g, block_edges=64)
    present = {tuple(e) for e in g.edge_list().tolist()}
    for step in range(60):
        if present and rng.random() < 0.5:
            u, v = list(present)[rng.integers(len(present))]
            m.delete_edge(int(u), int(v))
            present.discard((u, v))
        else:
            while True:
                u, v = int(rng.integers(200)), int(rng.integers(200))
                lo, hi = min(u, v), max(u, v)
                if u != v and (lo, hi) not in present:
                    break
            m.insert_edge(lo, hi, algorithm=algorithm)
            present.add((lo, hi))
        expect = imcore_bz(m.bg.materialize())
        np.testing.assert_array_equal(m.core, expect, err_msg=f"step {step}")


@st.composite
def graph_and_update(draw):
    n = draw(st.integers(3, 40))
    num_e = draw(st.integers(1, min(n * (n - 1) // 2, 80)))
    edges = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=num_e, max_size=num_e,
        )
    )
    return n, edges, draw(st.randoms(use_true_random=False))


@given(graph_and_update())
@settings(max_examples=80, deadline=None)
def test_property_insert_then_delete_roundtrip(gau):
    n, edges, rnd = gau
    g = CSRGraph.from_edges(n, np.array(edges, dtype=np.int64).reshape(-1, 2))
    if g.m == 0:
        return
    m = CoreMaintainer(g, block_edges=8)
    core0 = m.core.copy()
    # pick a non-edge to insert (if any)
    e = g.edge_list()
    present = {tuple(x) for x in e.tolist()}
    non_edges = [
        (a, b) for a in range(n) for b in range(a + 1, n) if (a, b) not in present
    ]
    if non_edges:
        a, b = non_edges[rnd.randrange(len(non_edges))]
        algo = "semiinsert*" if rnd.random() < 0.5 else "semiinsert"
        m.insert_edge(a, b, algorithm=algo)
        expect = imcore_bz(m.bg.materialize())
        np.testing.assert_array_equal(m.core, expect)
        m2_engine_graph = m.bg.base
        m.engine.graph = m2_engine_graph
        m.engine.reader.graph = m2_engine_graph
        m.delete_edge(a, b)
        np.testing.assert_array_equal(m.core, core0)  # roundtrip (Thm 3.1)


def test_maintenance_cheaper_than_recompute():
    g = chung_lu(3000, 12000, seed=9)
    m = CoreMaintainer(g, block_edges=256)
    full = HostEngine(g, block_edges=256).semicore_star("seq")
    e = g.edge_list()
    total_io = 0
    for i in range(20):
        u, v = e[i * 37]
        s = m.delete_edge(int(u), int(v))
        total_io += s.edge_block_reads
        s = m.insert_edge(int(u), int(v))
        total_io += s.edge_block_reads
    # per-op maintenance I/O is far below one full decomposition (Fig. 10)
    assert total_io / 40 < full.edge_block_reads / 5


# ----------------------------------------------------- batched backend settle
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_apply_batch_settled_backend_matches_recompute(backend):
    """Non-numpy backends ingest a micro-batch through one warm-started
    SemiCore* batch settle; (core, cnt) must equal recompute-from-scratch
    after every batch (DESIGN.md §11).  Pins ``parallel_maint=False``: this
    test covers the serial batch-settle path specifically (the parallel
    grouped settle has its own battery in test_parallel_maint.py)."""
    g = chung_lu(250, 1000, seed=13)
    e = g.edge_list()
    rng = np.random.default_rng(3)
    dels = [tuple(map(int, e[i])) for i in rng.choice(len(e), 12, replace=False)]
    present = set(map(tuple, e))
    ins = []
    while len(ins) < 8:
        u, v = sorted(map(int, rng.integers(0, g.n, 2)))
        if u != v and (u, v) not in present:
            ins.append((u, v))
            present.add((u, v))
    serial = RuntimeSettings(backend=backend, parallel_maint=False)
    m = CoreMaintainer(g, block_edges=64, settings=serial)
    ref = CoreMaintainer(g, block_edges=64)  # numpy per-edge reference
    for batch_d, batch_i in ((dels[:6], ins[:4]), (dels[6:], ins[4:])):
        s = m.apply(UpdateBatch.from_pairs(batch_d, batch_i))
        ref.apply(UpdateBatch.from_pairs(batch_d, batch_i))
        assert s.algorithm == f"batch-settle({backend})"
        assert s.num_deletes == 6 and s.num_inserts == 4
        final = m.bg.materialize()
        np.testing.assert_array_equal(m.core, imcore_bz(final))
        np.testing.assert_array_equal(m.core, ref.core)
        np.testing.assert_array_equal(m.cnt, ref.cnt)
