#!/usr/bin/env bash
# Tier-1 CI: dev deps (best effort — hermetic images fall back to the
# repro.compat hypothesis stub), full test suite, streaming bench smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
  || echo "WARN: dev-dep install failed; relying on repro.compat fallbacks" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_stream.py --quick
