#!/usr/bin/env bash
# Tier-1 CI: dev deps (best effort — hermetic images fall back to the
# repro.compat hypothesis stub), full test suite, streaming bench smoke.
#
# The workflow matrix (.github/workflows/ci.yml) runs this leg at
# python {3.10, 3.12} x device-count {1, 8}; the 8-device legs export
# XLA_FLAGS=--xla_force_host_platform_device_count=8 so the shard backend
# (engine.ShardedBackend, DESIGN.md §13) exercises a real 8-way mesh on the
# CPU runner end to end — pytest sweep, backend smoke, and trajectory gate.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
  || echo "WARN: dev-dep install failed; relying on repro.compat fallbacks" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# backend-matrix smoke: the same batch superstep on every compute substrate
# (engine.py, DESIGN.md §11/§13), selected through the REPRO_BACKEND env
# default.  Exactness + trace parity only; wall-clock is gated below by the
# perf-trajectory harness.
for backend in numpy xla pallas shard; do
  REPRO_BACKEND=$backend PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_backends.py --smoke
done

# legacy per-pass loop (REPRO_DEVICE_RESIDENT=0, DESIGN.md §12) must stay
# exact: same fixpoint, same planner trace as the resident default
REPRO_DEVICE_RESIDENT=0 REPRO_BACKEND=xla \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_backends.py --smoke

# pallas-fused leg (DESIGN.md §16): the pallas smoke above already runs the
# fused single-kernel superstep (the default); this one pins the per-probe
# segment_sum_active oracle path (REPRO_PALLAS_FUSED=0) so the fallback and
# its accounting parity stay exact too
REPRO_PALLAS_FUSED=0 REPRO_BACKEND=pallas \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_backends.py --smoke

# perf-trajectory regression gate: measure the 4-backend matrix on the small
# cell plus numpy/xla/pallas on the large cell (interpret-mode fused-superstep
# decompose) and compare warm-wall ratios + jit-trace counts against the
# committed BENCH_backends.json baseline (fails on >1.5x warm-wall regression
# or any jit-trace-count increase; replaces the old "xla <= 40x numpy + 2s"
# hack).  The candidate lands in
# benchmarks/results/BENCH_backends_current.json for the artifact upload.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_backends.py --check-trajectory

# maintenance-scaling trajectory gate (DESIGN.md §18): sustained updates/s
# of the parallel grouped settle vs the serial oracle across batch sizes on
# the fixed 10k/60k cell.  Same-machine ratio, so machine-speed independent;
# fails if the batch=64 speedup drops below 2x.  Also re-asserts the
# differential contract (parallel state bit-identical to serial) inside the
# bench itself.  Rows merge into results/stream.json under "maint_scaling".
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_stream.py --quick --maint-scaling

# update-API deprecation lint (DESIGN.md §18): no internal caller may use a
# deprecated spelling (apply_batch, 3-arg wal.append) — shims exist for
# external callers only.
python scripts/check_deprecations.py

# telemetry leg (DESIGN.md §14): run the large bench cell with tracing on,
# emitting a Perfetto-loadable Chrome trace (superstep_trace.json), the full
# registry in Prometheus text exposition (metrics.prom) and a markdown
# summary (obs_summary.md).  Gates on instrumentation overhead: the traced
# warm wall must stay within 5% (+50ms floor) of the REPRO_OBS=0 wall.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_backends.py --obs-cell

# registry-sourced superstep roofline: achieved-vs-peak bytes/s where the
# numerator is the repro_io_bytes_read_total delta, never hand math
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/roofline.py --superstep --quick

# fused-superstep roofline (DESIGN.md §16): same registry-sourced sweep with
# the pallas single-kernel backend included; writes
# results/fused_superstep_roofline.{json,md} (the .md feeds the step summary)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/roofline.py --fused-superstep --quick

# CI observability: render the backend x algorithm wall-clock table and the
# telemetry-cell summary into the workflow step summary (no-op outside
# GitHub Actions)
if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_backends.py --summary >> "$GITHUB_STEP_SUMMARY"
  cat benchmarks/results/obs_summary.md >> "$GITHUB_STEP_SUMMARY"
  cat benchmarks/results/fused_superstep_roofline.md >> "$GITHUB_STEP_SUMMARY"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_stream.py --quick

# parallel-maint oracle smoke (DESIGN.md §18): the same mixed streaming
# workload forced onto the serial parity oracle — REPRO_PARALLEL_MAINT=0
# must stay a working end-to-end configuration, since it is how the
# differential battery pins bit-identity.
REPRO_PARALLEL_MAINT=0 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_stream.py --quick

# updates/s cell into the workflow step summary
if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - >> "$GITHUB_STEP_SUMMARY" <<'PYEOF'
import json
cell = json.load(open("benchmarks/results/stream.json"))["maint_scaling"]
print("\n### Maintenance scaling (parallel grouped settle vs serial oracle)\n")
print("| batch | parallel upd/s | serial upd/s | speedup | p99 settle ms | gated |")
print("|---|---|---|---|---|---|")
for r in cell["rows"]:
    print(f"| {r['batch']} | {r['parallel_updates_per_s']:.0f} "
          f"| {r['serial_updates_per_s']:.0f} | {r['speedup']:.2f}x "
          f"| {r['parallel_p99_ms']:.1f} | {'yes' if r['gated'] else ''} |")
PYEOF
fi

# replication leg (DESIGN.md §15): 1 writer + 2 replicas (+1 late joiner)
# tailing the WAL under sustained ingest with rotation every few batches.
# Asserts bounded replica lag and bit-identical watermarked replies at the
# final epoch; results/replication.json rides the artifact upload.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_replication.py --smoke

# chaos leg (DESIGN.md §17): the fixed-seed fault-injection soak — a writer
# plus two replicas under a seeded randomized schedule of injected I/O
# errors, torn writes, bit flips, lying fsyncs and ENOSPC; every seed must
# end bit-identical to the in-memory oracle with every injected fault
# visible in repro_faults_injected_total.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest tests/test_faults.py -q -k chaos_soak

# the replication smoke re-run with 2ms of injected WAL-append latency: the
# bounded-lag and bit-identity gates must hold while appends are slow, and
# the run must account every slowed append in the fault counters (asserted
# inside the bench); the admission-backpressure overload cell rides along,
# merging into results/stream.json.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_replication.py --smoke --wal-append-latency-ms 2
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_stream.py --quick --overload
if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/fault_summary.py >> "$GITHUB_STEP_SUMMARY"
fi

# out-of-core smoke: build a ~1M-edge graph from chunks in a temp dir,
# memmap-load it, decompose, and compare against the in-memory build
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_outofcore.py --smoke
