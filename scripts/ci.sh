#!/usr/bin/env bash
# Tier-1 CI: dev deps (best effort — hermetic images fall back to the
# repro.compat hypothesis stub), full test suite, streaming bench smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
  || echo "WARN: dev-dep install failed; relying on repro.compat fallbacks" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# backend-matrix smoke: the same batch superstep on every compute substrate
# (engine.py, DESIGN.md §11), selected through the REPRO_BACKEND env default.
# The xla leg also gates device-resident wall-clock against numpy (a loose
# multiple; see bench_backends.smoke) so a host-loop regression fails CI.
for backend in numpy xla pallas; do
  REPRO_BACKEND=$backend PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_backends.py --smoke
done

# legacy per-pass loop (REPRO_DEVICE_RESIDENT=0, DESIGN.md §12) must stay
# exact: same fixpoint, same planner trace as the resident default
REPRO_DEVICE_RESIDENT=0 REPRO_BACKEND=xla \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python benchmarks/bench_backends.py --smoke

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_stream.py --quick

# out-of-core smoke: build a ~1M-edge graph from chunks in a temp dir,
# memmap-load it, decompose, and compare against the in-memory build
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_outofcore.py --smoke
