#!/usr/bin/env bash
# Tier-1 CI: dev deps (best effort — hermetic images fall back to the
# repro.compat hypothesis stub), full test suite, streaming bench smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
  || echo "WARN: dev-dep install failed; relying on repro.compat fallbacks" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# backend-matrix smoke: the same batch superstep on every compute substrate
# (engine.py, DESIGN.md §11), selected through the REPRO_BACKEND env default
for backend in numpy xla pallas; do
  REPRO_BACKEND=$backend PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_backends.py --smoke
done

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_stream.py --quick

# out-of-core smoke: build a ~1M-edge graph from chunks in a temp dir,
# memmap-load it, decompose, and compare against the in-memory build
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/bench_outofcore.py --smoke
