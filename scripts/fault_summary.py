"""Render the chaos-leg fault counters as a markdown step summary.

Reads ``benchmarks/results/replication.json`` (fault-injection counters
from the ``--wal-append-latency-ms`` smoke) and the ``overload`` block of
``benchmarks/results/stream.json`` (admission-backpressure cell) and
prints a small markdown report for ``$GITHUB_STEP_SUMMARY``.  Missing
files are skipped, so the script is safe to run on partial CI legs.

  PYTHONPATH=src python scripts/fault_summary.py >> "$GITHUB_STEP_SUMMARY"
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results")


def _load(name: str) -> dict | None:
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main() -> None:
    print("## Fault injection / durability (DESIGN.md §17)\n")

    rep = _load("replication.json")
    if rep and rep.get("faults_injected_total"):
        print(f"Replication smoke under "
              f"{rep.get('wal_append_latency_ms', 0):g} ms injected "
              f"WAL-append latency — lag p95 {rep['lag_p95']:.1f}, "
              f"max {rep['lag_max']} (bounded), "
              f"{rep['replicas']} replicas bit-identical at epoch "
              f"{rep['epochs']}.\n")
        print("| fault (op/kind) | injections |")
        print("|---|---|")
        for key, cnt in sorted(rep.get("faults_injected", {}).items()):
            print(f"| `{key}` | {cnt} |")
        print(f"| **total** | **{rep['faults_injected_total']}** |")
        print()
    else:
        print("_no replication fault-injection results_\n")

    stream = _load("stream.json")
    over = (stream or {}).get("overload")
    if over:
        print("Admission backpressure (overload cell): "
              f"{over['accepted_updates_per_s']:.0f} accepted updates/s, "
              f"shed rate {over['shed_rate']:.3f} "
              f"({over['shed_batches']} batches), "
              f"{over['deferred_batches']} deferred batches, "
              f"p99 admission latency {over['admission_p99_ms']:.2f} ms "
              f"(budget {over['budget']}).\n")
    else:
        print("_no overload-cell results_\n")


if __name__ == "__main__":
    sys.exit(main())
