#!/usr/bin/env python
"""Lint: no internal caller may use a deprecated update-API spelling.

PR 10 fronts all maintenance behind ``CoreMaintainer.apply(UpdateBatch)``
and typed WAL op records; the historical pair-of-lists spellings survive
only as deprecated shims for external callers.  This lint keeps the repo
itself honest: ``src/``, ``benchmarks/``, ``examples/`` and ``scripts/``
must not call a shim (the shim definitions themselves, and tests that
explicitly cover shim equivalence, are exempt).

    PYTHONPATH=src python scripts/check_deprecations.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: directories whose python files must be shim-free
LINTED_DIRS = ("src", "benchmarks", "examples", "scripts")

#: deprecated spelling -> (regex, allowed files).  Allowed files are the
#: definition/shim sites; everything else is a violation.
RULES = [
    (
        "CoreMaintainer.apply_batch(deletes, inserts)",
        re.compile(r"\.apply_batch\s*\("),
        {
            os.path.join("src", "repro", "core", "maintenance.py"),
        },
    ),
    (
        "CoreMaintainer.insert_edge/delete_edge(u, v)",
        # `(?<!g)` exempts BufferedGraph receivers (bg./self.bg./g.): the
        # structural graph mutators share these names and are not deprecated
        re.compile(r"(?<!g)\.(?:insert_edge|delete_edge)\s*\("),
        {
            os.path.join("src", "repro", "core", "maintenance.py"),
        },
    ),
    (
        "WriteAheadLog.append(epoch, deletes, inserts) [3-arg pair form]",
        # an append whose top-level comma count implies 3+ args
        re.compile(r"\bwal\.append\s*\(([^()]*,){2,}[^()]*\)|"
                   r"\.append\s*\(\s*[^,()]+,\s*\[[^\]]*\]\s*,"),
        {
            os.path.join("src", "repro", "stream", "wal.py"),
        },
    ),
]


def lint() -> int:
    failures = []
    for d in LINTED_DIRS:
        root = os.path.join(REPO, d)
        if not os.path.isdir(root):
            continue
        for dirpath, _dirnames, filenames in os.walk(root):
            for fn in filenames:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, REPO)
                with open(path, encoding="utf-8") as f:
                    lines = f.readlines()
                for name, rx, allowed in RULES:
                    if rel in allowed or rel == os.path.join(
                            "scripts", "check_deprecations.py"):
                        continue
                    for i, line in enumerate(lines, 1):
                        code = line.split("#", 1)[0]
                        if rx.search(code):
                            failures.append(f"{rel}:{i}: deprecated {name}")
    if failures:
        print("deprecated update-API spellings found:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("check_deprecations OK: no internal caller uses a deprecated "
          "update-API spelling")
    return 0


if __name__ == "__main__":
    sys.exit(lint())
