from .optimizer import (AdamWConfig, adamw_init, adamw_update,
                        adamw_state_avals, q8_encode, q8_decode, compress_psum)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "adamw_state_avals",
           "q8_encode", "q8_decode", "compress_psum"]
