"""Optimizers: AdamW with optional int8-quantized moments + grad compression.

The int8 moment store (blockwise absmax scaling, à la 8-bit Adam) is what
makes the 480B/671B train cells fit v5e HBM: 2 (bf16 w) + 1 (m) + 1 (v)
bytes/param instead of 16 (DESIGN.md §8).  Implemented in pure JAX so the
quantize/dequantize fuses into the update; state layouts shard exactly like
their parameters.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32
_BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    quantize_moments: bool = False  # int8 m/v with per-block scales


# ----------------------------------------------------- int8 moment codecs
def _q8_shapes(shape):
    n = 1
    for s in shape:
        n *= s
    blocks = -(-n // _BLOCK)
    blocks = -(-blocks // 64) * 64  # shardable over any batch-axis size
    return n, blocks


def q8_encode(x):
    n, blocks = _q8_shapes(x.shape)
    flat = jnp.pad(x.reshape(-1).astype(F32), (0, blocks * _BLOCK - n))
    flat = flat.reshape(blocks, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0].astype(F32)


def q8_decode(q, scale, shape):
    n, _ = _q8_shapes(shape)
    flat = q.astype(F32) * scale[:, None]
    return flat.reshape(-1)[:n].reshape(shape)


def q8_state_specs(shape):
    """(q, scale) avals for a parameter of `shape` (dry-run sizing)."""
    n, blocks = _q8_shapes(shape)
    return (jax.ShapeDtypeStruct((blocks, _BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((blocks,), F32))


# ------------------------------------------------------------------ AdamW
def adamw_init(params, cfg: AdamWConfig):
    def one(p):
        if cfg.quantize_moments:
            q, s = q8_encode(jnp.zeros_like(p, F32))
            return {"m_q": q, "m_s": s, "v_q": q, "v_s": s}
        return {"m": jnp.zeros_like(p, F32), "v": jnp.zeros_like(p, F32)}

    return {"step": jnp.zeros((), jnp.int32), "mu": jax.tree.map(one, params)}


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    t = step.astype(F32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def one(p, g, mu):
        g = g.astype(F32)
        if cfg.quantize_moments:
            m = q8_decode(mu["m_q"], mu["m_s"], p.shape)
            v = q8_decode(mu["v_q"], mu["v_s"], p.shape)
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p.astype(F32) - cfg.lr * (upd + cfg.weight_decay * p.astype(F32))
        if cfg.quantize_moments:
            mq, ms = q8_encode(m)
            vq, vs = q8_encode(v)
            new_mu = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            new_mu = {"m": m, "v": v}
        return new_p.astype(p.dtype), new_mu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [one(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, {"step": step, "mu": new_mu}


def adamw_state_avals(param_avals, cfg: AdamWConfig):
    """Optimizer-state avals matching adamw_init (dry-run path)."""
    def one(p):
        if cfg.quantize_moments:
            q, s = q8_state_specs(p.shape)
            return {"m_q": q, "m_s": s, "v_q": q, "v_s": s}
        a = jax.ShapeDtypeStruct(p.shape, F32)
        return {"m": a, "v": a}

    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "mu": jax.tree.map(one, param_avals),
    }


# -------------------------------------------------- gradient compression
def compress_psum(grads, axis_name: str):
    """int8 all-reduce: quantize -> psum int32 -> dequantize (bandwidth/4).

    Used inside shard_map data-parallel training when grad compression is on.
    """
    def one(g):
        q, s = q8_encode(g)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(s, axis_name)  # conservative shared scale
        n = jax.lax.psum(1, axis_name)
        return (qsum.astype(F32) * (ssum / n)[:, None] / n).reshape(-1)[
            : g.size
        ].reshape(g.shape)

    return jax.tree.map(one, grads)
