"""Per-family shape cells + input_specs(): ShapeDtypeStruct stand-ins for
every model input of every (arch x shape) cell — shardable, no allocation.

Cell inventory (40): 5 LM archs x 4 shapes, 4 GNN archs x 4 shapes,
1 recsys arch x 4 shapes.  Extra: the paper's own web-scale decomposition
cells (semicore-webscale) ride the same machinery.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import LMConfig, GNNConfig, RecsysConfig, CoreGraphConfig

I32, F32 = jnp.int32, jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# --------------------------------------------------------------------- LM
LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, step="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, step="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, step="decode"),
}

# ------------------------------------------------------------------- GNN
GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          step="train", mode="full"),
    "minibatch_lg": dict(n_nodes=232_965, n_edges=114_615_892,
                         batch_nodes=1024, fanout=(15, 10), d_feat=602,
                         step="train", mode="sampled"),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         step="train", mode="full"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                     step="train", mode="molecule"),
}

# ---------------------------------------------------------------- recsys
RECSYS_SHAPES = {
    "train_batch": dict(batch=65536, step="train"),
    "serve_p99": dict(batch=512, step="serve"),
    "serve_bulk": dict(batch=262_144, step="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, step="retrieval"),
}

# ------------------------------------------------- paper's own workload
COREGRAPH_SHAPES = {
    "decompose": dict(step="decompose"),
}

SHAPES_BY_KIND = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
    "coregraph": COREGRAPH_SHAPES,
}


def shape_names(cfg) -> list[str]:
    return list(SHAPES_BY_KIND[cfg.kind])


# ---------------------------------------------------------------- specs
def _lm_specs(cfg: LMConfig, sh: dict, reduced: bool):
    from ..models.transformer import make_kv_cache_specs

    B, S = sh["global_batch"], sh["seq_len"]
    if reduced:
        B, S = min(B, 2), min(S, 64)
    if sh["step"] == "train":
        return {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
    if sh["step"] == "prefill":
        return {"tokens": _sds((B, S), I32)}
    # decode: one new token against a cache of length seq_len
    return {
        "tokens": _sds((B, 1), I32),
        "caches": make_kv_cache_specs(cfg, B, S),
    }


def _gnn_specs(cfg: GNNConfig, sh: dict, reduced: bool):
    mode = sh["mode"]
    if mode == "full":
        N, E, F = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
        if reduced:
            N, E, F = 64, 256, 8
        # pad the edge axis to a shardable multiple; padded edges point at a
        # dummy sink node N (losses only read real rows)
        E = -(-E // 512) * 512
        N = N + 1
        batch = {"src": _sds((E,), I32), "dst": _sds((E,), I32)}
        if cfg.arch == "schnet":
            batch |= {"z": _sds((N,), I32), "pos": _sds((N, 3), F32),
                      "y": _sds((N,), F32)}
        elif cfg.arch == "egnn":
            batch |= {"x": _sds((N, F), F32), "pos": _sds((N, 3), F32),
                      "y": _sds((N,), F32)}
        else:
            batch |= {"x": _sds((N, F), F32), "labels": _sds((N - 1,), I32)}
        return batch, N
    if mode == "sampled":
        B = sh["batch_nodes"]
        f1, f2 = sh["fanout"]
        F = sh["d_feat"]
        if reduced:
            B, f1, f2, F = 8, 3, 2, 8
        N = B * (1 + f1 + f1 * f2)     # flattened sampled subgraph, seeds first
        E = 2 * (B * f1 + B * f1 * f2)  # both directions
        batch = {"src": _sds((E,), I32), "dst": _sds((E,), I32)}
        if cfg.arch == "schnet":
            batch |= {"z": _sds((N,), I32), "pos": _sds((N, 3), F32),
                      "y": _sds((B,), F32)}
        elif cfg.arch == "egnn":
            batch |= {"x": _sds((N, F), F32), "pos": _sds((N, 3), F32),
                      "y": _sds((B,), F32)}
        else:
            batch |= {"x": _sds((N, F), F32), "labels": _sds((B,), I32)}
        return batch, N
    # molecule: disjoint union of `batch` small graphs
    G = sh["batch"] if not reduced else 4
    n1, e1, F = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
    N, E = G * n1, G * e1 * 2
    batch = {"src": _sds((E,), I32), "dst": _sds((E,), I32),
             "graph_ids": _sds((N,), I32), "y": _sds((G,), F32)}
    if cfg.arch == "schnet":
        batch |= {"z": _sds((N,), I32), "pos": _sds((N, 3), F32)}
    elif cfg.arch == "egnn":
        batch |= {"x": _sds((N, F), F32), "pos": _sds((N, 3), F32)}
    else:
        batch |= {"x": _sds((N, F), F32)}
        batch["labels"] = _sds((G,), I32)
        del batch["y"]
    return batch, N


def _recsys_specs(cfg: RecsysConfig, sh: dict, reduced: bool):
    B = sh["batch"] if not reduced else 4
    base = {
        "hist_ids": _sds((B, cfg.hist_len), I32),
        "profile_ids": _sds((B, cfg.n_profile_fields, cfg.profile_bag), I32),
    }
    if sh["step"] == "train":
        base |= {"target_id": _sds((B,), I32),
                 "negative_ids": _sds((B, cfg.num_sampled_negatives), I32)}
    if sh["step"] == "retrieval":
        C = sh["n_candidates"] if not reduced else 64
        base |= {"candidate_ids": _sds((C,), I32)}
    return base


def input_specs(cfg, shape_name: str, *, num_shards: int = 1,
                reduced: bool = False):
    """Returns (step_kind, avals).  For GNN cells avals include num_nodes."""
    sh = SHAPES_BY_KIND[cfg.kind][shape_name]
    if cfg.kind == "lm":
        return sh["step"], _lm_specs(cfg, sh, reduced)
    if cfg.kind == "gnn":
        batch, N = _gnn_specs(cfg, sh, reduced)
        return sh["step"], {"batch": batch, "num_nodes": N}
    if cfg.kind == "recsys":
        return sh["step"], _recsys_specs(cfg, sh, reduced)
    if cfg.kind == "coregraph":
        from ..core.distributed import sharded_graph_specs
        c: CoreGraphConfig = cfg
        specs, probes, V = sharded_graph_specs(c.n, c.m_directed, num_shards,
                                               c.max_deg)
        specs["core0"] = _sds((c.n,), I32)
        return "decompose", {"specs": specs, "num_probes": probes}
    raise ValueError(cfg.kind)
