"""Architecture registry: --arch <id> -> config object."""
from __future__ import annotations

from importlib import import_module

_MODULES = {
    "yi-34b": "yi_34b",
    "qwen3-14b": "qwen3_14b",
    "qwen3-0.6b": "qwen3_0_6b",
    "arctic-480b": "arctic_480b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "graphsage-reddit": "graphsage_reddit",
    "gcn-cora": "gcn_cora",
    "schnet": "schnet",
    "egnn": "egnn",
    "mind": "mind",
    "semicore-webscale": "semicore_webscale",
}

ARCH_IDS = [a for a in _MODULES if a != "semicore-webscale"]


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[arch_id]}").CONFIG
