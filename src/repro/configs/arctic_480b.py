"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv=8,
    d_ff=4864, vocab=32000, d_head=128,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_parallel=True),
)
