"""gcn-cora [gnn] — 2-layer GCN, symmetric norm [arXiv:1609.02907]."""
from .base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora", arch="gcn", n_layers=2, d_hidden=16, aggregator="mean",
    norm="sym", num_classes=7,
)
