"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]."""
from .base import LMConfig, MoEConfig, MLAConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128, n_kv=128,
    d_ff=18432,  # dense prefix layers' FFN width
    vocab=129280,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1,
                  first_k_dense=3),
    mla=MLAConfig(q_lora=1536, kv_lora=512, dh_nope=128, dh_rope=64, dh_v=128),
    mtp_depth=1,
)
