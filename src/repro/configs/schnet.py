"""schnet [gnn] — 3 interactions, 300 RBF, cutoff 10 [arXiv:1706.08566]."""
from .base import GNNConfig

CONFIG = GNNConfig(
    name="schnet", arch="schnet", n_layers=3, d_hidden=64, n_rbf=300,
    cutoff=10.0,
)
