"""Config dataclasses for every architecture family (+ reduced smoke configs)."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # DeepSeek shared experts
    dense_parallel: bool = False # Arctic: dense residual MLP in parallel
    capacity_factor: float = 1.25
    first_k_dense: int = 0       # DeepSeek: first layers are dense


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp_depth: int = 0           # DeepSeek multi-token prediction modules
    dtype: Any = jnp.bfloat16
    kind: str = "lm"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def reduced(self) -> "LMConfig":
        """Smoke-test scale: same family, tiny dims."""
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=min(8, self.moe.num_experts),
                          d_ff_expert=64, first_k_dense=min(1, self.moe.first_k_dense))
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora=32, kv_lora=16, dh_nope=16, dh_rope=8, dh_v=16)
        return replace(
            self, n_layers=2, d_model=64,
            n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256,
            moe=moe, mla=mla, mtp_depth=min(self.mtp_depth, 1), dtype=jnp.float32,
        )


@dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                   # graphsage | gcn | schnet | egnn
    n_layers: int
    d_hidden: int
    aggregator: str = "mean"
    sample_sizes: tuple = ()
    norm: str | None = None     # gcn: "sym"
    n_rbf: int = 0              # schnet
    cutoff: float = 0.0         # schnet
    equivariance: str | None = None  # egnn: "E(n)"
    num_classes: int = 16
    dtype: Any = jnp.float32
    kind: str = "gnn"

    def reduced(self) -> "GNNConfig":
        return replace(self, d_hidden=min(self.d_hidden, 16),
                       n_rbf=min(self.n_rbf, 16) if self.n_rbf else 0)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    n_items: int = 1_000_000
    hist_len: int = 50
    n_profile_fields: int = 8    # multi-hot user-profile bag fields
    profile_vocab: int = 100_000
    profile_bag: int = 16        # slots per bag (EmbeddingBag input)
    mlp_dim: int = 256
    num_sampled_negatives: int = 128
    dtype: Any = jnp.float32
    kind: str = "recsys"

    def reduced(self) -> "RecsysConfig":
        return replace(self, n_items=1000, profile_vocab=500, embed_dim=16,
                       hist_len=8, profile_bag=4, mlp_dim=32,
                       num_sampled_negatives=16)


@dataclass(frozen=True)
class CoreGraphConfig:
    """The paper's own workload: web-scale core decomposition (Table I scale)."""
    name: str
    n: int
    m_directed: int
    max_deg: int
    kind: str = "coregraph"
    block_edges: int = 4096      # edge-table block size (storage.DEFAULT_BLOCK_EDGES)
    pool_blocks: int = 1         # BlockReader LRU pool; 1 = paper's single buffer
    build_chunk_edges: int = 1 << 22  # out-of-core build ingest chunk (build.py)
    backend: str = "numpy"       # batch-schedule compute backend (engine.py §11):
                                 # numpy | xla | pallas | shard
    num_shards: int | None = None  # mesh width for backend="shard"
                                 # (engine.ShardedBackend, DESIGN.md §13):
                                 # contiguous edge shards minimax-balanced by
                                 # edge count, replicated O(n) core, one
                                 # all_gather of owned slices per superstep.
                                 # None = every visible device;
                                 # REPRO_NUM_SHARDS overrides the default.
    superstep_chunk: int = 8     # device-resident passes per host round-trip
                                 # (resident.py §12) — threaded through
                                 # decompose / CoreMaintainer / CoreService
                                 # (superstep_chunk=cfg.superstep_chunk);
                                 # REPRO_RESIDENT_CHUNK overrides the default.
                                 # Per-chunk frontier record is chunk × n bools
                                 # pulled back once per round-trip — size it so
                                 # that stays small next to the O(n) node state.

    def reduced(self) -> "CoreGraphConfig":
        return replace(self, n=2000, m_directed=16_000, max_deg=64,
                       build_chunk_edges=1 << 12)
