"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import LMConfig

CONFIG = LMConfig(
    name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    d_ff=20480, vocab=64000, d_head=128, rope_theta=5_000_000.0,
)
