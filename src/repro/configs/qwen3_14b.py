"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""
from .base import LMConfig

CONFIG = LMConfig(
    name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv=8,
    d_ff=17408, vocab=151936, d_head=128, qk_norm=True, rope_theta=1_000_000.0,
)
