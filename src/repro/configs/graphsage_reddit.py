"""graphsage-reddit [gnn] — mean aggregator, fanout 25-10 [arXiv:1706.02216]."""
from .base import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit", arch="graphsage", n_layers=2, d_hidden=128,
    aggregator="mean", sample_sizes=(25, 10), num_classes=41,
)
