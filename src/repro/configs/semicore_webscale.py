"""The paper's own workload at Table-I scale: Clueweb / UK / Twitter-sized
semi-external core decomposition cells (directed edge counts = 2m).

``pool_blocks`` sizes the BlockReader LRU buffer pool (DESIGN.md §10): the
paper's experiments use a single block buffer (``pool_blocks=1``); the pooled
variants model a realistic page cache for skip-heavy SemiCore+/SemiCore*
passes over interleaved adjacency lists.  ``build_chunk_edges`` is the ingest
chunk of the external-memory CSR builder (graph/build.py): peak build memory
is O(n) node state + O(build_chunk_edges) scratch, never O(m).
"""
from .base import CoreGraphConfig

CLUEWEB = CoreGraphConfig(name="semicore-clueweb", n=978_408_098,
                          m_directed=85_148_214_938, max_deg=75_611_696,
                          block_edges=4096, pool_blocks=1,
                          build_chunk_edges=1 << 24)
UK = CoreGraphConfig(name="semicore-uk", n=105_896_555,
                     m_directed=7_477_467_296, max_deg=975_419,
                     block_edges=4096, pool_blocks=1,
                     build_chunk_edges=1 << 24)
TWITTER = CoreGraphConfig(name="semicore-twitter", n=41_652_230,
                          m_directed=2_936_730_364, max_deg=2_997_487,
                          block_edges=4096, pool_blocks=1,
                          build_chunk_edges=1 << 24)
# Pooled variant: same Clueweb cell with a 256-block (~4 MiB) page cache for
# the skip-heavy maintenance / SemiCore* passes.
CLUEWEB_POOLED = CoreGraphConfig(name="semicore-clueweb-pooled",
                                 n=978_408_098, m_directed=85_148_214_938,
                                 max_deg=75_611_696, block_edges=4096,
                                 pool_blocks=256, build_chunk_edges=1 << 24)
# Pallas-backend variant: the batch superstep running through the
# block-skipping kernels (engine.PallasBackend, DESIGN.md §11) — SemiCore*
# frontier shrinkage becomes skipped DMAs.  Sized to the Twitter cell, not
# Clueweb: the pallas backend holds the edge table resident (host + HBM), so
# its single-host envelope is bounded by memory for 2m int32 ids — and by
# the kernel's float32-exact count range (max_deg < 2**24; bind() rejects
# larger).  A device-sharded kernel path is what the Clueweb cell needs.
# The fixpoint runs device-resident (DESIGN.md §12): superstep_chunk=4
# bounds the per-round-trip frontier record at 4 × n ≈ 167 MB of bools —
# the O(n)-state budget dominates it, and at ~20 passes the loop still
# needs only ~5 round-trips.
TWITTER_PALLAS = CoreGraphConfig(name="semicore-twitter-pallas",
                                 n=41_652_230, m_directed=2_936_730_364,
                                 max_deg=2_997_487, block_edges=4096,
                                 pool_blocks=1, build_chunk_edges=1 << 24,
                                 backend="pallas", superstep_chunk=4)
# Sharded-backend variant: the Clueweb cell on a 256-chip mesh
# (engine.ShardedBackend, DESIGN.md §13).  Per-device: ~333M int32 edge-shard
# slots (1.3 GB, minimax-balanced so padding stays ~0) + the replicated
# 978M x 4 B core array = 3.9 GB — the paper's "< 4.2 GB" bound per chip.
# One all_gather of the owned core slices (n x 4 B over ICI) per superstep.
CLUEWEB_SHARD = CoreGraphConfig(name="semicore-clueweb-shard",
                                n=978_408_098, m_directed=85_148_214_938,
                                max_deg=75_611_696, block_edges=4096,
                                pool_blocks=1, build_chunk_edges=1 << 24,
                                backend="shard", num_shards=256,
                                superstep_chunk=8)
CONFIG = CLUEWEB
