"""The paper's own workload at Table-I scale: Clueweb / UK / Twitter-sized
semi-external core decomposition cells (directed edge counts = 2m)."""
from .base import CoreGraphConfig

CLUEWEB = CoreGraphConfig(name="semicore-clueweb", n=978_408_098,
                          m_directed=85_148_214_938, max_deg=75_611_696)
UK = CoreGraphConfig(name="semicore-uk", n=105_896_555,
                     m_directed=7_477_467_296, max_deg=975_419)
TWITTER = CoreGraphConfig(name="semicore-twitter", n=41_652_230,
                          m_directed=2_936_730_364, max_deg=2_997_487)
CONFIG = CLUEWEB
