from .base import LMConfig, GNNConfig, RecsysConfig, CoreGraphConfig, MoEConfig, MLAConfig
from .registry import get_config, ARCH_IDS
from .shapes import SHAPES_BY_KIND, shape_names, input_specs

__all__ = ["LMConfig", "GNNConfig", "RecsysConfig", "CoreGraphConfig",
           "MoEConfig", "MLAConfig", "get_config", "ARCH_IDS",
           "SHAPES_BY_KIND", "shape_names", "input_specs"]
