"""mind [recsys] — 4 interests, 3 capsule iterations [arXiv:1904.08030]."""
from .base import RecsysConfig

CONFIG = RecsysConfig(
    name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
    n_items=1_000_000, hist_len=50,
)
