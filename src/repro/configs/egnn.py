"""egnn [gnn] — 4 layers, E(n)-equivariant [arXiv:2102.09844]."""
from .base import GNNConfig

CONFIG = GNNConfig(
    name="egnn", arch="egnn", n_layers=4, d_hidden=64, equivariance="E(n)",
)
