"""One resolver for every ``REPRO_*`` runtime knob (DESIGN.md §18).

Historically each subsystem read its own environment variable at its own
call site (``engine.resolve_backend``, ``resident.resident_enabled``,
``kernels.default_interpret``, ``fused_superstep.fused_enabled``,
``obs.metrics.obs_enabled``, ...) with subtly different parsing rules.
This module is now the single place those knobs are declared, parsed and
resolved; the historical module-level functions remain as thin delegates.

Resolution order for every knob (``Settings.resolve`` and :func:`setting`):

    environment variable  >  constructor/keyword override  >  default

Environment reads happen *per call* — a dict get, not a cached import-time
snapshot — so tests and long-lived services can flip a knob mid-process
(e.g. ``REPRO_PARALLEL_MAINT=0`` to fall back to the serial maintenance
oracle) without re-importing anything.

Knobs
-----
``backend``            ``REPRO_BACKEND``            default compute backend name
``device_resident``    ``REPRO_DEVICE_RESIDENT``    device-resident fixpoint (=0 off)
``resident_chunk``     ``REPRO_RESIDENT_CHUNK``     lax.scan passes per round-trip
``pallas_fused``       ``REPRO_PALLAS_FUSED``       fused single-kernel superstep
``pallas_interpret``   ``REPRO_PALLAS_INTERPRET``   tri-state: None = auto by host
``fused_block_edges``  ``REPRO_FUSED_BLOCK_EDGES``  kernel tile size (None = adapt)
``obs``                ``REPRO_OBS``                telemetry registry on/off
``parallel_maint``     ``REPRO_PARALLEL_MAINT``     grouped batched maintenance
"""
from __future__ import annotations

import os
from dataclasses import dataclass, fields

__all__ = [
    "Settings",
    "get_settings",
    "setting",
    "ENV_VARS",
    "DEFAULT_RESIDENT_CHUNK",
]

#: knob name -> environment variable
ENV_VARS = {
    "backend": "REPRO_BACKEND",
    "device_resident": "REPRO_DEVICE_RESIDENT",
    "resident_chunk": "REPRO_RESIDENT_CHUNK",
    "pallas_fused": "REPRO_PALLAS_FUSED",
    "pallas_interpret": "REPRO_PALLAS_INTERPRET",
    "fused_block_edges": "REPRO_FUSED_BLOCK_EDGES",
    "obs": "REPRO_OBS",
    "parallel_maint": "REPRO_PARALLEL_MAINT",
}

#: lax.scan passes per host round-trip (mirrored by resident.DEFAULT_CHUNK)
DEFAULT_RESIDENT_CHUNK = 8

_FALSY = ("0", "false", "no", "off")


def _parse_flag(raw: str):
    """Generous boolean: anything but the falsy spellings is on."""
    return raw.strip().lower() not in _FALSY


def _parse_strict_zero(raw: str):
    """Historical ``!= "0"`` parsing (device_resident, obs)."""
    return raw != "0"


def _parse_chunk(raw: str):
    try:
        return max(1, int(raw))
    except ValueError:
        return DEFAULT_RESIDENT_CHUNK


def _parse_block_edges(raw: str):
    raw = raw.strip()
    if not raw:
        return None  # empty string == unset (historical behavior)
    return int(raw)  # range-validated at the use site (>= 8)


_PARSERS = {
    "backend": lambda raw: raw,
    "device_resident": _parse_strict_zero,
    "resident_chunk": _parse_chunk,
    "pallas_fused": _parse_flag,
    "pallas_interpret": _parse_flag,
    "fused_block_edges": _parse_block_edges,
    "obs": _parse_strict_zero,
    "parallel_maint": _parse_flag,
}

_UNSET = object()


def setting(name: str, override=_UNSET):
    """Resolve one knob: env (if set) > ``override`` (if given, non-None) >
    dataclass default.  This is the fast path used by the historical
    accessor functions — it reads exactly one environment variable."""
    raw = os.environ.get(ENV_VARS[name])
    if raw is not None:
        parsed = _PARSERS[name](raw)
        if parsed is not None:
            return parsed
    if override is not _UNSET and override is not None:
        return override
    return _DEFAULTS[name]


@dataclass(frozen=True)
class Settings:
    """Resolved runtime configuration.

    Construct directly for explicit values, or via :meth:`resolve` /
    :func:`get_settings` to apply the env > override > default order.
    Instances are frozen: a component handed a ``Settings`` object sees a
    consistent snapshot for its lifetime, while code that wants live env
    semantics calls :func:`get_settings` (or :func:`setting`) per use.
    """

    backend: str = "numpy"
    device_resident: bool = True
    resident_chunk: int = DEFAULT_RESIDENT_CHUNK
    pallas_fused: bool = True
    pallas_interpret: bool | None = None  # None: auto (compiled on TPU/GPU)
    fused_block_edges: int | None = None  # None: adapt to the graph
    obs: bool = True
    parallel_maint: bool = True

    @classmethod
    def resolve(cls, **overrides) -> "Settings":
        """Build a Settings snapshot with env > override > default per knob.

        ``None`` overrides mean "not specified" for every knob except the
        genuinely tri-state ``pallas_interpret``/``fused_block_edges``,
        where ``None`` is also the default, so the distinction is moot.
        """
        unknown = set(overrides) - set(ENV_VARS)
        if unknown:
            raise TypeError(f"unknown settings: {sorted(unknown)}")
        vals = {k: setting(k, overrides.get(k, _UNSET)) for k in ENV_VARS}
        return cls(**vals)

    def env(self) -> dict[str, str]:
        """Render as environment-variable assignments (for subprocesses)."""
        out = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if v is None:
                continue
            if isinstance(v, bool):
                out[ENV_VARS[f.name]] = "1" if v else "0"
            else:
                out[ENV_VARS[f.name]] = str(v)
        return out


_DEFAULTS = {f.name: f.default for f in fields(Settings)}


def get_settings(**overrides) -> Settings:
    """The module-level resolver: ``Settings.resolve`` with live env reads."""
    return Settings.resolve(**overrides)
