"""Batched serving engine: prefill + decode loop over a KV cache.

Continuous-batching-lite: fixed request slots; finished slots are refilled
from the queue between decode steps (slot state is just (tokens, length)).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..models import transformer as tfm


class ServeEngine:
    def __init__(self, params, cfg: LMConfig, batch_slots: int, max_len: int):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch_slots, max_len
        caches = tfm.make_kv_cache_specs(cfg, batch_slots, max_len)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), caches)
        self._decode = jax.jit(
            lambda p, t, c: tfm.serve_decode(p, cfg, t, c))

    def prefill(self, prompts: np.ndarray):
        """prompts (B, S): run the prompt through decode steps (simple path)."""
        B, S = prompts.shape
        assert B == self.batch
        logits = None
        for i in range(S):
            logits, self.caches = self._decode(
                self.params, jnp.asarray(prompts[:, i:i + 1]), self.caches)
        return logits

    def generate(self, prompts: np.ndarray, steps: int, greedy: bool = True):
        logits = self.prefill(prompts)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok))
            logits, self.caches = self._decode(self.params, tok, self.caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)
