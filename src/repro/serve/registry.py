"""Registry of serving surfaces.

The repo serves two kinds of traffic: token generation (``ServeEngine``,
continuous-batching LM decode) and graph-state queries (``CoreService``,
streaming core decomposition).  Deployments pick a surface by name; new
surfaces register a factory here.
"""
from __future__ import annotations

__all__ = ["register_service", "service_factory", "create_service",
           "available_services"]

_SERVICES: dict[str, type] = {}


def register_service(name: str, factory) -> None:
    if name in _SERVICES and _SERVICES[name] is not factory:
        raise ValueError(f"service {name!r} already registered")
    _SERVICES[name] = factory


def service_factory(name: str):
    try:
        return _SERVICES[name]
    except KeyError:
        raise KeyError(
            f"unknown service {name!r}; available: {available_services()}"
        ) from None


def create_service(name: str, *args, **kwargs):
    return service_factory(name)(*args, **kwargs)


def available_services() -> tuple[str, ...]:
    return tuple(sorted(_SERVICES))
