from .engine import ServeEngine
from .registry import (available_services, create_service, register_service,
                       service_factory)
from ..stream import CoreService

register_service("lm", ServeEngine)
register_service("core-stream", CoreService)

__all__ = [
    "ServeEngine", "CoreService",
    "register_service", "service_factory", "create_service",
    "available_services",
]
