from .engine import ServeEngine
from .registry import (available_services, create_service, register_service,
                       service_factory)
from ..stream import CoreReplica, CoreService

register_service("lm", ServeEngine)
register_service("core-stream", CoreService)
register_service("core-replica", CoreReplica)

__all__ = [
    "ServeEngine", "CoreService", "CoreReplica",
    "register_service", "service_factory", "create_service",
    "available_services",
]
