"""jax version bridges for the pinned 0.4.x line vs newer public APIs."""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map

    _HAS_PUBLIC = True
except ImportError:  # jax < 0.6: experimental location, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _HAS_PUBLIC = False

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kwargs):
    """``jax.shard_map`` with the modern signature on either jax line.

    Newer jax renamed ``check_rep`` to ``check_vma``; this forwards the flag
    under whichever name the installed jax understands.
    """
    if _HAS_PUBLIC:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma, **kwargs)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma, **kwargs)
