"""Deterministic fallback for ``hypothesis`` when it is not installed.

The test suite declares ``hypothesis`` as a dev dependency (pyproject.toml),
but hermetic images may lack it and cannot reach an index.  This module
provides a minimal, API-compatible subset — ``given``, ``settings`` and the
``strategies`` the suite actually uses — backed by a seeded PRNG so every run
draws the same examples.  It is a *gate*, not a replacement: no shrinking, no
example database, no health checks.  ``install_hypothesis_fallback()`` is a
no-op when the real package is importable.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__all__ = ["install_hypothesis_fallback"]

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a sampler: ``example(rnd) -> value``."""

    def __init__(self, sample, is_data: bool = False):
        self._sample = sample
        self.is_data = is_data

    def example(self, rnd: random.Random):
        return self._sample(rnd)


class _DataObject:
    """The value drawn for ``st.data()``: interactive draws inside the test."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: _Strategy, label=None):
        return strategy.example(self._rnd)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


def _lists(elements: _Strategy, *, min_size: int = 0, max_size: int | None = None) -> _Strategy:
    def sample(r: random.Random):
        hi = min_size + 10 if max_size is None else max_size
        k = r.randint(min_size, max(hi, min_size))
        return [elements.example(r) for _ in range(k)]

    return _Strategy(sample)


def _tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda r: tuple(s.example(r) for s in strategies))


def _randoms(use_true_random: bool = False) -> _Strategy:
    return _Strategy(lambda r: random.Random(r.randrange(2**32)))


def _data() -> _Strategy:
    return _Strategy(None, is_data=True)


def _composite(fn):
    """``@st.composite``: fn(draw, *args) becomes a strategy factory."""

    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def sample(r: random.Random):
            return fn(lambda s: s.example(r), *args, **kwargs)

        return _Strategy(sample)

    return builder


def _settings(**kwargs):
    """Records settings on the function; only ``max_examples`` is honored."""

    def deco(fn):
        fn._fallback_settings = dict(kwargs)
        return fn

    return deco


def _given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(fn, "_fallback_settings", {})
            n = int(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rnd = random.Random((base << 20) + i)
                drawn = [
                    _DataObject(rnd) if s.is_data else s.example(rnd)
                    for s in strategies
                ]
                try:
                    fn(*args, *drawn, **kwargs)
                except BaseException:
                    print(
                        f"[hypothesis-fallback] {fn.__qualname__} failed on "
                        f"example {i}: {drawn!r}"[:2000],
                        file=sys.stderr,
                    )
                    raise

        # Strategies fill the trailing parameters; expose only the leading
        # ones (pytest fixtures) so collection does not look for "fixtures"
        # named after drawn arguments.
        params = list(inspect.signature(fn).parameters.values())
        remaining = params[: max(len(params) - len(strategies), 0)]
        wrapper.__signature__ = inspect.Signature(remaining)
        del wrapper.__wrapped__  # keep pytest off the original signature
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


def install_hypothesis_fallback() -> bool:
    """Register the fallback as ``hypothesis`` if the real one is missing.

    Returns True when the fallback was installed, False when the real
    package (or a previously installed fallback) is already importable.
    """
    if "hypothesis" in sys.modules:
        return False
    try:
        import hypothesis  # noqa: F401  (real package wins)

        return False
    except ImportError:
        pass

    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.booleans = _booleans
    st.sampled_from = _sampled_from
    st.lists = _lists
    st.tuples = _tuples
    st.randoms = _randoms
    st.data = _data
    st.composite = _composite

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.strategies = st
    mod.__is_fallback__ = True

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return True
