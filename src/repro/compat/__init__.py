"""Compatibility shims: optional-dependency fallbacks and version bridges."""
from .hypothesis_fallback import install_hypothesis_fallback

__all__ = ["install_hypothesis_fallback"]
