"""Uniform neighbor sampling (GraphSAGE) over CSR storage.

Produces fixed-fanout, padded sampled subgraphs suitable for jit'd train steps:
the ``minibatch_lg`` shape cell (batch_nodes=1024, fanout 15-10) runs a real
two-hop sampler on the host and feeds static-shape device batches.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .storage import CSRGraph

__all__ = ["NeighborSampler", "SampledBlock"]


@dataclass
class SampledBlock:
    """One hop of sampling: for each seed, ``fanout`` neighbor slots.

    ``neighbors`` -- (num_seeds, fanout) int32 global node ids, padded with the
                     seed's own id (self-loop padding keeps aggregation sane).
    ``mask``      -- (num_seeds, fanout) bool, True for real samples.
    """

    seeds: np.ndarray
    neighbors: np.ndarray
    mask: np.ndarray


class NeighborSampler:
    """Uniform without-replacement-ish neighbor sampler over CSR."""

    def __init__(self, graph: CSRGraph, seed: int = 0):
        self.graph = graph
        self.rng = np.random.default_rng(seed)

    def sample_hop(self, seeds: np.ndarray, fanout: int) -> SampledBlock:
        g = self.graph
        seeds = np.asarray(seeds, dtype=np.int64)
        deg = (g.indptr[seeds + 1] - g.indptr[seeds]).astype(np.int64)
        # draw `fanout` uniform positions per seed (with replacement — the
        # standard GraphSAGE estimator); isolated seeds get self-loop padding.
        pos = self.rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(seeds), fanout))
        flat = np.minimum(g.indptr[seeds][:, None] + pos, len(g.adj) - 1)
        nbrs = g.adj[flat].astype(np.int32)
        has_nbrs = deg[:, None] > 0
        nbrs = np.where(has_nbrs, nbrs, seeds[:, None].astype(np.int32))
        mask = np.broadcast_to(has_nbrs, nbrs.shape)
        return SampledBlock(seeds=seeds, neighbors=nbrs, mask=mask)

    def sample_batch(self, batch_nodes: np.ndarray, fanouts: tuple[int, ...]):
        """Multi-hop sampling: returns a list of SampledBlock, innermost last.

        Layer l aggregates from blocks[l]; seeds of hop i are the (flattened)
        neighbors of hop i-1, GraphSAGE-style.
        """
        blocks: list[SampledBlock] = []
        seeds = np.asarray(batch_nodes, dtype=np.int64)
        for f in fanouts:
            blk = self.sample_hop(seeds, f)
            blocks.append(blk)
            seeds = blk.neighbors.reshape(-1).astype(np.int64)
        return blocks
