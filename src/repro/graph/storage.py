"""Graph storage: CSR node/edge tables with blocked, I/O-accounted access.

Mirrors the paper's disk layout (§II *Graph Storage*): the **edge table** stores
``nbr(v_1), nbr(v_2), ...`` consecutively as adjacency lists; the **node table**
stores the offset and degree of every node.  The edge table is partitioned into
fixed-size blocks of ``block_size`` edges — the unit of I/O accounting under the
external-memory model of Aggarwal & Vitter [1].

Two backings are provided:
  * in-memory numpy arrays (tests, benchmarks, generators), and
  * on-disk ``.npy`` files opened with ``np.memmap`` (true out-of-core runs),
both behind the same :class:`CSRGraph` interface.

Graphs too large for ``CSRGraph.from_edges`` (whole-array sorts) are built by
the external-memory pipeline in :mod:`repro.graph.build`, which emits this
exact on-disk layout with O(n) + O(chunk) peak memory (DESIGN.md §10).

:class:`BlockReader` models the paper's single in-memory block buffer; the
``pool_blocks`` parameter generalizes it to an LRU buffer pool (a realistic
page cache) while keeping ``pool_blocks=1`` bit-identical to the paper's
accounting — see DESIGN.md §10 for the exact semantics.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..faults import fs as _faults
from ..obs import metrics as _metrics

__all__ = [
    "CSRGraph",
    "BlockReader",
    "paper_example_graph",
    "DEFAULT_BLOCK_EDGES",
]

# Registry mirrors of the paper's I/O accounting (DESIGN.md §14).  Incremented
# at the same source lines as the reader's own counters so a registry delta
# around any run reconciles exactly with its DecompResult / reader fields.
_IO_READS = _metrics.counter(
    "repro_io_edge_block_reads_total",
    "Edge-table block read I/Os under the paper's blocked access model",
).labels()
_IO_HITS = _metrics.counter(
    "repro_io_edge_block_pool_hits_total",
    "Edge-table block reads answered from a resident buffer-pool block",
).labels()
_IO_EVICTIONS = _metrics.counter(
    "repro_io_edge_block_evictions_total",
    "LRU buffer-pool evictions of edge-table blocks",
).labels()
_IO_NODE_READS = _metrics.counter(
    "repro_io_node_table_reads_total",
    "Node-table block read I/Os (sequential node scans)",
).labels()
_IO_BYTES = _metrics.counter(
    "repro_io_bytes_read_total",
    "Bytes read under the blocked I/O model (edge + node table)",
).labels()

# 4096 edges * 4 bytes = 16 KiB per block: one DMA/disk-friendly tile.
DEFAULT_BLOCK_EDGES = 4096


@dataclass
class CSRGraph:
    """Undirected graph in CSR form (each edge stored in both endpoint lists).

    ``indptr``  -- int64 array of shape (n + 1,): the node table offsets.
    ``adj``     -- int32 array of shape (2m,): the edge table.
    """

    indptr: np.ndarray
    adj: np.ndarray

    def __post_init__(self) -> None:
        self.indptr = np.asarray(self.indptr, dtype=np.int64)
        # adj may be a memmap; only coerce dtype when needed.
        if self.adj.dtype != np.int32:
            self.adj = np.asarray(self.adj, dtype=np.int32)

    # ------------------------------------------------------------------ basic
    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        """Number of *undirected* edges."""
        return len(self.adj) // 2

    @property
    def num_directed(self) -> int:
        return len(self.adj)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def neighbors(self, v: int) -> np.ndarray:
        return self.adj[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        return bool(np.isin(v, self.neighbors(u)).item())

    # ------------------------------------------------------------ construction
    @classmethod
    def from_edges(cls, n: int, edges: np.ndarray, *, dedup: bool = True) -> "CSRGraph":
        """Build from an (E, 2) array of undirected edges (any orientation).

        Self loops are dropped; parallel edges are deduplicated when ``dedup``.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if len(edges):
            edges = edges[edges[:, 0] != edges[:, 1]]
        if dedup and len(edges):
            lo = np.minimum(edges[:, 0], edges[:, 1])
            hi = np.maximum(edges[:, 0], edges[:, 1])
            key = lo * np.int64(n) + hi
            _, idx = np.unique(key, return_index=True)
            edges = np.stack([lo[idx], hi[idx]], axis=1)
        # symmetrize
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        counts = np.bincount(src, minlength=n).astype(np.int64)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        # sort neighbors within each list for deterministic layouts
        order2 = np.lexsort((dst, src))
        out = dst[order2].astype(np.int32)
        return cls(indptr=indptr, adj=out)

    def edge_list(self) -> np.ndarray:
        """Return (m, 2) array with each undirected edge once (u < v)."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.indptr))
        dst = self.adj.astype(np.int64)
        mask = src < dst
        return np.stack([src[mask], dst[mask]], axis=1)

    def directed_pairs(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) for every directed copy (2m entries), src sorted."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        return src, self.adj

    # ------------------------------------------------------------- subgraphs
    def induced_subgraph(self, nodes: np.ndarray) -> "CSRGraph":
        """Induced subgraph with nodes relabeled 0..len(nodes)-1."""
        nodes = np.asarray(nodes, dtype=np.int64)
        remap = np.full(self.n, -1, dtype=np.int64)
        remap[nodes] = np.arange(len(nodes))
        e = self.edge_list()
        keep = (remap[e[:, 0]] >= 0) & (remap[e[:, 1]] >= 0)
        e = remap[e[keep]]
        return CSRGraph.from_edges(len(nodes), e, dedup=False)

    def sample_edges(self, frac: float, seed: int = 0) -> "CSRGraph":
        """Keep a random fraction of edges (incident nodes kept; §VI-C)."""
        e = self.edge_list()
        rng = np.random.default_rng(seed)
        keep = rng.random(len(e)) < frac
        return CSRGraph.from_edges(self.n, e[keep], dedup=False)

    def sample_nodes(self, frac: float, seed: int = 0) -> "CSRGraph":
        """Induced subgraph of a random node sample (§VI-C)."""
        rng = np.random.default_rng(seed)
        nodes = np.flatnonzero(rng.random(self.n) < frac)
        return self.induced_subgraph(nodes)

    def relabel(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel node ids: new id of old node v is perm[v]."""
        e = self.edge_list()
        perm = np.asarray(perm, dtype=np.int64)
        return CSRGraph.from_edges(self.n, perm[e], dedup=False)

    # ------------------------------------------------------------------- disk
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "indptr.npy"), self.indptr)
        np.save(os.path.join(path, "adj.npy"), self.adj)
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"n": self.n, "m": self.m}, f)

    @classmethod
    def load(cls, path: str, *, mmap: bool = True) -> "CSRGraph":
        mode = "r" if mmap else None
        indptr = np.load(os.path.join(path, "indptr.npy"), mmap_mode=mode)
        adj = np.load(os.path.join(path, "adj.npy"), mmap_mode=mode)
        g = cls.__new__(cls)
        g.indptr = np.asarray(indptr, dtype=np.int64)
        g.adj = adj  # keep memmapped: the "edge table on disk"
        return g


class BlockReader:
    """Block-granular, I/O-accounted access to the edge table.

    Models the paper's sequential-scan access: a single in-memory block buffer;
    reading edge positions within the currently buffered block is free, any
    other block costs one read I/O.  Sequential full scans therefore cost
    ``ceil(2m / B)`` I/Os, and skip-heavy scans (SemiCore+/SemiCore*) cost one
    I/O per *distinct* block actually touched, exactly as in the paper.

    ``pool_blocks`` generalizes the single buffer to an LRU buffer pool
    (DESIGN.md §10): a read of a pool-resident block is a hit (free), a miss
    costs one read I/O and evicts the least-recently-used block.
    ``pool_blocks=1`` degenerates to exactly the paper's single-buffer model —
    every existing I/O trace is preserved bit-for-bit.
    """

    def __init__(
        self,
        graph: CSRGraph,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
        retry=None,
    ):
        self.graph = graph
        self.block_edges = int(block_edges)
        self.pool_blocks = max(1, int(pool_blocks))
        self.retry = retry  # optional faults.RetryPolicy for block fills
        self.reads = 0  # edge-table block read I/Os
        self.node_table_reads = 0  # node-table block read I/Os
        self.hits = 0  # pool hits (reads answered from a resident block)
        self._pool: OrderedDict[int, None] = OrderedDict()  # resident blocks, LRU order
        # node-table entries per block: entries are (offset 8B, degree 4B) =
        # 12 bytes; one block is block_edges * 4 bytes of edge data.
        self._node_entries_per_block = max(1, (self.block_edges * 4) // 12)

    # -- accounting ---------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return -(-self.graph.num_directed // self.block_edges)

    def invalidate(self) -> None:
        """Drop every resident block (the backing CSR was rewritten)."""
        self._pool.clear()

    def reset_io(self) -> None:
        self.reads = 0
        self.node_table_reads = 0
        self.hits = 0
        self.invalidate()

    @property
    def bytes_read(self) -> int:
        return self.reads * self.block_edges * 4 + self.node_table_reads * self.block_edges * 4

    @property
    def resident_blocks(self) -> tuple[int, ...]:
        """Resident block ids, least- to most-recently used."""
        return tuple(self._pool)

    # -- access -------------------------------------------------------------
    def _touch(self, block: int) -> None:
        pool = self._pool
        if block in pool:
            pool.move_to_end(block)
            self.hits += 1
            _IO_HITS.inc()
            return
        self.reads += 1
        _IO_READS.inc()
        _IO_BYTES.inc(self.block_edges * 4)
        pool[block] = None
        while len(pool) > self.pool_blocks:
            pool.popitem(last=False)
            _IO_EVICTIONS.inc()

    def charge_pass(self, blocks: np.ndarray) -> None:
        """Account one batch-schedule pass touching ``blocks`` (distinct,
        ascending ids).

        With ``pool_blocks == 1`` this reproduces the paper's single-buffer
        accounting exactly: a batch pass streams the covered blocks through
        the buffer in ascending order, so every distinct covered block costs
        one read I/O per pass and the buffer state is left untouched (the
        original implementation).  With a larger pool, blocks still resident
        from earlier passes hit for free; LRU's inclusion property makes the
        total read count non-increasing in ``pool_blocks``.

        The pool>1 path simulates LRU exactly without touching every block in
        Python: only blocks resident at pass start can hit (a once-evicted
        block always has ≥ pool_blocks fresher distinct blocks until it is
        re-read), and for a resident block at pass position ``i`` with
        pass-start LRU rank ``rho`` the number of distinct fresher blocks at
        its touch is ``i + (|resident| - 1 - rho) - #(prior pass touches of
        residents fresher than rho)`` — so the hit test loops over at most
        ``pool_blocks`` candidates while everything else stays vectorized.
        """
        blocks = np.asarray(blocks, dtype=np.int64)
        k = len(blocks)
        if self.pool_blocks == 1:
            self.reads += k
            _IO_READS.inc(k)
            _IO_BYTES.inc(k * self.block_edges * 4)
            return
        if k == 0:
            return
        pool = self._pool
        P = self.pool_blocks
        hits = 0
        resident = np.fromiter(pool.keys(), np.int64, len(pool))  # LRU -> MRU
        if len(resident):
            order = np.argsort(resident)
            pos = np.searchsorted(resident[order], blocks)
            pos = np.minimum(pos, len(resident) - 1)
            cand = np.flatnonzero(resident[order][pos] == blocks)
            rhos = order[pos[cand]]  # pass-start LRU rank of each candidate
            nres = len(resident)
            seen: list[int] = []
            for i, rho in zip(cand.tolist(), rhos.tolist()):
                fresher = i + (nres - 1 - rho) - sum(1 for r in seen if r > rho)
                if fresher < P:
                    hits += 1
                seen.append(rho)
        self.reads += k - hits
        self.hits += hits
        _IO_READS.inc(k - hits)
        _IO_HITS.inc(hits)
        _IO_BYTES.inc((k - hits) * self.block_edges * 4)
        # post-pass pool: the P most recently touched distinct blocks =
        # untouched residents (old recency order) then the pass tail
        if len(resident):
            untouched = resident[~np.isin(resident, blocks)]
        else:
            untouched = resident
        # evictions a per-block LRU simulation would have made this pass:
        # misses minus the pool-size growth
        end_size = min(len(untouched) + k, P)
        _IO_EVICTIONS.inc((k - hits) - (end_size - len(resident)))
        pool.clear()
        for b in untouched[max(0, len(untouched) + k - P):].tolist():
            pool[b] = None
        for b in blocks[max(0, k - P):].tolist():
            pool[b] = None

    def _fill_span(self, first: int, last: int) -> list[int]:
        """Touch blocks ``first..last``, fetching the missing ones.

        The fetch point (the fault hook, standing in for the disk read) runs
        *before* a missing block is charged or made resident, so a failed
        fill leaves no pool entry and no I/O charge behind — a retried read
        misses again, is charged exactly once, and the
        hits + evictions = reads - pool-growth reconciliation stays exact.
        Blocks already filled earlier in the span stay resident across a
        mid-span failure: their data really did arrive, and the retry
        legitimately hits them.
        """
        filled: list[int] = []
        for b in range(first, last + 1):
            if b not in self._pool:
                _faults.on_op("block.read")  # may raise a transient IOError
                filled.append(b)
            self._touch(b)
        return filled

    def load_neighbors(self, v: int) -> np.ndarray:
        """Load nbr(v), touching every block the adjacency list spans."""
        lo = int(self.graph.indptr[v])
        hi = int(self.graph.indptr[v + 1])
        if hi > lo:
            first = lo // self.block_edges
            last = (hi - 1) // self.block_edges
            if self.retry is None:
                filled = self._fill_span(first, last)
            else:
                filled = self.retry.call(
                    self._fill_span, first, last, op="block.read")
            try:
                return self.graph.adj[lo:hi]
            except OSError:
                # a block charged as read never delivered its bytes (memmap
                # page-in failure): invalidate this call's fills and undo
                # their charges so residency never lies about disk state.
                for b in filled:
                    if b in self._pool:
                        del self._pool[b]
                        self.reads -= 1
                raise
        return self.graph.adj[lo:hi]

    def account_node_table_scan(self, v_lo: int, v_hi: int) -> None:
        """Charge node-table I/O for sequentially scanning nodes [v_lo, v_hi]."""
        if v_hi < v_lo:
            return
        span = v_hi - v_lo + 1
        blocks = -(-span // self._node_entries_per_block)
        self.node_table_reads += blocks
        _IO_NODE_READS.inc(blocks)
        _IO_BYTES.inc(blocks * self.block_edges * 4)


def paper_example_graph() -> CSRGraph:
    """The 9-node, 15-edge running example of the paper (Fig. 1).

    Reconstructed from the degree row of Fig. 2 (Init = deg) and the traces of
    Examples 4.1 (nbr(v3) values {3,3,3,3,5,3}), 4.2 (v5's larger neighbors are
    v6, v7, v8), and 5.3 (v2's status flip decrements cnt(v4), so (v2,v4) ∈ E):
    cores are {v0..v3: 3, v4..v7: 2, v8: 1}; deleting (v0, v1) drops v0..v3 to
    2; then inserting (v4, v6) lifts {v3,v4,v5,v6} to 3.
    """
    edges = np.array(
        [
            (0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3),  # K4: the 3-core
            (2, 4),
            (3, 4), (3, 5), (3, 6),
            (4, 5),
            (5, 6), (5, 7), (5, 8),
            (6, 7),
        ],
        dtype=np.int64,
    )
    return CSRGraph.from_edges(9, edges)
