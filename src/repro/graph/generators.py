"""Synthetic graph generators standing in for the paper's dataset suite (Table I).

The paper's 12 datasets (DBLP .. Clueweb) cannot ship with this repo; we generate
graphs with matching *structural regimes* instead:

  * ``chung_lu``  -- power-law expected-degree graphs (social-network-like);
  * ``rmat``      -- Kronecker/R-MAT graphs (web-crawl-like, heavy skew; Graph500);
  * ``erdos_renyi`` -- uniform random (control / tests);
  * ``ba``        -- Barabási–Albert preferential attachment.

All generators are deterministic in ``seed`` and return :class:`CSRGraph`.

The ``*_chunks`` variants stream the same structural regimes as ``(k, 2)``
edge chunks instead of whole arrays — O(chunk) memory per draw — and feed the
external-memory builder (:func:`repro.graph.build.build_csr`) so multi-10M-edge
synthetic webs never materialize an edge list (DESIGN.md §10).
"""
from __future__ import annotations

import numpy as np

from .storage import CSRGraph

__all__ = [
    "chung_lu", "rmat", "erdos_renyi", "ba", "DATASET_SUITE", "make_dataset",
    "rmat_chunks", "powerlaw_chunks", "uniform_chunks",
]


def erdos_renyi(n: int, m: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(int(m * 1.15) + 8, 2), dtype=np.int64)
    return CSRGraph.from_edges(n, e[: m * 2])


def chung_lu(n: int, m: int, gamma: float = 2.5, seed: int = 0) -> CSRGraph:
    """Power-law expected-degree model: w_i ∝ (i + i0)^(-1/(gamma-1))."""
    rng = np.random.default_rng(seed)
    i0 = n ** (1.0 / (gamma - 1.0)) / 10.0 + 1.0
    w = (np.arange(n) + i0) ** (-1.0 / (gamma - 1.0))
    p = w / w.sum()
    # draw 2*target endpoints; dedup shrinks the count back toward target
    draws = int(m * 1.3) + 16
    src = rng.choice(n, size=draws, p=p)
    dst = rng.choice(n, size=draws, p=p)
    # random relabel so node id does not correlate with degree
    perm = rng.permutation(n)
    e = np.stack([perm[src], perm[dst]], axis=1)
    return CSRGraph.from_edges(n, e)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> CSRGraph:
    """R-MAT / Kronecker generator (web-graph-like skew), n = 2**scale.

    Materialized via the streaming generator: with one chunk covering every
    edge the RNG is consumed in the same order, so this is the single source
    of the quadrant recursion (see :func:`rmat_chunks`).
    """
    n = 1 << scale
    m = n * edge_factor
    e = np.concatenate(
        list(rmat_chunks(scale, edge_factor, a, b, c, seed, chunk_edges=m))
    )
    return CSRGraph.from_edges(n, e)


def ba(n: int, attach: int = 4, seed: int = 0) -> CSRGraph:
    """Barabási–Albert via the repeated-nodes trick (vectorized-ish)."""
    rng = np.random.default_rng(seed)
    targets = list(range(attach))
    repeated: list[int] = []
    edges = []
    for v in range(attach, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * attach)
        idx = rng.integers(0, len(repeated), size=attach)
        targets = [repeated[i] for i in idx]
    return CSRGraph.from_edges(n, np.array(edges, dtype=np.int64))


# --------------------------------------------------------------------------
# Streaming chunk generators (out-of-core ingestion; DESIGN.md §10).  Each
# yields (k, 2) int64 edge chunks, deterministic in ``seed``; duplicates and
# self loops are the builder's problem (it dedups/drops while merging).
def rmat_chunks(scale: int, edge_factor: int = 16, a: float = 0.57,
                b: float = 0.19, c: float = 0.19, seed: int = 0,
                chunk_edges: int = 1 << 20):
    """Stream R-MAT edges (n = 2**scale, ~n * edge_factor raw draws)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    for lo in range(0, m, chunk_edges):
        k = min(chunk_edges, m - lo)
        src = np.zeros(k, dtype=np.int64)
        dst = np.zeros(k, dtype=np.int64)
        for bit in range(scale):
            r1 = rng.random(k)
            r2 = rng.random(k)
            src_bit = r1 > (a + b)
            ab = np.where(src_bit, c / (c + (1 - a - b - c)), a / (a + b))
            dst_bit = r2 > ab
            src |= src_bit.astype(np.int64) << bit
            dst |= dst_bit.astype(np.int64) << bit
        yield np.stack([src, dst], axis=1)


def powerlaw_chunks(n: int, m: int, gamma: float = 2.5, seed: int = 0,
                    chunk_edges: int = 1 << 20):
    """Stream Chung-Lu power-law edges: endpoints ~ w_i ∝ (i + i0)^(-1/(γ-1)).

    Endpoint draws use inverse-transform sampling over the weight cumsum, so
    per-chunk work is O(chunk log n) with no renormalization.  Persistent
    state is O(n) — the weight cumsum plus the id permutation decorrelating
    id from degree — which is the paper's node-state budget, not an edge
    list.
    """
    rng = np.random.default_rng(seed)
    i0 = n ** (1.0 / (gamma - 1.0)) / 10.0 + 1.0
    alpha = -1.0 / (gamma - 1.0)
    # cumulative weights of (i + i0)**alpha approximated by the integral's
    # closed form would drift from the discrete sum; n is at most the node
    # count we can hold anyway (O(n) is in-budget), so keep the exact cumsum.
    w = (np.arange(n) + i0) ** alpha
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    perm = rng.permutation(n)  # decorrelate id and degree
    for lo in range(0, m, chunk_edges):
        k = min(chunk_edges, m - lo)
        src = np.searchsorted(cdf, rng.random(k), side="left")
        dst = np.searchsorted(cdf, rng.random(k), side="left")
        yield np.stack([perm[src], perm[dst]], axis=1).astype(np.int64)


def uniform_chunks(n: int, m: int, seed: int = 0, chunk_edges: int = 1 << 20):
    """Stream uniform (Erdős–Rényi-style) endpoint pairs."""
    rng = np.random.default_rng(seed)
    for lo in range(0, m, chunk_edges):
        k = min(chunk_edges, m - lo)
        yield rng.integers(0, n, size=(k, 2), dtype=np.int64)


# --------------------------------------------------------------------------
# A scaled-down stand-in for Table I: name -> (generator, kwargs).  Sizes are
# chosen to run on one CPU core while spanning the paper's density regimes
# (density = m/n from 2.1 [WIKI] to 43.5 [Clueweb]).
DATASET_SUITE: dict[str, tuple] = {
    "dblp-sim":    ("chung_lu", dict(n=30_000, m=100_000, gamma=2.3)),
    "youtube-sim": ("chung_lu", dict(n=60_000, m=160_000, gamma=2.2)),
    "wiki-sim":    ("chung_lu", dict(n=100_000, m=210_000, gamma=2.1)),
    "cpt-sim":     ("erdos_renyi", dict(n=80_000, m=350_000)),
    "lj-sim":      ("chung_lu", dict(n=100_000, m=870_000, gamma=2.5)),
    "orkut-sim":   ("chung_lu", dict(n=60_000, m=2_300_000, gamma=2.8)),
    "webbase-sim": ("rmat", dict(scale=16, edge_factor=9)),
    "twitter-sim": ("rmat", dict(scale=15, edge_factor=36)),
    "uk-sim":      ("rmat", dict(scale=16, edge_factor=35)),
}

_GENERATORS = {"chung_lu": chung_lu, "erdos_renyi": erdos_renyi, "rmat": rmat, "ba": ba}


def make_dataset(name: str, seed: int = 0) -> CSRGraph:
    gen, kwargs = DATASET_SUITE[name]
    return _GENERATORS[gen](seed=seed, **kwargs)
