"""Synthetic graph generators standing in for the paper's dataset suite (Table I).

The paper's 12 datasets (DBLP .. Clueweb) cannot ship with this repo; we generate
graphs with matching *structural regimes* instead:

  * ``chung_lu``  -- power-law expected-degree graphs (social-network-like);
  * ``rmat``      -- Kronecker/R-MAT graphs (web-crawl-like, heavy skew; Graph500);
  * ``erdos_renyi`` -- uniform random (control / tests);
  * ``ba``        -- Barabási–Albert preferential attachment.

All generators are deterministic in ``seed`` and return :class:`CSRGraph`.
"""
from __future__ import annotations

import numpy as np

from .storage import CSRGraph

__all__ = ["chung_lu", "rmat", "erdos_renyi", "ba", "DATASET_SUITE", "make_dataset"]


def erdos_renyi(n: int, m: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(int(m * 1.15) + 8, 2), dtype=np.int64)
    return CSRGraph.from_edges(n, e[: m * 2])


def chung_lu(n: int, m: int, gamma: float = 2.5, seed: int = 0) -> CSRGraph:
    """Power-law expected-degree model: w_i ∝ (i + i0)^(-1/(gamma-1))."""
    rng = np.random.default_rng(seed)
    i0 = n ** (1.0 / (gamma - 1.0)) / 10.0 + 1.0
    w = (np.arange(n) + i0) ** (-1.0 / (gamma - 1.0))
    p = w / w.sum()
    # draw 2*target endpoints; dedup shrinks the count back toward target
    draws = int(m * 1.3) + 16
    src = rng.choice(n, size=draws, p=p)
    dst = rng.choice(n, size=draws, p=p)
    # random relabel so node id does not correlate with degree
    perm = rng.permutation(n)
    e = np.stack([perm[src], perm[dst]], axis=1)
    return CSRGraph.from_edges(n, e)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> CSRGraph:
    """R-MAT / Kronecker generator (web-graph-like skew), n = 2**scale."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > (a + b)
        ab = np.where(src_bit, c / (c + (1 - a - b - c)), a / (a + b))
        dst_bit = r2 > ab
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    e = np.stack([src, dst], axis=1)
    return CSRGraph.from_edges(n, e)


def ba(n: int, attach: int = 4, seed: int = 0) -> CSRGraph:
    """Barabási–Albert via the repeated-nodes trick (vectorized-ish)."""
    rng = np.random.default_rng(seed)
    targets = list(range(attach))
    repeated: list[int] = []
    edges = []
    for v in range(attach, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * attach)
        idx = rng.integers(0, len(repeated), size=attach)
        targets = [repeated[i] for i in idx]
    return CSRGraph.from_edges(n, np.array(edges, dtype=np.int64))


# --------------------------------------------------------------------------
# A scaled-down stand-in for Table I: name -> (generator, kwargs).  Sizes are
# chosen to run on one CPU core while spanning the paper's density regimes
# (density = m/n from 2.1 [WIKI] to 43.5 [Clueweb]).
DATASET_SUITE: dict[str, tuple] = {
    "dblp-sim":    ("chung_lu", dict(n=30_000, m=100_000, gamma=2.3)),
    "youtube-sim": ("chung_lu", dict(n=60_000, m=160_000, gamma=2.2)),
    "wiki-sim":    ("chung_lu", dict(n=100_000, m=210_000, gamma=2.1)),
    "cpt-sim":     ("erdos_renyi", dict(n=80_000, m=350_000)),
    "lj-sim":      ("chung_lu", dict(n=100_000, m=870_000, gamma=2.5)),
    "orkut-sim":   ("chung_lu", dict(n=60_000, m=2_300_000, gamma=2.8)),
    "webbase-sim": ("rmat", dict(scale=16, edge_factor=9)),
    "twitter-sim": ("rmat", dict(scale=15, edge_factor=36)),
    "uk-sim":      ("rmat", dict(scale=16, edge_factor=35)),
}

_GENERATORS = {"chung_lu": chung_lu, "erdos_renyi": erdos_renyi, "rmat": rmat, "ba": ba}


def make_dataset(name: str, seed: int = 0) -> CSRGraph:
    gen, kwargs = DATASET_SUITE[name]
    return _GENERATORS[gen](seed=seed, **kwargs)
