"""External-memory CSR construction: build web-scale graphs without ever
holding the edge list in memory (DESIGN.md §10).

``CSRGraph.from_edges`` sorts the whole edge array — O(m) memory — which caps
graph size at RAM and blocks the paper's headline regime (978.5M nodes /
42.6B edges in 4.2 GB).  :func:`build_csr` replaces it with the classic
external mergesort pipeline of the semi-external model:

1. **Run formation** — edge chunks (an iterator of ``(k, 2)`` arrays, ``.npy``
   shards, or a text edge list) are canonicalized (self loops dropped,
   ``(lo, hi)`` orientation), packed into uint64 keys ``lo << 32 | hi``,
   sorted and locally deduplicated in O(chunk), and written to disk as sorted
   runs.  Degrees are counted later, from the deduped merged stream (stage 3).
2. **K-way merge** — the runs are memmapped and merged with a vectorized
   multi-way merge: each round takes one block per run, cuts at the minimum of
   the blocks' last keys (every remaining key ≤ the cut lives in the current
   blocks), sorts/dedups the candidates, and streams the unique keys to the
   merged edge file.  Merges cascade with bounded fan-in (classic external
   mergesort levels), so scratch stays O(chunk) no matter how many runs the
   ingest produced.
3. **CSR emission** — ``indptr`` is the degree cumsum (O(n)); the adjacency is
   an ``open_memmap``-backed ``adj.npy`` filled by a streaming symmetrizing
   scatter with an O(n) write-cursor array.  Because the merged stream is
   sorted by ``(lo, hi)`` and each edge emits its two directed copies in
   stream order, every node's neighbor list comes out ascending — byte-for-
   byte the ``from_edges`` layout, in ``CSRGraph.save`` format.

Peak memory is O(n) node state + O(chunk) scratch, never O(m).

An optional degree-descending relabel pass (``relabel="degree"``) re-runs the
pipeline over the merged file with ids permuted so node 0 has the highest
degree — the paper's node-ordering lever (§VI): high-degree nodes converge
late, and packing them into a contiguous id prefix shrinks the SemiCore+/*
scan ranges and node-table I/O.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import json
from dataclasses import dataclass, field

import numpy as np
from numpy.lib.format import open_memmap

__all__ = ["build_csr", "BuildStats", "edge_chunks_from_npy", "edge_chunks_from_text"]

# Default ingest/merge chunk: 4M edges = 64 MB of packed keys.
DEFAULT_CHUNK_EDGES = 1 << 22
# adj.npy stores neighbors as int32 (CSRGraph's edge-table dtype), so ids
# must stay within int32 even though the packed uint64 keys could hold more.
_MAX_ID = (1 << 31) - 1
# Max runs merged at once; deeper inputs cascade through merge levels so the
# per-level scratch stays O(MERGE_FANOUT · block) = O(chunk).
MERGE_FANOUT = 8


@dataclass
class BuildStats:
    """What one external-memory build did, and what it cost."""

    n: int
    m: int  # undirected edges after dedup
    edges_ingested: int  # raw input rows (incl. self loops / duplicates)
    chunks: int
    runs: int
    merge_rounds: int
    relabel: str = "none"
    perm: np.ndarray | None = None  # new_id = perm[old_id] (relabel only)
    out_dir: str = ""
    # peak transient scratch (edges) held by any single pipeline stage; the
    # O(n) arrays (degree counter, indptr, write cursor) are reported apart.
    peak_scratch_edges: int = 0
    node_state_bytes: int = 0

    def to_json(self) -> dict:
        d = {k: v for k, v in self.__dict__.items() if k != "perm"}
        d["has_perm"] = self.perm is not None
        return d


# ======================================================================
# chunk sources
# ======================================================================
def edge_chunks_from_npy(paths, chunk_edges: int = DEFAULT_CHUNK_EDGES):
    """Yield (k, 2) int64 chunks from .npy edge shards without loading them.

    Each shard is an (E_i, 2) integer array; shards are memmapped and sliced,
    so memory stays O(chunk_edges) regardless of shard size.
    """
    if isinstance(paths, (str, os.PathLike)):
        paths = [paths]
    for p in paths:
        arr = np.load(p, mmap_mode="r")
        if arr.ndim != 2 or arr.shape[1] != 2:
            raise ValueError(f"{p}: expected an (E, 2) edge array, got {arr.shape}")
        for lo in range(0, len(arr), chunk_edges):
            yield np.asarray(arr[lo : lo + chunk_edges], dtype=np.int64)


def edge_chunks_from_text(path, chunk_edges: int = DEFAULT_CHUNK_EDGES):
    """Yield (k, 2) int64 chunks from a whitespace-separated edge list.

    Lines starting with ``#`` or ``%`` (SNAP / KONECT headers) are skipped.
    Memory is O(chunk_edges); the file is never read whole.
    """
    buf: list[int] = []
    with open(path) as f:
        for line in f:
            if not line or line[0] in "#%\n":
                continue
            parts = line.split()
            if len(parts) < 2:
                continue
            buf.append(int(parts[0]))
            buf.append(int(parts[1]))
            if len(buf) >= 2 * chunk_edges:
                yield np.array(buf, dtype=np.int64).reshape(-1, 2)
                buf = []
    if buf:
        yield np.array(buf, dtype=np.int64).reshape(-1, 2)


def _as_chunks(edges, chunk_edges: int):
    """Normalize any supported edge source into an iterator of (k, 2) arrays."""
    if isinstance(edges, (str, os.PathLike)):
        p = os.fspath(edges)
        if p.endswith(".npy"):
            return edge_chunks_from_npy(p, chunk_edges)
        return edge_chunks_from_text(p, chunk_edges)
    if isinstance(edges, (list, tuple)) and edges and all(
        isinstance(e, (str, os.PathLike)) for e in edges
    ):
        return edge_chunks_from_npy(edges, chunk_edges)
    if isinstance(edges, np.ndarray):
        arr = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        return (arr[lo : lo + chunk_edges] for lo in range(0, len(arr), chunk_edges))
    return iter(edges)


# ======================================================================
# stage 1: run formation
# ======================================================================
def _open_run(ref) -> np.ndarray:
    """Memmap one sorted run: ``ref`` is (path, key count)."""
    path, count = ref
    return np.memmap(path, dtype="<u8", mode="r", shape=(count,))


def _form_runs(chunks, run_dir: str, chunk_edges: int):
    """Canonicalize + locally sort/dedup each chunk into a sorted key run.

    Returns (run refs, rows ingested, chunk count, peak scratch edges,
    max node id seen in any chunk — self loops included — or -1); a run ref
    is ``(path, count)`` over a raw little-endian uint64 key file.
    """
    runs: list[tuple[str, int]] = []
    ingested = 0
    nchunks = 0
    peak = 0
    max_id = -1
    pending: list[np.ndarray] = []  # buffered canonical keys, < chunk_edges total
    pending_total = 0

    def emit(keys_parts: list[np.ndarray]) -> None:
        nonlocal peak
        keys = np.concatenate(keys_parts) if len(keys_parts) > 1 else keys_parts[0]
        keys = np.unique(keys)  # sort + local dedup
        peak = max(peak, int(len(keys)))
        path = os.path.join(run_dir, f"run_{len(runs):05d}.u64")
        keys.astype("<u8").tofile(path)
        runs.append((path, len(keys)))

    for chunk in chunks:
        chunk = np.asarray(chunk, dtype=np.int64).reshape(-1, 2)
        nchunks += 1
        ingested += len(chunk)
        if not len(chunk):
            continue
        u, v = chunk[:, 0], chunk[:, 1]
        chunk_max = max(int(u.max()), int(v.max()))
        if u.min() < 0 or v.min() < 0 or chunk_max > _MAX_ID:
            raise ValueError("node ids must fit in int32 (0 <= id < 2**31)")
        # the id space includes nodes seen only in (dropped) self loops
        max_id = max(max_id, chunk_max)
        keep = u != v  # drop self loops
        lo = np.minimum(u[keep], v[keep]).astype(np.uint64)
        hi = np.maximum(u[keep], v[keep]).astype(np.uint64)
        if not len(lo):
            continue
        keys = (lo << np.uint64(32)) | hi
        # buffer small chunks into full-size runs so a tiny ingest chunk size
        # doesn't explode the run count (degrees are counted post-merge)
        pending.append(keys)
        pending_total += len(keys)
        if pending_total >= chunk_edges:
            emit(pending)
            pending, pending_total = [], 0
    if pending_total:
        emit(pending)
    return runs, ingested, nchunks, peak, max_id


# ======================================================================
# stage 2: vectorized k-way merge with streaming dedup
# ======================================================================
def _merge_runs(runs, out_path: str, merge_block: int):
    """K-way merge sorted uint64 key runs into one deduped sorted raw file.

    Classic cut-at-min-of-block-maxima merge: every remaining key ≤ the cut is
    guaranteed to sit inside the runs' current blocks, so each round is one
    vectorized concat/sort/unique over ≤ num_runs · merge_block keys.

    Returns (total unique keys, merge rounds, peak scratch edges).
    """
    mms = [_open_run(r) for r in runs]
    sizes = [len(a) for a in mms]
    cursors = [0] * len(mms)
    total = 0
    rounds = 0
    peak = 0
    with open(out_path, "wb") as out:
        live = [i for i, s in enumerate(sizes) if s > 0]
        while live:
            rounds += 1
            blocks = []
            lasts = []
            for i in live:
                c = cursors[i]
                blk = np.asarray(mms[i][c : c + merge_block])
                blocks.append(blk)
                lasts.append(blk[-1])
            cut = min(lasts)
            cand = []
            for i, blk in zip(live, blocks):
                take = int(np.searchsorted(blk, cut, side="right"))
                cand.append(blk[:take])
                cursors[i] += take
            merged = np.unique(np.concatenate(cand))
            peak = max(peak, int(sum(len(b) for b in blocks) + len(merged)))
            out.write(merged.tobytes())
            total += len(merged)
            live = [i for i in live if cursors[i] < sizes[i]]
    return total, rounds, peak


def _merge_cascade(runs, scratch: str, out_path: str, chunk_edges: int):
    """Merge any number of runs into ``out_path`` with ≤ MERGE_FANOUT fan-in.

    Every input run lives under the build's private scratch tree, so each
    group's files are unlinked the moment the group is merged — peak disk is
    ~2× the deduped data (consumed level + produced level), and memory is
    O(chunk) regardless of run count.
    """
    merge_block = max(256, chunk_edges // MERGE_FANOUT)
    rounds = 0
    peak = 0
    level = 0
    while len(runs) > MERGE_FANOUT:
        nxt = []
        for i in range(0, len(runs), MERGE_FANOUT):
            group = runs[i : i + MERGE_FANOUT]
            path = os.path.join(scratch, f"merge_L{level}_{i:05d}.u64")
            cnt, r, p = _merge_runs(group, path, merge_block)
            rounds += r
            peak = max(peak, p)
            nxt.append((path, cnt))
            for gpath, _ in group:
                os.unlink(gpath)
        runs = nxt
        level += 1
    m, r, p = _merge_runs(runs, out_path, merge_block)
    for gpath, _ in runs:
        os.unlink(gpath)
    return m, rounds + r, max(peak, p)


# ======================================================================
# stage 3: streaming CSR emission
# ======================================================================
def _iter_unpacked(merged_path: str, m: int, chunk_edges: int):
    """Yield (lo, hi) int64 chunks from a merged uint64 key file (memmapped)."""
    if not m:
        return
    keys = np.memmap(merged_path, dtype="<u8", mode="r", shape=(m,))
    for s in range(0, m, chunk_edges):
        k = np.asarray(keys[s : s + chunk_edges])
        yield (k >> np.uint64(32)).astype(np.int64), (
            k & np.uint64(0xFFFFFFFF)
        ).astype(np.int64)


def _count_degrees(merged_path: str, m: int, n: int, chunk_edges: int) -> np.ndarray:
    """Both-direction degree counts of the merged stream (one O(n) array).

    Per-chunk work is O(chunk log chunk) — only the ids a chunk touches are
    updated, so the pass stays cheap even when n >> chunk (webscale configs).
    """
    deg = np.zeros(n, dtype=np.int64)
    for lo, hi in _iter_unpacked(merged_path, m, chunk_edges):
        for ids in (lo, hi):
            uids, counts = np.unique(ids, return_counts=True)
            deg[uids] += counts
    return deg


def _emit_csr(merged_path: str, m: int, n: int, out_dir: str, chunk_edges: int):
    """Scatter the merged (lo, hi) stream into indptr.npy / adj.npy on disk.

    Two O(n) arrays (degree counter, then write cursor) plus an O(chunk)
    scatter buffer; adj.npy is written through an open_memmap, so the 2m-entry
    edge table never materializes in memory.
    """
    deg = _count_degrees(merged_path, m, n, chunk_edges)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])

    os.makedirs(out_dir, exist_ok=True)
    np.save(os.path.join(out_dir, "indptr.npy"), indptr)
    if m == 0:  # np.memmap cannot back a zero-length file
        np.save(os.path.join(out_dir, "adj.npy"), np.zeros(0, dtype=np.int32))
        with open(os.path.join(out_dir, "meta.json"), "w") as f:
            json.dump({"n": n, "m": 0}, f)
        return
    adj = open_memmap(
        os.path.join(out_dir, "adj.npy"), mode="w+", dtype=np.int32, shape=(2 * m,)
    )
    cursor = indptr[:-1].copy()  # next write slot per node
    for lo, hi in _iter_unpacked(merged_path, m, chunk_edges):
        # interleave the two directed copies edge-by-edge so each node's
        # contributions arrive in global (lo, hi) stream order — that order is
        # ascending per neighbor list (smaller neighbors first via the hi
        # side, larger after via the lo side), i.e. the from_edges layout.
        src = np.stack([lo, hi], axis=1).ravel()
        dst = np.stack([hi, lo], axis=1).ravel()
        order = np.argsort(src, kind="stable")
        s_sorted, d_sorted = src[order], dst[order]
        # within-chunk slot of each directed edge under its source node, via
        # the sorted runs — O(chunk) work, no O(n) temporaries per chunk
        uids, first_idx, counts = np.unique(
            s_sorted, return_index=True, return_counts=True
        )
        offset = np.arange(len(s_sorted), dtype=np.int64) - np.repeat(
            first_idx, counts
        )
        adj[cursor[s_sorted] + offset] = d_sorted.astype(np.int32)
        cursor[uids] += counts
    adj.flush()
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump({"n": n, "m": m}, f)


def _relabel_chunks(merged_path: str, m: int, perm: np.ndarray, chunk_edges: int):
    """Yield the merged edge stream with ids mapped through ``perm``."""
    for lo, hi in _iter_unpacked(merged_path, m, chunk_edges):
        yield np.stack([perm[lo], perm[hi]], axis=1)


# ======================================================================
# driver
# ======================================================================
def build_csr(
    edges,
    out_dir: str,
    *,
    n: int | None = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    relabel: str = "none",
    tmp_dir: str | None = None,
) -> BuildStats:
    """Build the on-disk CSR tables for an edge stream, out of core.

    ``edges`` may be an iterator/iterable of ``(k, 2)`` integer arrays, a
    ``.npy`` path or list of ``.npy`` shard paths, a text edge-list path, or a
    single in-memory array (chunked internally).  Self loops are dropped,
    duplicates (either orientation) deduplicated, and the result symmetrized.

    ``n`` fixes the node count; by default it is inferred as ``max id + 1``.
    ``relabel="degree"`` additionally permutes ids degree-descending (stable)
    before emission and records the permutation in ``BuildStats.perm``
    (``new = perm[old]``).

    The output directory holds ``indptr.npy`` / ``adj.npy`` / ``meta.json`` —
    the exact :meth:`CSRGraph.save` layout — ready for
    ``CSRGraph.load(out_dir, mmap=True)``.
    """
    if relabel not in ("none", "degree"):
        raise ValueError(f"unknown relabel mode {relabel!r}")
    chunk_edges = max(int(chunk_edges), 1024)
    scratch = tempfile.mkdtemp(prefix="csrbuild_", dir=tmp_dir)
    try:
        run_dir = os.path.join(scratch, "runs")
        os.makedirs(run_dir)
        chunks = _as_chunks(edges, chunk_edges)
        runs, ingested, nchunks, peak1, max_id = _form_runs(
            chunks, run_dir, chunk_edges
        )
        n_inferred = max_id + 1
        if n is None:
            n = n_inferred
        elif n_inferred > n:
            raise ValueError(f"edge endpoints exceed n={n} (max id {n_inferred - 1})")
        n = int(n)

        merged_path = os.path.join(scratch, "merged.u64")
        m, rounds, peak2 = _merge_cascade(runs, scratch, merged_path, chunk_edges)

        perm = None
        if relabel == "degree" and m:
            deg = _count_degrees(merged_path, m, n, chunk_edges)
            order = np.argsort(-deg, kind="stable")  # old ids, new-id order
            perm = np.empty(n, dtype=np.int64)
            perm[order] = np.arange(n, dtype=np.int64)
            # re-run the pipeline over the permuted stream (ids re-ordered =>
            # keys must be re-sorted); dedup is a no-op the second time.
            run_dir2 = os.path.join(scratch, "runs2")
            os.makedirs(run_dir2)
            runs2, _, _, p1, _ = _form_runs(
                _relabel_chunks(merged_path, m, perm, chunk_edges), run_dir2,
                chunk_edges,
            )
            merged_path = os.path.join(scratch, "merged2.u64")
            m2, rounds2, p2 = _merge_cascade(
                runs2, run_dir2, merged_path, chunk_edges
            )
            if m2 != m:  # persisted-output integrity: survive python -O
                raise RuntimeError(
                    f"relabel must be a bijection (merged {m2} keys, expected {m})"
                )
            rounds += rounds2
            peak1, peak2 = max(peak1, p1), max(peak2, p2)
            runs = runs + runs2

        _emit_csr(merged_path, m, n, out_dir, chunk_edges)
        return BuildStats(
            n=n,
            m=m,
            edges_ingested=ingested,
            chunks=nchunks,
            runs=len(runs),
            merge_rounds=rounds,
            relabel=relabel,
            perm=perm,
            out_dir=out_dir,
            peak_scratch_edges=max(peak1, peak2, 1),
            node_state_bytes=int(n * 8 * 3),  # degree counter, cursor, indptr
        )
    finally:
        shutil.rmtree(scratch, ignore_errors=True)
