from .storage import CSRGraph, BlockReader, paper_example_graph, DEFAULT_BLOCK_EDGES
from .generators import chung_lu, rmat, erdos_renyi, ba, make_dataset, DATASET_SUITE
from .updates import BufferedGraph
from .sampler import NeighborSampler, SampledBlock

__all__ = [
    "CSRGraph", "BlockReader", "paper_example_graph", "DEFAULT_BLOCK_EDGES",
    "chung_lu", "rmat", "erdos_renyi", "ba", "make_dataset", "DATASET_SUITE",
    "BufferedGraph", "NeighborSampler", "SampledBlock",
]
