from .storage import CSRGraph, BlockReader, paper_example_graph, DEFAULT_BLOCK_EDGES
from .generators import (
    chung_lu, rmat, erdos_renyi, ba, make_dataset, DATASET_SUITE,
    rmat_chunks, powerlaw_chunks, uniform_chunks,
)
from .updates import BufferedGraph
from .build import build_csr, BuildStats, edge_chunks_from_npy, edge_chunks_from_text
from .sampler import NeighborSampler, SampledBlock

__all__ = [
    "CSRGraph", "BlockReader", "paper_example_graph", "DEFAULT_BLOCK_EDGES",
    "chung_lu", "rmat", "erdos_renyi", "ba", "make_dataset", "DATASET_SUITE",
    "rmat_chunks", "powerlaw_chunks", "uniform_chunks",
    "BufferedGraph", "build_csr", "BuildStats",
    "edge_chunks_from_npy", "edge_chunks_from_text",
    "NeighborSampler", "SampledBlock",
]
