"""Edge-update memory buffer (paper §V.A, *Graph Maintenance*).

The graph on disk is stored as adjacency lists; rewriting them per update would
be prohibitive.  Following the paper, a bounded in-memory buffer holds the
latest inserted/deleted edges, indexed by endpoint; ``nbr(v)`` reads merge the
on-disk list with the buffered deltas.  When the buffer fills, the CSR is
rewritten ("flushed") and the buffer cleared.
"""
from __future__ import annotations

import numpy as np

from .storage import CSRGraph

__all__ = ["BufferedGraph"]


def _pair_add(index: dict[int, set[int]], u: int, v: int) -> None:
    index.setdefault(u, set()).add(v)
    index.setdefault(v, set()).add(u)


def _pair_discard(index: dict[int, set[int]], u: int, v: int) -> None:
    """Drop (u, v) from both endpoint sets, removing emptied entries.

    Keeping the index free of empty sets is part of the bounded-buffer
    contract: its footprint must track the *buffered* updates, not every node
    ever probed or touched.
    """
    for a, b in ((u, v), (v, u)):
        s = index.get(a)
        if s is not None:
            s.discard(b)
            if not s:
                del index[a]


class BufferedGraph:
    """A CSRGraph plus an edge-update buffer with merged neighbor reads.

    The two endpoint indexes ``_ins``/``_del`` are plain dicts, never
    defaultdicts: membership probes on a defaultdict materialize an empty set
    per probed node, which on a long stream of (mostly rejected) updates grows
    the buffer O(#nodes-touched) and breaks the bounded-buffer contract.
    """

    def __init__(self, graph: CSRGraph, buffer_capacity: int = 1 << 16):
        self.base = graph
        self.capacity = int(buffer_capacity)
        self._ins: dict[int, set[int]] = {}
        self._del: dict[int, set[int]] = {}
        self._size = 0
        self._deg_delta = np.zeros(graph.n, dtype=np.int64)
        self.flushes = 0
        self._flush_hooks: list = []
        # structural version: bumped by every applied update and every flush.
        # Consumers caching derived structure (the device-resident edge table,
        # engine.DeviceBackend) key their caches on it.
        self.version = 0

    def add_flush_hook(self, fn) -> None:
        """Register ``fn(self)`` to run after every CSR rewrite (flush).

        The streaming service uses this to observe storage epochs: a flush
        invalidates any reader state pointed at the old CSR arrays.
        """
        self._flush_hooks.append(fn)

    # ------------------------------------------------------------------ state
    @property
    def n(self) -> int:
        return self.base.n

    @property
    def m(self) -> int:
        return self.base.m + self._size

    def degree(self, v: int) -> int:
        return self.base.degree(v) + int(self._deg_delta[v])

    def degrees(self) -> np.ndarray:
        return self.base.degrees() + self._deg_delta

    # ---------------------------------------------------------------- updates
    def insert_edge(self, u: int, v: int) -> bool:
        """Insert (u, v); returns False if the edge already exists."""
        if u == v:
            return False
        if v in self._ins.get(u, ()):
            return False
        if v in self._del.get(u, ()):  # re-inserting a buffered deletion
            _pair_discard(self._del, u, v)
            self._size -= 1
        else:
            if self.base.has_edge(u, v):
                return False
            _pair_add(self._ins, u, v)
            self._size += 1
        self._deg_delta[u] += 1
        self._deg_delta[v] += 1
        self.version += 1
        self._maybe_flush()
        return True

    def delete_edge(self, u: int, v: int) -> bool:
        """Delete (u, v); returns False if the edge does not exist."""
        if v in self._del.get(u, ()):
            return False
        if v in self._ins.get(u, ()):
            _pair_discard(self._ins, u, v)
            self._size -= 1
        else:
            if not self.base.has_edge(u, v):
                return False
            _pair_add(self._del, u, v)
            self._size += 1
        self._deg_delta[u] -= 1
        self._deg_delta[v] -= 1
        self.version += 1
        self._maybe_flush()
        return True

    # ----------------------------------------------------------------- reads
    def merged_neighbors(self, v: int, disk_nbrs: np.ndarray) -> np.ndarray:
        """Apply buffered deltas for v to its on-disk adjacency list."""
        dels = self._del.get(v)
        ins = self._ins.get(v)
        if not dels and not ins:
            return disk_nbrs
        out = disk_nbrs
        if dels:
            out = out[~np.isin(out, np.fromiter(dels, dtype=np.int32))]
        if ins:
            out = np.concatenate([out, np.fromiter(ins, dtype=np.int32)])
        return out

    # ----------------------------------------------------------------- flush
    def _maybe_flush(self) -> None:
        if self._size >= self.capacity:
            self.flush()

    def flush(self) -> None:
        """Rewrite the CSR applying all buffered updates."""
        if self._size == 0:
            return
        e = self.base.edge_list()
        dels = set()
        for u, vs in self._del.items():
            for v in vs:
                dels.add((min(u, v), max(u, v)))
        if dels:
            keep = np.array(
                [(min(a, b), max(a, b)) not in dels for a, b in e], dtype=bool
            )
            e = e[keep]
        adds = set()
        for u, vs in self._ins.items():
            for v in vs:
                adds.add((min(u, v), max(u, v)))
        if adds:
            e = np.concatenate([e, np.array(sorted(adds), dtype=np.int64)])
        self.base = CSRGraph.from_edges(self.n, e, dedup=False)
        self._ins.clear()
        self._del.clear()
        self._size = 0
        self._deg_delta[:] = 0
        self.flushes += 1
        self.version += 1
        for fn in self._flush_hooks:
            fn(self)

    def materialize(self) -> CSRGraph:
        """Flush and return the up-to-date CSR."""
        self.flush()
        return self.base
