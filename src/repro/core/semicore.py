"""Semi-external core decomposition: SemiCore (Alg. 3), SemiCore+ (Alg. 4),
SemiCore* (Alg. 5) — the paper's contribution — over blocked, I/O-accounted
storage.

Two schedules are provided (see DESIGN.md §2, changed assumption 2):

* ``schedule="seq"``  — the paper's exact pseudocode: one pass processes nodes
  v_min..v_max in order, later nodes see earlier nodes' *new* values within the
  same pass (Gauss–Seidel), with in-pass forward triggering via UpdateRange.
  This is the faithful reproduction; the unit tests assert the paper's exact
  traces (Figs. 2/4/5: 36 / 23 / 11 node computations on the running example).
  The seq schedule always runs on the numpy host path — it is the reference
  every other configuration is checked against.
* ``schedule="batch"`` — all due nodes of a pass are recomputed simultaneously
  from the pass-start state (Jacobi).  This is the vectorized host analogue of
  the SPMD/TPU engine (one superstep == one pass) and converges to the same
  fixpoint by the locality property (Thm 4.1); cnt maintenance stays *exact*
  under simultaneous updates (see the push-rule derivation in DESIGN.md).
  The batch loop lives in :mod:`repro.core.engine` (PassPlanner + pluggable
  ComputeBackend: numpy / xla / pallas — DESIGN.md §11); ``backend=``
  selects the substrate, every backend reaches the identical fixpoint.
  Device backends run the whole fixpoint device-resident by default
  (:mod:`repro.core.resident`, DESIGN.md §12): node state and the edge
  table upload once, many fused passes execute per host round-trip, and the
  planner's I/O trace is replayed bit-identically from the per-pass
  frontier summaries.

Both schedules account I/O identically: one read I/O per distinct edge-table
block touched per pass (single-buffer sequential scan, external-memory model),
plus node-table blocks for the scanned [v_min, v_max] range.
"""
from __future__ import annotations

import numpy as np

from .. import runtime as _runtime
from ..graph.storage import CSRGraph, BlockReader, DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from ..obs import trace as _trace
from .engine import DecompResult, PassPlanner, _pass_obs, run_batch
from .localcore import local_core

__all__ = ["DecompResult", "HostEngine", "decompose"]


def _seq_only(backend) -> None:
    """The seq schedule is the faithful paper reference: numpy host only.

    A non-numpy request — explicit or via the ``REPRO_BACKEND`` env default —
    raises rather than silently running numpy, so the two spellings agree.
    Internal reference-path callers pass ``backend="numpy"`` explicitly.
    """
    if backend is None:
        backend = _runtime.setting("backend")
    if backend is not None and str(getattr(backend, "name", backend)) != "numpy":
        raise ValueError(
            "schedule='seq' is the paper-faithful reference path and runs on "
            "the numpy host backend only; use schedule='batch' for "
            f"backend={backend!r}"
        )


class HostEngine:
    """Host-side semi-external engine over blocked storage (+ update buffer).

    ``pool_blocks`` sizes the :class:`BlockReader` LRU buffer pool; the
    default of 1 is the paper's single-buffer model (DESIGN.md §10).
    Batch-schedule compute is delegated to :mod:`repro.core.engine`; pass
    ``backend=`` ("numpy" | "xla" | "pallas" | "shard", or a ComputeBackend
    instance) to pick the substrate.
    """

    def __init__(
        self,
        graph,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
        retry=None,
        settings: "_runtime.Settings | None" = None,
    ):
        #: optional consolidated knob snapshot (repro.runtime.Settings);
        #: supplies the default backend/chunk where a call leaves them None
        #: (env vars still win — the documented env > override > default
        #: order is applied per call through runtime.setting).
        self.settings = settings
        if isinstance(graph, BufferedGraph):
            self.buffered: BufferedGraph | None = graph
            base = graph.base
        else:
            self.buffered = None
            base = graph
        self.graph = base
        self.reader = BlockReader(
            base, block_edges, pool_blocks=pool_blocks, retry=retry)
        self.planner = PassPlanner(self)

    # ------------------------------------------------------------------ reads
    def _sync(self) -> None:
        """Re-point at the current base CSR after a buffer flush rewrite."""
        if self.buffered is not None and self.buffered.base is not self.graph:
            self.graph = self.buffered.base
            self.reader.graph = self.graph
            self.reader.invalidate()  # resident blocks belong to the old CSR

    def nbrs(self, v: int) -> np.ndarray:
        self._sync()
        raw = self.reader.load_neighbors(v)
        if self.buffered is not None:
            return self.buffered.merged_neighbors(v, raw)
        return raw

    def degrees(self) -> np.ndarray:
        if self.buffered is not None:
            return self.buffered.degrees()
        return self.graph.degrees()

    @property
    def n(self) -> int:
        return self.graph.n

    def _defaults(self, backend, superstep_chunk):
        """Fill unset per-call knobs from this engine's Settings."""
        if self.settings is not None:
            if backend is None:
                backend = _runtime.setting("backend", self.settings.backend)
            if superstep_chunk is None:
                superstep_chunk = _runtime.setting(
                    "resident_chunk", self.settings.resident_chunk)
        return backend, superstep_chunk

    # =====================================================================
    # Algorithm 3: SemiCore
    # =====================================================================
    def semicore(self, schedule: str = "seq", backend=None,
                 superstep_chunk: int | None = None) -> DecompResult:
        if schedule == "batch":
            backend, superstep_chunk = self._defaults(backend, superstep_chunk)
            return run_batch(self, "semicore", backend,
                             superstep_chunk=superstep_chunk)
        _seq_only(backend)
        n = self.n
        core = self.degrees().astype(np.int64)
        comp = 0
        iters = 0
        upd_hist, comp_hist = [], []
        update = True
        om = _pass_obs("semicore", "numpy", "seq")
        while update:
            update = False
            iters += 1
            upd = 0
            with _trace.span("superstep", cat="engine", algorithm="semicore",
                             backend="numpy", schedule="seq",
                             index=iters) as sp:
                self.reader.account_node_table_scan(0, n - 1)
                for v in range(n):
                    nbrs = self.nbrs(v)
                    c_old = int(core[v])
                    c_new = local_core(c_old, core[nbrs])
                    comp += 1
                    if c_new != c_old:
                        core[v] = c_new
                        update = True
                        upd += 1
                if sp.active:
                    sp.set(computed=n, updates=upd)
            om[0].inc()
            om[1].inc(n)
            om[2].inc(upd)
            upd_hist.append(upd)
            comp_hist.append(n)
        return self._result(core, None, iters, comp, "semicore", "seq", upd_hist, comp_hist)

    # =====================================================================
    # Algorithm 4: SemiCore+
    # =====================================================================
    def semicore_plus(self, schedule: str = "seq", backend=None,
                      superstep_chunk: int | None = None) -> DecompResult:
        if schedule == "batch":
            backend, superstep_chunk = self._defaults(backend, superstep_chunk)
            return run_batch(self, "semicore+", backend,
                             superstep_chunk=superstep_chunk)
        _seq_only(backend)
        n = self.n
        core = self.degrees().astype(np.int64)
        active = np.ones(n, dtype=bool)
        vmin, vmax = 0, n - 1
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        update = True
        om = _pass_obs("semicore+", "numpy", "seq")
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            upd = cpt = 0
            scan_lo = vmin
            v = vmin
            with _trace.span("superstep", cat="engine", algorithm="semicore+",
                             backend="numpy", schedule="seq",
                             index=iters) as sp:
                while v <= vmax:
                    if active[v]:
                        active[v] = False
                        nbrs = self.nbrs(v)
                        c_old = int(core[v])
                        c_new = local_core(c_old, core[nbrs])
                        cpt += 1
                        if c_new != c_old:
                            core[v] = c_new
                            upd += 1
                            for u in nbrs:
                                active[u] = True
                                u = int(u)
                                # UpdateRange (Alg. 4 lines 17-21)
                                if u > vmax:
                                    vmax = u
                                if u < v:
                                    update = True
                                    nvmin = min(nvmin, u)
                                    nvmax = max(nvmax, u)
                    v += 1
                self.reader.account_node_table_scan(scan_lo, vmax)
                if sp.active:
                    sp.set(computed=cpt, updates=upd)
            om[0].inc()
            om[1].inc(cpt)
            om[2].inc(upd)
            vmin, vmax = nvmin, nvmax
            upd_hist.append(upd)
            comp_hist.append(cpt)
            comp += cpt
        return self._result(core, None, iters, comp, "semicore+", "seq", upd_hist, comp_hist)

    # =====================================================================
    # Algorithm 5: SemiCore*
    # =====================================================================
    def semicore_star(
        self,
        schedule: str = "seq",
        *,
        core: np.ndarray | None = None,
        cnt: np.ndarray | None = None,
        vrange: tuple[int, int] | None = None,
        backend=None,
        superstep_chunk: int | None = None,
        _count_first_pass_all: bool = True,
    ) -> DecompResult:
        """Full Algorithm 5; with (core, cnt, vrange) given, runs its lines
        4-14 as a warm-started settle loop (used by SemiDelete*/SemiInsert)."""
        if schedule == "batch":
            backend, superstep_chunk = self._defaults(backend, superstep_chunk)
            return run_batch(self, "semicore*", backend, core=core, cnt=cnt,
                             superstep_chunk=superstep_chunk)
        _seq_only(backend)
        n = self.n
        warm = core is not None
        if not warm:
            core = self.degrees().astype(np.int64)
            cnt = np.zeros(n, dtype=np.int64)
            vmin, vmax = 0, n - 1
        else:
            core = np.asarray(core, dtype=np.int64)
            assert cnt is not None
            cnt = np.asarray(cnt, dtype=np.int64)
            vmin, vmax = vrange if vrange is not None else (0, n - 1)
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        update = True
        om = _pass_obs("semicore*", "numpy", "seq")
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            upd = cpt = 0
            scan_lo = vmin
            v = vmin
            with _trace.span("superstep", cat="engine", algorithm="semicore*",
                             backend="numpy", schedule="seq",
                             index=iters) as sp:
                while v <= vmax:
                    if cnt[v] < core[v]:
                        nbrs = self.nbrs(v)
                        c_old = int(core[v])
                        nbr_cores = core[nbrs]
                        c_new = local_core(c_old, nbr_cores)
                        cpt += 1
                        if c_new != c_old:
                            upd += 1
                        core[v] = c_new
                        # ComputeCnt (Eq. 2)
                        cnt[v] = int((nbr_cores >= c_new).sum())
                        # UpdateNbrCnt: push decrements into (c_new, c_old]
                        push = nbrs[(nbr_cores > c_new) & (nbr_cores <= c_old)]
                        if len(push):
                            np.subtract.at(cnt, push, 1)
                        # UpdateRange over now-deficient neighbors
                        for u in nbrs:
                            u = int(u)
                            if cnt[u] < core[u]:
                                if u > vmax:
                                    vmax = u
                                if u < v:
                                    update = True
                                    nvmin = min(nvmin, u)
                                    nvmax = max(nvmax, u)
                    v += 1
                self.reader.account_node_table_scan(scan_lo, vmax)
                if sp.active:
                    sp.set(computed=cpt, updates=upd)
            om[0].inc()
            om[1].inc(cpt)
            om[2].inc(upd)
            vmin, vmax = nvmin, nvmax
            upd_hist.append(upd)
            comp_hist.append(cpt)
            comp += cpt
        return self._result(core, cnt, iters, comp, "semicore*", "seq", upd_hist, comp_hist)

    # ------------------------------------------------------------------ utils
    def _result(self, core, cnt, iters, comp, algo, schedule, upd, cpt) -> DecompResult:
        return DecompResult(
            core=core,
            cnt=cnt,
            iterations=iters,
            node_computations=comp,
            edge_block_reads=self.reader.reads,
            node_table_reads=self.reader.node_table_reads,
            algorithm=algo,
            schedule=schedule,
            updates_per_iter=upd,
            computations_per_iter=cpt,
            backend="numpy",
        )


def decompose(
    graph,
    algorithm: str = "semicore*",
    schedule: str = "batch",
    block_edges: int = DEFAULT_BLOCK_EDGES,
    pool_blocks: int = 1,
    backend=None,
    superstep_chunk: int | None = None,
    settings: "_runtime.Settings | None" = None,
) -> DecompResult:
    """One-call core decomposition with the chosen paper algorithm.

    ``backend`` picks the batch-schedule compute substrate ("numpy" | "xla" |
    "pallas" | "shard" | a ComputeBackend instance); ``None`` defers to the
    ``REPRO_BACKEND`` environment variable (default numpy).  The seq schedule
    is the paper-faithful numpy reference path.  ``superstep_chunk`` sizes
    the device-resident passes-per-round-trip (CoreGraphConfig field /
    REPRO_RESIDENT_CHUNK env; DESIGN.md §12) — ignored off the resident path.
    ``settings`` (a :class:`repro.runtime.Settings`) supplies defaults for
    every knob left ``None`` here, with env vars still taking precedence —
    the one env > override > default resolution order (DESIGN.md §18).
    """
    eng = HostEngine(graph, block_edges, pool_blocks=pool_blocks,
                     settings=settings)
    if algorithm == "semicore":
        return eng.semicore(schedule, backend=backend,
                            superstep_chunk=superstep_chunk)
    if algorithm == "semicore+":
        return eng.semicore_plus(schedule, backend=backend,
                                 superstep_chunk=superstep_chunk)
    if algorithm == "semicore*":
        return eng.semicore_star(schedule, backend=backend,
                                 superstep_chunk=superstep_chunk)
    raise ValueError(f"unknown algorithm {algorithm!r}")
