"""Semi-external core decomposition: SemiCore (Alg. 3), SemiCore+ (Alg. 4),
SemiCore* (Alg. 5) — the paper's contribution — over blocked, I/O-accounted
storage.

Two schedules are provided (see DESIGN.md §2, changed assumption 2):

* ``schedule="seq"``  — the paper's exact pseudocode: one pass processes nodes
  v_min..v_max in order, later nodes see earlier nodes' *new* values within the
  same pass (Gauss–Seidel), with in-pass forward triggering via UpdateRange.
  This is the faithful reproduction; the unit tests assert the paper's exact
  traces (Figs. 2/4/5: 36 / 23 / 11 node computations on the running example).
* ``schedule="batch"`` — all due nodes of a pass are recomputed simultaneously
  from the pass-start state (Jacobi).  This is the vectorized host analogue of
  the SPMD/TPU engine (one superstep == one pass) and converges to the same
  fixpoint by the locality property (Thm 4.1); cnt maintenance stays *exact*
  under simultaneous updates (see the push-rule derivation in DESIGN.md).

Both schedules account I/O identically: one read I/O per distinct edge-table
block touched per pass (single-buffer sequential scan, external-memory model),
plus node-table blocks for the scanned [v_min, v_max] range.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..graph.storage import CSRGraph, BlockReader, DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from .localcore import local_core, h_index_batch, compute_cnt_batch

__all__ = ["DecompResult", "HostEngine", "decompose"]


@dataclass
class DecompResult:
    core: np.ndarray
    cnt: np.ndarray | None
    iterations: int
    node_computations: int
    edge_block_reads: int
    node_table_reads: int
    algorithm: str
    schedule: str
    updates_per_iter: list = field(default_factory=list)
    computations_per_iter: list = field(default_factory=list)

    @property
    def kmax(self) -> int:
        return int(self.core.max()) if len(self.core) else 0

    @property
    def memory_bytes(self) -> int:
        """O(n) node-state bytes held in memory (the paper's bound)."""
        per_node = 8 + (8 if self.cnt is not None else 0) + 1
        return len(self.core) * per_node


class HostEngine:
    """Host-side semi-external engine over blocked storage (+ update buffer).

    ``pool_blocks`` sizes the :class:`BlockReader` LRU buffer pool; the
    default of 1 is the paper's single-buffer model (DESIGN.md §10).
    """

    def __init__(
        self,
        graph,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
    ):
        if isinstance(graph, BufferedGraph):
            self.buffered: BufferedGraph | None = graph
            base = graph.base
        else:
            self.buffered = None
            base = graph
        self.graph = base
        self.reader = BlockReader(base, block_edges, pool_blocks=pool_blocks)

    # ------------------------------------------------------------------ reads
    def _sync(self) -> None:
        """Re-point at the current base CSR after a buffer flush rewrite."""
        if self.buffered is not None and self.buffered.base is not self.graph:
            self.graph = self.buffered.base
            self.reader.graph = self.graph
            self.reader.invalidate()  # resident blocks belong to the old CSR

    def nbrs(self, v: int) -> np.ndarray:
        self._sync()
        raw = self.reader.load_neighbors(v)
        if self.buffered is not None:
            return self.buffered.merged_neighbors(v, raw)
        return raw

    def degrees(self) -> np.ndarray:
        if self.buffered is not None:
            return self.buffered.degrees()
        return self.graph.degrees()

    @property
    def n(self) -> int:
        return self.graph.n

    # =====================================================================
    # Algorithm 3: SemiCore
    # =====================================================================
    def semicore(self, schedule: str = "seq") -> DecompResult:
        if schedule == "batch":
            return self._semicore_batch()
        n = self.n
        core = self.degrees().astype(np.int64)
        comp = 0
        iters = 0
        upd_hist, comp_hist = [], []
        update = True
        while update:
            update = False
            iters += 1
            upd = 0
            self.reader.account_node_table_scan(0, n - 1)
            for v in range(n):
                nbrs = self.nbrs(v)
                c_old = int(core[v])
                c_new = local_core(c_old, core[nbrs])
                comp += 1
                if c_new != c_old:
                    core[v] = c_new
                    update = True
                    upd += 1
            upd_hist.append(upd)
            comp_hist.append(n)
        return self._result(core, None, iters, comp, "semicore", "seq", upd_hist, comp_hist)

    def _semicore_batch(self) -> DecompResult:
        n = self.n
        g = self.graph
        core = self.degrees().astype(np.int64)
        all_nodes = np.arange(n, dtype=np.int64)
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        while True:
            iters += 1
            vals, seg_ptr, nbr_flat = self._gather(all_nodes, core)
            self.reader.account_node_table_scan(0, n - 1)
            h = np.minimum(h_index_batch(vals, seg_ptr), core)
            changed = int((h != core).sum())
            upd_hist.append(changed)
            comp_hist.append(n)
            comp += n
            core = h
            if changed == 0:
                break
        return self._result(core, None, iters, comp, "semicore", "batch", upd_hist, comp_hist)

    # =====================================================================
    # Algorithm 4: SemiCore+
    # =====================================================================
    def semicore_plus(self, schedule: str = "seq") -> DecompResult:
        if schedule == "batch":
            return self._semicore_plus_batch()
        n = self.n
        core = self.degrees().astype(np.int64)
        active = np.ones(n, dtype=bool)
        vmin, vmax = 0, n - 1
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            upd = cpt = 0
            scan_lo = vmin
            v = vmin
            while v <= vmax:
                if active[v]:
                    active[v] = False
                    nbrs = self.nbrs(v)
                    c_old = int(core[v])
                    c_new = local_core(c_old, core[nbrs])
                    cpt += 1
                    if c_new != c_old:
                        core[v] = c_new
                        upd += 1
                        for u in nbrs:
                            active[u] = True
                            u = int(u)
                            # UpdateRange (Alg. 4 lines 17-21)
                            if u > vmax:
                                vmax = u
                            if u < v:
                                update = True
                                nvmin = min(nvmin, u)
                                nvmax = max(nvmax, u)
                v += 1
            self.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax
            upd_hist.append(upd)
            comp_hist.append(cpt)
            comp += cpt
        return self._result(core, None, iters, comp, "semicore+", "seq", upd_hist, comp_hist)

    def _semicore_plus_batch(self) -> DecompResult:
        n = self.n
        core = self.degrees().astype(np.int64)
        frontier = np.arange(n, dtype=np.int64)
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        while len(frontier):
            iters += 1
            vals, seg_ptr, nbr_flat = self._gather(frontier, core)
            self.reader.account_node_table_scan(int(frontier[0]), int(frontier[-1]))
            h = np.minimum(h_index_batch(vals, seg_ptr), core[frontier])
            changed_mask = h != core[frontier]
            comp += len(frontier)
            comp_hist.append(len(frontier))
            upd_hist.append(int(changed_mask.sum()))
            core[frontier] = h
            # Lemma 4.1: only neighbors of changed nodes can change next pass
            lens = np.diff(seg_ptr)
            seg_changed = np.repeat(changed_mask, lens)
            frontier = np.unique(nbr_flat[seg_changed].astype(np.int64))
            frontier = frontier[core[frontier] > 0]
        return self._result(core, None, iters, comp, "semicore+", "batch", upd_hist, comp_hist)

    # =====================================================================
    # Algorithm 5: SemiCore*
    # =====================================================================
    def semicore_star(
        self,
        schedule: str = "seq",
        *,
        core: np.ndarray | None = None,
        cnt: np.ndarray | None = None,
        vrange: tuple[int, int] | None = None,
        _count_first_pass_all: bool = True,
    ) -> DecompResult:
        """Full Algorithm 5; with (core, cnt, vrange) given, runs its lines
        4-14 as a warm-started settle loop (used by SemiDelete*/SemiInsert)."""
        if schedule == "batch":
            return self._semicore_star_batch(core=core, cnt=cnt)
        n = self.n
        warm = core is not None
        if not warm:
            core = self.degrees().astype(np.int64)
            cnt = np.zeros(n, dtype=np.int64)
            vmin, vmax = 0, n - 1
        else:
            core = np.asarray(core, dtype=np.int64)
            assert cnt is not None
            cnt = np.asarray(cnt, dtype=np.int64)
            vmin, vmax = vrange if vrange is not None else (0, n - 1)
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            upd = cpt = 0
            scan_lo = vmin
            v = vmin
            while v <= vmax:
                if cnt[v] < core[v]:
                    nbrs = self.nbrs(v)
                    c_old = int(core[v])
                    nbr_cores = core[nbrs]
                    c_new = local_core(c_old, nbr_cores)
                    cpt += 1
                    if c_new != c_old:
                        upd += 1
                    core[v] = c_new
                    # ComputeCnt (Eq. 2)
                    cnt[v] = int((nbr_cores >= c_new).sum())
                    # UpdateNbrCnt: push decrements into (c_new, c_old]
                    push = nbrs[(nbr_cores > c_new) & (nbr_cores <= c_old)]
                    if len(push):
                        np.subtract.at(cnt, push, 1)
                    # UpdateRange over now-deficient neighbors
                    for u in nbrs:
                        u = int(u)
                        if cnt[u] < core[u]:
                            if u > vmax:
                                vmax = u
                            if u < v:
                                update = True
                                nvmin = min(nvmin, u)
                                nvmax = max(nvmax, u)
                v += 1
            self.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax
            upd_hist.append(upd)
            comp_hist.append(cpt)
            comp += cpt
        return self._result(core, cnt, iters, comp, "semicore*", "seq", upd_hist, comp_hist)

    def _semicore_star_batch(
        self, *, core: np.ndarray | None = None, cnt: np.ndarray | None = None
    ) -> DecompResult:
        n = self.n
        warm = core is not None
        if not warm:
            core = self.degrees().astype(np.int64)
            cnt = np.zeros(n, dtype=np.int64)
        else:
            core = np.asarray(core, dtype=np.int64).copy()
            cnt = np.asarray(cnt, dtype=np.int64).copy()
        comp, iters = 0, 0
        upd_hist, comp_hist = [], []
        frontier = np.flatnonzero((cnt < core) & (core > 0))
        while len(frontier):
            iters += 1
            vals_old, seg_ptr, nbr_flat = self._gather(frontier, core)
            self.reader.account_node_table_scan(int(frontier[0]), int(frontier[-1]))
            c_old_f = core[frontier].copy()
            h = np.minimum(h_index_batch(vals_old, seg_ptr), c_old_f)
            comp += len(frontier)
            comp_hist.append(len(frontier))
            upd_hist.append(int((h != c_old_f).sum()))
            core[frontier] = h
            # exact cnt under simultaneous updates (DESIGN.md §2):
            # (1) recompute cnt of frontier against pass-start neighbor values
            cnt[frontier] = compute_cnt_batch(vals_old, seg_ptr, h)
            # (2) push decrements: edge (v in F -> u) with
            #     core_now(u) in (h(v), c_old(v)]
            lens = np.diff(seg_ptr)
            h_rep = np.repeat(h, lens)
            c_old_rep = np.repeat(c_old_f, lens)
            core_now_u = core[nbr_flat]
            mask = (core_now_u > h_rep) & (core_now_u <= c_old_rep)
            if mask.any():
                dec = np.bincount(nbr_flat[mask].astype(np.int64), minlength=n)
                cnt -= dec
            frontier = np.flatnonzero((cnt < core) & (core > 0))
        return self._result(core, cnt, iters, comp, "semicore*", "batch", upd_hist, comp_hist)

    # ------------------------------------------------------------------ utils
    def _gather(self, nodes: np.ndarray, core: np.ndarray):
        """Flattened adjacency of ``nodes`` + exact block-I/O accounting.

        Returns (neighbor core values, segment offsets, flat neighbor ids).
        """
        self._sync()
        g = self.graph
        lo = g.indptr[nodes]
        hi = g.indptr[nodes + 1]
        lens = (hi - lo).astype(np.int64)
        total = int(lens.sum())
        seg_ptr = np.zeros(len(nodes) + 1, dtype=np.int64)
        np.cumsum(lens, out=seg_ptr[1:])
        if total:
            flat = np.repeat(lo - seg_ptr[:-1], lens) + np.arange(total, dtype=np.int64)
            nbr_flat = np.asarray(g.adj)[flat]
        else:
            nbr_flat = np.empty(0, dtype=np.int32)
        # block I/O: union of [lo//B, hi-1//B] intervals, streamed through the
        # reader's buffer pool in ascending order (single buffer when
        # pool_blocks == 1, LRU page cache otherwise)
        B = self.reader.block_edges
        nz = lens > 0
        if nz.any():
            first = (lo[nz] // B).astype(np.int64)
            last = ((hi[nz] - 1) // B).astype(np.int64)
            nb = self.reader.num_blocks
            diff = np.zeros(nb + 1, dtype=np.int64)
            np.add.at(diff, first, 1)
            np.add.at(diff, last + 1, -1)
            covered = np.cumsum(diff[:-1]) > 0
            self.reader.charge_pass(np.flatnonzero(covered))
        # merge buffered edge deltas (in-memory, no extra block I/O): locate
        # the dirty nodes vectorized and splice only their segments, so a
        # handful of buffered updates costs O(|dirty|) Python work plus the
        # unavoidable flat-array copy — never a loop over the whole frontier
        if self.buffered is not None and self.buffered._size:
            dirty = np.fromiter(
                self.buffered._ins.keys() | self.buffered._del.keys(),
                dtype=np.int64,
            )
            hit = np.flatnonzero(np.isin(nodes, dirty))
            if len(hit):
                merged = [
                    np.asarray(
                        self.buffered.merged_neighbors(
                            int(nodes[i]), nbr_flat[seg_ptr[i] : seg_ptr[i + 1]]
                        ),
                        dtype=np.int32,
                    )
                    for i in hit
                ]
                new_lens = np.diff(seg_ptr)
                new_lens[hit] = [len(s) for s in merged]
                new_ptr = np.zeros(len(nodes) + 1, dtype=np.int64)
                np.cumsum(new_lens, out=new_ptr[1:])
                out = np.empty(int(new_ptr[-1]), dtype=np.int32)
                prev_old = 0
                prev_new = 0
                for seg, i in zip(merged, hit):
                    span = int(seg_ptr[i]) - prev_old  # untouched run before i
                    out[prev_new : prev_new + span] = nbr_flat[prev_old : prev_old + span]
                    prev_new += span
                    out[prev_new : prev_new + len(seg)] = seg
                    prev_new += len(seg)
                    prev_old = int(seg_ptr[i + 1])
                out[prev_new:] = nbr_flat[prev_old:]
                nbr_flat, seg_ptr = out, new_ptr
        return core[nbr_flat], seg_ptr, nbr_flat

    def _result(self, core, cnt, iters, comp, algo, schedule, upd, cpt) -> DecompResult:
        return DecompResult(
            core=core,
            cnt=cnt,
            iterations=iters,
            node_computations=comp,
            edge_block_reads=self.reader.reads,
            node_table_reads=self.reader.node_table_reads,
            algorithm=algo,
            schedule=schedule,
            updates_per_iter=upd,
            computations_per_iter=cpt,
        )


def decompose(
    graph,
    algorithm: str = "semicore*",
    schedule: str = "batch",
    block_edges: int = DEFAULT_BLOCK_EDGES,
    pool_blocks: int = 1,
) -> DecompResult:
    """One-call core decomposition with the chosen paper algorithm."""
    eng = HostEngine(graph, block_edges, pool_blocks=pool_blocks)
    if algorithm == "semicore":
        return eng.semicore(schedule)
    if algorithm == "semicore+":
        return eng.semicore_plus(schedule)
    if algorithm == "semicore*":
        return eng.semicore_star(schedule)
    raise ValueError(f"unknown algorithm {algorithm!r}")
