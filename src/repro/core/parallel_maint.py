"""Parallel independent-group maintenance settle (DESIGN.md §18).

The serial batch path settles every micro-batch with a full exact-cnt
prologue + SemiCore* warm settle — O(E) device work and a near-cold warm
start (``core0 + I``) no matter how local the updates are.  This module is
the batched alternative: bound the possible damage of every update (Li &
Yu, arXiv 1207.4567), partition the batch into independent groups (Wang et
al., arXiv 1612.09368), and settle *all* groups as one device-resident
masked fixpoint in which non-candidate nodes are frozen and the warm start
is exact on the insert side (a host-side peel of each candidate component).

Per-update candidate bound (all sets computed on the post-update graph,
levels w.r.t. the round-start cores; ``cnt`` is Eq. 2 and equals the
paper's mcd for a node at its own level):

* **Insert at level c** (``c = min(core0[u], core0[v])``): only nodes with
  ``core0 == c`` reachable from the root through nodes with ``core0 == c``
  and ``cnt >= c+1`` can rise, and by at most 1 (the purecore bound — a
  node with ``cnt <= c`` cannot reach ``c+1`` neighbors of rank ``c+1``
  and blocks propagation, and that exclusion is stable under same-level
  raises).  The candidate set is the root's *exact* purecore component,
  computed by whole-level label propagation over the flat merged adjacency
  — no per-node BFS, no lost-completeness cap.  An empty set (no endpoint
  qualifies) means nothing can rise.  A component larger than the cap is
  *heavy*: the round takes the serial warm-settle fallback.

* **Delete at level c**: a delete can only force drops, and drops cascade
  strictly *downward* in level (a node dropping from c supports exactly
  the thresholds in ``(core_new, c]``), so the prefix ``core0 <= c`` is a
  complete candidate set for any cascade the delete can start.  Deletes
  whose endpoints stay non-deficient after the structural cnt deltas are
  absorbed (nothing can change).  Prefix candidates cost nothing: they add
  no warm bump, so frozen-but-masked nodes never enter the frontier unless
  a cascade actually reaches them.

The rise set of a level-c component is resolved exactly *before* the
device settle by a host peel: start from the whole component optimistically
risen, and repeatedly drop every member whose support at ``c+1``
(neighbors with ``core0 >= c+1`` plus surviving co-members) falls short.
The greatest fixpoint of that shrinking iteration is precisely the rise
set the masked device fixpoint would grind out of a blanket ``c+1`` bump —
computed in O(component edges) on host instead of O(E)-per-pass on device,
and still a sound upper bound under concurrent deletes (drops only shrink
support, and the settle corrects from above).  Survivors are warmed to
``c+1`` and cnt is patched in one vectorized pass (a raised node crosses
the threshold of exactly its neighbors with ``core0`` in ``(old, warm]``;
raised nodes are recounted exactly against the warm values), then ONE
masked SemiCore* fixpoint settles every group —
``resident.run_resident(..., settle_mask=...)`` on device backends, a
thread-free warm-start seq settle on numpy.  Its initial frontier is the
delete-deficient set only: the insert side arrives pre-settled.

Two inserts can *compound* — a level-c raise bumps the threshold-(c+1)
support of a node no component admitted, newly qualifying it for the
level-(c+1) riser structure, past the per-insert +1 bound.  Instead of
merging and serializing such groups up front, the settle runs **saturation
rounds**: after each round, any node that actually rose becomes a root for
the next round, re-planned on the settled state (same graph, same resident
structure — nothing is undone or re-applied).  A missed rise always has a
minimal-level witness that passes the purecore test on the settled state
and is connected to a prior riser or insert endpoint through its level
component (else the rise was available before the batch, contradicting the
pre-batch exactness), so re-rooting at risers is complete; each extra
round strictly raises some core, so the loop terminates.  In the common
case round 2 finds no qualifying roots and plans nothing.

Convergence-from-above with a frozen boundary is exact iff the frozen
values are; the feasibility certificate ``all(cnt >= core)`` checks
exactly that (the settle keeps cnt exact *everywhere*, frozen nodes
included, via the push rule), and a violation escalates the round to the
serial warm settle — so the result is bit-identical to the serial oracle
by construction, which the differential battery asserts.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..obs import metrics as _metrics
from .engine import warm_settle

__all__ = ["DEFAULT_GROUP_CAP", "UpdateCand", "BatchPlan", "plan_batch",
           "grouped_settle"]

#: candidate-set cap per group: an insert whose purecore component exceeds
#: this is *heavy* and sends the round to the serial warm-settle fallback
DEFAULT_GROUP_CAP = 2048

#: hard bound on saturation rounds (every extra round strictly raises some
#: core, so this only guards a planner bug)
_MAX_ROUNDS = 64

_GROUPS_SETTLED = _metrics.counter(
    "repro_maintenance_groups_total",
    "Independent maintenance groups planned by the parallel settle",
).labels(outcome="settled")
_GROUPS_FALLBACK = _metrics.counter(
    "repro_maintenance_groups_total",
    "Independent maintenance groups planned by the parallel settle",
).labels(outcome="fallback")
_GROUP_SIZE = _metrics.histogram(
    "repro_maintenance_group_size_nodes",
    "Candidate-set size per planned maintenance group",
    buckets=_metrics.DEFAULT_COUNT_BUCKETS,
)
_ESCALATIONS = _metrics.counter(
    "repro_maintenance_escalations_total",
    "Masked settles whose feasibility certificate failed (serial redo)",
)
_ROUNDS = _metrics.histogram(
    "repro_maintenance_settle_rounds",
    "Saturation rounds needed to settle one micro-batch",
    buckets=(1, 2, 3, 4, 6, 8, 16),
)


@dataclass
class UpdateCand:
    """One applied update (or riser re-root) with its candidate bound."""

    kind: str              # "+" insert, "-" delete, "^" riser re-root
    u: int
    v: int
    level: int             # min(core0[u], core0[v]); riser: its new core
    op: int                # position in the applied order (-1: re-root)
    cand: np.ndarray       # candidate node ids (empty: absorbed or prefix)
    prefix_level: int = -1  # >= 0: candidates are {x : core0[x] <= level}
    size: int = 0          # true candidate count (prefix included)
    heavy: bool = False    # insert component exceeded the cap


@dataclass
class BatchPlan:
    """One round's updates and their independent-group partition."""

    updates: list = field(default_factory=list)   # UpdateCand, applied order
    groups: list = field(default_factory=list)    # lists of UpdateCand

    @property
    def heavy(self) -> bool:
        return any(up.heavy for up in self.updates)

    @property
    def largest_group(self) -> int:
        sizes = [sum(up.size for up in g) for g in self.groups]
        return max(sizes, default=0)


class _Arrays:
    """One batch's planning snapshot: the flat merged adjacency."""

    def __init__(self, engine):
        nbr_flat, seg_ptr = engine.planner.full_structure()
        self.dst = np.asarray(nbr_flat, dtype=np.int64)
        self.seg = np.asarray(seg_ptr, dtype=np.int64)
        self.n = len(self.seg) - 1
        self.src = np.repeat(np.arange(self.n, dtype=np.int64),
                             np.diff(self.seg))

    def nbrs(self, v: int) -> np.ndarray:
        return self.dst[self.seg[v]:self.seg[v + 1]]


def _level_components(arr: _Arrays, core0, cnt, c):
    """Exact purecore components at level ``c`` by label propagation.

    Returns ``(sel, lab)``: the purecore membership mask and per-node
    component labels (min member id; -1 off-level).
    """
    sel = (core0 == c) & (cnt >= c + 1)
    lab = np.where(sel, np.arange(arr.n, dtype=np.int64), -1)
    em = sel[arr.src] & sel[arr.dst]
    a, b = arr.src[em], arr.dst[em]
    while True:
        new = lab.copy()
        np.minimum.at(new, b, lab[a])
        if np.array_equal(new, lab):
            break
        lab = new
    return sel, lab


def _peel(arr: _Arrays, core0, S: np.ndarray, c: int) -> np.ndarray:
    """Exact rise set of the level-``c`` candidate mask ``S``.

    Greatest fixpoint of: keep ``x`` in the risen set iff its support at
    ``c+1`` — neighbors with ``core0 >= c+1`` plus surviving co-risers —
    reaches ``c+1``.  ``base`` is optimism-independent, so it's computed
    once; the loop touches only the in-``S`` edges.
    """
    es = S[arr.src]
    base = np.zeros(arr.n, dtype=np.int64)
    np.add.at(base, arr.src[es],
              (core0[arr.dst[es]] >= c + 1).astype(np.int64))
    ie = es & S[arr.dst]
    a, b = arr.src[ie], arr.dst[ie]
    cur = S.copy()
    while True:
        inS = np.zeros(arr.n, dtype=np.int64)
        np.add.at(inS, a, cur[b].astype(np.int64))
        keep = cur & (base + inS >= c + 1)
        if np.array_equal(keep, cur):
            return cur
        cur = keep


def plan_batch(engine, core0, cnt, applied, cap=DEFAULT_GROUP_CAP,
               arr: _Arrays | None = None) -> BatchPlan:
    """Candidate sets + independent-group partition for one micro-batch.

    ``applied`` is ``[(kind, u, v), ...]`` of the structurally-applied
    (non-noop) updates; ``core0`` the round-start cores; ``cnt`` the exact
    Eq. 2 counts *after* the structural deltas (w.r.t. ``core0``);
    ``arr`` an optional pre-built adjacency snapshot of the same graph.
    """
    if arr is None:
        arr = _Arrays(engine)
    plan = BatchPlan()
    levels: dict = {}  # level -> (sel, lab), lazily built

    def level_cache(c):
        if c not in levels:
            levels[c] = _level_components(arr, core0, cnt, c)
        return levels[c]

    for i, (kind, u, v) in enumerate(applied):
        u, v = int(u), int(v)
        c = int(min(core0[u], core0[v]))
        if kind == "+":
            sel, lab = level_cache(c)
            roots = [e for e in (u, v) if sel[e]]
            if roots:
                labs = np.unique(lab[roots])
                cand = np.flatnonzero(sel & np.isin(lab, labs))
            else:
                cand = np.empty(0, dtype=np.int64)
            plan.updates.append(UpdateCand(
                kind="+", u=u, v=v, level=c, op=i, cand=cand,
                size=len(cand), heavy=len(cand) > cap))
        else:
            deficient = [e for e in (u, v)
                         if core0[e] == c and cnt[e] < core0[e]]
            if deficient:
                plan.updates.append(UpdateCand(
                    kind="-", u=u, v=v, level=c, op=i,
                    cand=np.empty(0, dtype=np.int64),
                    prefix_level=c, size=int((core0 <= c).sum())))
            else:
                plan.updates.append(UpdateCand(
                    kind="-", u=u, v=v, level=c, op=i,
                    cand=np.empty(0, dtype=np.int64)))

    _partition(plan)
    return plan


def _partition(plan: BatchPlan) -> None:
    """Union-find on candidate overlap: the independent groups (reported
    in :class:`~repro.core.maintenance.MaintStats`; execution settles all
    groups in one masked fixpoint, so independence is observability, not a
    scheduling constraint)."""
    parent = list(range(len(plan.updates)))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    owner: dict = {}  # node -> first update index claiming it
    for i, up in enumerate(plan.updates):
        for w in up.cand:
            j = owner.setdefault(int(w), i)
            if j != i:
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[rj] = ri

    comps: dict = {}
    for i, up in enumerate(plan.updates):
        if up.size:
            comps.setdefault(find(i), []).append(up)
    plan.groups = list(comps.values())
    for g in plan.groups:
        _GROUP_SIZE.observe(sum(up.size for up in g))


def plan_risers(arr: _Arrays, core0, cnt, risers, cap=DEFAULT_GROUP_CAP
                ) -> BatchPlan:
    """Plan one saturation re-root round on the settled state.

    A +1 rise can enable further rises in exactly two places: the riser
    itself (now at a new level) and any neighbor whose own-level support
    the rise crossed (``core0[w] == new core of the riser``) — nothing
    else's Eq. 2 count moved.  Purecore components rooted at whichever of
    those pass the purecore test cover every remaining rise (the
    minimal-level witness of a missed rise passes the test and shares a
    component with such a node).  Usually empty — risers land with tight
    support."""
    plan = BatchPlan()
    rm = np.zeros(arr.n, dtype=bool)
    rm[risers] = True
    em = rm[arr.src]
    touched = arr.dst[em]
    touched = touched[core0[touched] == core0[arr.src[em]]]
    roots = np.unique(np.concatenate([risers, touched])) \
        if len(touched) else np.asarray(risers)
    for c in np.unique(core0[roots]):
        c = int(c)
        sel, lab = _level_components(arr, core0, cnt, c)
        rl = roots[(core0[roots] == c) & sel[roots]]
        if not len(rl):
            continue
        for l in np.unique(lab[rl]):
            cand = np.flatnonzero(lab == l)
            plan.updates.append(UpdateCand(
                kind="^", u=int(l), v=int(l), level=c, op=-1, cand=cand,
                size=len(cand), heavy=len(cand) > cap))
    _partition(plan)
    return plan


def _prep_state(arr: _Arrays, core0, cnt, updates):
    """Peeled warm bound + incrementally-exact cnt for the masked settle.

    Per level, the union of insert candidate sets is peeled to its exact
    rise set and the survivors warmed to ``level + 1``; cnt is then
    patched in one vectorized pass over the flat adjacency — no full
    Eq. 2 scan: a raised node ``y`` crosses the threshold of exactly its
    non-raised neighbors with ``core0`` in ``(core0[y], warm[y]]`` (+1
    each), and every raised node is recounted exactly against the warm
    values.  Level sets are disjoint, so the single-pass rules compose
    exactly.
    """
    warm = core0.copy()
    cnt = cnt.copy()
    mask = np.zeros(arr.n, dtype=bool)
    pmax = -1
    by_level: dict = {}
    for up in updates:
        if up.prefix_level >= 0:
            pmax = max(pmax, up.prefix_level)
        elif len(up.cand):
            S = by_level.get(up.level)
            if S is None:
                S = by_level[up.level] = np.zeros(arr.n, dtype=bool)
            S[up.cand] = True
    for c, S in by_level.items():
        risen = _peel(arr, core0, S, c)
        warm[risen] = c + 1
        mask |= risen
    if pmax >= 0:
        mask |= core0 <= pmax
    fresh = warm > core0
    if fresh.any():
        src, dst = arr.src, arr.dst
        pe = fresh[src] & ~fresh[dst] & (core0[dst] > core0[src]) \
            & (core0[dst] <= warm[src])
        np.add.at(cnt, dst[pe], 1)
        fe = fresh[src]
        s = src[fe]
        acc = np.zeros(arr.n, dtype=np.int64)
        np.add.at(acc, s, (warm[dst[fe]] >= warm[s]).astype(np.int64))
        cnt[fresh] = acc[fresh]
    return warm, cnt, mask


def _settle_round(maintainer, warm, cnt, mask, info):
    """One round's masked fixpoint from the peeled warm state.

    Returns ``(core, cnt, ok)`` — ``ok`` False when the feasibility
    certificate failed and the caller must escalate to the serial path.
    """
    engine = maintainer.engine
    backend = maintainer.backend

    from .resident import resident_enabled, run_resident

    deficient = (cnt < warm) & (warm > 0) & mask
    resident = backend.device_resident and (
        resident_enabled() or getattr(backend, "requires_resident", False))
    if not deficient.any():
        core_f, cnt_f = warm, cnt
    elif resident:
        r = run_resident(engine, "semicore*", backend, core=warm,
                         cnt=cnt, settle_mask=mask,
                         superstep_chunk=maintainer.superstep_chunk)
        core_f, cnt_f = r.core, r.cnt
        info["iterations"] += r.iterations
        info["node_computations"] += r.node_computations
    else:
        # thread-free host settle (numpy, and the moral equivalent on a
        # device backend running without the resident working set): one
        # warm-start seq settle whose UpdateRange chases every cascade —
        # any node it touches outside the mask was drop-deficient, which
        # the masked path would have escalated on anyway
        d0 = np.flatnonzero(deficient)
        r = engine.semicore_star("seq", core=warm, cnt=cnt,
                                 vrange=(int(d0.min()), int(d0.max())),
                                 backend="numpy")
        core_f, cnt_f = r.core, r.cnt
        info["iterations"] += r.iterations
        info["node_computations"] += r.node_computations

    ok = bool(np.all(cnt_f >= core_f))
    return core_f, cnt_f, ok


def grouped_settle(maintainer, applied, cap=DEFAULT_GROUP_CAP):
    """The grouped maintenance settle for one structurally-applied batch.

    ``applied`` is the ordered ``[(kind, u, v), ...]`` list of non-noop
    updates; ``maintainer.cnt`` must already carry their structural deltas
    (Eq. 2 w.r.t. the pre-batch cores on the post-batch graph).  Settles in
    saturation rounds (see module docstring) and returns ``(core, cnt,
    summary, info)`` — ``summary`` a :class:`BatchPlan` aggregating every
    round's groups, ``info`` the settle counters (``iterations``,
    ``node_computations``, ``rounds``, ``reroots``, ``fallbacks``,
    ``escalated``, ``fallback``).
    """
    engine = maintainer.engine
    backend = maintainer.backend
    summary = BatchPlan()
    info = {"iterations": 0, "node_computations": 0, "rounds": 0,
            "reroots": 0, "fallbacks": 0, "escalated": 0,
            "fallback": False}
    total_ins = sum(1 for k, _, _ in applied if k == "+")

    def serial(core0):
        # warm = min(core0 + I, deg) with I the whole batch's insert count
        # is a sound bound from any round's start state (round cores only
        # grow, so round + I dominates the true post-batch cores)
        r = warm_settle(engine, core0, total_ins, backend,
                        superstep_chunk=maintainer.superstep_chunk)
        info["iterations"] += r.iterations
        info["node_computations"] += r.node_computations
        info["fallbacks"] += 1
        info["fallback"] = True
        return r.core, r.cnt

    arr = _Arrays(engine)  # the graph never changes during the settle
    risers = None  # round 1 plans from the updates; later from risers
    while True:
        info["rounds"] += 1
        core0 = maintainer.core
        if risers is None:
            plan = plan_batch(engine, core0, maintainer.cnt, applied, cap,
                              arr=arr)
        else:
            plan = plan_risers(arr, core0, maintainer.cnt, risers, cap)
            if not plan.updates:
                break
            info["reroots"] += len(plan.updates)
        summary.updates.extend(plan.updates)
        summary.groups.extend(plan.groups)

        if plan.heavy or info["rounds"] > _MAX_ROUNDS:
            # a candidate component exceeded the size threshold: the
            # exact-cnt prologue + SemiCore* warm settle covers everything
            for g in plan.groups:
                _GROUPS_FALLBACK.inc()
            core_f, cnt_f = serial(core0)
            maintainer.core, maintainer.cnt = core_f, cnt_f
            break
        for g in plan.groups:
            _GROUPS_SETTLED.inc()

        warm, cnt, mask = _prep_state(arr, core0, maintainer.cnt,
                                      plan.updates)
        if risers is not None and not np.any(warm > core0) \
                and not np.any((cnt < warm) & (warm > 0) & mask):
            break  # re-root peeled to nothing: already saturated
        core_f, cnt_f, ok = _settle_round(maintainer, warm, cnt, mask, info)
        if not ok:
            # feasibility certificate failed: a frozen node should have
            # dropped (an unforeseen leak).  The serial warm settle from
            # this round's pre-state is always exact.
            _ESCALATIONS.inc()
            info["escalated"] += 1
            core_f, cnt_f = serial(core0)
        maintainer.core, maintainer.cnt = core_f, cnt_f

        risers = np.flatnonzero(core_f > core0)
        if not len(risers):
            break

    _ROUNDS.observe(max(info["rounds"], 1))
    return maintainer.core, maintainer.cnt, summary, info
