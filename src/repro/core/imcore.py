"""IMCore: the in-memory core decomposition baseline (Algorithm 1).

Two exact implementations:

* :func:`imcore_bz` — the Batagelj–Zaversnik O(m+n) bin-sort peeling [9],
  faithful to Algorithm 1 (used as the oracle in unit/property tests).
* :func:`imcore_peel` — vectorized batch peeling (numpy): repeatedly strips
  every node of degree ≤ k at once.  Exact, and much faster in numpy for the
  benchmark-scale graphs.
"""
from __future__ import annotations

import numpy as np

from ..graph.storage import CSRGraph

__all__ = ["imcore_bz", "imcore_peel"]


def imcore_bz(graph: CSRGraph) -> np.ndarray:
    """Batagelj–Zaversnik bin-sort core decomposition. Returns core numbers."""
    n = graph.n
    indptr, adj = graph.indptr, np.asarray(graph.adj)
    deg = np.diff(indptr).astype(np.int64)
    md = int(deg.max()) if n else 0
    counts = np.bincount(deg, minlength=md + 1)
    # bin_start[d] = start position of degree-d nodes in `vert`
    bin_start = np.concatenate([[0], np.cumsum(counts)])[:-1].copy()
    vert = np.argsort(deg, kind="stable").astype(np.int64)
    pos = np.empty(n, dtype=np.int64)
    pos[vert] = np.arange(n)
    deg = deg.copy()

    core = np.zeros(n, dtype=np.int64)
    for i in range(n):
        v = vert[i]
        core[v] = deg[v]
        for u in adj[indptr[v] : indptr[v + 1]]:
            if deg[u] > deg[v]:
                du, pu = deg[u], pos[u]
                pw = bin_start[du]
                w = vert[pw]
                if u != w:  # swap u to the front of its bin
                    pos[u], pos[w] = pw, pu
                    vert[pu], vert[pw] = w, u
                bin_start[du] += 1
                deg[u] -= 1
    return core


def imcore_peel(graph: CSRGraph) -> np.ndarray:
    """Vectorized exact peeling: strip all nodes with degree ≤ k per round."""
    n = graph.n
    src, dst = graph.directed_pairs()
    src = src.astype(np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    deg = graph.degrees().copy()
    core = np.zeros(n, dtype=np.int64)
    alive = np.ones(n, dtype=bool)
    remaining = n
    k = 0
    while remaining:
        amin = deg[alive].min()
        k = max(k, int(amin))
        while True:
            f = alive & (deg <= k)
            if not f.any():
                break
            core[f] = k
            alive[f] = False
            remaining -= int(f.sum())
            # drop removed nodes' edges; decrement alive neighbors
            emask = f[src]
            if emask.any():
                dec = np.bincount(dst[emask], minlength=n)
                deg -= dec
                keep = ~emask & alive[src] & alive[dst]
                src, dst = src[keep], dst[keep]
        if remaining and len(src) == 0:
            # all remaining nodes are isolated at the current k level
            core[alive] = k
            break
    return core
