"""EMCore (Cheng et al., ICDE'11) — the external-memory baseline (Algorithm 2).

A faithful-in-structure reimplementation used for the paper's comparisons
(Fig. 9): partition-based, top-down range computation with core upper bounds,
deposited degrees, partition write-back, and *unbounded* memory in the worst
case — the drawback SemiCore* removes.

Correctness argument (tested against the IMCore oracle): to finalize cores in
[k_l, k_u], it suffices to peel the union of loaded partitions' residual
subgraphs plus per-node deposited degrees (edges to already-finalized
higher-core nodes count at every level, since those neighbors' cores exceed
any value in the current range); every node with true core >= k_l has
ub >= core >= k_l and is therefore loaded.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..graph.storage import CSRGraph, DEFAULT_BLOCK_EDGES

__all__ = ["emcore", "EMCoreResult"]


@dataclass
class EMCoreResult:
    core: np.ndarray
    rounds: int
    read_blocks: int
    write_blocks: int
    peak_memory_edges: int
    over_budget_rounds: int

    @property
    def peak_memory_bytes(self) -> int:
        return self.peak_memory_edges * 8 + len(self.core) * 17


def _peel_with_deposits(n_local, indptr, adj, dep):
    """Peel (local CSR + deposited degrees); deposits never get removed."""
    deg = np.diff(indptr) + dep
    core = np.zeros(n_local, dtype=np.int64)
    alive = np.ones(n_local, dtype=bool)
    remaining = n_local
    src = np.repeat(np.arange(n_local, dtype=np.int64), np.diff(indptr))
    dst = adj.astype(np.int64)
    k = 0
    while remaining:
        k = max(k, int(deg[alive].min()))
        while True:
            f = alive & (deg <= k)
            if not f.any():
                break
            core[f] = k
            alive[f] = False
            remaining -= int(f.sum())
            emask = f[src]
            if emask.any():
                deg -= np.bincount(dst[emask], minlength=n_local)
                keep = ~emask & alive[dst]
                src, dst = src[keep], dst[keep]
    return core


def emcore(
    graph: CSRGraph,
    num_partitions: int = 16,
    memory_budget_edges: int | None = None,
    block_edges: int = DEFAULT_BLOCK_EDGES,
) -> EMCoreResult:
    n = graph.n
    deg = graph.degrees()
    total_dir = graph.num_directed
    if memory_budget_edges is None:
        memory_budget_edges = max(total_dir // 4, 4 * block_edges)

    # --- line 1: partition into ~equal-edge contiguous node ranges ----------
    bounds = [0]
    target = total_dir / num_partitions
    acc = 0
    for v in range(n):
        acc += int(deg[v])
        if acc >= target * len(bounds) and v + 1 < n:
            bounds.append(v + 1)
    bounds.append(n)
    part_of = np.zeros(n, dtype=np.int64)
    for p in range(len(bounds) - 1):
        part_of[bounds[p] : bounds[p + 1]] = p
    nparts = len(bounds) - 1

    # per-partition residual adjacency (the "partitions on disk")
    parts: list[dict] = []
    for p in range(nparts):
        lo, hi = bounds[p], bounds[p + 1]
        parts.append(
            {
                "nodes": np.arange(lo, hi, dtype=np.int64),
                "indptr": graph.indptr[lo : hi + 1] - graph.indptr[lo],
                "adj": np.array(graph.adj[graph.indptr[lo] : graph.indptr[hi]]),
            }
        )

    ub = deg.astype(np.int64).copy()  # lines 2-3: ub(v) init
    dep = np.zeros(n, dtype=np.int64)  # deposited degrees
    core = np.zeros(n, dtype=np.int64)
    finalized = np.zeros(n, dtype=bool)
    read_blocks = write_blocks = 0
    peak_mem = 0
    over_budget = 0
    rounds = 0

    ku = int(ub.max()) if n else 0
    while ku > 0 and not finalized.all():
        rounds += 1
        # --- line 6: estimate k_l from the memory budget --------------------
        pmax = np.array(
            [int(ub[p["nodes"]].max()) if len(p["nodes"]) else -1 for p in parts]
        )
        psize = np.array([len(p["adj"]) for p in parts])
        kl = ku
        while kl > 1:
            load = psize[pmax >= kl - 1].sum()
            if load > memory_budget_edges:
                break
            kl -= 1
        sel = np.flatnonzero(pmax >= kl)
        if not len(sel):
            ku = kl - 1
            continue
        loaded_edges = int(psize[sel].sum())
        if loaded_edges > memory_budget_edges:
            over_budget += 1
        peak_mem = max(peak_mem, loaded_edges)
        read_blocks += -(-loaded_edges // block_edges)

        # --- lines 7-9: build G_mem and peel with deposits -------------------
        # edges to non-loaded nodes are dropped: those neighbors have
        # ub < kl, hence core < kl <= any value finalized this round; they
        # can never support a node at level >= kl (exact for this range).
        gnodes = np.concatenate([parts[p]["nodes"] for p in sel])
        local = np.full(n, -1, dtype=np.int64)
        local[gnodes] = np.arange(len(gnodes))
        srcs, dsts = [], []
        for p in sel:
            P = parts[p]
            s = np.repeat(P["nodes"], np.diff(P["indptr"]))
            d = P["adj"]
            keep = local[d] >= 0
            srcs.append(local[s[keep]])
            dsts.append(local[d[keep]])
        src_l = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
        dst_l = np.concatenate(dsts) if dsts else np.empty(0, np.int64)
        loc_indptr = np.zeros(len(gnodes) + 1, dtype=np.int64)
        np.cumsum(np.bincount(src_l, minlength=len(gnodes)), out=loc_indptr[1:])
        order = np.argsort(src_l, kind="stable")
        loc_adj = dst_l[order]
        cmem = _peel_with_deposits(len(gnodes), loc_indptr, loc_adj, dep[gnodes])

        # --- lines 9-12: finalize cores in [kl, ku]; update ub/dep ----------
        fin_local = cmem >= kl
        fin_nodes = gnodes[fin_local]
        core[fin_nodes] = cmem[fin_local]
        finalized[fin_nodes] = True
        rem_mask_global = np.zeros(n, dtype=bool)
        rem_mask_global[fin_nodes] = True
        ub[gnodes[~fin_local]] = np.minimum(ub[gnodes[~fin_local]], kl - 1)

        # remove finalized nodes from *all* partitions, deposit degrees,
        # write partitions back (lines 10-13)
        sel_set = set(sel.tolist())
        for p in range(nparts):
            P = parts[p]
            if not len(P["nodes"]):
                continue
            keep_node = ~rem_mask_global[P["nodes"]]
            s = np.repeat(P["nodes"], np.diff(P["indptr"]))
            d = P["adj"]
            gone = rem_mask_global[d]
            src_kept = ~rem_mask_global[s]
            # deposit: kept nodes count their removed neighbors forever
            deposit_src = s[gone & src_kept]
            if len(deposit_src):
                np.add.at(dep, deposit_src, 1)
            ekeep = src_kept & ~gone
            s, d = s[ekeep], d[ekeep]
            new_nodes = P["nodes"][keep_node]
            relocal = np.full(n, -1, dtype=np.int64)
            relocal[new_nodes] = np.arange(len(new_nodes))
            cnts = np.bincount(relocal[s], minlength=len(new_nodes)) if len(s) else np.zeros(len(new_nodes), np.int64)
            new_indptr = np.zeros(len(new_nodes) + 1, dtype=np.int64)
            np.cumsum(cnts, out=new_indptr[1:])
            order = np.argsort(relocal[s], kind="stable") if len(s) else np.empty(0, np.int64)
            P["nodes"] = new_nodes
            P["indptr"] = new_indptr
            P["adj"] = d[order].astype(np.int64)
            if p in sel_set and len(P["adj"]):
                write_blocks += -(-len(P["adj"]) // block_edges)
        ku = kl - 1

    return EMCoreResult(
        core=core,
        rounds=rounds,
        read_blocks=read_blocks,
        write_blocks=write_blocks,
        peak_memory_edges=peak_mem,
        over_budget_rounds=over_budget,
    )
