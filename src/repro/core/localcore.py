"""LocalCore (Eq. 1) — the h-index operator over neighbor core values.

``core(v) = max k s.t. |{u ∈ nbr(v) : core(u) ≥ k}| ≥ k`` is exactly the
h-index of the multiset of neighbor core values.  Provided here:

* :func:`local_core` — the paper's LocalCore(c_old, nbr(v)) procedure
  (Algorithm 3 lines 11-20), O(deg(v)), numpy scalar version;
* :func:`h_index_batch` — vectorized h-index over many nodes at once
  (flattened CSR segments), used by the batch-schedule host engine and as the
  numpy oracle for the JAX/SPMD operators.
"""
from __future__ import annotations

import numpy as np

__all__ = ["local_core", "h_index_batch", "compute_cnt_batch"]


def local_core(c_old: int, nbr_cores: np.ndarray) -> int:
    """Paper Algorithm 3, lines 11-20.  Returns the new core upper bound."""
    c_old = int(c_old)
    if c_old <= 0 or len(nbr_cores) == 0:
        return 0
    # num(i): neighbors with core == i (i < c_old) or core >= c_old (i == c_old)
    capped = np.minimum(nbr_cores, c_old)
    num = np.bincount(capped, minlength=c_old + 1)
    # s(k) = #{u : min(core(u), c_old) >= k} scanned from k = c_old down
    suffix = np.cumsum(num[::-1])[::-1]
    ks = np.arange(c_old + 1)
    ok = np.flatnonzero(suffix[1:] >= ks[1:])
    return int(ok[-1] + 1) if len(ok) else 0


def h_index_batch(vals: np.ndarray, seg_ptr: np.ndarray) -> np.ndarray:
    """h-index per segment of a flattened, CSR-style value array.

    ``vals``    -- (E,) neighbor core values, segment-contiguous.
    ``seg_ptr`` -- (P+1,) offsets delimiting the P segments.

    Uses the sorted-descending identity: with values sorted descending within
    a segment, h = #{i : v_i >= i+1} (0-indexed ranks).
    """
    P = len(seg_ptr) - 1
    lens = np.diff(seg_ptr)
    if len(vals) == 0:
        return np.zeros(P, dtype=np.int64)
    seg_ids = np.repeat(np.arange(P, dtype=np.int64), lens)
    order = np.lexsort((-vals, seg_ids))
    sv = vals[order]
    start = np.repeat(seg_ptr[:-1], lens)
    rank = np.arange(len(vals), dtype=np.int64) - start
    contrib = (sv >= rank + 1).astype(np.int64)
    return np.bincount(seg_ids, weights=contrib, minlength=P).astype(np.int64)


def compute_cnt_batch(
    vals: np.ndarray, seg_ptr: np.ndarray, thresholds: np.ndarray
) -> np.ndarray:
    """cnt per segment: #{u in segment : vals(u) >= threshold(segment)} (Eq. 2)."""
    P = len(seg_ptr) - 1
    lens = np.diff(seg_ptr)
    if len(vals) == 0:
        return np.zeros(P, dtype=np.int64)
    seg_ids = np.repeat(np.arange(P, dtype=np.int64), lens)
    thr = np.repeat(thresholds, lens)
    return np.bincount(
        seg_ids, weights=(vals >= thr).astype(np.int64), minlength=P
    ).astype(np.int64)
