"""I/O-efficient core maintenance (paper §V): SemiDelete* (Alg. 6),
SemiInsert (Alg. 7), SemiInsert* (Alg. 8).

All three run over the same blocked storage + edge-update memory buffer
(§V.A *Graph Maintenance*) and keep the decomposition state (core, cnt)
exact after every operation, so maintenance ops chain indefinitely.

Algorithm 8 bookkeeping note (the pseudocode is ambiguous between two
readings of its lines 11-12 / 22-25; we resolved it against the exact cnt
trace of Example 5.3):  a ○-status node's cnt follows the *predictive*
Eq. 4 (cnt*) — it already counts every still-promising core==c_old
candidate, so a neighbor's ?→○ promotion must NOT increment it (only
Eq.2-maintained nodes, i.e. core==c_old+1 originals, get +1), and a
neighbor's ○→✕ flip decrements Eq.2-maintained nodes via the
core==c_old+1 loop and ○ nodes via the status==○ loop, once each.  With
this reading the final cnt values are exactly Eq. 2 w.r.t. the new cores
(verified by tests against recomputation-from-scratch).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..graph.storage import CSRGraph, DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from ..obs import metrics as _metrics, trace as _trace
from .engine import ComputeBackend, resolve_backend, warm_settle
from .semicore import HostEngine

__all__ = ["MaintStats", "BatchMaintStats", "CoreMaintainer"]

# apply_batch settle latency, labeled by path: "per-edge" is the paper's
# seq maintenance (Algs. 6-8), "batch-settle" the warm_settle discipline of
# the device backends (DESIGN.md §14; the exact-cnt prologue cost is the
# separate repro_maintenance_cnt_prologue_seconds histogram in engine.py)
_SETTLE_SECONDS = _metrics.histogram(
    "repro_maintenance_settle_seconds",
    "apply_batch settle latency per micro-batch",
)
_BATCHES = _metrics.counter(
    "repro_maintenance_batches_total",
    "Micro-batches applied by CoreMaintainer.apply_batch",
)
_UPDATES_APPLIED = _metrics.counter(
    "repro_maintenance_updates_applied_total",
    "Structural edge updates applied (deletes + inserts, no-ops excluded)",
)

_PHI, _Q, _CIRC, _CROSS = 0, 1, 2, 3


@dataclass
class MaintStats:
    algorithm: str
    node_computations: int
    edge_block_reads: int
    node_table_reads: int
    iterations: int
    num_changed: int


@dataclass
class BatchMaintStats:
    """Aggregate stats for one micro-batch of edge updates (stream path)."""

    algorithm: str
    num_deletes: int
    num_inserts: int
    num_noops: int  # updates already reflected in the graph (skipped)
    node_computations: int
    edge_block_reads: int
    node_table_reads: int
    iterations: int
    num_changed: int  # nodes whose core differs from the batch-start core


class CoreMaintainer:
    """Holds (core, cnt) over a BufferedGraph; applies edge updates.

    ``backend`` selects the batch-schedule compute substrate (DESIGN.md §11,
    §13) for the settle loops.  The default ("numpy" via ``backend=None``)
    keeps the paper's per-edge seq maintenance (Algs. 6-8) exactly as
    before; any other backend switches :meth:`apply_batch` to the batched
    settle path (structural update + one warm-started SemiCore* batch
    settle on that backend — the stream/recovery discipline).  Device
    backends settle on their bound resident structure — the flat table for
    xla/pallas, the sharded mesh table for ``"shard"`` — with the exact-cnt
    prologue computed in place; the structure is version-keyed, so a no-op
    batch re-uploads (and re-shards) nothing.
    """

    def __init__(
        self,
        graph,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        state: tuple[np.ndarray, np.ndarray] | None = None,
        pool_blocks: int = 1,
        backend=None,
        superstep_chunk: int | None = None,
        retry=None,
    ):
        self.bg = graph if isinstance(graph, BufferedGraph) else BufferedGraph(graph)
        self.engine = HostEngine(
            self.bg, block_edges, pool_blocks=pool_blocks, retry=retry)
        self.backend = resolve_backend(backend)
        self.superstep_chunk = superstep_chunk
        if self.backend.device_resident and not isinstance(
                backend, ComputeBackend):
            # long-lived owner of a backend it created itself: keep the
            # device-resident edge table cached across apply_batch calls —
            # it is version-keyed, so a batch that changed structure rebuilds
            # it and a no-op batch re-uploads nothing (DESIGN.md §12).  A
            # caller-supplied instance is left untouched: its one-shot
            # unbind-drops-everything guarantee stays the caller's to manage.
            self.backend.retain_structure = True
        if state is None:
            if self.backend.name == "numpy":
                r = self.engine.semicore_star("seq", backend="numpy")
            else:
                r = self.engine.semicore_star(
                    "batch", backend=self.backend,
                    superstep_chunk=superstep_chunk)
            self.core, self.cnt = r.core, r.cnt
        else:
            self.core = np.asarray(state[0], dtype=np.int64).copy()
            self.cnt = np.asarray(state[1], dtype=np.int64).copy()

    # ------------------------------------------------------------------ utils
    def _io_snapshot(self):
        return (self.engine.reader.reads, self.engine.reader.node_table_reads)

    def _io_delta(self, snap):
        return (
            self.engine.reader.reads - snap[0],
            self.engine.reader.node_table_reads - snap[1],
        )

    # =====================================================================
    # Micro-batch application (streaming §V: deletes first, then inserts)
    # =====================================================================
    def apply_batch(
        self,
        deletes,
        inserts,
        insert_algorithm: str = "semiinsert*",
    ) -> BatchMaintStats:
        """Apply a coalesced micro-batch of updates, deletes before inserts.

        Updates that are already reflected in the graph (deleting a missing
        edge, inserting a present one) are counted as no-ops rather than
        raised — the stream admission path resolves each edge's *final*
        state, so a no-op just means the stream and the graph already agree.

        On a non-numpy backend the whole batch settles in one warm-started
        SemiCore* batch run instead of per-edge seq maintenance.
        """
        if self.backend.name != "numpy":
            return self._apply_batch_settled(deletes, inserts)
        snap = self._io_snapshot()
        core0 = self.core.copy()
        comp = iters = nd = ni = noop = 0
        t0 = time.perf_counter()
        with _trace.span("maintenance.apply_batch", cat="maintenance",
                         path="per-edge", deletes=len(deletes),
                         inserts=len(inserts)) as sp:
            for u, v in deletes:
                try:
                    s = self.delete_edge(int(u), int(v))
                except KeyError:
                    noop += 1
                    continue
                comp += s.node_computations
                iters += s.iterations
                nd += 1
            for u, v in inserts:
                try:
                    s = self.insert_edge(int(u), int(v),
                                         algorithm=insert_algorithm)
                except KeyError:
                    noop += 1
                    continue
                comp += s.node_computations
                iters += s.iterations
                ni += 1
            if sp.active:
                sp.set(applied=nd + ni, noops=noop)
        _SETTLE_SECONDS.labels(path="per-edge").observe(
            time.perf_counter() - t0)
        _BATCHES.labels(path="per-edge").inc()
        _UPDATES_APPLIED.labels(path="per-edge").inc(nd + ni)
        io = self._io_delta(snap)
        return BatchMaintStats(
            algorithm=f"batch({insert_algorithm})",
            num_deletes=nd,
            num_inserts=ni,
            num_noops=noop,
            node_computations=comp,
            edge_block_reads=io[0],
            node_table_reads=io[1],
            iterations=iters,
            num_changed=int((self.core != core0).sum()),
        )

    def _apply_batch_settled(self, deletes, inserts) -> BatchMaintStats:
        """Batched maintenance on a compute backend (DESIGN.md §11):
        structural updates first, then one :func:`engine.warm_settle` —
        the same warm-upper-bound + exact-cnt + SemiCore* batch discipline
        the recovery path uses."""
        snap = self._io_snapshot()
        core0 = self.core.copy()
        nd = ni = noop = 0
        t0 = time.perf_counter()
        with _trace.span("maintenance.batch_settle", cat="maintenance",
                         path="batch-settle", backend=self.backend.name,
                         deletes=len(deletes), inserts=len(inserts)) as sp:
            for u, v in deletes:
                if self.bg.delete_edge(int(u), int(v)):
                    nd += 1
                else:
                    noop += 1
            for u, v in inserts:
                if self.bg.insert_edge(int(u), int(v)):
                    ni += 1
                else:
                    noop += 1
            comp = iters = 0
            if nd or ni:
                r = warm_settle(self.engine, self.core, ni, self.backend,
                                superstep_chunk=self.superstep_chunk)
                self.core, self.cnt = r.core, r.cnt
                comp, iters = r.node_computations, r.iterations
            if sp.active:
                sp.set(applied=nd + ni, noops=noop, iterations=iters)
        _SETTLE_SECONDS.labels(path="batch-settle").observe(
            time.perf_counter() - t0)
        _BATCHES.labels(path="batch-settle").inc()
        _UPDATES_APPLIED.labels(path="batch-settle").inc(nd + ni)
        io = self._io_delta(snap)
        return BatchMaintStats(
            algorithm=f"batch-settle({self.backend.name})",
            num_deletes=nd,
            num_inserts=ni,
            num_noops=noop,
            node_computations=comp,
            edge_block_reads=io[0],
            node_table_reads=io[1],
            iterations=iters,
            num_changed=int((self.core != core0).sum()),
        )

    # =====================================================================
    # Algorithm 6: SemiDelete*
    # =====================================================================
    def delete_edge(self, u: int, v: int) -> MaintStats:
        if not self.bg.delete_edge(u, v):
            raise KeyError(f"edge ({u}, {v}) does not exist")
        snap = self._io_snapshot()
        old_core = self.core.copy()
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu < cv:
            self.cnt[u] -= 1
            rng = (u, u)
        elif cv < cu:
            self.cnt[v] -= 1
            rng = (v, v)
        else:
            self.cnt[u] -= 1
            self.cnt[v] -= 1
            rng = (min(u, v), max(u, v))
        r = self.engine.semicore_star(
            "seq", core=self.core, cnt=self.cnt, vrange=rng, backend="numpy"
        )
        self.core, self.cnt = r.core, r.cnt
        io = self._io_delta(snap)
        return MaintStats(
            "semidelete*",
            r.node_computations,
            io[0],
            io[1],
            r.iterations,
            int((self.core != old_core).sum()),
        )

    # =====================================================================
    # Algorithm 7: SemiInsert (two-phase)
    # =====================================================================
    def insert_edge(self, u: int, v: int, algorithm: str = "semiinsert*") -> MaintStats:
        if algorithm == "semiinsert*":
            return self._insert_star(u, v)
        return self._insert_two_phase(u, v)

    def _insert_common(self, u: int, v: int):
        """Alg. 7 lines 1-5 (shared with Alg. 8)."""
        if not self.bg.insert_edge(u, v):
            raise KeyError(f"edge ({u}, {v}) already exists")
        if self.core[u] > self.core[v]:
            u, v = v, u
        self.cnt[u] += 1
        if self.core[v] == self.core[u]:
            self.cnt[v] += 1
        return u, v, int(self.core[u])

    def _insert_two_phase(self, u0: int, v0: int) -> MaintStats:
        snap = self._io_snapshot()
        old_core = self.core.copy()
        core, cnt, eng = self.core, self.cnt, self.engine
        n = eng.n
        u, v, c_old = self._insert_common(u0, v0)

        # --- phase 1: grow + optimistically promote the candidate set -------
        active = np.zeros(n, dtype=bool)
        active[u] = True
        vmin = vmax = u
        comp = 0
        iters = 0
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            scan_lo = vmin
            w = vmin
            while w <= vmax:
                if active[w] and core[w] == c_old:
                    core[w] = c_old + 1
                    nbrs = eng.nbrs(w)
                    comp += 1
                    ncores = core[nbrs]
                    cnt[w] = int((ncores >= c_old + 1).sum())
                    bumped = nbrs[ncores == c_old + 1]  # lines 15-16 (Eq. 2)
                    if len(bumped):
                        np.add.at(cnt, bumped, 1)
                    for x in nbrs[ncores == c_old]:  # lines 17-20
                        x = int(x)
                        if not active[x]:
                            active[x] = True
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                w += 1
            eng.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax

        # --- phase 2: settle with Algorithm 5 (lines 22-25) -----------------
        act = np.flatnonzero(active)
        rng = (min(int(act.min()), u), max(int(act.max()), u))
        r = eng.semicore_star("seq", core=core, cnt=cnt, vrange=rng,
                              backend="numpy")
        self.core, self.cnt = r.core, r.cnt
        io = self._io_delta(snap)
        return MaintStats(
            "semiinsert",
            comp + r.node_computations,
            io[0],
            io[1],
            iters + r.iterations,
            int((self.core != old_core).sum()),
        )

    # =====================================================================
    # Algorithm 8: SemiInsert* (one-phase status machine)
    # =====================================================================
    def _insert_star(self, u0: int, v0: int) -> MaintStats:
        snap = self._io_snapshot()
        old_core = self.core.copy()
        core, cnt, eng = self.core, self.cnt, self.engine
        n = eng.n
        u, v, c_old = self._insert_common(u0, v0)

        status = np.full(n, _PHI, dtype=np.uint8)
        status[u] = _Q
        vmin = vmax = u
        comp = 0
        iters = 0
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            scan_lo = vmin
            w = vmin
            while w <= vmax:
                nbrs = None
                if status[w] == _Q:
                    nbrs = eng.nbrs(w)
                    comp += 1
                    # ComputeCnt* (Eq. 4; lines 29-33)
                    ncores = core[nbrs]
                    nst = status[nbrs]
                    cnt[w] = int(
                        (
                            (ncores > c_old)
                            | (
                                (ncores == c_old)
                                & (cnt[nbrs] >= c_old + 1)
                                & (nst != _CROSS)
                            )
                        ).sum()
                    )
                    status[w] = _CIRC
                    core[w] = c_old + 1
                    # lines 11-12: Eq.2-maintained peers gain w
                    bumped = nbrs[(ncores == c_old + 1) & (nst != _CIRC)]
                    if len(bumped):
                        np.add.at(cnt, bumped, 1)
                    if cnt[w] >= c_old + 1:  # lines 13-17: expand
                        cand = nbrs[
                            (ncores == c_old)
                            & (cnt[nbrs] >= c_old + 1)
                            & (nst == _PHI)
                        ]
                        for x in cand:
                            x = int(x)
                            status[x] = _Q
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                if status[w] == _CIRC and cnt[w] < c_old + 1:  # lines 18-27
                    if nbrs is None:
                        nbrs = eng.nbrs(w)
                        comp += 1
                    ncores = core[nbrs]
                    cnt[w] = int((ncores >= c_old).sum())  # ComputeCnt(nbr, c_old)
                    status[w] = _CROSS
                    core[w] = c_old
                    nst = status[nbrs]
                    # lines 22-23: Eq.2-maintained peers lose w ...
                    dec = nbrs[(ncores == c_old + 1) & (nst != _CIRC)]
                    if len(dec):
                        np.subtract.at(cnt, dec, 1)
                    # lines 24-27: ... and ○ nodes lose a promising candidate
                    circ = nbrs[nst == _CIRC]
                    for x in circ:
                        x = int(x)
                        cnt[x] -= 1
                        if cnt[x] < c_old + 1:
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                w += 1
            eng.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax

        io = self._io_delta(snap)
        return MaintStats(
            "semiinsert*",
            comp,
            io[0],
            io[1],
            iters,
            int((self.core != old_core).sum()),
        )
