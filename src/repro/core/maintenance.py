"""I/O-efficient core maintenance (paper §V): SemiDelete* (Alg. 6),
SemiInsert (Alg. 7), SemiInsert* (Alg. 8).

All three run over the same blocked storage + edge-update memory buffer
(§V.A *Graph Maintenance*) and keep the decomposition state (core, cnt)
exact after every operation, so maintenance ops chain indefinitely.

Algorithm 8 bookkeeping note (the pseudocode is ambiguous between two
readings of its lines 11-12 / 22-25; we resolved it against the exact cnt
trace of Example 5.3):  a ○-status node's cnt follows the *predictive*
Eq. 4 (cnt*) — it already counts every still-promising core==c_old
candidate, so a neighbor's ?→○ promotion must NOT increment it (only
Eq.2-maintained nodes, i.e. core==c_old+1 originals, get +1), and a
neighbor's ○→✕ flip decrements Eq.2-maintained nodes via the
core==c_old+1 loop and ○ nodes via the status==○ loop, once each.  With
this reading the final cnt values are exactly Eq. 2 w.r.t. the new cores
(verified by tests against recomputation-from-scratch).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass

import numpy as np

from .. import runtime as _runtime
from ..graph.storage import CSRGraph, DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from ..obs import metrics as _metrics, trace as _trace
from .engine import ComputeBackend, resolve_backend, warm_settle
from .semicore import HostEngine
from .update import Delete, Insert, UpdateBatch

__all__ = ["MaintStats", "BatchMaintStats", "CoreMaintainer"]

# apply_batch settle latency, labeled by path: "per-edge" is the paper's
# seq maintenance (Algs. 6-8), "batch-settle" the warm_settle discipline of
# the device backends (DESIGN.md §14; the exact-cnt prologue cost is the
# separate repro_maintenance_cnt_prologue_seconds histogram in engine.py)
_SETTLE_SECONDS = _metrics.histogram(
    "repro_maintenance_settle_seconds",
    "apply_batch settle latency per micro-batch",
)
_BATCHES = _metrics.counter(
    "repro_maintenance_batches_total",
    "Micro-batches applied by CoreMaintainer.apply_batch",
)
_UPDATES_APPLIED = _metrics.counter(
    "repro_maintenance_updates_applied_total",
    "Structural edge updates applied (deletes + inserts, no-ops excluded)",
)

_PHI, _Q, _CIRC, _CROSS = 0, 1, 2, 3


@dataclass
class MaintStats:
    """Unified maintenance result — per-edge ops and micro-batches alike.

    The positional prefix (algorithm .. num_changed) is the historical
    per-edge ``MaintStats``; the ``num_*`` trio is the historical
    ``BatchMaintStats`` (now an alias); the ``groups``/``largest_group``/
    ``fallbacks``/``settle_passes`` tail is the parallel grouped settle
    (DESIGN.md §18) and stays zero on every serial path.
    """

    algorithm: str
    node_computations: int = 0
    edge_block_reads: int = 0
    node_table_reads: int = 0
    iterations: int = 0
    num_changed: int = 0  # nodes whose core differs from the op-start core
    num_deletes: int = 0
    num_inserts: int = 0
    num_noops: int = 0  # updates already reflected in the graph (skipped)
    groups: int = 0  # independent groups planned by the parallel settle
    largest_group: int = 0  # candidate-node count of the largest group
    fallbacks: int = 0  # ineligible groups + feasibility escalations
    settle_passes: int = 0  # fixpoint passes of the grouped settle


#: historical name for the micro-batch result (same type since the unification)
BatchMaintStats = MaintStats


class CoreMaintainer:
    """Holds (core, cnt) over a BufferedGraph; applies edge updates.

    ``backend`` selects the batch-schedule compute substrate (DESIGN.md §11,
    §13) for the settle loops.  The default ("numpy" via ``backend=None``)
    keeps the paper's per-edge seq maintenance (Algs. 6-8) exactly as
    before; any other backend switches :meth:`apply_batch` to the batched
    settle path (structural update + one warm-started SemiCore* batch
    settle on that backend — the stream/recovery discipline).  Device
    backends settle on their bound resident structure — the flat table for
    xla/pallas, the sharded mesh table for ``"shard"`` — with the exact-cnt
    prologue computed in place; the structure is version-keyed, so a no-op
    batch re-uploads (and re-shards) nothing.
    """

    def __init__(
        self,
        graph,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        state: tuple[np.ndarray, np.ndarray] | None = None,
        pool_blocks: int = 1,
        backend=None,
        superstep_chunk: int | None = None,
        retry=None,
        settings: "_runtime.Settings | None" = None,
        group_cap: int | None = None,
    ):
        if settings is not None:
            if backend is None:
                backend = settings.backend
            if superstep_chunk is None:
                superstep_chunk = settings.resident_chunk
        self._parallel_default = (
            None if settings is None else settings.parallel_maint)
        self.settings = settings
        self.group_cap = group_cap
        self.bg = graph if isinstance(graph, BufferedGraph) else BufferedGraph(graph)
        self.engine = HostEngine(
            self.bg, block_edges, pool_blocks=pool_blocks, retry=retry,
            settings=settings)
        self.backend = resolve_backend(backend)
        self.superstep_chunk = superstep_chunk
        if self.backend.device_resident and not isinstance(
                backend, ComputeBackend):
            # long-lived owner of a backend it created itself: keep the
            # device-resident edge table cached across apply_batch calls —
            # it is version-keyed, so a batch that changed structure rebuilds
            # it and a no-op batch re-uploads nothing (DESIGN.md §12).  A
            # caller-supplied instance is left untouched: its one-shot
            # unbind-drops-everything guarantee stays the caller's to manage.
            self.backend.retain_structure = True
        if state is None:
            if self.backend.name == "numpy":
                r = self.engine.semicore_star("seq", backend="numpy")
            else:
                r = self.engine.semicore_star(
                    "batch", backend=self.backend,
                    superstep_chunk=superstep_chunk)
            self.core, self.cnt = r.core, r.cnt
        else:
            self.core = np.asarray(state[0], dtype=np.int64).copy()
            self.cnt = np.asarray(state[1], dtype=np.int64).copy()

    # ------------------------------------------------------------------ utils
    def _io_snapshot(self):
        return (self.engine.reader.reads, self.engine.reader.node_table_reads)

    def _io_delta(self, snap):
        return (
            self.engine.reader.reads - snap[0],
            self.engine.reader.node_table_reads - snap[1],
        )

    # =====================================================================
    # Unified update surface (streaming §V; DESIGN.md §18)
    # =====================================================================
    def apply(
        self,
        batch: UpdateBatch,
        insert_algorithm: str = "semiinsert*",
    ) -> MaintStats:
        """Apply one micro-batch of typed, order-preserving updates.

        This is the single maintenance entry point: ``batch`` is an
        :class:`UpdateBatch` of :class:`Insert`/:class:`Delete` ops (any
        iterable of ops is promoted).  Updates already reflected in the
        graph (deleting a missing edge, inserting a present one) count as
        no-ops — the stream admission path resolves each edge's *final*
        state, so a no-op just means the stream and the graph agree.

        Dispatch: the parallel independent-group settle (DESIGN.md §18)
        unless ``REPRO_PARALLEL_MAINT=0`` / ``Settings.parallel_maint``
        disables it, in which case the serial oracle runs — the paper's
        per-edge seq maintenance (Algs. 6-8) on numpy, one warm-started
        SemiCore* batch settle on device backends.  Every path lands on the
        same exact (core, cnt) fixpoint.
        """
        if not isinstance(batch, UpdateBatch):
            batch = UpdateBatch(tuple(batch))
        if _runtime.setting("parallel_maint", self._parallel_default):
            return self._apply_parallel(batch, insert_algorithm)
        if self.backend.name != "numpy":
            return self._apply_batch_settled(batch.deletes, batch.inserts)
        return self._apply_per_edge(batch, insert_algorithm)

    def apply_batch(
        self,
        deletes,
        inserts,
        insert_algorithm: str = "semiinsert*",
    ) -> BatchMaintStats:
        """Deprecated shim: use :meth:`apply` with an :class:`UpdateBatch`.

        Equivalent to ``apply(UpdateBatch.from_pairs(deletes, inserts))``
        (deletes first — the historical coalesced order).
        """
        warnings.warn(
            "CoreMaintainer.apply_batch(deletes, inserts) is deprecated; "
            "use apply(UpdateBatch.from_pairs(deletes, inserts))",
            DeprecationWarning, stacklevel=2)
        return self.apply(UpdateBatch.from_pairs(deletes, inserts),
                          insert_algorithm=insert_algorithm)

    def _apply_per_edge(self, batch: UpdateBatch,
                        insert_algorithm: str) -> MaintStats:
        """The paper's serial per-edge maintenance, in op order."""
        snap = self._io_snapshot()
        core0 = self.core.copy()
        comp = iters = nd = ni = noop = 0
        t0 = time.perf_counter()
        with _trace.span("maintenance.apply_batch", cat="maintenance",
                         path="per-edge", deletes=len(batch.deletes),
                         inserts=len(batch.inserts)) as sp:
            for op in batch:
                try:
                    if isinstance(op, Delete):
                        s = self._delete_edge(int(op.u), int(op.v))
                        nd += 1
                    else:
                        s = self._insert_edge(int(op.u), int(op.v),
                                              algorithm=insert_algorithm)
                        ni += 1
                except KeyError:
                    noop += 1
                    continue
                comp += s.node_computations
                iters += s.iterations
            if sp.active:
                sp.set(applied=nd + ni, noops=noop)
        _SETTLE_SECONDS.labels(path="per-edge").observe(
            time.perf_counter() - t0)
        _BATCHES.labels(path="per-edge").inc()
        _UPDATES_APPLIED.labels(path="per-edge").inc(nd + ni)
        io = self._io_delta(snap)
        return MaintStats(
            algorithm=f"batch({insert_algorithm})",
            num_deletes=nd,
            num_inserts=ni,
            num_noops=noop,
            node_computations=comp,
            edge_block_reads=io[0],
            node_table_reads=io[1],
            iterations=iters,
            num_changed=int((self.core != core0).sum()),
        )

    def _apply_parallel(self, batch: UpdateBatch,
                        insert_algorithm: str) -> MaintStats:
        """Parallel independent-group settle (DESIGN.md §18).

        Structural phase first: every op lands in the buffered graph and
        its Eq. 2 delta lands in cnt — all w.r.t. the *pre-batch* cores, so
        after the loop cnt is exactly Eq. 2 (core0, post-batch graph).
        :func:`parallel_maint.grouped_settle` then plans per-update
        candidate sets, partitions them into independent groups and settles
        the whole batch in saturation rounds — host-side peel of each
        level's exact rise set, then one group-masked device fixpoint per
        round, re-rooted at capped risers until exact.  Oversized candidate
        sets and a failed cnt>=core certificate escalate to the serial warm
        settle, so every path lands on the same fixpoint.
        """
        from .parallel_maint import DEFAULT_GROUP_CAP, grouped_settle

        snap = self._io_snapshot()
        core0 = self.core
        cnt = self.cnt
        nd = ni = noop = 0
        applied: list = []
        t0 = time.perf_counter()
        with _trace.span("maintenance.parallel_settle", cat="maintenance",
                         path="parallel", backend=self.backend.name,
                         deletes=len(batch.deletes),
                         inserts=len(batch.inserts)) as sp:
            for op in batch:
                u, v = int(op.u), int(op.v)
                if isinstance(op, Delete):
                    if not self.bg.delete_edge(u, v):
                        noop += 1
                        continue
                    nd += 1
                    if core0[u] <= core0[v]:
                        cnt[u] -= 1
                    if core0[v] <= core0[u]:
                        cnt[v] -= 1
                    applied.append(("-", u, v))
                else:
                    if not self.bg.insert_edge(u, v):
                        noop += 1
                        continue
                    ni += 1
                    if core0[u] <= core0[v]:
                        cnt[u] += 1
                    if core0[v] <= core0[u]:
                        cnt[v] += 1
                    applied.append(("+", u, v))
            changed = 0
            groups = largest = fallbacks = passes = comp = 0
            if applied:
                cap = (DEFAULT_GROUP_CAP if self.group_cap is None
                       else self.group_cap)
                core_f, cnt_f, plan, info = grouped_settle(
                    self, applied, cap)
                changed = int((core_f != core0).sum())
                groups = len(plan.groups)
                largest = plan.largest_group
                fallbacks = info["fallbacks"]
                passes = info["iterations"]
                comp = info["node_computations"]
            if sp.active:
                sp.set(applied=nd + ni, noops=noop, groups=groups,
                       fallbacks=fallbacks, iterations=passes)
        _SETTLE_SECONDS.labels(path="parallel").observe(
            time.perf_counter() - t0)
        _BATCHES.labels(path="parallel").inc()
        _UPDATES_APPLIED.labels(path="parallel").inc(nd + ni)
        io = self._io_delta(snap)
        return MaintStats(
            algorithm=f"parallel({self.backend.name})",
            num_deletes=nd,
            num_inserts=ni,
            num_noops=noop,
            node_computations=comp,
            edge_block_reads=io[0],
            node_table_reads=io[1],
            iterations=passes,
            num_changed=changed,
            groups=groups,
            largest_group=largest,
            fallbacks=fallbacks,
            settle_passes=passes,
        )

    def _apply_batch_settled(self, deletes, inserts) -> BatchMaintStats:
        """Batched maintenance on a compute backend (DESIGN.md §11):
        structural updates first, then one :func:`engine.warm_settle` —
        the same warm-upper-bound + exact-cnt + SemiCore* batch discipline
        the recovery path uses."""
        snap = self._io_snapshot()
        core0 = self.core.copy()
        nd = ni = noop = 0
        t0 = time.perf_counter()
        with _trace.span("maintenance.batch_settle", cat="maintenance",
                         path="batch-settle", backend=self.backend.name,
                         deletes=len(deletes), inserts=len(inserts)) as sp:
            for u, v in deletes:
                if self.bg.delete_edge(int(u), int(v)):
                    nd += 1
                else:
                    noop += 1
            for u, v in inserts:
                if self.bg.insert_edge(int(u), int(v)):
                    ni += 1
                else:
                    noop += 1
            comp = iters = 0
            if nd or ni:
                r = warm_settle(self.engine, self.core, ni, self.backend,
                                superstep_chunk=self.superstep_chunk)
                self.core, self.cnt = r.core, r.cnt
                comp, iters = r.node_computations, r.iterations
            if sp.active:
                sp.set(applied=nd + ni, noops=noop, iterations=iters)
        _SETTLE_SECONDS.labels(path="batch-settle").observe(
            time.perf_counter() - t0)
        _BATCHES.labels(path="batch-settle").inc()
        _UPDATES_APPLIED.labels(path="batch-settle").inc(nd + ni)
        io = self._io_delta(snap)
        return BatchMaintStats(
            algorithm=f"batch-settle({self.backend.name})",
            num_deletes=nd,
            num_inserts=ni,
            num_noops=noop,
            node_computations=comp,
            edge_block_reads=io[0],
            node_table_reads=io[1],
            iterations=iters,
            num_changed=int((self.core != core0).sum()),
        )

    # =====================================================================
    # Algorithm 6: SemiDelete*
    # =====================================================================
    def delete_edge(self, u: int, v: int) -> MaintStats:
        """Deprecated shim: use ``apply(UpdateBatch((Delete(u, v),)))``."""
        warnings.warn(
            "CoreMaintainer.delete_edge(u, v) is deprecated; use "
            "apply(UpdateBatch((Delete(u, v),)))",
            DeprecationWarning, stacklevel=2)
        return self._delete_edge(u, v)

    def _delete_edge(self, u: int, v: int) -> MaintStats:
        if not self.bg.delete_edge(u, v):
            raise KeyError(f"edge ({u}, {v}) does not exist")
        snap = self._io_snapshot()
        old_core = self.core.copy()
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu < cv:
            self.cnt[u] -= 1
            rng = (u, u)
        elif cv < cu:
            self.cnt[v] -= 1
            rng = (v, v)
        else:
            self.cnt[u] -= 1
            self.cnt[v] -= 1
            rng = (min(u, v), max(u, v))
        r = self.engine.semicore_star(
            "seq", core=self.core, cnt=self.cnt, vrange=rng, backend="numpy"
        )
        self.core, self.cnt = r.core, r.cnt
        io = self._io_delta(snap)
        return MaintStats(
            "semidelete*",
            r.node_computations,
            io[0],
            io[1],
            r.iterations,
            int((self.core != old_core).sum()),
            num_deletes=1,
        )

    # =====================================================================
    # Algorithm 7: SemiInsert (two-phase)
    # =====================================================================
    def insert_edge(self, u: int, v: int, algorithm: str = "semiinsert*") -> MaintStats:
        """Deprecated shim: use ``apply(UpdateBatch((Insert(u, v),)))``."""
        warnings.warn(
            "CoreMaintainer.insert_edge(u, v) is deprecated; use "
            "apply(UpdateBatch((Insert(u, v),)))",
            DeprecationWarning, stacklevel=2)
        return self._insert_edge(u, v, algorithm=algorithm)

    def _insert_edge(self, u: int, v: int,
                     algorithm: str = "semiinsert*") -> MaintStats:
        if algorithm == "semiinsert*":
            return self._insert_star(u, v)
        return self._insert_two_phase(u, v)

    def _insert_common(self, u: int, v: int):
        """Alg. 7 lines 1-5 (shared with Alg. 8)."""
        if not self.bg.insert_edge(u, v):
            raise KeyError(f"edge ({u}, {v}) already exists")
        if self.core[u] > self.core[v]:
            u, v = v, u
        self.cnt[u] += 1
        if self.core[v] == self.core[u]:
            self.cnt[v] += 1
        return u, v, int(self.core[u])

    def _insert_two_phase(self, u0: int, v0: int) -> MaintStats:
        snap = self._io_snapshot()
        old_core = self.core.copy()
        core, cnt, eng = self.core, self.cnt, self.engine
        n = eng.n
        u, v, c_old = self._insert_common(u0, v0)

        # --- phase 1: grow + optimistically promote the candidate set -------
        active = np.zeros(n, dtype=bool)
        active[u] = True
        vmin = vmax = u
        comp = 0
        iters = 0
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            scan_lo = vmin
            w = vmin
            while w <= vmax:
                if active[w] and core[w] == c_old:
                    core[w] = c_old + 1
                    nbrs = eng.nbrs(w)
                    comp += 1
                    ncores = core[nbrs]
                    cnt[w] = int((ncores >= c_old + 1).sum())
                    bumped = nbrs[ncores == c_old + 1]  # lines 15-16 (Eq. 2)
                    if len(bumped):
                        np.add.at(cnt, bumped, 1)
                    for x in nbrs[ncores == c_old]:  # lines 17-20
                        x = int(x)
                        if not active[x]:
                            active[x] = True
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                w += 1
            eng.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax

        # --- phase 2: settle with Algorithm 5 (lines 22-25) -----------------
        act = np.flatnonzero(active)
        rng = (min(int(act.min()), u), max(int(act.max()), u))
        r = eng.semicore_star("seq", core=core, cnt=cnt, vrange=rng,
                              backend="numpy")
        self.core, self.cnt = r.core, r.cnt
        io = self._io_delta(snap)
        return MaintStats(
            "semiinsert",
            comp + r.node_computations,
            io[0],
            io[1],
            iters + r.iterations,
            int((self.core != old_core).sum()),
            num_inserts=1,
        )

    # =====================================================================
    # Algorithm 8: SemiInsert* (one-phase status machine)
    # =====================================================================
    def _insert_star(self, u0: int, v0: int) -> MaintStats:
        snap = self._io_snapshot()
        old_core = self.core.copy()
        core, cnt, eng = self.core, self.cnt, self.engine
        n = eng.n
        u, v, c_old = self._insert_common(u0, v0)

        status = np.full(n, _PHI, dtype=np.uint8)
        status[u] = _Q
        vmin = vmax = u
        comp = 0
        iters = 0
        update = True
        while update:
            update = False
            iters += 1
            nvmin, nvmax = n - 1, 0
            scan_lo = vmin
            w = vmin
            while w <= vmax:
                nbrs = None
                if status[w] == _Q:
                    nbrs = eng.nbrs(w)
                    comp += 1
                    # ComputeCnt* (Eq. 4; lines 29-33)
                    ncores = core[nbrs]
                    nst = status[nbrs]
                    cnt[w] = int(
                        (
                            (ncores > c_old)
                            | (
                                (ncores == c_old)
                                & (cnt[nbrs] >= c_old + 1)
                                & (nst != _CROSS)
                            )
                        ).sum()
                    )
                    status[w] = _CIRC
                    core[w] = c_old + 1
                    # lines 11-12: Eq.2-maintained peers gain w
                    bumped = nbrs[(ncores == c_old + 1) & (nst != _CIRC)]
                    if len(bumped):
                        np.add.at(cnt, bumped, 1)
                    if cnt[w] >= c_old + 1:  # lines 13-17: expand
                        cand = nbrs[
                            (ncores == c_old)
                            & (cnt[nbrs] >= c_old + 1)
                            & (nst == _PHI)
                        ]
                        for x in cand:
                            x = int(x)
                            status[x] = _Q
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                if status[w] == _CIRC and cnt[w] < c_old + 1:  # lines 18-27
                    if nbrs is None:
                        nbrs = eng.nbrs(w)
                        comp += 1
                    ncores = core[nbrs]
                    cnt[w] = int((ncores >= c_old).sum())  # ComputeCnt(nbr, c_old)
                    status[w] = _CROSS
                    core[w] = c_old
                    nst = status[nbrs]
                    # lines 22-23: Eq.2-maintained peers lose w ...
                    dec = nbrs[(ncores == c_old + 1) & (nst != _CIRC)]
                    if len(dec):
                        np.subtract.at(cnt, dec, 1)
                    # lines 24-27: ... and ○ nodes lose a promising candidate
                    circ = nbrs[nst == _CIRC]
                    for x in circ:
                        x = int(x)
                        cnt[x] -= 1
                        if cnt[x] < c_old + 1:
                            if x > vmax:
                                vmax = x
                            if x < w:
                                update = True
                                nvmin = min(nvmin, x)
                                nvmax = max(nvmax, x)
                w += 1
            eng.reader.account_node_table_scan(scan_lo, vmax)
            vmin, vmax = nvmin, nvmax

        io = self._io_delta(snap)
        return MaintStats(
            "semiinsert*",
            comp,
            io[0],
            io[1],
            iters,
            int((self.core != old_core).sum()),
            num_inserts=1,
        )
