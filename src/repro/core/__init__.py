"""The paper's contribution: semi-external core decomposition + maintenance."""
from .imcore import imcore_bz, imcore_peel
from .emcore import emcore, EMCoreResult
from .localcore import local_core, h_index_batch, compute_cnt_batch
from .engine import (
    ComputeBackend,
    DeviceBackend,
    NumpyBackend,
    PallasBackend,
    PassPlanner,
    XLABackend,
    resolve_backend,
    run_batch,
)
from . import resident
from .resident import run_resident, trace_count
from .semicore import HostEngine, DecompResult, decompose
from .update import Delete, Insert, UpdateBatch
from .maintenance import BatchMaintStats, CoreMaintainer, MaintStats
from .parallel_maint import DEFAULT_GROUP_CAP, grouped_settle, plan_batch

__all__ = [
    "imcore_bz", "imcore_peel", "emcore", "EMCoreResult",
    "local_core", "h_index_batch", "compute_cnt_batch",
    "ComputeBackend", "DeviceBackend", "NumpyBackend", "XLABackend",
    "PallasBackend", "PassPlanner", "resolve_backend", "run_batch",
    "resident", "run_resident", "trace_count",
    "HostEngine", "DecompResult", "decompose",
    "Insert", "Delete", "UpdateBatch",
    "CoreMaintainer", "MaintStats", "BatchMaintStats",
    "DEFAULT_GROUP_CAP", "grouped_settle", "plan_batch",
]
