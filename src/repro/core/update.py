"""Typed edge-update vocabulary shared by maintenance, WAL, and replicas.

One batch type — :class:`UpdateBatch`, an order-preserving sequence of
:class:`Insert`/:class:`Delete` ops — is now the unit of work everywhere an
edge update crosses a boundary: ``CoreMaintainer.apply``, ``CoreWriter``
admission, WAL records, and ``CoreReplica`` replay all speak it.  The
historical ``(deletes, inserts)`` pair-of-lists shape survives as
properties (and :meth:`UpdateBatch.from_pairs`) because the settle
algorithms are order-insensitive *within* a coalesced batch: admission
resolves each edge to its final state, so deletes-then-inserts is a
canonical replay order, not information loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, Sequence, Tuple

__all__ = ["Insert", "Delete", "UpdateBatch"]


@dataclass(frozen=True)
class Insert:
    """Insert undirected edge (u, v)."""

    u: int
    v: int
    kind = "+"

    def edge(self) -> Tuple[int, int]:
        return (int(self.u), int(self.v))


@dataclass(frozen=True)
class Delete:
    """Delete undirected edge (u, v)."""

    u: int
    v: int
    kind = "-"

    def edge(self) -> Tuple[int, int]:
        return (int(self.u), int(self.v))


_OP_TYPES = {"+": Insert, "-": Delete}


class UpdateBatch:
    """An ordered, immutable micro-batch of edge updates.

    Iterating yields the ops in submission order.  ``deletes``/``inserts``
    project the legacy pair-of-lists view (each preserving relative order).
    """

    __slots__ = ("ops",)

    def __init__(self, ops: Iterable = ()):  # ops: Insert | Delete
        ops = tuple(ops)
        for op in ops:
            if not isinstance(op, (Insert, Delete)):
                raise TypeError(
                    f"UpdateBatch takes Insert/Delete ops, got {op!r}")
        self.ops = ops

    # ------------------------------------------------------------ builders
    @classmethod
    def from_pairs(
        cls,
        deletes: Sequence[Tuple[int, int]] = (),
        inserts: Sequence[Tuple[int, int]] = (),
    ) -> "UpdateBatch":
        """Build from the legacy ``(deletes, inserts)`` pair of edge lists
        (deletes first — the canonical coalesced order)."""
        return cls(
            [Delete(int(u), int(v)) for u, v in deletes]
            + [Insert(int(u), int(v)) for u, v in inserts]
        )

    @classmethod
    def from_wire(cls, ops: Iterable[Sequence]) -> "UpdateBatch":
        """Decode the WAL wire form: ``[["+"|"-", u, v], ...]``."""
        return cls(_OP_TYPES[k](int(u), int(v)) for k, u, v in ops)

    def to_wire(self) -> list:
        """Encode for a WAL record: ``[[kind, u, v], ...]`` in op order."""
        return [[op.kind, int(op.u), int(op.v)] for op in self.ops]

    # ----------------------------------------------------------- legacy view
    @property
    def deletes(self) -> list:
        return [op.edge() for op in self.ops if isinstance(op, Delete)]

    @property
    def inserts(self) -> list:
        return [op.edge() for op in self.ops if isinstance(op, Insert)]

    # ------------------------------------------------------------- protocol
    def __iter__(self) -> Iterator:
        return iter(self.ops)

    def __len__(self) -> int:
        return len(self.ops)

    def __bool__(self) -> bool:
        return bool(self.ops)

    def __eq__(self, other) -> bool:
        return isinstance(other, UpdateBatch) and self.ops == other.ops

    def __hash__(self) -> int:
        return hash(self.ops)

    def __repr__(self) -> str:
        nd, ni = len(self.deletes), len(self.inserts)
        return f"UpdateBatch({len(self.ops)} ops: {nd} del, {ni} ins)"
