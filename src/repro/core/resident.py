"""Device-resident fixpoint: the whole batch superstep loop on the device.

PR 3's backend layer made the arithmetic pluggable but kept the *loop* on the
host: every pass re-uploaded node state (and, for the xla backend, re-packed
the frontier's edge segments), ran one jitted op, and downloaded the result —
~27 host↔device round-trips and O(passes) retraces per decompose, which made
the accelerator backends 20–100× slower than numpy in wall-clock despite
walking identical passes.  This module is the fix (DESIGN.md §12):

* **Residency** — ``core``, ``cnt``, the active/frontier mask, and the flat
  edge table ``(nbr, rows)`` are uploaded once at bind.  The edge table is
  cached in a :class:`ResidentStructure` keyed by the planner's structure
  token (base CSR identity + ``BufferedGraph.version``), so a long-lived
  ``CoreMaintainer`` re-binding after a no-op batch — or re-running on an
  unchanged graph — re-uploads nothing.

* **Fused superstep** — one pass (h-index binary-search probes → cnt refresh
  → push rule → ``cnt(v) < core(v)`` frontier gating → convergence flag) is
  a single traced function; ``lax.scan`` runs ``chunk`` passes per host
  round-trip, each gated by ``lax.cond`` so post-convergence slots cost
  nothing.  The jit is cached per (substrate, algorithm, probe count), so
  compiles per decompose are O(1) — independent of pass count — and O(log
  kmax) across graphs of one shape (the probe count is the only
  value-dependent static).

* **Accounting parity** — the chunk returns a small summary (per-pass update
  counts + the pinned per-pass frontier masks) pulled back once per chunk;
  the host *replays* frontier evolution through the same
  :class:`~repro.core.engine.PassPlanner` charges the per-pass path makes
  (edge-block coverage, node-table scans, pallas kernel-block activity).
  Because every backend computes the same exact integer fixpoint, the
  replayed frontiers are identical sets to the numpy backend's — so
  ``edge_block_reads`` / ``node_table_reads`` / ``kernel_blocks_*`` stay
  bit-identical, as the differential sweep asserts.

The shared :func:`fused_hindex` / :func:`fused_counts` helpers (gather
neighbor cores + probe loop in one traced body) are also what the SPMD
engine's per-shard superstep consumes (``distributed.py``).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

from .. import runtime as _runtime
from ..obs import trace as _trace
# registry series shared with the per-pass path: the replay increments the
# exact counters engine.run_batch / PallasBackend.begin_pass would have
from .engine import _KB_ACTIVE, _KB_SKIPPED, _MAINT_PROLOGUE, _pass_obs

__all__ = [
    "ResidentStructure",
    "ShardedStructure",
    "build_structure",
    "build_sharded_structure",
    "build_shard_chunk_fn",
    "run_resident",
    "run_sharded",
    "resident_enabled",
    "trace_count",
    "chunk_len",
    "fused_hindex",
    "fused_counts",
    "RESIDENT_ENV_VAR",
    "CHUNK_ENV_VAR",
    "DEFAULT_CHUNK",
]

RESIDENT_ENV_VAR = "REPRO_DEVICE_RESIDENT"
CHUNK_ENV_VAR = "REPRO_RESIDENT_CHUNK"
# Passes per host round-trip.  Small enough that the per-chunk frontier
# record (chunk × n bools) stays negligible next to the edge table; large
# enough that dispatch overhead amortizes (a typical decompose converges in
# ~2-4 chunks).  CoreGraphConfig.superstep_chunk / REPRO_RESIDENT_CHUNK tune.
DEFAULT_CHUNK = 8

# Incremented at *trace* time by every resident jit body: retraces — not
# calls — bump it, so tests and the benchmark can count compiles per
# decompose (the O(passes)-retrace regression guard).
_TRACE_COUNT = [0]


def trace_count() -> int:
    """Total resident-path jit traces so far in this process."""
    return _TRACE_COUNT[0]


def resident_enabled() -> bool:
    """Device residency is the default for device backends;
    ``REPRO_DEVICE_RESIDENT=0`` falls back to the per-pass PR 3 path.
    Resolved through :func:`repro.runtime.setting`."""
    return _runtime.setting("device_resident")


def chunk_len(explicit: int | None = None) -> int:
    """Effective passes-per-round-trip: explicit argument (the
    ``superstep_chunk`` threaded from configs/owners) > env > default."""
    if explicit is not None:
        return max(1, int(explicit))
    return _runtime.setting("resident_chunk")


# ===========================================================================
# Fused ops: neighbor gather + probe loop in one traced body.  Shared between
# the resident superstep below and the SPMD engine's per-shard superstep.
# ===========================================================================
def fused_counts(core, dst, rows, edge_mask, thresholds, num_rows,
                 *, segment_sum_fn):
    """#{edges (v,u) : core[u] >= thresholds[row(v)]} per row (Eq. 2)."""
    import jax.numpy as jnp

    from .engine import edge_ge_counts

    return edge_ge_counts(
        jnp.take(core, dst, mode="clip"), rows, edge_mask, thresholds,
        num_rows, segment_sum_fn=segment_sum_fn)


def fused_hindex(core, dst, rows, edge_mask, c_old, num_probes,
                 *, segment_sum_fn, unroll: bool = False):
    """Binary-search h = max k <= c_old with count_ge(k) >= k (Eq. 1)."""
    import jax.numpy as jnp

    from .engine import hindex_bsearch

    return hindex_bsearch(
        jnp.take(core, dst, mode="clip"), rows, edge_mask, c_old, num_probes,
        segment_sum_fn=segment_sum_fn, unroll=unroll)


# ===========================================================================
# Resident structure: the flat merged edge table, uploaded once per version
# ===========================================================================
@dataclass
class ResidentStructure:
    """The device-resident working set of one graph version.

    Host-side ``seg_ptr`` stays for the accounting replay (block coverage of
    a frontier); ``graph``/``version`` form the validity token — holding the
    graph reference keeps its identity stable for the ``is`` test.
    """

    graph: object            # base CSRGraph this structure was built from
    version: int             # BufferedGraph.version at build time (0 if none)
    n: int
    E: int                   # merged flat edge count (buffered deltas applied)
    dmax: int                # max merged degree (pallas float32-range check)
    seg_ptr: np.ndarray      # (n+1,) int64 flat-table offsets, host
    nbr_j: object            # (E_pad,) int32 device — edge targets
    rows_j: object           # (E_pad,) int32 device — edge source per slot
    segptr_j: object         # (n+1,) int32 device — flat-table offsets
    E_pad: int = 0           # bucket-padded device length (>= E)
    fused_tables: dict = field(default_factory=dict)
    trimmed: tuple | None = None  # cached (nbr, rows) exact-E device views

    def matches(self, planner) -> bool:
        buffered = planner.eng.buffered
        ver = buffered.version if buffered is not None else 0
        return self.graph is planner.eng.graph and self.version == ver

    def fused(self, block_edges: int):
        """Compact-rank kernel table for the fused superstep (DESIGN.md
        §16), built once per (structure, tile size) and cached for the
        structure's lifetime — the same upload-once contract as the flat
        edge table above."""
        ft = self.fused_tables.get(block_edges)
        if ft is None:
            from ..kernels.fused_superstep import build_fused_table

            ft = build_fused_table(self.seg_ptr,
                                   np.asarray(self.nbr_j)[:self.E],
                                   self.n, block_edges)
            self.fused_tables[block_edges] = ft
        return ft

    def edge_table(self, kind: str):
        """(nbr, rows) device arrays for one substrate.

        The xla substrate reduces edges exclusively through segptr-bounded
        prefix sums (:func:`_sorted_segsum`), so it takes the bucket-padded
        table as-is: the padded tail can never reach a segment sum, and the
        stable shape keeps the chunk jits cached across structural versions
        (the maintenance hot loop would otherwise recompile on every edge
        insert/delete).  The pallas blocked kernels scatter by edge slot and
        get the exact-length view instead."""
        if kind != "pallas" or self.E == self.E_pad:
            return self.nbr_j, self.rows_j
        if self.trimmed is None:
            self.trimmed = (self.nbr_j[:self.E], self.rows_j[:self.E])
        return self.trimmed


_EDGE_BUCKET = 8192


def _edge_pad(E: int) -> int:
    """Device-table length for ``E`` edge slots: next power of two below one
    bucket, then bucket multiples.  Small graphs recompile O(log E) times as
    they grow; at scale the shape only changes when E crosses a bucket
    boundary, so the maintenance undo/redo churn (±batch edges per round)
    almost never invalidates the chunk jit cache."""
    if E <= 0:
        return 0
    if E < _EDGE_BUCKET:
        return 1 << (E - 1).bit_length()
    return -(-E // _EDGE_BUCKET) * _EDGE_BUCKET


def build_structure(planner) -> ResidentStructure:
    """Merged flat adjacency of all nodes, uploaded once (charge-free, like
    the per-pass pallas bind it replaces — disk I/O stays per-pass,
    replayed planner-side)."""
    import jax.numpy as jnp

    planner.eng._sync()
    nbr_flat, seg_ptr = planner.full_structure()
    n = planner.n
    if len(nbr_flat) >= (1 << 31) or n >= (1 << 31):
        # the device table is int32 end-to-end (ids, rows, seg_ptr offsets;
        # jax x64 is off) — fail loudly instead of wrapping offsets negative
        # and converging to a silently-wrong core array
        raise ValueError(
            f"device-resident table needs int32 offsets: 2m={len(nbr_flat)} "
            f"n={n} exceeds 2**31; use the numpy backend (or shard via "
            "distributed.py) for this graph")
    lens = np.diff(seg_ptr)
    E = int(len(nbr_flat))
    E_pad = _edge_pad(E)
    nbr = np.zeros(E_pad, dtype=np.int32)
    nbr[:E] = nbr_flat
    rows = np.zeros(E_pad, dtype=np.int32)
    rows[:E] = np.repeat(np.arange(n, dtype=np.int64), lens)
    buffered = planner.eng.buffered
    return ResidentStructure(
        graph=planner.eng.graph,
        version=buffered.version if buffered is not None else 0,
        n=n,
        E=E,
        E_pad=E_pad,
        dmax=int(lens.max()) if len(lens) else 0,
        seg_ptr=np.asarray(seg_ptr, dtype=np.int64),
        nbr_j=jnp.asarray(nbr),
        rows_j=jnp.asarray(rows),
        segptr_j=jnp.asarray(np.asarray(seg_ptr, dtype=np.int32)),
    )


# ===========================================================================
# The fused, chunked superstep jits (cached per substrate × algorithm)
# ===========================================================================
def _sorted_segsum(segptr):
    """Segment-sum over the resident table's *sorted* rows: prefix-sum +
    boundary gathers instead of a scatter (XLA CPU scatters serialize; the
    cumsum path is what makes the resident loop run at numpy-like speed).
    Exact: integer cumsum, E < 2**31."""
    import jax.numpy as jnp

    def segsum(vals):
        cs = jnp.concatenate(
            [jnp.zeros((1,), vals.dtype), jnp.cumsum(vals)])
        return (jnp.take(cs, segptr[1:], mode="clip")
                - jnp.take(cs, segptr[:-1], mode="clip"))

    return segsum


def _substrate(kind: str, block_edges: int, interpret: bool):
    """segment_sum_fn factory: given the pass's structure + activity mask,
    return the (vals, rows, num_segments) reduction the shared probe ops
    consume — the blocked DMA-skipping kernel for pallas, the sorted
    prefix-sum reduction for xla."""
    if kind == "pallas":
        from ..kernels.ops import make_superstep_segsum

        def for_pass(rows, segptr, node_active, num_segments):
            apply_ = make_superstep_segsum(
                rows, node_active, num_segments,
                block_edges=block_edges, interpret=interpret)
            return lambda vals, _rows, _ns: apply_(vals)
    else:
        def for_pass(rows, segptr, node_active, num_segments):
            apply_ = _sorted_segsum(segptr)
            return lambda vals, _rows, _ns: apply_(vals)
    return for_pass


@lru_cache(maxsize=None)
def _chunk_fns(kind: str, block_edges: int, interpret: bool, algorithm: str,
               fused: bool = False, masked: bool = False):
    """Build + jit the chunked superstep for one substrate × algorithm.

    With ``masked`` (semicore* only — the grouped-maintenance settle,
    DESIGN.md §18) the chunk takes one extra ``cand`` bool operand and every
    pass ANDs it into the next frontier: non-candidate nodes are frozen —
    their core is never recomputed (the frontier is the only thing that
    writes core) while their cnt still receives exact push decrements from
    falling candidate neighbors, so independent groups converge inside the
    same ``lax.scan`` without interacting.

    ``num_probes`` / ``num_segments`` / ``chunk`` are static: one compile per
    decompose (jax re-traces only on new shapes or probe counts — O(log kmax)
    across graphs, never O(passes)).

    Node-state bookkeeping that scatters along unsorted ``nbr`` (the push
    rule, changed-neighbor propagation) is rewritten through the undirected
    symmetry — edge (v→u) exists iff (u→v) does — as a *sorted* row
    reduction, so the whole superstep runs scatter-free (prefix sums +
    gathers; XLA CPU scatters would serialize it).

    With ``fused`` (the pallas hot path, DESIGN.md §16) each superstep is
    ONE ``pallas_call`` — ``kernels.fused_superstep.fused_pass`` replaces
    the whole per-probe body; the scan/cond convergence scaffolding and
    every returned summary are identical, so the host replay is untouched.
    The static ``dims`` tuple rides the kernel table (same trace-count
    contract: only shapes and the probe count retrace).
    """
    import jax
    import jax.numpy as jnp

    if masked and algorithm != "semicore*":
        raise ValueError("masked settle is a semicore* (cnt-gated) "
                         f"discipline; got {algorithm!r}")

    if fused:
        from ..kernels import fused_superstep as fsk

        if algorithm == "semicore":
            def chunk(core, done, arrs, *, num_probes, num_segments, chunk,
                      dims):
                _TRACE_COUNT[0] += 1
                all_active = jnp.ones((num_segments,), jnp.bool_)

                def run(args):
                    core, _ = args
                    core2, _, _, upd = fsk.fused_pass(
                        core, core, all_active, arrs, dims=dims,
                        num_probes=num_probes, algorithm="semicore",
                        interpret=interpret)
                    return (core2, upd == 0), upd

                def skip(args):
                    core, done = args
                    return (core, done), jnp.int32(0)

                def step(carry, _):
                    core, done = carry
                    carry2, upd = jax.lax.cond(done, skip, run, (core, done))
                    return carry2, (upd, ~done)

                (core, done), (upds, ran) = jax.lax.scan(
                    step, (core, done), None, length=chunk)
                return core, done, upds, ran

        elif algorithm == "semicore+":
            def chunk(core, active, arrs, *, num_probes, num_segments, chunk,
                      dims):
                _TRACE_COUNT[0] += 1

                def run(args):
                    core, active = args
                    core2, _, active2, upd = fsk.fused_pass(
                        core, core, active, arrs, dims=dims,
                        num_probes=num_probes, algorithm="semicore+",
                        interpret=interpret)
                    return (core2, active2), upd

                def skip(args):
                    return args, jnp.int32(0)

                def step(carry, _):
                    _, active = carry
                    ran = jnp.any(active)
                    carry2, upd = jax.lax.cond(ran, run, skip, carry)
                    return carry2, (active, upd, ran)

                (core, active), (fronts, upds, ran) = jax.lax.scan(
                    step, (core, active), None, length=chunk)
                done = ~jnp.any(active)
                return core, active, done, fronts, upds, ran

        elif algorithm == "semicore*":
            def _scan_star(core, cnt, active, cand, arrs, num_probes, chunk,
                           dims):
                def run(args):
                    core, cnt, active = args
                    core2, cnt2, active2, upd = fsk.fused_pass(
                        core, cnt, active, arrs, dims=dims,
                        num_probes=num_probes, algorithm="semicore*",
                        interpret=interpret)
                    if cand is not None:
                        active2 = active2 & cand
                    return (core2, cnt2, active2), upd

                def skip(args):
                    return args, jnp.int32(0)

                def step(carry, _):
                    _, _, active = carry
                    ran = jnp.any(active)
                    carry2, upd = jax.lax.cond(ran, run, skip, carry)
                    return carry2, (active, upd, ran)

                (core, cnt, active), (fronts, upds, ran) = jax.lax.scan(
                    step, (core, cnt, active), None, length=chunk)
                done = ~jnp.any(active)
                return core, cnt, active, done, fronts, upds, ran

            if masked:
                def chunk(core, cnt, active, cand, arrs, *, num_probes,
                          num_segments, chunk, dims):
                    _TRACE_COUNT[0] += 1
                    return _scan_star(core, cnt, active, cand, arrs,
                                      num_probes, chunk, dims)
            else:
                def chunk(core, cnt, active, arrs, *, num_probes,
                          num_segments, chunk, dims):
                    _TRACE_COUNT[0] += 1
                    return _scan_star(core, cnt, active, None, arrs,
                                      num_probes, chunk, dims)

        else:
            raise ValueError(f"unknown algorithm {algorithm!r}")

        return jax.jit(chunk, static_argnames=("num_probes", "num_segments",
                                               "chunk", "dims"))

    for_pass = _substrate(kind, block_edges, interpret)

    def hindex_pass(core, active, nbr, rows, segptr, num_probes, n):
        segsum = for_pass(rows, segptr, active, n)
        mask = jnp.ones(rows.shape, jnp.bool_)
        c_old = jnp.where(active, core, 0)
        return fused_hindex(core, nbr, rows, mask, c_old, num_probes,
                            segment_sum_fn=segsum)

    if algorithm == "semicore":
        # every node, every pass; done after the first no-update pass
        def chunk(core, done, nbr, rows, segptr, *, num_probes, num_segments,
                  chunk):
            _TRACE_COUNT[0] += 1
            all_active = jnp.ones((num_segments,), jnp.bool_)

            def run(args):
                core, _ = args
                h = hindex_pass(core, all_active, nbr, rows, segptr,
                                num_probes, num_segments)
                upd = jnp.sum((h != core).astype(jnp.int32))
                return (h, upd == 0), upd

            def skip(args):
                core, done = args
                return (core, done), jnp.int32(0)

            def step(carry, _):
                core, done = carry
                carry2, upd = jax.lax.cond(done, skip, run, (core, done))
                return carry2, (upd, ~done)

            (core, done), (upds, ran) = jax.lax.scan(
                step, (core, done), None, length=chunk)
            return core, done, upds, ran

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    if algorithm == "semicore+":
        # neighbors of changed nodes (Lemma 4.1), alive nodes only
        def chunk(core, active, nbr, rows, segptr, *, num_probes,
                  num_segments, chunk):
            _TRACE_COUNT[0] += 1
            row_sum = _sorted_segsum(segptr)

            def run(args):
                core, active = args
                h = hindex_pass(core, active, nbr, rows, segptr, num_probes,
                                num_segments)
                changed = active & (h != core)
                core2 = jnp.where(active, h, core)
                # u is next-frontier iff some neighbor changed — by symmetry
                # a row reduction over u's own (sorted) segment
                touched = row_sum(
                    jnp.take(changed, nbr, mode="clip").astype(jnp.int32))
                active2 = (touched > 0) & (core2 > 0)
                return (core2, active2), jnp.sum(changed.astype(jnp.int32))

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, active = carry
                ran = jnp.any(active)
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, active), (fronts, upds, ran) = jax.lax.scan(
                step, (core, active), None, length=chunk)
            done = ~jnp.any(active)
            return core, active, done, fronts, upds, ran

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    if algorithm == "semicore*":
        # cnt-gated (Lemma 4.2) with exact cnt maintenance under
        # simultaneous updates: refresh vs pass-start values, then the
        # UpdateNbrCnt push rule (DESIGN.md §2) — all on device
        def _scan_star(core, cnt, active, cand, nbr, rows, segptr,
                       num_probes, num_segments, chunk):
            row_sum = _sorted_segsum(segptr)

            def run(args):
                core, cnt, active = args
                segsum = for_pass(rows, segptr, active, num_segments)
                mask = jnp.ones(rows.shape, jnp.bool_)
                nbr_vals = jnp.take(core, nbr, mode="clip")  # pass-start
                c_old = jnp.where(active, core, 0)
                from .engine import edge_ge_counts, hindex_bsearch
                h = hindex_bsearch(nbr_vals, rows, mask, c_old, num_probes,
                                   segment_sum_fn=segsum)
                upd = jnp.sum((active & (h != core)).astype(jnp.int32))
                core2 = jnp.where(active, h, core)
                # (1) recompute cnt of the frontier vs pass-start values
                thr = jnp.where(active, h, 0)
                refreshed = edge_ge_counts(nbr_vals, rows, mask, thr,
                                           num_segments,
                                           segment_sum_fn=segsum)
                # (2) push decrements: dec[u] = #{edges (v in F -> u) :
                #     core_now(u) in (h(v), c_old(v)]} — by symmetry summed
                #     over u's own sorted segment, v = nbr[e]
                core2_row = jnp.take(core2, rows, mode="clip")
                act_nbr = jnp.take(active, nbr, mode="clip")
                h_nbr = jnp.take(h, nbr, mode="clip")
                c_old_nbr = jnp.take(core, nbr, mode="clip")
                push = act_nbr & (core2_row > h_nbr) & (core2_row <= c_old_nbr)
                dec = row_sum(push.astype(jnp.int32))
                cnt2 = jnp.where(active, refreshed, cnt) - dec
                active2 = (cnt2 < core2) & (core2 > 0)
                if cand is not None:
                    active2 = active2 & cand
                return (core2, cnt2, active2), upd

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, _, active = carry
                ran = jnp.any(active)
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, cnt, active), (fronts, upds, ran) = jax.lax.scan(
                step, (core, cnt, active), None, length=chunk)
            done = ~jnp.any(active)
            return core, cnt, active, done, fronts, upds, ran

        if masked:
            def chunk(core, cnt, active, cand, nbr, rows, segptr, *,
                      num_probes, num_segments, chunk):
                _TRACE_COUNT[0] += 1
                return _scan_star(core, cnt, active, cand, nbr, rows, segptr,
                                  num_probes, num_segments, chunk)
        else:
            def chunk(core, cnt, active, nbr, rows, segptr, *, num_probes,
                      num_segments, chunk):
                _TRACE_COUNT[0] += 1
                return _scan_star(core, cnt, active, None, nbr, rows, segptr,
                                  num_probes, num_segments, chunk)

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    raise ValueError(f"unknown algorithm {algorithm!r}")


@lru_cache(maxsize=None)
def _counts_all_fn(kind: str, block_edges: int, interpret: bool,
                   fused: bool = False):
    """Full-table exact-cnt scan (warm_settle's Eq. 2 prologue), resident."""
    import jax
    import jax.numpy as jnp

    if fused:
        from ..kernels import fused_superstep as fsk

        def counts_all(core, arrs, *, num_segments, num_probes, dims):
            _TRACE_COUNT[0] += 1
            all_active = jnp.ones((num_segments,), jnp.bool_)
            return fsk.fused_counts(core, core, all_active, arrs, dims=dims,
                                    num_probes=num_probes,
                                    interpret=interpret)

        return jax.jit(counts_all, static_argnames=("num_segments",
                                                    "num_probes", "dims"))

    for_pass = _substrate(kind, block_edges, interpret)

    def counts_all(core, nbr, rows, segptr, *, num_segments):
        _TRACE_COUNT[0] += 1
        all_active = jnp.ones((num_segments,), jnp.bool_)
        segsum = for_pass(rows, segptr, all_active, num_segments)
        mask = jnp.ones(rows.shape, jnp.bool_)
        return fused_counts(core, nbr, rows, mask, core, num_segments,
                            segment_sum_fn=segsum)

    return jax.jit(counts_all, static_argnames=("num_segments",))


# ===========================================================================
# Host-side accounting replay
# ===========================================================================
def _replay_kernel_blocks(tally: dict | None, rs: ResidentStructure,
                          be: int, nb: int, frontier: np.ndarray) -> None:
    """Kernel-block activity of one pass over ``frontier`` — the pallas
    ``begin_pass`` coverage formula (spans over the merged flat table),
    verbatim, so the resident report matches the per-pass path bit-for-bit
    (including its ``if self.E`` guard: an edgeless table has no kernel
    blocks to charge)."""
    if tally is None or not len(frontier) or rs.E == 0:
        return
    lo = rs.seg_ptr[frontier]
    hi = rs.seg_ptr[frontier + 1]
    nz = lo < hi
    cov = np.zeros(nb + 1, dtype=np.int64)
    if nz.any():
        np.add.at(cov, lo[nz] // be, 1)
        np.add.at(cov, (hi[nz] - 1) // be + 1, -1)
    na = int((np.cumsum(cov[:-1]) > 0).sum())
    tally["kernel_blocks_active"] += na
    tally["kernel_blocks_skipped"] += nb - na
    _KB_ACTIVE.inc(na)
    _KB_SKIPPED.inc(nb - na)


def _replay_pass(planner, frontier: np.ndarray, tally: dict | None,
                 rs: ResidentStructure, be: int, nb: int) -> None:
    """Re-issue the exact planner charges one per-pass iteration makes for
    ``frontier`` (sorted node ids): edge-block coverage over the *raw* CSR
    ranges (what ``gather``/``charge_only`` charge), the node-table scan,
    and the pallas kernel-block activity."""
    if not len(frontier):
        return
    planner.charge_only(frontier)
    planner.account_node_scan(int(frontier[0]), int(frontier[-1]))
    _replay_kernel_blocks(tally, rs, be, nb, frontier)


# ===========================================================================
# The runner
# ===========================================================================
def run_resident(engine, algorithm: str, backend, *,
                 core: np.ndarray | None = None,
                 cnt: np.ndarray | None = None,
                 initial_cnt_scan: bool = False,
                 superstep_chunk: int | None = None,
                 max_supersteps: int | None = None,
                 settle_mask: np.ndarray | None = None):
    """Run a batch-schedule decomposition with the fixpoint device-resident.

    Mirrors :func:`engine.run_batch` pass-for-pass (same frontiers, same
    update/computation histories, same planner accounting) but with node
    state and the edge table living on the device across passes.  With
    ``initial_cnt_scan`` (the warm-settle discipline), ``cnt`` is recomputed
    exactly on device from the warm ``core`` upper bound — one accounted
    full scan — before the SemiCore* passes.

    ``settle_mask`` (semicore* only) freezes every node outside the mask:
    the frontier starts at ``(cnt < core) & (core > 0) & mask`` and stays
    inside the mask for the whole run — the grouped-maintenance settle
    (DESIGN.md §18).  Frozen nodes keep their core; their cnt still takes
    exact push decrements from falling masked neighbors.

    A mesh-sharded backend (``ShardedBackend``) dispatches to
    :func:`run_sharded`: same contract, edge table sharded over the mesh.
    """
    if getattr(backend, "mesh_sharded", False):
        return run_sharded(engine, algorithm, backend, core=core, cnt=cnt,
                           initial_cnt_scan=initial_cnt_scan,
                           superstep_chunk=superstep_chunk,
                           max_supersteps=max_supersteps,
                           settle_mask=settle_mask)
    if max_supersteps is not None:
        raise ValueError("max_supersteps is only supported on the shard "
                         "backend (chunk-granular budgeted runs)")
    if settle_mask is not None and algorithm != "semicore*":
        raise ValueError("settle_mask is a semicore* (cnt-gated) discipline")

    import jax.numpy as jnp

    from .engine import DecompResult

    planner = engine.planner
    n = engine.n
    rs = backend.bind_resident(planner)
    kind, be, interpret = backend.resident_substrate(planner)
    # kernel blocks (pallas replay only; be is unused elsewhere).  The
    # accounting block size stays the planner's regardless of the fused
    # kernel's tile size — kernel_blocks_active/skipped replay is the PR 3
    # coverage formula at ``be`` granularity either way.
    nb = -(-max(rs.E, 1) // be) if kind == "pallas" else 0
    tally = ({"kernel_blocks_active": 0, "kernel_blocks_skipped": 0}
             if kind == "pallas" else None)
    chunk = chunk_len(superstep_chunk)
    om = _pass_obs(algorithm, backend.name)

    if kind == "pallas":
        from ..kernels import fused_superstep as fsk

        fused = fsk.fused_enabled() and rs.E > 0
    else:
        fused = False

    nbr_j, rows_j = rs.edge_table(kind)

    def substrate_args():
        """Positional + static-kw tail of the chunk fns for this substrate:
        the fused path ships the compact-rank kernel table, the per-probe
        paths the flat edge table (bucket-padded for xla, exact for pallas)."""
        if fused:
            ft = rs.fused(fsk.fused_block_edges(rs.E))
            return (ft.arrays,), {"dims": ft.dims}
        return (nbr_j, rows_j, rs.segptr_j), {}

    warm = core is not None
    if warm:
        core = np.asarray(core, dtype=np.int64).copy()
    else:
        core = engine.degrees().astype(np.int64)
    cmax = int(core.max()) if n else 0
    num_probes = max(1, int(np.ceil(np.log2(cmax + 2))))
    core_j = jnp.asarray(core.astype(np.int32))

    upd_hist: list = []
    comp_hist: list = []
    iters = 0
    comp = 0
    all_nodes = np.arange(n, dtype=np.int64)

    def result(core_f, cnt_f):
        rep = tally or {}
        backend.unbind()
        return DecompResult(
            core=np.asarray(core_f, dtype=np.int64),
            cnt=None if cnt_f is None else np.asarray(cnt_f, dtype=np.int64),
            iterations=iters,
            node_computations=comp,
            edge_block_reads=planner.reader.reads,
            node_table_reads=planner.reader.node_table_reads,
            algorithm=algorithm,
            schedule="batch",
            updates_per_iter=upd_hist,
            computations_per_iter=comp_hist,
            backend=backend.name,
            kernel_blocks_active=rep.get("kernel_blocks_active", 0),
            kernel_blocks_skipped=rep.get("kernel_blocks_skipped", 0),
        )

    # ------------------------------------------------------------ semicore*
    if algorithm == "semicore*":
        if initial_cnt_scan:
            # warm_settle prologue: one accounted full scan recomputes cnt
            # exactly (Eq. 2) w.r.t. the warm upper bound — on device
            t0 = time.perf_counter()
            with _trace.span("cnt_prologue", cat="maintenance",
                             backend=backend.name, nodes=n):
                planner.charge_only(all_nodes)
                planner.account_node_scan(0, n - 1)
                _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
                if rs.E and fused:
                    counts_all = _counts_all_fn(kind, be, interpret, True)
                    sargs, skw = substrate_args()
                    cnt_j = counts_all(core_j, *sargs, num_segments=n,
                                       num_probes=num_probes, **skw)
                elif rs.E:
                    counts_all = _counts_all_fn(kind, be, interpret)
                    cnt_j = counts_all(core_j, nbr_j, rows_j,
                                       rs.segptr_j, num_segments=n)
                else:
                    cnt_j = jnp.zeros((n,), jnp.int32)
                cnt = np.asarray(cnt_j, dtype=np.int64)
            _MAINT_PROLOGUE.observe(time.perf_counter() - t0)
        elif warm:
            cnt = np.asarray(cnt, dtype=np.int64).copy()
            cnt_j = jnp.asarray(cnt.astype(np.int32))
        else:
            cnt = np.zeros(n, dtype=np.int64)
            cnt_j = jnp.zeros((n,), jnp.int32)
        active0 = (cnt < core) & (core > 0)
        if settle_mask is not None:
            active0 &= np.asarray(settle_mask, dtype=bool)
        if rs.E == 0:
            # edgeless table: any deficient node drops straight to h = 0 in
            # one pass, and nothing can re-activate — numpy's loop verbatim
            if active0.any():
                f = np.flatnonzero(active0)
                iters, comp = 1, len(f)
                upd_hist.append(int((core[f] != 0).sum()))
                comp_hist.append(len(f))
                _replay_pass(planner, f, tally, rs, be, nb)
                om[0].inc()
                om[1].inc(len(f))
                om[2].inc(int((core[f] != 0).sum()))
                core[f] = 0
                cnt[f] = 0
            return result(core, cnt)
        if not active0.any():
            # settled warm state: zero passes, like numpy's while-loop
            return result(core, cnt)
        masked = settle_mask is not None
        fn = _chunk_fns(kind, be, interpret, algorithm, fused, masked)
        sargs, skw = substrate_args()
        if masked:
            cand_j = jnp.asarray(np.asarray(settle_mask, dtype=bool))
            sargs = (cand_j,) + sargs
        active_j = jnp.asarray(active0)
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore*", backend=backend.name,
                             chunk=chunk) as sp:
                core_j, cnt_j, active_j, done, fronts, upds, ran = fn(
                    core_j, cnt_j, active_j, *sargs,
                    num_probes=num_probes, num_segments=n, chunk=chunk,
                    **skw)
                iters, comp = _replay_chunk(
                    planner, rs, be, nb, tally, np.asarray(fronts),
                    np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                    iters, comp, om, "semicore*")
                if sp.active:
                    sp.set(passes_run=int(np.asarray(ran).sum()))
            if bool(done):
                break
        return result(core_j, cnt_j)

    # ------------------------------------------------- semicore / semicore+
    if rs.E == 0:
        # h == core == degrees == 0 everywhere: semicore runs exactly one
        # all-node pass; semicore+ starts from the all-node frontier and
        # likewise converges on pass one (numpy loop, charge-for-charge)
        if algorithm == "semicore" or n:
            iters, comp = 1, n
            upd_hist.append(0)
            comp_hist.append(n)
            planner.charge_only(all_nodes)
            planner.account_node_scan(0, n - 1)
            _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
            om[0].inc()
            om[1].inc(n)
        return result(core, None)

    if algorithm == "semicore":
        # every node, every pass — the final no-update pass included
        fn = _chunk_fns(kind, be, interpret, algorithm, fused)
        sargs, skw = substrate_args()
        done_j = jnp.asarray(False)
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore", backend=backend.name,
                             chunk=chunk) as sp:
                core_j, done_j, upds, ran = fn(
                    core_j, done_j, *sargs,
                    num_probes=num_probes, num_segments=n, chunk=chunk,
                    **skw)
                ran = np.asarray(ran)
                upds = np.asarray(upds)
                for k in range(len(ran)):
                    if not ran[k]:
                        break
                    iters += 1
                    comp += n
                    upd_hist.append(int(upds[k]))
                    comp_hist.append(n)
                    planner.charge_only(all_nodes)
                    planner.account_node_scan(0, n - 1)
                    _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
                    om[0].inc()
                    om[1].inc(n)
                    om[2].inc(int(upds[k]))
                    _trace.instant("superstep.replay", cat="engine",
                                   algorithm="semicore", index=iters,
                                   frontier=n, updates=int(upds[k]))
                if sp.active:
                    sp.set(passes_run=int(ran.sum()))
            if bool(done_j):
                break
        return result(core_j, None)

    if algorithm == "semicore+":
        fn = _chunk_fns(kind, be, interpret, algorithm, fused)
        sargs, skw = substrate_args()
        active_j = jnp.ones((n,), jnp.bool_)
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore+", backend=backend.name,
                             chunk=chunk) as sp:
                core_j, active_j, done, fronts, upds, ran = fn(
                    core_j, active_j, *sargs,
                    num_probes=num_probes, num_segments=n, chunk=chunk,
                    **skw)
                iters, comp = _replay_chunk(
                    planner, rs, be, nb, tally, np.asarray(fronts),
                    np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                    iters, comp, om, "semicore+")
                if sp.active:
                    sp.set(passes_run=int(np.asarray(ran).sum()))
            if bool(done):
                break
        return result(core_j, None)

    raise ValueError(f"unknown algorithm {algorithm!r}")


def _replay_chunk(planner, rs, be, nb, tally, fronts, upds, ran,
                  upd_hist, comp_hist, iters, comp, om=None, algorithm=""):
    """Replay the planner charges for the executed passes of one chunk.

    ``om`` is the (passes, frontier, updates) counter triple from
    :func:`engine._pass_obs`; the replayed per-pass markers are emitted as
    trace instants from the same pinned frontier masks the planner charges
    come from, so tracing never perturbs the bit-identical guarantee."""
    for k in range(len(ran)):
        if not ran[k]:
            break
        frontier = np.flatnonzero(fronts[k]).astype(np.int64)
        iters += 1
        comp += len(frontier)
        upd_hist.append(int(upds[k]))
        comp_hist.append(int(len(frontier)))
        _replay_pass(planner, frontier, tally, rs, be, nb)
        if om is not None:
            om[0].inc()
            om[1].inc(len(frontier))
            om[2].inc(int(upds[k]))
        _trace.instant("superstep.replay", cat="engine", algorithm=algorithm,
                       index=iters, frontier=int(len(frontier)),
                       updates=int(upds[k]))
    return iters, comp


# ===========================================================================
# Mesh-sharded execution (the `shard` backend, DESIGN.md §13)
# ===========================================================================
@dataclass
class ShardedStructure:
    """The on-mesh working set of one graph version.

    The merged flat adjacency is cut into contiguous node-range shards
    (``distributed.shard_arrays``: minimax edge balance, int32-validated)
    and device_put once per structural version — the same version-keyed
    cache contract as :class:`ResidentStructure`.  Host copies of the
    owned-slot maps stay for reassembling global masks/arrays from the
    per-shard slices the chunk fns emit.
    """

    graph: object            # base CSRGraph this structure was built from
    version: int             # BufferedGraph.version at build time (0 if none)
    n: int
    E: int                   # merged flat edge count (buffered deltas applied)
    S: int                   # mesh width (number of shards)
    V: int                   # owned-node slots per shard (padded)
    seg_ptr: np.ndarray      # (n+1,) int64 merged flat offsets, host
    owned_ids_h: np.ndarray  # (S, V) int32 host — global id per slot (pad n)
    owned_mask_h: np.ndarray # (S, V) bool host
    owned_flat: np.ndarray   # (S*V,) int32 host — all_gather-ordered ids
    pad_edges: int           # S * Emax - E (rectangular-layout waste)
    per_shard_edges: np.ndarray  # (S,) int64
    mesh: object             # jax Mesh over the first S devices
    dst_j: object            # (S, Emax) int32, sharded
    rows_j: object           # (S, Emax) int32, sharded
    emask_j: object          # (S, Emax) bool, sharded
    lseg_j: object           # (S, V+1) int32, sharded — local CSR offsets
    owned_ids_j: object      # (S, V) int32, sharded
    owned_mask_j: object     # (S, V) bool, sharded

    def matches(self, planner) -> bool:
        buffered = planner.eng.buffered
        ver = buffered.version if buffered is not None else 0
        return self.graph is planner.eng.graph and self.version == ver


def build_sharded_structure(planner, num_shards: int,
                            devices=None) -> ShardedStructure:
    """Merged flat adjacency of all nodes, sharded and uploaded once
    (charge-free, like :func:`build_structure` — disk I/O stays per-pass,
    replayed planner-side).  ``devices`` pins the mesh to an explicit
    device list (default: the first ``num_shards`` visible devices)."""
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from .distributed import shard_arrays

    planner.eng._sync()
    nbr_flat, seg_ptr = planner.full_structure()
    n = planner.n
    sg = shard_arrays(nbr_flat, seg_ptr, num_shards, n=n)
    S = sg.owned_ids.shape[0]
    pool = list(devices) if devices is not None else jax.devices()
    mesh = Mesh(np.asarray(pool[:S]), ("shard",))
    sh = NamedSharding(mesh, P("shard"))
    owned_flat = sg.owned_ids.reshape(-1).astype(np.int32)
    buffered = planner.eng.buffered
    return ShardedStructure(
        graph=planner.eng.graph,
        version=buffered.version if buffered is not None else 0,
        n=n,
        E=int(len(nbr_flat)),
        S=S,
        V=int(sg.owned_ids.shape[1]),
        seg_ptr=np.asarray(seg_ptr, dtype=np.int64),
        owned_ids_h=sg.owned_ids,
        owned_mask_h=sg.owned_mask,
        owned_flat=owned_flat,
        pad_edges=int(sg.pad_edges),
        per_shard_edges=sg.per_shard_edges,
        mesh=mesh,
        dst_j=jax.device_put(sg.dst, sh),
        rows_j=jax.device_put(sg.rows, sh),
        emask_j=jax.device_put(sg.edge_mask, sh),
        lseg_j=jax.device_put(sg.lsegptr, sh),
        owned_ids_j=jax.device_put(sg.owned_ids, sh),
        owned_mask_j=jax.device_put(sg.owned_mask, sh),
    )


def _local_segsum(lseg):
    """Per-shard segment sum over the shard's *sorted* local rows: prefix
    sums + boundary gathers (the :func:`_sorted_segsum` discipline applied
    to the shard's local offsets; padding slots are empty trailing
    segments, so padded edges never contribute)."""
    import jax.numpy as jnp

    def segsum(vals, _rows, _num_segments):
        cs = jnp.concatenate([jnp.zeros((1,), vals.dtype), jnp.cumsum(vals)])
        return (jnp.take(cs, lseg[1:], mode="clip")
                - jnp.take(cs, lseg[:-1], mode="clip"))

    return segsum


@lru_cache(maxsize=None)
def _shard_chunk_fn(mesh, algorithm: str, n: int, num_probes: int,
                    chunk: int, unroll: bool, masked: bool = False):
    """Build + jit the on-mesh chunked superstep for one mesh × algorithm.

    The per-shard superstep body is the same fused arithmetic the flat
    resident path scans (:func:`fused_hindex` / :func:`fused_counts` probe
    code via the shared engine ops) applied to the shard's local edge
    arrays; one ``jax.lax.all_gather`` of the owned core slices per
    superstep rebuilds the replicated core, and one scalar ``psum`` carries
    the convergence count.  The push rule / changed-neighbor propagation
    read the *gathered* post-update core instead of a local ``h`` (for an
    inactive neighbor ``core2 == core`` makes the push predicate
    unsatisfiable, so no activity mask crosses shards), which keeps every
    superstep at exactly one all_gather.

    Per-pass owned frontier slices come back through the scan's ys —
    sharded outputs, no extra collective — for the host accounting replay.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat.jaxshims import shard_map
    from .engine import edge_ge_counts, hindex_bsearch

    axes = tuple(mesh.axis_names)
    shard = P(axes)
    repl = P()

    def strip(*arrs):
        return tuple(a[0] for a in arrs)

    def gather_core(core, c_new, owned_flat):
        gathered = jax.lax.all_gather(c_new, axes, tiled=True)
        return jnp.zeros((n + 1,), core.dtype).at[owned_flat].set(gathered)[:n]

    def flat_ids(owned_ids):
        # the static scatter index map: gathered ONCE per chunk call (not
        # per superstep, and not shipped replicated from the host — the
        # §13 memory model keeps replicated inputs at core-in + core-out)
        return jax.lax.all_gather(owned_ids, axes, tiled=True)

    if algorithm == "semicore":
        # every node, every pass; done after the first no-update pass
        def body(core, done, dst, rows, emask, lseg, owned_ids, owned_mask):
            _TRACE_COUNT[0] += 1
            dst, rows, emask, lseg, owned_ids, owned_mask = strip(
                dst, rows, emask, lseg, owned_ids, owned_mask)
            segsum = _local_segsum(lseg)
            owned_flat = flat_ids(owned_ids)

            def run(args):
                core, _ = args
                nbr_vals = jnp.take(core, dst, mode="clip")
                c_old = jnp.where(owned_mask,
                                  jnp.take(core, owned_ids, mode="clip"), 0)
                h = hindex_bsearch(nbr_vals, rows, emask, c_old, num_probes,
                                   segment_sum_fn=segsum, unroll=unroll)
                core2 = gather_core(core, h, owned_flat)
                upd = jnp.sum((core2 != core).astype(jnp.int32))
                return (core2, upd == 0), upd

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, done = carry
                carry2, upd = jax.lax.cond(done, skip, run, carry)
                return carry2, (upd, ~done)

            (core, done), (upds, ran) = jax.lax.scan(
                step, (core, done), None, length=chunk)
            return core, done, upds, ran

        in_specs = (repl, repl, shard, shard, shard, shard, shard, shard)
        out_specs = (repl, repl, repl, repl)

    elif algorithm == "semicore+":
        # neighbors of changed nodes (Lemma 4.1), alive nodes only; the
        # changed mask is derived globally from the gathered core
        # (core2 != core), so propagation is a local row reduction
        def body(core, active_b, nact, dst, rows, emask, lseg, owned_ids,
                 owned_mask):
            _TRACE_COUNT[0] += 1
            dst, rows, emask, lseg, owned_ids, owned_mask, active0 = strip(
                dst, rows, emask, lseg, owned_ids, owned_mask, active_b)
            segsum = _local_segsum(lseg)
            owned_flat = flat_ids(owned_ids)

            def run(args):
                core, active, _ = args
                nbr_vals = jnp.take(core, dst, mode="clip")
                c_owned = jnp.where(owned_mask,
                                    jnp.take(core, owned_ids, mode="clip"), 0)
                c_old = jnp.where(active, c_owned, 0)
                h = hindex_bsearch(nbr_vals, rows, emask, c_old, num_probes,
                                   segment_sum_fn=segsum, unroll=unroll)
                c_new = jnp.where(active, h, c_owned)
                core2 = gather_core(core, c_new, owned_flat)
                upd = jnp.sum((core2 != core).astype(jnp.int32))
                changed_e = jnp.take(core2 != core, dst, mode="clip") & emask
                touched = segsum(changed_e.astype(jnp.int32), rows, 0)
                active2 = (touched > 0) & (c_new > 0) & owned_mask
                nact2 = jax.lax.psum(
                    jnp.sum(active2.astype(jnp.int32)), axes)
                return (core2, active2, nact2), upd

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, active, nact = carry
                ran = nact > 0
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, active, nact), (fronts, upds, ran) = jax.lax.scan(
                step, (core, active0, nact), None, length=chunk)
            return (core, active[None], nact, fronts[:, None, :], upds, ran)

        in_specs = (repl, shard, repl, shard, shard, shard, shard, shard,
                    shard)
        out_specs = (repl, shard, repl, P(None, axes, None), repl, repl)

    elif algorithm == "semicore*":
        # cnt-gated (Lemma 4.2) with exact cnt maintenance: cnt stays
        # owner-local (each shard maintains its owned slice), the push rule
        # reads the gathered core2 in place of the neighbor's local h.
        # ``masked`` adds a per-slot candidate operand ANDed into every
        # next frontier (the grouped-maintenance settle, DESIGN.md §18).
        def body(core, cnt_b, active_b, nact, *tail):
            _TRACE_COUNT[0] += 1
            if masked:
                cand_b, dst, rows, emask, lseg, owned_ids, owned_mask = tail
                (cand,) = strip(cand_b)
            else:
                dst, rows, emask, lseg, owned_ids, owned_mask = tail
                cand = None
            dst, rows, emask, lseg, owned_ids, owned_mask, cnt0, active0 = \
                strip(dst, rows, emask, lseg, owned_ids, owned_mask, cnt_b,
                      active_b)
            segsum = _local_segsum(lseg)
            owned_flat = flat_ids(owned_ids)

            def run(args):
                core, cnt, active, _ = args
                nbr_vals = jnp.take(core, dst, mode="clip")  # pass-start
                c_owned = jnp.where(owned_mask,
                                    jnp.take(core, owned_ids, mode="clip"), 0)
                c_old = jnp.where(active, c_owned, 0)
                h = hindex_bsearch(nbr_vals, rows, emask, c_old, num_probes,
                                   segment_sum_fn=segsum, unroll=unroll)
                c_new = jnp.where(active, h, c_owned)
                core2 = gather_core(core, c_new, owned_flat)
                upd = jnp.sum((core2 != core).astype(jnp.int32))
                # (1) recompute cnt of the frontier vs pass-start values
                thr = jnp.where(active, h, 0)
                refreshed = edge_ge_counts(nbr_vals, rows, emask, thr,
                                           c_old.shape[0],
                                           segment_sum_fn=segsum)
                # (2) push decrements: dec[u] = #{edges (v in F -> u) :
                #     core_now(u) in (h(v), c_old(v)]} — core2[v] stands in
                #     for h(v) (equal where v is active; for inactive v,
                #     core2 == core makes the interval empty)
                c2_row = jnp.take(c_new, rows, mode="clip")
                push = (emask & (c2_row > jnp.take(core2, dst, mode="clip"))
                        & (c2_row <= nbr_vals))
                dec = segsum(push.astype(jnp.int32), rows, 0)
                cnt2 = jnp.where(active, refreshed, cnt) - dec
                active2 = (cnt2 < c_new) & (c_new > 0) & owned_mask
                if cand is not None:
                    active2 = active2 & cand
                nact2 = jax.lax.psum(
                    jnp.sum(active2.astype(jnp.int32)), axes)
                return (core2, cnt2, active2, nact2), upd

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, _, active, nact = carry
                ran = nact > 0
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, cnt, active, nact), (fronts, upds, ran) = jax.lax.scan(
                step, (core, cnt0, active0, nact), None, length=chunk)
            return (core, cnt[None], active[None], nact,
                    fronts[:, None, :], upds, ran)

        in_specs = (repl, shard, shard, repl) \
            + ((shard,) if masked else ()) \
            + (shard, shard, shard, shard, shard, shard)
        out_specs = (repl, shard, shard, repl, P(None, axes, None), repl,
                     repl)

    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=False)
    return jax.jit(
        sharded,
        in_shardings=tuple(NamedSharding(mesh, s) for s in in_specs),
    )


def build_shard_chunk_fn(mesh, algorithm: str, n: int, num_probes: int,
                         chunk: int | None = None):
    """Public builder of the on-mesh chunked superstep jit (also the
    dry-run cost-analysis entry, launch/steps.py).  ``REPRO_UNROLL_SCANS=1``
    unrolls the h-index probe loop so cost analysis sees every scan."""
    return _shard_chunk_fn(mesh, algorithm, n, num_probes, chunk_len(chunk),
                           os.environ.get("REPRO_UNROLL_SCANS") == "1")


@lru_cache(maxsize=None)
def _shard_counts_fn(mesh, n: int):
    """Full-table exact-cnt scan (warm_settle's Eq. 2 prologue), on-mesh:
    each shard counts its owned nodes' thresholds over local edges."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat.jaxshims import shard_map
    from .engine import edge_ge_counts

    axes = tuple(mesh.axis_names)
    shard = P(axes)
    repl = P()

    def body(core, dst, rows, emask, lseg, owned_ids, owned_mask):
        _TRACE_COUNT[0] += 1
        dst = dst[0]; rows = rows[0]; emask = emask[0]; lseg = lseg[0]
        owned_ids = owned_ids[0]; owned_mask = owned_mask[0]
        segsum = _local_segsum(lseg)
        c_owned = jnp.where(owned_mask,
                            jnp.take(core, owned_ids, mode="clip"), 0)
        nbr_vals = jnp.take(core, dst, mode="clip")
        cnt = edge_ge_counts(nbr_vals, rows, emask, c_owned,
                             c_owned.shape[0], segment_sum_fn=segsum)
        return cnt[None]

    in_specs = (repl, shard, shard, shard, shard, shard, shard)
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=shard, check_vma=False)
    return jax.jit(
        sharded,
        in_shardings=tuple(NamedSharding(mesh, s) for s in in_specs),
    )


def run_sharded(engine, algorithm: str, backend, *,
                core: np.ndarray | None = None,
                cnt: np.ndarray | None = None,
                initial_cnt_scan: bool = False,
                superstep_chunk: int | None = None,
                max_supersteps: int | None = None,
                settle_mask: np.ndarray | None = None):
    """Run a batch-schedule decomposition with the fixpoint on-mesh.

    The shard-layout sibling of the flat resident runner: identical passes,
    histories, and planner replay (the differential sweep asserts parity at
    every shard count), with the edge table sharded over the mesh and cnt
    maintained owner-local.  ``max_supersteps`` budgets the run exactly
    (the final chunk's scan length is clamped to the remaining budget) for
    checkpoint demos — the partial core is a valid upper bound by monotone
    convergence.
    """
    import jax.numpy as jnp

    from .engine import DecompResult

    if settle_mask is not None and algorithm != "semicore*":
        raise ValueError("settle_mask is a semicore* (cnt-gated) discipline")
    planner = engine.planner
    n = engine.n
    ss = backend.bind_resident(planner)
    chunk = chunk_len(superstep_chunk)
    unroll = os.environ.get("REPRO_UNROLL_SCANS") == "1"
    om = _pass_obs(algorithm, backend.name)

    warm = core is not None
    if warm:
        core = np.asarray(core, dtype=np.int64).copy()
    else:
        core = engine.degrees().astype(np.int64)
    cmax = int(core.max()) if n else 0
    num_probes = max(1, int(np.ceil(np.log2(cmax + 2))))
    core_j = jnp.asarray(core.astype(np.int32))

    upd_hist: list = []
    comp_hist: list = []
    iters = 0
    comp = 0
    all_nodes = np.arange(n, dtype=np.int64)
    own_ids = ss.owned_ids_h[ss.owned_mask_h]  # global id per real slot

    def localize(arr, fill, dtype):
        """Scatter a global (n,) array into the (S, V) owned-slot layout."""
        out = np.full((ss.S, ss.V), fill, dtype=dtype)
        out[ss.owned_mask_h] = arr[own_ids].astype(dtype)
        return out

    def globalize(slices, fill, dtype):
        """Gather (S, V) owned-slot slices back to a global (n,) array."""
        out = np.full(n, fill, dtype=dtype)
        out[own_ids] = np.asarray(slices)[ss.owned_mask_h]
        return out

    def front_masks(fronts):
        """(chunk, S, V) pass-start owned slices -> (chunk, n) bool masks."""
        fronts = np.asarray(fronts)
        return np.stack([globalize(fronts[k], False, bool)
                         for k in range(len(fronts))])

    def budget_hit():
        return max_supersteps is not None and iters >= max_supersteps

    def budget_fn():
        """The chunk jit, with the scan length clamped to the remaining
        superstep budget so a budget below the chunk size is honored
        exactly (each distinct length hits the lru'd jit cache)."""
        c = chunk if max_supersteps is None else \
            max(1, min(chunk, max_supersteps - iters))
        return _shard_chunk_fn(ss.mesh, algorithm, n, num_probes, c, unroll,
                               settle_mask is not None)

    def result(core_f, cnt_f):
        backend.unbind()
        return DecompResult(
            core=np.asarray(core_f, dtype=np.int64),
            cnt=None if cnt_f is None else np.asarray(cnt_f, dtype=np.int64),
            iterations=iters,
            node_computations=comp,
            edge_block_reads=planner.reader.reads,
            node_table_reads=planner.reader.node_table_reads,
            algorithm=algorithm,
            schedule="batch",
            updates_per_iter=upd_hist,
            computations_per_iter=comp_hist,
            backend=backend.name,
            num_shards=ss.S,
            shard_pad_edges=ss.pad_edges,
        )

    # ------------------------------------------------------------ semicore*
    if algorithm == "semicore*":
        if initial_cnt_scan:
            # warm_settle prologue: one accounted full scan recomputes cnt
            # exactly (Eq. 2) w.r.t. the warm upper bound — on the mesh,
            # against the bound sharded structure
            t0 = time.perf_counter()
            with _trace.span("cnt_prologue", cat="maintenance",
                             backend=backend.name, nodes=n):
                planner.charge_only(all_nodes)
                planner.account_node_scan(0, n - 1)
                if ss.E:
                    counts = _shard_counts_fn(ss.mesh, n)
                    cnt_lj = counts(core_j, ss.dst_j, ss.rows_j, ss.emask_j,
                                    ss.lseg_j, ss.owned_ids_j, ss.owned_mask_j)
                    cnt = globalize(cnt_lj, 0, np.int64)
                else:
                    cnt = np.zeros(n, dtype=np.int64)
            _MAINT_PROLOGUE.observe(time.perf_counter() - t0)
        elif warm:
            cnt = np.asarray(cnt, dtype=np.int64).copy()
        else:
            cnt = np.zeros(n, dtype=np.int64)
        active0 = (cnt < core) & (core > 0)
        if settle_mask is not None:
            active0 &= np.asarray(settle_mask, dtype=bool)
        if ss.E == 0:
            # edgeless table: any deficient node drops straight to h = 0 in
            # one pass, and nothing can re-activate — numpy's loop verbatim
            if active0.any():
                f = np.flatnonzero(active0)
                iters, comp = 1, len(f)
                upd_hist.append(int((core[f] != 0).sum()))
                comp_hist.append(len(f))
                _replay_pass(planner, f, None, ss, 0, 0)
                om[0].inc()
                om[1].inc(len(f))
                om[2].inc(int((core[f] != 0).sum()))
                core[f] = 0
                cnt[f] = 0
            return result(core, cnt)
        if not active0.any():
            # settled warm state: zero passes, like numpy's while-loop
            return result(core, cnt)
        cnt_lj = localize(cnt, 0, np.int32)
        act_lj = localize(active0, False, bool)
        cand_args = ()
        if settle_mask is not None:
            cand_args = (localize(
                np.asarray(settle_mask, dtype=bool), False, bool),)
        nact = np.int32(active0.sum())
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore*", backend=backend.name,
                             shards=ss.S, chunk=chunk) as sp:
                core_j, cnt_lj, act_lj, nact, fronts, upds, ran = budget_fn()(
                    core_j, cnt_lj, act_lj, nact, *cand_args, ss.dst_j,
                    ss.rows_j, ss.emask_j, ss.lseg_j, ss.owned_ids_j,
                    ss.owned_mask_j)
                iters, comp = _replay_chunk(
                    planner, ss, 0, 0, None, front_masks(fronts),
                    np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                    iters, comp, om, "semicore*")
                if sp.active:
                    sp.set(passes_run=int(np.asarray(ran).sum()))
            if int(nact) == 0 or budget_hit():
                break
        return result(core_j, globalize(cnt_lj, 0, np.int64))

    # ------------------------------------------------- semicore / semicore+
    if ss.E == 0:
        # h == core == degrees == 0 everywhere: semicore runs exactly one
        # all-node pass; semicore+ starts from the all-node frontier and
        # likewise converges on pass one (numpy loop, charge-for-charge)
        if algorithm == "semicore" or n:
            iters, comp = 1, n
            upd_hist.append(0)
            comp_hist.append(n)
            planner.charge_only(all_nodes)
            planner.account_node_scan(0, n - 1)
            om[0].inc()
            om[1].inc(n)
        return result(core, None)

    if algorithm == "semicore":
        # every node, every pass — the final no-update pass included
        done_j = jnp.asarray(False)
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore", backend=backend.name,
                             shards=ss.S, chunk=chunk) as sp:
                core_j, done_j, upds, ran = budget_fn()(
                    core_j, done_j, ss.dst_j, ss.rows_j, ss.emask_j,
                    ss.lseg_j, ss.owned_ids_j, ss.owned_mask_j)
                ran = np.asarray(ran)
                upds = np.asarray(upds)
                for k in range(len(ran)):
                    if not ran[k]:
                        break
                    iters += 1
                    comp += n
                    upd_hist.append(int(upds[k]))
                    comp_hist.append(n)
                    planner.charge_only(all_nodes)
                    planner.account_node_scan(0, n - 1)
                    om[0].inc()
                    om[1].inc(n)
                    om[2].inc(int(upds[k]))
                    _trace.instant("superstep.replay", cat="engine",
                                   algorithm="semicore", index=iters,
                                   frontier=n, updates=int(upds[k]))
                if sp.active:
                    sp.set(passes_run=int(ran.sum()))
            if bool(done_j) or budget_hit():
                break
        return result(core_j, None)

    if algorithm == "semicore+":
        act_lj = localize(np.ones(n, dtype=bool), False, bool)
        nact = np.int32(n)
        while True:
            with _trace.span("resident.chunk", cat="engine",
                             algorithm="semicore+", backend=backend.name,
                             shards=ss.S, chunk=chunk) as sp:
                core_j, act_lj, nact, fronts, upds, ran = budget_fn()(
                    core_j, act_lj, nact, ss.dst_j, ss.rows_j, ss.emask_j,
                    ss.lseg_j, ss.owned_ids_j, ss.owned_mask_j)
                iters, comp = _replay_chunk(
                    planner, ss, 0, 0, None, front_masks(fronts),
                    np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                    iters, comp, om, "semicore+")
                if sp.active:
                    sp.set(passes_run=int(np.asarray(ran).sum()))
            if int(nact) == 0 or budget_hit():
                break
        return result(core_j, None)

    raise ValueError(f"unknown algorithm {algorithm!r}")
