"""Device-resident fixpoint: the whole batch superstep loop on the device.

PR 3's backend layer made the arithmetic pluggable but kept the *loop* on the
host: every pass re-uploaded node state (and, for the xla backend, re-packed
the frontier's edge segments), ran one jitted op, and downloaded the result —
~27 host↔device round-trips and O(passes) retraces per decompose, which made
the accelerator backends 20–100× slower than numpy in wall-clock despite
walking identical passes.  This module is the fix (DESIGN.md §12):

* **Residency** — ``core``, ``cnt``, the active/frontier mask, and the flat
  edge table ``(nbr, rows)`` are uploaded once at bind.  The edge table is
  cached in a :class:`ResidentStructure` keyed by the planner's structure
  token (base CSR identity + ``BufferedGraph.version``), so a long-lived
  ``CoreMaintainer`` re-binding after a no-op batch — or re-running on an
  unchanged graph — re-uploads nothing.

* **Fused superstep** — one pass (h-index binary-search probes → cnt refresh
  → push rule → ``cnt(v) < core(v)`` frontier gating → convergence flag) is
  a single traced function; ``lax.scan`` runs ``chunk`` passes per host
  round-trip, each gated by ``lax.cond`` so post-convergence slots cost
  nothing.  The jit is cached per (substrate, algorithm, probe count), so
  compiles per decompose are O(1) — independent of pass count — and O(log
  kmax) across graphs of one shape (the probe count is the only
  value-dependent static).

* **Accounting parity** — the chunk returns a small summary (per-pass update
  counts + the pinned per-pass frontier masks) pulled back once per chunk;
  the host *replays* frontier evolution through the same
  :class:`~repro.core.engine.PassPlanner` charges the per-pass path makes
  (edge-block coverage, node-table scans, pallas kernel-block activity).
  Because every backend computes the same exact integer fixpoint, the
  replayed frontiers are identical sets to the numpy backend's — so
  ``edge_block_reads`` / ``node_table_reads`` / ``kernel_blocks_*`` stay
  bit-identical, as the differential sweep asserts.

The shared :func:`fused_hindex` / :func:`fused_counts` helpers (gather
neighbor cores + probe loop in one traced body) are also what the SPMD
engine's per-shard superstep consumes (``distributed.py``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

__all__ = [
    "ResidentStructure",
    "build_structure",
    "run_resident",
    "resident_enabled",
    "trace_count",
    "chunk_len",
    "fused_hindex",
    "fused_counts",
    "RESIDENT_ENV_VAR",
    "CHUNK_ENV_VAR",
    "DEFAULT_CHUNK",
]

RESIDENT_ENV_VAR = "REPRO_DEVICE_RESIDENT"
CHUNK_ENV_VAR = "REPRO_RESIDENT_CHUNK"
# Passes per host round-trip.  Small enough that the per-chunk frontier
# record (chunk × n bools) stays negligible next to the edge table; large
# enough that dispatch overhead amortizes (a typical decompose converges in
# ~2-4 chunks).  CoreGraphConfig.superstep_chunk / REPRO_RESIDENT_CHUNK tune.
DEFAULT_CHUNK = 8

# Incremented at *trace* time by every resident jit body: retraces — not
# calls — bump it, so tests and the benchmark can count compiles per
# decompose (the O(passes)-retrace regression guard).
_TRACE_COUNT = [0]


def trace_count() -> int:
    """Total resident-path jit traces so far in this process."""
    return _TRACE_COUNT[0]


def resident_enabled() -> bool:
    """Device residency is the default for device backends;
    ``REPRO_DEVICE_RESIDENT=0`` falls back to the per-pass PR 3 path."""
    return os.environ.get(RESIDENT_ENV_VAR, "1") != "0"


def chunk_len(explicit: int | None = None) -> int:
    """Effective passes-per-round-trip: explicit argument (the
    ``superstep_chunk`` threaded from configs/owners) > env > default."""
    if explicit is not None:
        return max(1, int(explicit))
    try:
        return max(1, int(os.environ.get(CHUNK_ENV_VAR, DEFAULT_CHUNK)))
    except ValueError:
        return DEFAULT_CHUNK


# ===========================================================================
# Fused ops: neighbor gather + probe loop in one traced body.  Shared between
# the resident superstep below and the SPMD engine's per-shard superstep.
# ===========================================================================
def fused_counts(core, dst, rows, edge_mask, thresholds, num_rows,
                 *, segment_sum_fn):
    """#{edges (v,u) : core[u] >= thresholds[row(v)]} per row (Eq. 2)."""
    import jax.numpy as jnp

    from .engine import edge_ge_counts

    return edge_ge_counts(
        jnp.take(core, dst, mode="clip"), rows, edge_mask, thresholds,
        num_rows, segment_sum_fn=segment_sum_fn)


def fused_hindex(core, dst, rows, edge_mask, c_old, num_probes,
                 *, segment_sum_fn, unroll: bool = False):
    """Binary-search h = max k <= c_old with count_ge(k) >= k (Eq. 1)."""
    import jax.numpy as jnp

    from .engine import hindex_bsearch

    return hindex_bsearch(
        jnp.take(core, dst, mode="clip"), rows, edge_mask, c_old, num_probes,
        segment_sum_fn=segment_sum_fn, unroll=unroll)


# ===========================================================================
# Resident structure: the flat merged edge table, uploaded once per version
# ===========================================================================
@dataclass
class ResidentStructure:
    """The device-resident working set of one graph version.

    Host-side ``seg_ptr`` stays for the accounting replay (block coverage of
    a frontier); ``graph``/``version`` form the validity token — holding the
    graph reference keeps its identity stable for the ``is`` test.
    """

    graph: object            # base CSRGraph this structure was built from
    version: int             # BufferedGraph.version at build time (0 if none)
    n: int
    E: int                   # merged flat edge count (buffered deltas applied)
    dmax: int                # max merged degree (pallas float32-range check)
    seg_ptr: np.ndarray      # (n+1,) int64 flat-table offsets, host
    nbr_j: object            # (E,) int32 device — edge targets
    rows_j: object           # (E,) int32 device — edge source per slot
    segptr_j: object         # (n+1,) int32 device — flat-table offsets

    def matches(self, planner) -> bool:
        buffered = planner.eng.buffered
        ver = buffered.version if buffered is not None else 0
        return self.graph is planner.eng.graph and self.version == ver


def build_structure(planner) -> ResidentStructure:
    """Merged flat adjacency of all nodes, uploaded once (charge-free, like
    the per-pass pallas bind it replaces — disk I/O stays per-pass,
    replayed planner-side)."""
    import jax.numpy as jnp

    planner.eng._sync()
    nbr_flat, seg_ptr = planner.full_structure()
    n = planner.n
    if len(nbr_flat) >= (1 << 31) or n >= (1 << 31):
        # the device table is int32 end-to-end (ids, rows, seg_ptr offsets;
        # jax x64 is off) — fail loudly instead of wrapping offsets negative
        # and converging to a silently-wrong core array
        raise ValueError(
            f"device-resident table needs int32 offsets: 2m={len(nbr_flat)} "
            f"n={n} exceeds 2**31; use the numpy backend (or shard via "
            "distributed.py) for this graph")
    lens = np.diff(seg_ptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), lens).astype(np.int32)
    buffered = planner.eng.buffered
    return ResidentStructure(
        graph=planner.eng.graph,
        version=buffered.version if buffered is not None else 0,
        n=n,
        E=int(len(nbr_flat)),
        dmax=int(lens.max()) if len(lens) else 0,
        seg_ptr=np.asarray(seg_ptr, dtype=np.int64),
        nbr_j=jnp.asarray(np.asarray(nbr_flat, dtype=np.int32)),
        rows_j=jnp.asarray(rows),
        segptr_j=jnp.asarray(np.asarray(seg_ptr, dtype=np.int32)),
    )


# ===========================================================================
# The fused, chunked superstep jits (cached per substrate × algorithm)
# ===========================================================================
def _sorted_segsum(segptr):
    """Segment-sum over the resident table's *sorted* rows: prefix-sum +
    boundary gathers instead of a scatter (XLA CPU scatters serialize; the
    cumsum path is what makes the resident loop run at numpy-like speed).
    Exact: integer cumsum, E < 2**31."""
    import jax.numpy as jnp

    def segsum(vals):
        cs = jnp.concatenate(
            [jnp.zeros((1,), vals.dtype), jnp.cumsum(vals)])
        return (jnp.take(cs, segptr[1:], mode="clip")
                - jnp.take(cs, segptr[:-1], mode="clip"))

    return segsum


def _substrate(kind: str, block_edges: int, interpret: bool):
    """segment_sum_fn factory: given the pass's structure + activity mask,
    return the (vals, rows, num_segments) reduction the shared probe ops
    consume — the blocked DMA-skipping kernel for pallas, the sorted
    prefix-sum reduction for xla."""
    if kind == "pallas":
        from ..kernels.ops import make_superstep_segsum

        def for_pass(rows, segptr, node_active, num_segments):
            apply_ = make_superstep_segsum(
                rows, node_active, num_segments,
                block_edges=block_edges, interpret=interpret)
            return lambda vals, _rows, _ns: apply_(vals)
    else:
        def for_pass(rows, segptr, node_active, num_segments):
            apply_ = _sorted_segsum(segptr)
            return lambda vals, _rows, _ns: apply_(vals)
    return for_pass


@lru_cache(maxsize=None)
def _chunk_fns(kind: str, block_edges: int, interpret: bool, algorithm: str):
    """Build + jit the chunked superstep for one substrate × algorithm.

    ``num_probes`` / ``num_segments`` / ``chunk`` are static: one compile per
    decompose (jax re-traces only on new shapes or probe counts — O(log kmax)
    across graphs, never O(passes)).

    Node-state bookkeeping that scatters along unsorted ``nbr`` (the push
    rule, changed-neighbor propagation) is rewritten through the undirected
    symmetry — edge (v→u) exists iff (u→v) does — as a *sorted* row
    reduction, so the whole superstep runs scatter-free (prefix sums +
    gathers; XLA CPU scatters would serialize it).
    """
    import jax
    import jax.numpy as jnp

    for_pass = _substrate(kind, block_edges, interpret)

    def hindex_pass(core, active, nbr, rows, segptr, num_probes, n):
        segsum = for_pass(rows, segptr, active, n)
        mask = jnp.ones(rows.shape, jnp.bool_)
        c_old = jnp.where(active, core, 0)
        return fused_hindex(core, nbr, rows, mask, c_old, num_probes,
                            segment_sum_fn=segsum)

    if algorithm == "semicore":
        # every node, every pass; done after the first no-update pass
        def chunk(core, done, nbr, rows, segptr, *, num_probes, num_segments,
                  chunk):
            _TRACE_COUNT[0] += 1
            all_active = jnp.ones((num_segments,), jnp.bool_)

            def run(args):
                core, _ = args
                h = hindex_pass(core, all_active, nbr, rows, segptr,
                                num_probes, num_segments)
                upd = jnp.sum((h != core).astype(jnp.int32))
                return (h, upd == 0), upd

            def skip(args):
                core, done = args
                return (core, done), jnp.int32(0)

            def step(carry, _):
                core, done = carry
                carry2, upd = jax.lax.cond(done, skip, run, (core, done))
                return carry2, (upd, ~done)

            (core, done), (upds, ran) = jax.lax.scan(
                step, (core, done), None, length=chunk)
            return core, done, upds, ran

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    if algorithm == "semicore+":
        # neighbors of changed nodes (Lemma 4.1), alive nodes only
        def chunk(core, active, nbr, rows, segptr, *, num_probes,
                  num_segments, chunk):
            _TRACE_COUNT[0] += 1
            row_sum = _sorted_segsum(segptr)

            def run(args):
                core, active = args
                h = hindex_pass(core, active, nbr, rows, segptr, num_probes,
                                num_segments)
                changed = active & (h != core)
                core2 = jnp.where(active, h, core)
                # u is next-frontier iff some neighbor changed — by symmetry
                # a row reduction over u's own (sorted) segment
                touched = row_sum(
                    jnp.take(changed, nbr, mode="clip").astype(jnp.int32))
                active2 = (touched > 0) & (core2 > 0)
                return (core2, active2), jnp.sum(changed.astype(jnp.int32))

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, active = carry
                ran = jnp.any(active)
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, active), (fronts, upds, ran) = jax.lax.scan(
                step, (core, active), None, length=chunk)
            done = ~jnp.any(active)
            return core, active, done, fronts, upds, ran

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    if algorithm == "semicore*":
        # cnt-gated (Lemma 4.2) with exact cnt maintenance under
        # simultaneous updates: refresh vs pass-start values, then the
        # UpdateNbrCnt push rule (DESIGN.md §2) — all on device
        def chunk(core, cnt, active, nbr, rows, segptr, *, num_probes,
                  num_segments, chunk):
            _TRACE_COUNT[0] += 1
            row_sum = _sorted_segsum(segptr)

            def run(args):
                core, cnt, active = args
                segsum = for_pass(rows, segptr, active, num_segments)
                mask = jnp.ones(rows.shape, jnp.bool_)
                nbr_vals = jnp.take(core, nbr, mode="clip")  # pass-start
                c_old = jnp.where(active, core, 0)
                from .engine import edge_ge_counts, hindex_bsearch
                h = hindex_bsearch(nbr_vals, rows, mask, c_old, num_probes,
                                   segment_sum_fn=segsum)
                upd = jnp.sum((active & (h != core)).astype(jnp.int32))
                core2 = jnp.where(active, h, core)
                # (1) recompute cnt of the frontier vs pass-start values
                thr = jnp.where(active, h, 0)
                refreshed = edge_ge_counts(nbr_vals, rows, mask, thr,
                                           num_segments,
                                           segment_sum_fn=segsum)
                # (2) push decrements: dec[u] = #{edges (v in F -> u) :
                #     core_now(u) in (h(v), c_old(v)]} — by symmetry summed
                #     over u's own sorted segment, v = nbr[e]
                core2_row = jnp.take(core2, rows, mode="clip")
                act_nbr = jnp.take(active, nbr, mode="clip")
                h_nbr = jnp.take(h, nbr, mode="clip")
                c_old_nbr = jnp.take(core, nbr, mode="clip")
                push = act_nbr & (core2_row > h_nbr) & (core2_row <= c_old_nbr)
                dec = row_sum(push.astype(jnp.int32))
                cnt2 = jnp.where(active, refreshed, cnt) - dec
                active2 = (cnt2 < core2) & (core2 > 0)
                return (core2, cnt2, active2), upd

            def skip(args):
                return args, jnp.int32(0)

            def step(carry, _):
                _, _, active = carry
                ran = jnp.any(active)
                carry2, upd = jax.lax.cond(ran, run, skip, carry)
                return carry2, (active, upd, ran)

            (core, cnt, active), (fronts, upds, ran) = jax.lax.scan(
                step, (core, cnt, active), None, length=chunk)
            done = ~jnp.any(active)
            return core, cnt, active, done, fronts, upds, ran

        return jax.jit(chunk,
                       static_argnames=("num_probes", "num_segments", "chunk"))

    raise ValueError(f"unknown algorithm {algorithm!r}")


@lru_cache(maxsize=None)
def _counts_all_fn(kind: str, block_edges: int, interpret: bool):
    """Full-table exact-cnt scan (warm_settle's Eq. 2 prologue), resident."""
    import jax
    import jax.numpy as jnp

    for_pass = _substrate(kind, block_edges, interpret)

    def counts_all(core, nbr, rows, segptr, *, num_segments):
        _TRACE_COUNT[0] += 1
        all_active = jnp.ones((num_segments,), jnp.bool_)
        segsum = for_pass(rows, segptr, all_active, num_segments)
        mask = jnp.ones(rows.shape, jnp.bool_)
        return fused_counts(core, nbr, rows, mask, core, num_segments,
                            segment_sum_fn=segsum)

    return jax.jit(counts_all, static_argnames=("num_segments",))


# ===========================================================================
# Host-side accounting replay
# ===========================================================================
def _replay_kernel_blocks(tally: dict | None, rs: ResidentStructure,
                          be: int, nb: int, frontier: np.ndarray) -> None:
    """Kernel-block activity of one pass over ``frontier`` — the pallas
    ``begin_pass`` coverage formula (spans over the merged flat table),
    verbatim, so the resident report matches the per-pass path bit-for-bit
    (including its ``if self.E`` guard: an edgeless table has no kernel
    blocks to charge)."""
    if tally is None or not len(frontier) or rs.E == 0:
        return
    lo = rs.seg_ptr[frontier]
    hi = rs.seg_ptr[frontier + 1]
    nz = lo < hi
    cov = np.zeros(nb + 1, dtype=np.int64)
    if nz.any():
        np.add.at(cov, lo[nz] // be, 1)
        np.add.at(cov, (hi[nz] - 1) // be + 1, -1)
    na = int((np.cumsum(cov[:-1]) > 0).sum())
    tally["kernel_blocks_active"] += na
    tally["kernel_blocks_skipped"] += nb - na


def _replay_pass(planner, frontier: np.ndarray, tally: dict | None,
                 rs: ResidentStructure, be: int, nb: int) -> None:
    """Re-issue the exact planner charges one per-pass iteration makes for
    ``frontier`` (sorted node ids): edge-block coverage over the *raw* CSR
    ranges (what ``gather``/``charge_only`` charge), the node-table scan,
    and the pallas kernel-block activity."""
    if not len(frontier):
        return
    planner.charge_only(frontier)
    planner.account_node_scan(int(frontier[0]), int(frontier[-1]))
    _replay_kernel_blocks(tally, rs, be, nb, frontier)


# ===========================================================================
# The runner
# ===========================================================================
def run_resident(engine, algorithm: str, backend, *,
                 core: np.ndarray | None = None,
                 cnt: np.ndarray | None = None,
                 initial_cnt_scan: bool = False,
                 superstep_chunk: int | None = None):
    """Run a batch-schedule decomposition with the fixpoint device-resident.

    Mirrors :func:`engine.run_batch` pass-for-pass (same frontiers, same
    update/computation histories, same planner accounting) but with node
    state and the edge table living on the device across passes.  With
    ``initial_cnt_scan`` (the warm-settle discipline), ``cnt`` is recomputed
    exactly on device from the warm ``core`` upper bound — one accounted
    full scan — before the SemiCore* passes.
    """
    import jax.numpy as jnp

    from .engine import DecompResult

    planner = engine.planner
    n = engine.n
    rs = backend.bind_resident(planner)
    kind, be, interpret = backend.resident_substrate(planner)
    # kernel blocks (pallas replay only; be is unused elsewhere)
    nb = -(-max(rs.E, 1) // be) if kind == "pallas" else 0
    tally = ({"kernel_blocks_active": 0, "kernel_blocks_skipped": 0}
             if kind == "pallas" else None)
    chunk = chunk_len(superstep_chunk)

    warm = core is not None
    if warm:
        core = np.asarray(core, dtype=np.int64).copy()
    else:
        core = engine.degrees().astype(np.int64)
    cmax = int(core.max()) if n else 0
    num_probes = max(1, int(np.ceil(np.log2(cmax + 2))))
    core_j = jnp.asarray(core.astype(np.int32))

    upd_hist: list = []
    comp_hist: list = []
    iters = 0
    comp = 0
    all_nodes = np.arange(n, dtype=np.int64)

    def result(core_f, cnt_f):
        rep = tally or {}
        backend.unbind()
        return DecompResult(
            core=np.asarray(core_f, dtype=np.int64),
            cnt=None if cnt_f is None else np.asarray(cnt_f, dtype=np.int64),
            iterations=iters,
            node_computations=comp,
            edge_block_reads=planner.reader.reads,
            node_table_reads=planner.reader.node_table_reads,
            algorithm=algorithm,
            schedule="batch",
            updates_per_iter=upd_hist,
            computations_per_iter=comp_hist,
            backend=backend.name,
            kernel_blocks_active=rep.get("kernel_blocks_active", 0),
            kernel_blocks_skipped=rep.get("kernel_blocks_skipped", 0),
        )

    # ------------------------------------------------------------ semicore*
    if algorithm == "semicore*":
        if initial_cnt_scan:
            # warm_settle prologue: one accounted full scan recomputes cnt
            # exactly (Eq. 2) w.r.t. the warm upper bound — on device
            planner.charge_only(all_nodes)
            planner.account_node_scan(0, n - 1)
            _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
            if rs.E:
                counts_all = _counts_all_fn(kind, be, interpret)
                cnt_j = counts_all(core_j, rs.nbr_j, rs.rows_j,
                                   rs.segptr_j, num_segments=n)
            else:
                cnt_j = jnp.zeros((n,), jnp.int32)
            cnt = np.asarray(cnt_j, dtype=np.int64)
        elif warm:
            cnt = np.asarray(cnt, dtype=np.int64).copy()
            cnt_j = jnp.asarray(cnt.astype(np.int32))
        else:
            cnt = np.zeros(n, dtype=np.int64)
            cnt_j = jnp.zeros((n,), jnp.int32)
        active0 = (cnt < core) & (core > 0)
        if rs.E == 0:
            # edgeless table: any deficient node drops straight to h = 0 in
            # one pass, and nothing can re-activate — numpy's loop verbatim
            if active0.any():
                f = np.flatnonzero(active0)
                iters, comp = 1, len(f)
                upd_hist.append(int((core[f] != 0).sum()))
                comp_hist.append(len(f))
                _replay_pass(planner, f, tally, rs, be, nb)
                core[f] = 0
                cnt[f] = 0
            return result(core, cnt)
        if not active0.any():
            # settled warm state: zero passes, like numpy's while-loop
            return result(core, cnt)
        fn = _chunk_fns(kind, be, interpret, algorithm)
        active_j = jnp.asarray(active0)
        while True:
            core_j, cnt_j, active_j, done, fronts, upds, ran = fn(
                core_j, cnt_j, active_j, rs.nbr_j, rs.rows_j, rs.segptr_j,
                num_probes=num_probes, num_segments=n, chunk=chunk)
            iters, comp = _replay_chunk(
                planner, rs, be, nb, tally, np.asarray(fronts),
                np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                iters, comp)
            if bool(done):
                break
        return result(core_j, cnt_j)

    # ------------------------------------------------- semicore / semicore+
    if rs.E == 0:
        # h == core == degrees == 0 everywhere: semicore runs exactly one
        # all-node pass; semicore+ starts from the all-node frontier and
        # likewise converges on pass one (numpy loop, charge-for-charge)
        if algorithm == "semicore" or n:
            iters, comp = 1, n
            upd_hist.append(0)
            comp_hist.append(n)
            planner.charge_only(all_nodes)
            planner.account_node_scan(0, n - 1)
            _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
        return result(core, None)

    if algorithm == "semicore":
        # every node, every pass — the final no-update pass included
        fn = _chunk_fns(kind, be, interpret, algorithm)
        done_j = jnp.asarray(False)
        while True:
            core_j, done_j, upds, ran = fn(
                core_j, done_j, rs.nbr_j, rs.rows_j, rs.segptr_j,
                num_probes=num_probes, num_segments=n, chunk=chunk)
            ran = np.asarray(ran)
            upds = np.asarray(upds)
            for k in range(len(ran)):
                if not ran[k]:
                    break
                iters += 1
                comp += n
                upd_hist.append(int(upds[k]))
                comp_hist.append(n)
                planner.charge_only(all_nodes)
                planner.account_node_scan(0, n - 1)
                _replay_kernel_blocks(tally, rs, be, nb, all_nodes)
            if bool(done_j):
                break
        return result(core_j, None)

    if algorithm == "semicore+":
        fn = _chunk_fns(kind, be, interpret, algorithm)
        active_j = jnp.ones((n,), jnp.bool_)
        while True:
            core_j, active_j, done, fronts, upds, ran = fn(
                core_j, active_j, rs.nbr_j, rs.rows_j, rs.segptr_j,
                num_probes=num_probes, num_segments=n, chunk=chunk)
            iters, comp = _replay_chunk(
                planner, rs, be, nb, tally, np.asarray(fronts),
                np.asarray(upds), np.asarray(ran), upd_hist, comp_hist,
                iters, comp)
            if bool(done):
                break
        return result(core_j, None)

    raise ValueError(f"unknown algorithm {algorithm!r}")


def _replay_chunk(planner, rs, be, nb, tally, fronts, upds, ran,
                  upd_hist, comp_hist, iters, comp):
    """Replay the planner charges for the executed passes of one chunk."""
    for k in range(len(ran)):
        if not ran[k]:
            break
        frontier = np.flatnonzero(fronts[k]).astype(np.int64)
        iters += 1
        comp += len(frontier)
        upd_hist.append(int(upds[k]))
        comp_hist.append(int(len(frontier)))
        _replay_pass(planner, frontier, tally, rs, be, nb)
    return iters, comp
