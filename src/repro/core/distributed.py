"""SPMD semi-external core decomposition over a TPU mesh (DESIGN.md §2, §5).

The paper's memory contract maps onto the pod as:

  * edge table  -> per-device CSR shards of *contiguous node ranges* balanced
    by edge count (the paper's sequential adjacency layout, so every owned
    node's LocalCore needs only local edges — no cross-device count reduction);
  * node state  -> the replicated ``core`` array, O(n) per device — the
    semi-external memory bound (Clueweb: 978M * 4B = 3.9 GB/device, the
    paper's "< 4.2 GB" headline number);
  * one pass    -> one superstep: local h-index refresh of owned nodes
    (Jacobi), then an ``all_gather`` of the owned slices (O(n) over ICI,
    the read-only-I/O discipline: edge shards never move).

LocalCore (Eq. 1) is evaluated as a vectorized *binary search* over k with a
segment-sum count per probe (log2(max_deg) probes/superstep), optionally gated
by the SemiCore* cnt rule (cnt(v) < core(v), Lemma 4.2), which is computed
locally for owned nodes (one extra segment-sum) since ``core`` is replicated.

Convergence from above is schedule-free (Thm 4.1 locality), so Jacobi
supersteps reach the same fixpoint as the paper's sequential passes; any
intermediate ``core`` is a valid warm restart (free crash consistency).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from ..compat.jaxshims import shard_map

from ..graph.storage import CSRGraph
from .engine import hindex_bucketed
from .resident import fused_counts, fused_hindex

__all__ = ["ShardedGraph", "shard_graph", "sharded_graph_specs", "distributed_decompose"]


@dataclass
class ShardedGraph:
    """Stacked per-shard CSR arrays (leading dim = number of shards)."""

    dst: np.ndarray        # (S, E) int32  — edge targets, padded
    rows: np.ndarray       # (S, E) int32  — local owner-row per edge
    edge_mask: np.ndarray  # (S, E) bool
    owned_ids: np.ndarray  # (S, V) int32  — global node id per local slot (pad -> n)
    owned_mask: np.ndarray # (S, V) bool
    deg: np.ndarray        # (n,)  int32   — global degrees (core init)
    n: int
    num_probes: int        # binary-search probes = ceil(log2(max_deg + 1))

    def device_arrays(self) -> dict:
        return dict(
            dst=self.dst, rows=self.rows, edge_mask=self.edge_mask,
            owned_ids=self.owned_ids, owned_mask=self.owned_mask,
        )


def shard_graph(graph: CSRGraph, num_shards: int) -> ShardedGraph:
    """Contiguous node-range shards balanced by (directed) edge count."""
    n = graph.n
    indptr = graph.indptr
    total = graph.num_directed
    # balanced contiguous ranges: node v goes to shard indptr[v] * S / total
    cuts = np.searchsorted(indptr[1:], np.arange(1, num_shards) * total / num_shards)
    bounds = np.concatenate([[0], cuts, [n]]).astype(np.int64)
    max_nodes = int(max(1, (np.diff(bounds)).max()))
    max_edges = int(
        max(1, (indptr[bounds[1:]] - indptr[bounds[:-1]]).max())
    )
    S = num_shards
    dst = np.zeros((S, max_edges), dtype=np.int32)
    rows = np.zeros((S, max_edges), dtype=np.int32)
    emask = np.zeros((S, max_edges), dtype=bool)
    owned = np.full((S, max_nodes), n, dtype=np.int32)
    omask = np.zeros((S, max_nodes), dtype=bool)
    for s in range(S):
        lo, hi = bounds[s], bounds[s + 1]
        e0, e1 = int(indptr[lo]), int(indptr[hi])
        ne, nv = e1 - e0, int(hi - lo)
        dst[s, :ne] = graph.adj[e0:e1]
        local_deg = np.diff(indptr[lo : hi + 1]).astype(np.int64)
        rows[s, :ne] = np.repeat(np.arange(nv, dtype=np.int32), local_deg)
        emask[s, :ne] = True
        owned[s, :nv] = np.arange(lo, hi, dtype=np.int32)
        omask[s, :nv] = True
    deg = graph.degrees().astype(np.int32)
    # core(v) <= ceil(sqrt(2m)) always (a k-core needs k+1 nodes of degree
    # >= k), so the degree init can be capped: fewer binary-search probes
    # and faster convergence for skewed graphs (EXPERIMENTS §Perf).
    kbound = int(np.sqrt(graph.num_directed)) + 1
    deg = np.minimum(deg, kbound).astype(np.int32)
    dmax = int(deg.max()) if n else 0
    return ShardedGraph(
        dst=dst, rows=rows, edge_mask=emask, owned_ids=owned, owned_mask=omask,
        deg=deg, n=n, num_probes=max(1, int(np.ceil(np.log2(dmax + 2)))),
    )


def sharded_graph_specs(
    n: int, m_directed: int, num_shards: int, max_deg: int
) -> tuple[dict, int, int]:
    """ShapeDtypeStructs for a graph of the given scale (dry-run path)."""
    V = -(-n // num_shards) + 1
    E = int(m_directed / num_shards * 1.05) + 8  # balanced-cut slack
    S = num_shards
    sds = jax.ShapeDtypeStruct
    specs = dict(
        dst=sds((S, E), jnp.int32),
        rows=sds((S, E), jnp.int32),
        edge_mask=sds((S, E), jnp.bool_),
        owned_ids=sds((S, V), jnp.int32),
        owned_mask=sds((S, V), jnp.bool_),
    )
    kbound = int(np.sqrt(m_directed)) + 1
    probes = max(1, int(np.ceil(np.log2(min(max_deg, kbound) + 2))))
    return specs, probes, V


# ---------------------------------------------------------------------------
# device-local superstep pieces (run per shard inside shard_map).  The actual
# gather + count / h-index math is the shared *fused* superstep code in
# core/resident.py — the same body the device-resident host engine scans its
# full table with — applied to the shard's local edge arrays.
# ---------------------------------------------------------------------------
def _xla_segment_sum(vals, rows, num_segments):
    return jax.ops.segment_sum(vals, rows, num_segments=num_segments)


def _local_counts(core, dst, rows, edge_mask, thresholds, num_rows):
    """#{local edges (v,u) : core[u] >= thresholds[row(v)]} per owned row."""
    return fused_counts(core, dst, rows, edge_mask, thresholds, num_rows,
                        segment_sum_fn=_xla_segment_sum)


def _local_hindex(core, dst, rows, edge_mask, c_old, num_probes):
    """Vectorized binary search for h = max k <= c_old with count_ge(k) >= k.

    REPRO_UNROLL_SCANS=1 unrolls the probes so cost analysis sees every scan
    (launch/dryrun.py sets it at trace time).
    """
    return fused_hindex(
        core, dst, rows, edge_mask, c_old, num_probes,
        segment_sum_fn=_xla_segment_sum,
        unroll=os.environ.get("REPRO_UNROLL_SCANS") == "1")


def build_decompose_fn(
    mesh: Mesh,
    n: int,
    num_probes: int,
    star_gating: bool = True,
    max_supersteps: int = 10_000,
    optimized: bool = True,
    gather_dtype=None,
    method: str = "bsearch",
):
    """jit'd distributed decomposition: (core0, shard arrays) -> (core, iters).

    Shards ride the flattened mesh (every axis), core is replicated.

    ``optimized`` (beyond-paper, EXPERIMENTS §Perf): hoists the (static)
    owned-id all-gather out of the superstep loop — the per-superstep ICI
    traffic drops from 2 x n x 4 B to n x |gather_dtype| B — and allows a
    compact ``gather_dtype`` (int16 when the initial upper bound fits).
    """
    axes = tuple(mesh.axis_names)
    shard_spec = P(axes)  # leading dim split over all axes jointly
    repl = P()
    gdt = gather_dtype or jnp.int32

    def whole(core0, dst, rows, edge_mask, owned_ids, owned_mask):
        dst = dst[0]; rows = rows[0]; edge_mask = edge_mask[0]
        owned_ids = owned_ids[0]; owned_mask = owned_mask[0]
        num_rows = owned_ids.shape[0]
        if optimized:
            # static scatter index: gathered ONCE, not every superstep
            owned_flat = jax.lax.all_gather(owned_ids, axes, tiled=True)

        def superstep(core):
            c_old = jnp.where(owned_mask, jnp.take(core, owned_ids, mode="clip"), 0)
            if star_gating:
                # SemiCore* rule (Lemma 4.2): recompute only if cnt < core.
                cnt = _local_counts(core, dst, rows, edge_mask, c_old, num_rows)
                frontier = (cnt < c_old) & owned_mask
            else:
                frontier = owned_mask
            if method == "bucket":
                h = _local_hindex_bucketed(core, dst, rows, edge_mask, c_old,
                                           owned_mask)
            else:
                h = _local_hindex(core, dst, rows, edge_mask, c_old, num_probes)
            c_new = jnp.where(frontier, jnp.minimum(h, c_old), c_old)
            changed = jax.lax.psum(
                jnp.sum((c_new != c_old).astype(jnp.int32)), axes)
            if optimized:
                gathered = jax.lax.all_gather(
                    c_new.astype(gdt), axes, tiled=True).astype(core.dtype)
                ids = owned_flat
            else:  # paper-faithful baseline combine (ids re-gathered)
                gathered = jax.lax.all_gather(c_new, axes, tiled=True)
                ids = jax.lax.all_gather(owned_ids, axes, tiled=True)
            new_core = jnp.zeros((n + 1,), core.dtype).at[ids].set(gathered)
            return new_core[:n], changed

        def cond(state):
            _, changed, it = state
            return (changed > 0) & (it < max_supersteps)

        def body(state):
            core, _, it = state
            core, changed = superstep(core)
            return core, changed, it + 1

        core, _, iters = jax.lax.while_loop(
            cond, body, (core0, jnp.int32(1), jnp.int32(0)))
        return core, iters

    sharded = shard_map(
        whole,
        mesh=mesh,
        in_specs=(repl, shard_spec, shard_spec, shard_spec, shard_spec, shard_spec),
        out_specs=(repl, repl),
        check_vma=False,
    )
    in_shardings = tuple(
        NamedSharding(mesh, s)
        for s in (repl, shard_spec, shard_spec, shard_spec, shard_spec, shard_spec)
    )
    return jax.jit(
        sharded,
        in_shardings=in_shardings,
        out_shardings=NamedSharding(mesh, repl),
    )


def _local_hindex_bucketed(core, dst, rows, edge_mask, c_old, owned_mask):
    """Single-pass h-index (O(E + V) per superstep): the shared
    engine.hindex_bucketed op over the shard's gathered neighbor cores —
    the §Perf memory-term optimization."""
    return hindex_bucketed(
        jnp.take(core, dst, mode="clip"), rows, edge_mask, c_old, owned_mask)


def build_superstep_fn(
    mesh: Mesh,
    n: int,
    num_probes: int,
    star_gating: bool = True,
    optimized: bool = True,
    gather_dtype=None,
    method: str = "bsearch",
):
    """One superstep as its own jit — the §Perf measurement unit (its HLO
    contains exactly the per-superstep collectives, no while-body ambiguity).

    ``optimized`` superstep takes the static gathered id map as an *input*
    (hoisted out of the iteration); baseline re-gathers ids every superstep.
    """
    axes = tuple(mesh.axis_names)
    shard_spec = P(axes)
    repl = P()
    gdt = gather_dtype or jnp.int32

    def one(core, dst, rows, edge_mask, owned_ids, owned_mask, owned_flat):
        dst = dst[0]; rows = rows[0]; edge_mask = edge_mask[0]
        owned_ids = owned_ids[0]; owned_mask = owned_mask[0]
        num_rows = owned_ids.shape[0]
        c_old = jnp.where(owned_mask, jnp.take(core, owned_ids, mode="clip"), 0)
        if star_gating:
            cnt = _local_counts(core, dst, rows, edge_mask, c_old, num_rows)
            frontier = (cnt < c_old) & owned_mask
        else:
            frontier = owned_mask
        if method == "bucket":
            h = _local_hindex_bucketed(core, dst, rows, edge_mask, c_old,
                                       owned_mask)
        else:
            h = _local_hindex(core, dst, rows, edge_mask, c_old, num_probes)
        c_new = jnp.where(frontier, jnp.minimum(h, c_old), c_old)
        changed = jax.lax.psum(jnp.sum((c_new != c_old).astype(jnp.int32)), axes)
        if optimized:
            gathered = jax.lax.all_gather(
                c_new.astype(gdt), axes, tiled=True).astype(core.dtype)
            ids = owned_flat
        else:
            gathered = jax.lax.all_gather(c_new, axes, tiled=True)
            ids = jax.lax.all_gather(owned_ids, axes, tiled=True)
        new_core = jnp.zeros((n + 1,), core.dtype).at[ids].set(gathered)
        return new_core[:n], changed

    sharded = shard_map(
        one, mesh=mesh,
        in_specs=(repl, shard_spec, shard_spec, shard_spec, shard_spec,
                  shard_spec, repl),
        out_specs=(repl, repl),
        check_vma=False,
    )
    shardings = tuple(NamedSharding(mesh, s) for s in
                      (repl, shard_spec, shard_spec, shard_spec, shard_spec,
                       shard_spec, repl))
    return jax.jit(sharded, in_shardings=shardings,
                   out_shardings=NamedSharding(mesh, repl))


def distributed_decompose(
    graph: CSRGraph,
    mesh: Mesh | None = None,
    star_gating: bool = True,
    core0: np.ndarray | None = None,
    method: str = "bsearch",
):
    """Host entry point: shard, run to convergence, return (core, supersteps).

    With ``core0`` given (e.g. a checkpointed intermediate state or the
    post-deletion upper bounds), performs a warm restart — monotone
    convergence makes any upper-bound state a valid init (fault tolerance).
    """
    if mesh is None:
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(len(dev)), ("shard",))
    S = int(np.prod(mesh.devices.shape))
    sg = shard_graph(graph, S)
    fn = build_decompose_fn(mesh, sg.n, sg.num_probes, star_gating,
                            method=method)
    init = sg.deg if core0 is None else np.asarray(core0, dtype=np.int32)
    core, iters = fn(
        jnp.asarray(init, dtype=jnp.int32),
        sg.dst, sg.rows, sg.edge_mask, sg.owned_ids, sg.owned_mask,
    )
    return np.asarray(core), int(iters)
