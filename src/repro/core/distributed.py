"""Sharded graph layout for the mesh backend (DESIGN.md §5, §13).

The paper's memory contract maps onto a device mesh as:

  * edge table  -> per-device CSR shards of *contiguous node ranges* balanced
    by edge count (the paper's sequential adjacency layout, so every owned
    node's LocalCore needs only local edges — no cross-device count reduction);
  * node state  -> the replicated ``core`` array, O(n) per device — the
    semi-external memory bound (Clueweb: 978M * 4B = 3.9 GB/device, the
    paper's "< 4.2 GB" headline number);
  * one pass    -> one superstep: local h-index refresh of owned nodes
    (Jacobi), then an ``all_gather`` of the owned slices (O(n) over ICI,
    the read-only-I/O discipline: edge shards never move).

This module is the *layout* half of that contract: :func:`shard_arrays` cuts
a flat CSR into stacked per-shard arrays (minimax-balanced contiguous ranges,
int32-validated like ``resident.build_structure``), :func:`shard_graph` wraps
it for a plain :class:`CSRGraph`, and :func:`sharded_graph_specs` produces
the matching ShapeDtypeStructs for the dry-run cost-analysis path.

The *execution* half lives in the engine since the shard ComputeBackend
landed (DESIGN.md §13): :class:`repro.core.engine.ShardedBackend` binds a
:class:`~repro.core.resident.ShardedStructure` built from these arrays and
runs the whole fixpoint on-mesh through the shared fused superstep bodies
(``resident.fused_hindex`` / ``fused_counts``), pass-for-pass identical to
the numpy backend.  :func:`distributed_decompose` is kept as a thin wrapper
over that backend — its old private superstep builders are gone.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax

from ..graph.storage import CSRGraph

__all__ = [
    "ShardedGraph",
    "shard_arrays",
    "shard_graph",
    "balanced_bounds",
    "sharded_graph_specs",
    "distributed_decompose",
]


@dataclass
class ShardedGraph:
    """Stacked per-shard CSR arrays (leading dim = number of shards).

    ``lsegptr`` holds each shard's *local* CSR offsets over its padded edge
    axis (empty segments for padding slots), so the on-mesh superstep can run
    its segment reductions as sorted prefix sums instead of scatters.
    ``pad_edges`` / ``per_shard_edges`` surface the padding cost of the
    rectangular (S, E) layout; the minimax balance below keeps it minimal
    for contiguous ranges.
    """

    dst: np.ndarray        # (S, E) int32  — edge targets, padded
    rows: np.ndarray       # (S, E) int32  — local owner-row per edge
    edge_mask: np.ndarray  # (S, E) bool
    owned_ids: np.ndarray  # (S, V) int32  — global node id per local slot (pad -> n)
    owned_mask: np.ndarray # (S, V) bool
    lsegptr: np.ndarray    # (S, V+1) int32 — local flat-table offsets
    bounds: np.ndarray     # (S+1,) int64  — contiguous node-range cuts
    deg: np.ndarray        # (n,)  int32   — global degrees (core init)
    n: int
    num_probes: int        # binary-search probes = ceil(log2(max_deg + 2))
    pad_edges: int         # S * E - total directed edges (wasted slots)
    per_shard_edges: np.ndarray  # (S,) int64 — real edges per shard

    def device_arrays(self) -> dict:
        return dict(
            dst=self.dst, rows=self.rows, edge_mask=self.edge_mask,
            owned_ids=self.owned_ids, owned_mask=self.owned_mask,
            lsegptr=self.lsegptr,
        )


def _validate_int32(total_edges: int, n: int) -> None:
    """The device shard tables are int32 end-to-end (ids, rows, local
    offsets; jax x64 is off) — fail loudly instead of wrapping offsets
    negative and converging to a silently-wrong core array (the same
    guard ``resident.build_structure`` applies to the flat table)."""
    if total_edges >= (1 << 31) or n >= (1 << 31):
        raise ValueError(
            f"sharded edge table needs int32 offsets: 2m={total_edges} "
            f"n={n} exceeds 2**31; raise num_shards only splits the edge "
            "axis, not the id space — use the numpy backend for this graph")


def balanced_bounds(seg_ptr: np.ndarray, num_shards: int) -> np.ndarray:
    """Contiguous node-range cuts minimizing the max per-shard edge count.

    The rectangular (S, E) device layout pads every shard to the heaviest
    shard's edge count, so the balance objective is *minimax*, not
    mean-squared: binary-search the smallest feasible load L, with greedy
    feasibility via ``searchsorted`` (each range takes the longest prefix
    fitting in L; a node's adjacency never splits, and L >= max degree
    guarantees progress).  O(S log n log m).
    """
    n = len(seg_ptr) - 1
    S = max(1, int(num_shards))
    total = int(seg_ptr[-1])
    if n == 0:
        return np.zeros(S + 1, dtype=np.int64)
    deg = np.diff(seg_ptr)
    lo = max(int(deg.max()) if n else 0, -(-total // S))
    hi = total

    def cuts(L):
        bounds = np.empty(S + 1, dtype=np.int64)
        bounds[0] = 0
        cur = 0
        for s in range(S):
            if cur >= n:
                bounds[s + 1] = n
                continue
            nxt = int(np.searchsorted(seg_ptr, seg_ptr[cur] + L,
                                      side="right")) - 1
            bounds[s + 1] = cur = max(min(nxt, n), cur + 1)
        return bounds if bounds[-1] >= n else None

    while lo < hi:
        mid = (lo + hi) // 2
        if cuts(mid) is not None:
            hi = mid
        else:
            lo = mid + 1
    return cuts(lo)


def shard_arrays(adj: np.ndarray, seg_ptr: np.ndarray, num_shards: int,
                 n: int | None = None) -> ShardedGraph:
    """Cut a flat CSR (``adj`` targets, ``seg_ptr`` offsets) into stacked
    per-shard arrays over minimax-balanced contiguous node ranges."""
    n = len(seg_ptr) - 1 if n is None else int(n)
    seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
    total = int(seg_ptr[-1])
    _validate_int32(total, n)
    S = max(1, int(num_shards))
    bounds = balanced_bounds(seg_ptr, S)
    per_shard = (seg_ptr[bounds[1:]] - seg_ptr[bounds[:-1]]).astype(np.int64)
    max_nodes = int(max(1, np.diff(bounds).max() if n else 1))
    max_edges = int(max(1, per_shard.max()))
    dst = np.zeros((S, max_edges), dtype=np.int32)
    rows = np.zeros((S, max_edges), dtype=np.int32)
    emask = np.zeros((S, max_edges), dtype=bool)
    owned = np.full((S, max_nodes), n, dtype=np.int32)
    omask = np.zeros((S, max_nodes), dtype=bool)
    lseg = np.zeros((S, max_nodes + 1), dtype=np.int32)
    for s in range(S):
        lo_v, hi_v = int(bounds[s]), int(bounds[s + 1])
        e0, e1 = int(seg_ptr[lo_v]), int(seg_ptr[hi_v])
        ne, nv = e1 - e0, hi_v - lo_v
        dst[s, :ne] = adj[e0:e1]
        local_deg = np.diff(seg_ptr[lo_v: hi_v + 1]).astype(np.int64)
        rows[s, :ne] = np.repeat(np.arange(nv, dtype=np.int32), local_deg)
        emask[s, :ne] = True
        owned[s, :nv] = np.arange(lo_v, hi_v, dtype=np.int32)
        omask[s, :nv] = True
        lseg[s, : nv + 1] = (seg_ptr[lo_v: hi_v + 1] - e0).astype(np.int32)
        lseg[s, nv + 1:] = ne  # padding slots: empty trailing segments
    deg = np.diff(seg_ptr).astype(np.int32)
    dmax = int(deg.max()) if n else 0
    return ShardedGraph(
        dst=dst, rows=rows, edge_mask=emask, owned_ids=owned,
        owned_mask=omask, lsegptr=lseg, bounds=bounds, deg=deg, n=n,
        num_probes=max(1, int(np.ceil(np.log2(dmax + 2)))),
        pad_edges=S * max_edges - total, per_shard_edges=per_shard,
    )


def shard_graph(graph: CSRGraph, num_shards: int) -> ShardedGraph:
    """Contiguous node-range shards of a plain CSR, balanced by edge count."""
    return shard_arrays(np.asarray(graph.adj), graph.indptr, num_shards,
                        n=graph.n)


def sharded_graph_specs(
    n: int, m_directed: int, num_shards: int, max_deg: int
) -> tuple[dict, int, int]:
    """ShapeDtypeStructs matching the shard chunk-fn signature (dry-run path:
    ``resident.build_shard_chunk_fn``)."""
    import jax.numpy as jnp

    V = -(-n // num_shards) + 1
    E = int(m_directed / num_shards * 1.05) + 8  # balanced-cut slack
    S = num_shards
    sds = jax.ShapeDtypeStruct
    specs = dict(
        dst=sds((S, E), jnp.int32),
        rows=sds((S, E), jnp.int32),
        edge_mask=sds((S, E), jnp.bool_),
        lsegptr=sds((S, V + 1), jnp.int32),
        owned_ids=sds((S, V), jnp.int32),
        owned_mask=sds((S, V), jnp.bool_),
        cnt=sds((S, V), jnp.int32),
        active=sds((S, V), jnp.bool_),
        nactive=sds((), jnp.int32),
    )
    probes = max(1, int(np.ceil(np.log2(max_deg + 2))))
    return specs, probes, V


def distributed_decompose(
    graph: CSRGraph,
    mesh=None,
    star_gating: bool = True,
    core0: np.ndarray | None = None,
    max_supersteps: int | None = None,
):
    """Thin wrapper over the ``shard`` ComputeBackend (DESIGN.md §13):
    shard, run the on-mesh fixpoint, return (core, supersteps).

    With ``core0`` given (e.g. a checkpointed intermediate state or the
    post-deletion upper bounds), performs a warm restart: monotone
    convergence makes any upper-bound state a valid init, and the exact-cnt
    prologue (the warm-settle discipline) re-derives cnt on the mesh.
    ``max_supersteps`` budgets the run exactly — the returned core is then
    a valid upper-bound checkpoint rather than the fixpoint.
    """
    from .engine import ShardedBackend
    from .resident import run_resident
    from .semicore import HostEngine

    if mesh is not None:
        devices = list(mesh.devices.flat)  # honor the caller's device pick
        S = len(devices)
    else:
        devices = None
        S = len(jax.devices())
    backend = ShardedBackend(num_shards=S, devices=devices)
    eng = HostEngine(graph)
    if core0 is not None:
        warm = np.minimum(np.asarray(core0, dtype=np.int64),
                          eng.degrees()).astype(np.int64)
        r = run_resident(eng, "semicore*", backend, core=warm,
                         initial_cnt_scan=True, max_supersteps=max_supersteps)
    else:
        algo = "semicore*" if star_gating else "semicore"
        r = run_resident(eng, algo, backend, max_supersteps=max_supersteps)
    return np.asarray(r.core), int(r.iterations)
