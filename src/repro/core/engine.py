"""Schedule-agnostic superstep engine: one pass-planner, pluggable compute.

The paper's whole contribution is a single access discipline — scan node
state, refresh h-indices gated by ``cnt(v) < core(v)``, skip untouched edge
blocks — and this module is its single implementation (DESIGN.md §11):

* :class:`PassPlanner` owns everything *about* a pass that is not arithmetic:
  frontier selection, scan-range bookkeeping, and all :class:`BlockReader`
  I/O accounting (edge-block coverage of a frontier, node-table scans).  The
  planner's accounting is backend-independent, so every backend reports the
  same ``edge_block_reads`` / ``node_table_reads`` trace for the same run —
  and the numpy backend's trace is bit-identical to the historical
  ``HostEngine`` batch loops it replaced.

* :class:`ComputeBackend` is the arithmetic: three ops over flattened CSR
  segments — ``h_index(vals, seg_ptr, c_old)`` (LocalCore, Eq. 1, capped at
  the old value), ``compute_cnt(vals, seg_ptr, thresholds)`` (Eq. 2), and
  ``push_decrements`` (the UpdateNbrCnt push rule).  All three are exact
  integer computations, so every backend converges through *identical*
  passes to the identical fixpoint.

* Backends: :class:`NumpyBackend` (the vectorized host reference from
  ``localcore.py``), :class:`XLABackend` (jit'd binary-search h-index over
  ``jax.ops.segment_sum``), :class:`PallasBackend` (h-index probes
  through ``kernels.ops.segment_sum_active``: the frontier-derived
  block-activity mask skips the DMA of untouched edge blocks, the paper's
  I/O saving expressed at the HBM->VMEM level; skipped blocks are reported
  alongside ``edge_block_reads``), and :class:`ShardedBackend` (the mesh
  substrate, DESIGN.md §13: per-device contiguous edge shards, replicated
  O(n) core, one ``all_gather`` of owned slices per superstep — the whole
  fixpoint runs on-mesh through ``resident.run_sharded``).

``push_decrements`` deliberately has a host-side default: cnt is O(n) node
state held *in memory* in the paper's model, and the push rule only touches
cnt using adjacency already scanned by the same pass — it is node-state
bookkeeping, not edge I/O.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

from .. import runtime as _runtime
from ..obs import metrics as _metrics, trace as _trace
from .localcore import h_index_batch, compute_cnt_batch

__all__ = [
    "DecompResult",
    "PassPlanner",
    "ComputeBackend",
    "NumpyBackend",
    "XLABackend",
    "PallasBackend",
    "ShardedBackend",
    "resolve_backend",
    "run_batch",
    "warm_settle",
    "edge_ge_counts",
    "hindex_bsearch",
    "hindex_bucketed",
    "BACKEND_ENV_VAR",
]

BACKEND_ENV_VAR = "REPRO_BACKEND"

# Registry mirrors of the pallas block-activity tallies (DESIGN.md §14);
# incremented at the same sites as the backend's own counters (begin_pass
# here, the pinned-mask replay in resident.py) so registry deltas reconcile
# exactly with DecompResult.kernel_blocks_active/skipped.
_KB_ACTIVE = _metrics.counter(
    "repro_kernel_blocks_active_total",
    "Pallas kernel blocks whose DMA was issued, summed over passes",
).labels()
_KB_SKIPPED = _metrics.counter(
    "repro_kernel_blocks_skipped_total",
    "Pallas kernel blocks skipped by the frontier activity mask",
).labels()

_MAINT_PROLOGUE = _metrics.histogram(
    "repro_maintenance_cnt_prologue_seconds",
    "Exact-cnt full-scan prologue cost of warm settles (Eq. 2 over all nodes)",
)


def _pass_obs(algorithm: str, backend_name: str, schedule: str = "batch"):
    """The per-pass counter series for one (algorithm, backend, schedule):
    (passes, frontier nodes, core updates).  Hoisted out of the superstep
    loops so each pass costs three plain ``inc`` calls."""
    lab = dict(algorithm=algorithm, backend=backend_name, schedule=schedule)
    return (
        _metrics.counter(
            "repro_engine_passes_total",
            "Supersteps executed (== DecompResult.iterations per run)",
        ).labels(**lab),
        _metrics.counter(
            "repro_engine_frontier_nodes_total",
            "Nodes recomputed, summed over passes (== node_computations)",
        ).labels(**lab),
        _metrics.counter(
            "repro_engine_updates_total",
            "Core-value updates, summed over passes",
        ).labels(**lab),
    )


def _kernel_counts(backend) -> tuple:
    return (getattr(backend, "kernel_blocks_active", 0),
            getattr(backend, "kernel_blocks_skipped", 0))


def _finish_pass_span(sp, backend, c_old_f, changed, ka0, ks0) -> None:
    """Attach pass args shown in the Perfetto side panel: updates, h-index
    probe depth (ceil(log2(cmax+2)) — the device backends' binary-search
    scan count for this frontier), and pallas block activity."""
    cmax = int(c_old_f.max()) if len(c_old_f) else 0
    sp.set(updates=int(changed),
           hindex_probes=int(np.ceil(np.log2(cmax + 2))) if cmax else 0)
    ka1, ks1 = _kernel_counts(backend)
    if (ka1 - ka0) or (ks1 - ks0):
        sp.set(kernel_blocks_active=ka1 - ka0,
               kernel_blocks_skipped=ks1 - ks0)


@dataclass
class DecompResult:
    core: np.ndarray
    cnt: np.ndarray | None
    iterations: int
    node_computations: int
    edge_block_reads: int
    node_table_reads: int
    algorithm: str
    schedule: str
    updates_per_iter: list = field(default_factory=list)
    computations_per_iter: list = field(default_factory=list)
    backend: str = "numpy"
    # Pallas backend only: per-pass kernel-block activity (DESIGN.md §11).
    # Active + skipped = total kernel blocks summed over passes; skipped
    # blocks issue no HBM->VMEM DMA (segsum_active.py).
    kernel_blocks_active: int = 0
    kernel_blocks_skipped: int = 0
    # Shard backend only (DESIGN.md §13): mesh width and the padding cost of
    # the rectangular (S, E) shard layout (slots wasted by balancing all
    # shards to the heaviest one's edge count).
    num_shards: int = 0
    shard_pad_edges: int = 0

    @property
    def kmax(self) -> int:
        return int(self.core.max()) if len(self.core) else 0

    @property
    def memory_bytes(self) -> int:
        """O(n) node-state bytes held in memory (the paper's bound)."""
        per_node = 8 + (8 if self.cnt is not None else 0) + 1
        return len(self.core) * per_node


# ===========================================================================
# Shared jittable ops (consumed by XLABackend AND the SPMD engine)
# ===========================================================================
def edge_ge_counts(nbr_vals, rows, edge_mask, thresholds, num_segments,
                   *, segment_sum_fn):
    """#{edges e : nbr_vals[e] >= thresholds[rows[e]]} per segment (Eq. 2).

    Traceable under jit; ``segment_sum_fn(vals, rows, num_segments)`` selects
    the reduction substrate (``jax.ops.segment_sum`` for XLA/SPMD, the Pallas
    blocked kernel for the TPU path).
    """
    import jax.numpy as jnp

    ok = (nbr_vals >= jnp.take(thresholds, rows, mode="clip")) & edge_mask
    return segment_sum_fn(ok.astype(jnp.int32), rows, num_segments)


def hindex_bsearch(nbr_vals, rows, edge_mask, c_old, num_probes,
                   *, segment_sum_fn, unroll: bool = False):
    """Vectorized binary search for h = max k <= c_old with count_ge(k) >= k.

    Exactly LocalCore (Eq. 1) capped at ``c_old``: count_ge is non-increasing
    in k, so the feasibility predicate is monotone and the search converges
    to ``min(h_index, c_old)`` in ``num_probes`` segment-sum scans.
    ``unroll`` expands the probe loop so cost analysis sees every scan
    (REPRO_UNROLL_SCANS, launch/dryrun.py).
    """
    import jax
    import jax.numpy as jnp

    num_rows = c_old.shape[0]
    lo = jnp.zeros_like(c_old)
    hi = c_old

    def probe(_, state):
        lo, hi = state
        mid = (lo + hi + 1) // 2
        cnt = edge_ge_counts(nbr_vals, rows, edge_mask, mid, num_rows,
                             segment_sum_fn=segment_sum_fn)
        ok = (cnt >= mid) & (mid > 0)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    if unroll:
        state = (lo, hi)
        for i in range(num_probes):
            state = probe(i, state)
        lo, hi = state
    else:
        lo, hi = jax.lax.fori_loop(0, num_probes, probe, (lo, hi))
    return lo


def hindex_bucketed(nbr_vals, rows, edge_mask, c_old, owned_mask):
    """Single-pass h-index: bucketed histogram + segmented suffix counts.

    O(E + V) per superstep instead of log2(kmax) masked edge scans — the
    §Perf memory-term optimization of the SPMD engine.  Buckets: node v owns
    positions [off[v], off[v] + c_old[v]] holding counts of
    min(nbr_vals, c_old(v)); suffix counts come from one global cumsum;
    h(v) = max k with s >= k.
    """
    import jax
    import jax.numpy as jnp

    V = c_old.shape[0]
    E = rows.shape[0]
    width = c_old + 1
    ends = jnp.cumsum(width)
    off = ends - width                      # exclusive offsets
    B = E + V + 1                           # static bucket-buffer bound
    capped = jnp.minimum(nbr_vals, jnp.take(c_old, rows, mode="clip"))
    idx = jnp.take(off, rows, mode="clip") + capped
    idx = jnp.where(edge_mask, idx, B - 1)  # masked edges -> dump slot
    hist = jnp.zeros((B,), jnp.int32).at[idx].add(1)
    g = jnp.cumsum(hist)                    # inclusive prefix counts
    # evaluate every bucket position: position p belongs to node v_of(p),
    # candidate k = p - off[v]; s = g[end_v - 1] - g[p - 1]
    pos = jnp.arange(B, dtype=jnp.int32)
    v_of = jnp.clip(jnp.searchsorted(ends, pos, side="right"), 0, V - 1)
    k = pos - jnp.take(off, v_of)
    end_idx = jnp.take(ends, v_of) - 1
    g_prev = jnp.where(pos > 0, jnp.take(g, jnp.maximum(pos - 1, 0)), 0)
    s = jnp.take(g, end_idx) - g_prev
    valid = (k >= 1) & (k <= jnp.take(c_old, v_of)) & (s >= k) & (
        pos < ends[V - 1]) & jnp.take(owned_mask, v_of)
    return jax.ops.segment_max(
        jnp.where(valid, k, 0), v_of, num_segments=V)


@lru_cache(maxsize=None)
def _pallas_full_ops(block_edges: int, interpret: bool):
    """jit'd full-table scans for the pallas backend: the shared
    :func:`hindex_bsearch` / :func:`edge_ge_counts` probe code with
    ``segment_sum_active`` as the reduction substrate, so the frontier's
    block-activity mask gates every probe's DMA and the whole probe loop
    (neighbor gather included) is one traced computation per pass."""
    from functools import partial

    import jax
    import jax.numpy as jnp

    from ..kernels.ops import segment_sum_active

    def segsum(vals, rows, num_segments, *, node_active):
        return segment_sum_active(vals, rows, node_active, num_segments,
                                  block_edges=block_edges, interpret=interpret)

    @partial(jax.jit, static_argnames=("num_probes", "num_segments"))
    def hindex(core0, nbr, rows, node_active, c_old, num_probes, num_segments):
        nbr_vals = jnp.take(core0, nbr, mode="clip")
        mask = jnp.ones(rows.shape, jnp.bool_)
        return hindex_bsearch(
            nbr_vals, rows, mask, c_old, num_probes,
            segment_sum_fn=partial(segsum, node_active=node_active))

    @partial(jax.jit, static_argnames=("num_segments",))
    def counts(core0, nbr, rows, node_active, thresholds, num_segments):
        nbr_vals = jnp.take(core0, nbr, mode="clip")
        mask = jnp.ones(rows.shape, jnp.bool_)
        return edge_ge_counts(
            nbr_vals, rows, mask, thresholds, num_segments,
            segment_sum_fn=partial(segsum, node_active=node_active))

    return hindex, counts


@lru_cache(maxsize=None)
def _xla_host_ops():
    """jit'd host-side wrappers over the shared ops (built lazily so the
    numpy-only path never imports jax)."""
    from functools import partial

    import jax

    def segsum(vals, rows, num_segments):
        return jax.ops.segment_sum(vals, rows, num_segments=num_segments)

    @partial(jax.jit, static_argnames=("num_probes",))
    def hindex(nbr_vals, rows, edge_mask, c_old, num_probes):
        return hindex_bsearch(nbr_vals, rows, edge_mask, c_old, num_probes,
                              segment_sum_fn=segsum)

    @jax.jit
    def counts(nbr_vals, rows, edge_mask, thresholds):
        return edge_ge_counts(nbr_vals, rows, edge_mask, thresholds,
                              thresholds.shape[0], segment_sum_fn=segsum)

    return hindex, counts


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


# ===========================================================================
# Compute backends
# ===========================================================================
class ComputeBackend:
    """Arithmetic of one superstep over flattened CSR segments.

    ``vals``/``seg_ptr`` follow the ``PassPlanner.gather`` layout: ``vals``
    holds the neighbor core values of the P frontier nodes segment-contiguous,
    ``seg_ptr`` the (P+1,) offsets.  All ops are exact over integers, so
    backends are interchangeable pass-for-pass.
    """

    name = "abstract"
    # whether the backend reads the gathered (vals, seg_ptr) arrays; a
    # full-table backend (pallas) can skip the host gather where the driver
    # needs nothing but the I/O charge (plain SemiCore).
    consumes_gather = True
    # device backends run the whole fixpoint device-resident (resident.py):
    # node state + edge table uploaded once, many passes per host round-trip
    # (REPRO_DEVICE_RESIDENT=0 falls back to the per-pass loop below).
    device_resident = False

    # -- lifecycle hooks (no-ops by default) --------------------------------
    def bind(self, planner: "PassPlanner") -> None:
        """Called once per run, before the first pass."""

    def unbind(self) -> None:
        """Called when a run's result is built; drop any bound working set."""

    def begin_pass(self, frontier: np.ndarray, core: np.ndarray) -> None:
        """Called at the start of every pass with the frontier node ids and
        the pass-start core array (before any in-pass mutation)."""

    def io_report(self) -> dict:
        """Backend-side I/O effects (e.g. skipped kernel blocks)."""
        return {}

    # -- ops ----------------------------------------------------------------
    def h_index(self, vals: np.ndarray, seg_ptr: np.ndarray,
                c_old: np.ndarray) -> np.ndarray:
        """min(h-index of each segment, c_old) — LocalCore (Eq. 1)."""
        raise NotImplementedError

    def compute_cnt(self, vals: np.ndarray, seg_ptr: np.ndarray,
                    thresholds: np.ndarray) -> np.ndarray:
        """#{u in segment : vals(u) >= threshold(segment)} — Eq. 2."""
        raise NotImplementedError

    def push_decrements(self, nbr_flat: np.ndarray, seg_ptr: np.ndarray,
                        h: np.ndarray, c_old: np.ndarray, core: np.ndarray,
                        n: int) -> np.ndarray:
        """UpdateNbrCnt push rule: dec[u] = #{edges (v -> u) in the frontier
        adjacency : core_now(u) in (h(v), c_old(v)]}.

        Host-side by default for every backend: cnt is in-memory O(n) node
        state and the push reuses adjacency the pass already scanned — no
        edge I/O is involved (DESIGN.md §11).
        """
        lens = np.diff(seg_ptr)
        h_rep = np.repeat(h, lens)
        c_old_rep = np.repeat(c_old, lens)
        core_now_u = core[nbr_flat]
        mask = (core_now_u > h_rep) & (core_now_u <= c_old_rep)
        if mask.any():
            return np.bincount(nbr_flat[mask].astype(np.int64), minlength=n)
        return np.zeros(n, dtype=np.int64)


class NumpyBackend(ComputeBackend):
    """The vectorized host reference (localcore.py) — the historical batch
    schedule, preserved bit-for-bit."""

    name = "numpy"

    def h_index(self, vals, seg_ptr, c_old):
        return np.minimum(h_index_batch(vals, seg_ptr), c_old)

    def compute_cnt(self, vals, seg_ptr, thresholds):
        return compute_cnt_batch(vals, seg_ptr, thresholds)


class DeviceBackend(ComputeBackend):
    """Shared device-residency machinery of the xla / pallas backends.

    The flat merged edge table is built and uploaded once per *graph
    version* — a :class:`~repro.core.resident.ResidentStructure` keyed by
    the planner's structure token — and reused across runs, supersteps, and
    (on a long-lived ``CoreMaintainer`` with ``retain_structure``) across
    ``apply_batch`` calls whose batches turned out structure-free.  This is
    the fix for PR 3's per-pass re-upload (`XLABackend._pack`) and per-bind
    O(m) ``np.repeat`` rebuild (`PallasBackend.bind`): structure moves to
    the device exactly once per distinct graph version.

    ``retain_structure=False`` (the default) keeps the one-shot
    ``decompose`` memory guarantee: ``unbind`` drops the host + device
    edge-table copies when the result is built.
    """

    device_resident = True
    # set by long-lived owners (CoreMaintainer): keep the structure cache
    # across unbind so the next batch on an unchanged graph re-uploads nothing
    retain_structure = False

    def __init__(self):
        self._resident = None
        self.structure_builds = 0  # cache-miss counter (tests / bench)

    def bind_resident(self, planner: "PassPlanner"):
        """The device-resident working set for the planner's current graph
        version; cached, rebuilt only on structural change."""
        from .resident import build_structure

        planner.eng._sync()
        rs = self._resident
        if rs is not None and rs.matches(planner):
            return rs
        rs = build_structure(planner)
        self._validate_structure(rs)
        self.structure_builds += 1
        self._resident = rs
        return rs

    def _validate_structure(self, rs) -> None:
        """Backend-specific structure checks (pallas float32 range)."""

    def resident_substrate(self, planner: "PassPlanner") -> tuple:
        """(kind, block_edges, interpret) — the static key of the resident
        superstep jit for this backend."""
        raise NotImplementedError

    def release_resident(self) -> None:
        if not self.retain_structure:
            self._resident = None

    def unbind(self):
        self.release_resident()


class XLABackend(DeviceBackend):
    """jit'd binary-search h-index over ``jax.ops.segment_sum`` — the same
    shared ops (:func:`edge_ge_counts` / :func:`hindex_bsearch`) the SPMD
    engine consumes.

    The default path is device-resident (resident.py): the edge table is
    uploaded once at bind and the whole fixpoint runs on device.  The
    per-pass methods below remain as the legacy / direct-use path
    (``REPRO_DEVICE_RESIDENT=0``): they operate on host-gathered frontier
    segments padded to powers of two (edges and segments independently) so
    jit recompiles O(log) times per graph instead of once per frontier size.
    """

    name = "xla"

    def __init__(self):
        super().__init__()
        # one-slot pack memo: a SemiCore* pass calls h_index then compute_cnt
        # with the *same* (vals, seg_ptr) arrays — pack and ship them once.
        # Holding the key arrays keeps their ids valid for the identity test.
        self._pack_memo: tuple | None = None

    def resident_substrate(self, planner):
        return ("xla", 0, False)

    def _pack(self, vals, seg_ptr):
        import jax.numpy as jnp

        memo = self._pack_memo
        if memo is not None and memo[0] is vals and memo[1] is seg_ptr:
            return memo[2]
        P = len(seg_ptr) - 1
        lens = np.diff(seg_ptr)
        E = int(len(vals))
        Ep = _next_pow2(max(E, 1))
        rows = np.zeros(Ep, dtype=np.int32)
        rows[:E] = np.repeat(np.arange(P, dtype=np.int32), lens)
        mask = np.zeros(Ep, dtype=bool)
        mask[:E] = True
        v = np.zeros(Ep, dtype=np.int32)
        v[:E] = vals
        packed = (jnp.asarray(v), jnp.asarray(rows), jnp.asarray(mask))
        self._pack_memo = (vals, seg_ptr, packed)
        return packed

    def unbind(self):
        self._pack_memo = None
        self.release_resident()

    def h_index(self, vals, seg_ptr, c_old):
        P = len(seg_ptr) - 1
        c_old = np.asarray(c_old, dtype=np.int64)
        cmax = int(c_old.max()) if P else 0
        if P == 0 or len(vals) == 0 or cmax == 0:
            return np.zeros(P, dtype=np.int64)
        import jax.numpy as jnp

        hindex, _ = _xla_host_ops()
        v, rows, mask = self._pack(vals, seg_ptr)
        Pp = _next_pow2(P)
        c = np.zeros(Pp, dtype=np.int32)
        c[:P] = c_old
        num_probes = int(np.ceil(np.log2(cmax + 2)))
        h = hindex(v, rows, mask, jnp.asarray(c), num_probes)
        return np.asarray(h[:P]).astype(np.int64)

    def compute_cnt(self, vals, seg_ptr, thresholds):
        P = len(seg_ptr) - 1
        if P == 0 or len(vals) == 0:
            return np.zeros(P, dtype=np.int64)
        import jax.numpy as jnp

        _, counts = _xla_host_ops()
        v, rows, mask = self._pack(vals, seg_ptr)
        Pp = _next_pow2(P)
        thr = np.zeros(Pp, dtype=np.int32)
        thr[:P] = thresholds
        cnt = counts(v, rows, mask, jnp.asarray(thr))
        return np.asarray(cnt[:P]).astype(np.int64)


class PallasBackend(DeviceBackend):
    """The paper's block discipline at the kernel layer (DESIGN.md §6, §11).

    The full edge table lives as one flat blocked axis (HBM); every pass
    derives a block-activity mask from the frontier and runs the h-index
    probes / cnt scans through ``kernels.ops.segment_sum_active``, whose
    ``index_map`` re-points inactive blocks at an already-resident tile — no
    DMA is issued for them.  Skipped blocks are counted once per pass (the
    mask is fixed across the probes of a pass, mirroring the paper's one
    read I/O per touched block per pass) and reported on the result as
    ``kernel_blocks_skipped`` alongside the planner's ``edge_block_reads``.

    The default path runs the whole fixpoint device-resident (resident.py)
    with the block-activity mask derived on-device from the frontier state;
    the per-pass methods below serve the ``REPRO_DEVICE_RESIDENT=0`` legacy
    loop.  Either way the edge table is the shared
    :class:`~repro.core.resident.ResidentStructure` — built and uploaded
    once per graph version, not per bind (the old per-``apply_batch``
    O(m) ``np.repeat`` rebuild).

    The hot path fuses the whole superstep into ONE ``pallas_call``
    (``kernels.fused_superstep``, DESIGN.md §16): both the device-resident
    fixpoint and the legacy per-pass methods below dispatch a single fused
    kernel per pass instead of one ``segment_sum_active`` launch per
    h-index probe (``REPRO_PALLAS_FUSED=0`` restores the per-probe oracle).

    ``interpret=None`` (the default) auto-selects via
    ``kernels.default_interpret()``: compiled kernels on TPU/GPU hosts, the
    Pallas interpreter everywhere else (overridable with
    ``REPRO_PALLAS_INTERPRET``).  Accounting kernel blocks are capped at
    512 edges; the fused kernel's tile size is independently tunable via
    ``REPRO_FUSED_BLOCK_EDGES``.
    """

    name = "pallas"
    consumes_gather = False  # scans its own resident full table

    def __init__(self, *, block_edges: int | None = None,
                 interpret: bool | None = None):
        super().__init__()
        self.block_edges = block_edges
        self.interpret = interpret
        self.kernel_blocks_active = 0
        self.kernel_blocks_skipped = 0
        self.passes = 0

    def _resolve_interpret(self) -> bool:
        from ..kernels import resolve_interpret

        return resolve_interpret(self.interpret)

    def _block_edges(self, planner) -> int:
        be = self.block_edges or min(planner.reader.block_edges, 512)
        return max(1, int(be))

    def resident_substrate(self, planner):
        return ("pallas", self._block_edges(planner),
                self._resolve_interpret())

    def _validate_structure(self, rs) -> None:
        # the kernel accumulates per-node counts in float32 (one-hot matmul +
        # scatter epilogue, kernels/ops.py): exact only below 2**24 — fail
        # loudly instead of converging to a silently-wrong core array
        if rs.dmax >= (1 << 24):
            raise ValueError(
                f"pallas backend: max degree {rs.dmax} exceeds the float32 "
                "integer-exact range (2**24) of the blocked segment-sum "
                "kernel; use the xla or numpy backend for this graph"
            )

    # -- lifecycle ----------------------------------------------------------
    def bind(self, planner):
        self._interpret = self._resolve_interpret()
        # per-run report: active + skipped = total kernel blocks x passes
        self.kernel_blocks_active = 0
        self.kernel_blocks_skipped = 0
        self.passes = 0
        rs = self.bind_resident(planner)  # cached across unchanged versions
        self.n = planner.n
        self.E = rs.E
        self.seg_ptr = rs.seg_ptr  # flat-table offsets, for block coverage
        self.be = self._block_edges(planner)
        self.nb = -(-max(rs.E, 1) // self.be)
        self._nbr_j, self._rows_j = rs.edge_table("pallas")

    def unbind(self):
        # don't keep per-pass state alive on a long-lived maintainer between
        # runs; the version-keyed structure cache obeys retain_structure
        for attr in ("seg_ptr", "_rows_j", "_nbr_j",
                     "_core0_j", "_active_j", "_frontier", "_cnt_cache"):
            if hasattr(self, attr):
                delattr(self, attr)
        self.release_resident()

    def begin_pass(self, frontier, core):
        import jax.numpy as jnp

        self.passes += 1
        self._cnt_cache = None  # (thresholds, cnt) from the fused h_index
        self._core0_j = jnp.asarray(np.asarray(core, dtype=np.int32))
        active = np.zeros(self.n, dtype=bool)
        active[np.asarray(frontier, dtype=np.int64)] = True
        self._active_j = jnp.asarray(active)
        self._frontier = np.asarray(frontier, dtype=np.int64)
        if self.E:
            # block activity from the frontier's flat-table spans, O(F + nb)
            # (a kernel block is active iff some frontier node's contiguous
            # edge range covers it — same mask the kernel derives per-row)
            lo = self.seg_ptr[self._frontier]
            hi = self.seg_ptr[self._frontier + 1]
            nz = lo < hi
            cov = np.zeros(self.nb + 1, dtype=np.int64)
            if nz.any():
                np.add.at(cov, lo[nz] // self.be, 1)
                np.add.at(cov, (hi[nz] - 1) // self.be + 1, -1)
            na = int((np.cumsum(cov[:-1]) > 0).sum())
            self.kernel_blocks_active += na
            self.kernel_blocks_skipped += self.nb - na
            _KB_ACTIVE.inc(na)
            _KB_SKIPPED.inc(self.nb - na)

    def io_report(self):
        return {
            "kernel_blocks_active": self.kernel_blocks_active,
            "kernel_blocks_skipped": self.kernel_blocks_skipped,
        }

    # -- full-table scans ---------------------------------------------------
    # Hot path (REPRO_PALLAS_FUSED != 0): ONE pallas_call per superstep —
    # the fused kernel returns (h, cnt_at_h) together, so the SemiCore*
    # pass's compute_cnt(thresholds == h) is served from a per-pass cache
    # with no extra dispatch.  REPRO_PALLAS_FUSED=0 reverts to the PR 3
    # per-probe dispatch (_pallas_full_ops), kept as the parity oracle.
    def h_index(self, vals, seg_ptr, c_old):
        import jax.numpy as jnp

        F = len(self._frontier)
        c_old = np.asarray(c_old, dtype=np.int64)
        cmax = int(c_old.max()) if F else 0
        if F == 0 or cmax == 0 or self.E == 0:
            return np.zeros(F, dtype=np.int64)
        num_probes = int(np.ceil(np.log2(cmax + 2)))
        from ..kernels import fused_superstep as fsk

        if fsk.fused_enabled():
            ft = self._resident.fused(fsk.fused_block_edges(self.E))
            h_j, cnth_j = fsk.fused_hindex(
                self._core0_j, self._active_j, ft.arrays, dims=ft.dims,
                num_probes=num_probes, interpret=self._interpret)
            h = np.asarray(h_j).astype(np.int64)[self._frontier]
            self._cnt_cache = (
                h, np.asarray(cnth_j).astype(np.int64)[self._frontier])
            return h
        hindex, _ = _pallas_full_ops(self.be, self._interpret)
        hi = np.zeros(self.n, dtype=np.int32)
        hi[self._frontier] = c_old
        h = hindex(self._core0_j, self._nbr_j, self._rows_j, self._active_j,
                   jnp.asarray(hi), num_probes, self.n)
        return np.asarray(h).astype(np.int64)[self._frontier]

    def compute_cnt(self, vals, seg_ptr, thresholds):
        import jax.numpy as jnp

        F = len(self._frontier)
        if F == 0 or self.E == 0:
            return np.zeros(F, dtype=np.int64)
        thr = np.zeros(self.n, dtype=np.int32)
        thr[self._frontier] = thresholds
        from ..kernels import fused_superstep as fsk

        if fsk.fused_enabled():
            cache = getattr(self, "_cnt_cache", None)
            if cache is not None and np.array_equal(
                    cache[0], np.asarray(thresholds, dtype=np.int64)):
                return cache[1]
            tmax = int(np.max(thresholds)) if F else 0
            num_probes = max(1, int(np.ceil(np.log2(tmax + 2))))
            ft = self._resident.fused(fsk.fused_block_edges(self.E))
            cnt = fsk.fused_counts(
                self._core0_j, jnp.asarray(thr), self._active_j, ft.arrays,
                dims=ft.dims, num_probes=num_probes,
                interpret=self._interpret)
            return np.asarray(cnt).astype(np.int64)[self._frontier]
        _, counts = _pallas_full_ops(self.be, self._interpret)
        cnt = counts(self._core0_j, self._nbr_j, self._rows_j, self._active_j,
                     jnp.asarray(thr), self.n)
        return np.asarray(cnt).astype(np.int64)[self._frontier]


class ShardedBackend(DeviceBackend):
    """The mesh substrate: the paper's semi-external contract on a device
    mesh (DESIGN.md §5, §13).

    Edge shards never move: :func:`~repro.core.distributed.shard_arrays`
    cuts the merged flat table into contiguous node ranges minimax-balanced
    by edge count, so every owned node's complete adjacency is local and the
    h-index / cnt arithmetic needs no cross-device reduction.  Node state
    (``core``) is replicated O(n) per device — the "< 4.2 GB" headline bound.
    The whole fixpoint runs on-mesh (``resident.run_sharded``): one
    ``shard_map``'d fused superstep per pass (the same
    ``resident.fused_hindex`` / ``fused_counts`` bodies the flat resident
    path scans), ``lax.scan`` chunks of cond-gated passes per host
    round-trip, and a *single* ``all_gather`` of the owned core slices per
    superstep (plus one scalar ``psum`` for convergence).  The planner's I/O
    trace is replayed bit-identically on host from the per-chunk pinned
    owned-frontier slices, so the shard backend walks the exact numpy
    passes — the differential sweep asserts it at every shard count.

    The bound :class:`~repro.core.resident.ShardedStructure` is cached per
    base-CSR version exactly like the flat resident table: a long-lived
    ``CoreMaintainer`` re-binding after a no-op batch re-shards nothing.

    ``num_shards=None`` uses every visible device; the mesh spans
    ``jax.devices()[:num_shards]`` (``REPRO_NUM_SHARDS`` /
    ``CoreGraphConfig.num_shards`` select it by env / config).  There is no
    per-pass host fallback: the shard backend is resident-only
    (``REPRO_DEVICE_RESIDENT=0`` does not apply).
    """

    name = "shard"
    consumes_gather = False
    mesh_sharded = True      # run_resident dispatches to run_sharded
    requires_resident = True  # no per-pass legacy loop exists for this one

    def __init__(self, num_shards: int | None = None, devices=None):
        super().__init__()
        self.num_shards = None if num_shards is None else int(num_shards)
        # explicit device list (e.g. from a caller's Mesh): the mesh is
        # built over exactly these, letting multi-tenant hosts pin the run
        # to a device subset instead of always taking jax.devices()[:S]
        self.devices = None if devices is None else list(devices)

    def resolve_shards(self) -> int:
        import jax

        avail = len(self.devices if self.devices is not None
                    else jax.devices())
        S = avail if self.num_shards is None else self.num_shards
        if not 1 <= S <= avail:
            raise ValueError(
                f"shard backend: num_shards={S} but only {avail} device(s) "
                "are visible; force host devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N or "
                "lower CoreGraphConfig.num_shards / REPRO_NUM_SHARDS")
        return S

    def bind_resident(self, planner: "PassPlanner"):
        from .resident import build_sharded_structure

        planner.eng._sync()
        S = self.resolve_shards()
        rs = self._resident
        if rs is not None and rs.S == S and rs.matches(planner):
            return rs
        rs = build_sharded_structure(planner, S, devices=self.devices)
        self.structure_builds += 1
        self._resident = rs
        return rs


def resolve_backend(backend) -> ComputeBackend:
    """Backend instance passthrough, or by name; ``None`` defers to the
    ``REPRO_BACKEND`` environment variable (default: numpy), resolved
    through :func:`repro.runtime.setting` like every other knob."""
    if isinstance(backend, ComputeBackend):
        return backend
    if backend is None:
        backend = _runtime.setting("backend") or "numpy"
    name = str(backend)
    if name == "numpy":
        return NumpyBackend()
    if name == "xla":
        return XLABackend()
    if name == "pallas":
        return PallasBackend()
    if name == "pallas-interpret":
        return PallasBackend(interpret=True)
    if name == "shard":
        ns = os.environ.get("REPRO_NUM_SHARDS")
        return ShardedBackend(num_shards=int(ns) if ns else None)
    raise ValueError(f"unknown compute backend {backend!r}")


# ===========================================================================
# Pass planner: frontier / vrange / I/O accounting
# ===========================================================================
class PassPlanner:
    """Owns the I/O side of a pass over blocked storage.

    Wraps a :class:`HostEngine` (graph + BlockReader + update buffer) and
    provides the two primitives every batch schedule is made of: gather the
    frontier's flattened adjacency (charging exact block I/O), and account a
    node-table scan over the frontier's id range.  Compute never touches the
    reader; backends never touch the planner's accounting.
    """

    def __init__(self, engine):
        self.eng = engine

    @property
    def reader(self):
        return self.eng.reader

    @property
    def n(self) -> int:
        return self.eng.n

    # ------------------------------------------------------------- structure
    def _segments(self, nodes: np.ndarray):
        """Flattened raw-CSR adjacency of ``nodes`` (no I/O charge, no
        buffered-delta merge): (nbr_flat, seg_ptr, lo, hi)."""
        g = self.eng.graph
        lo = g.indptr[nodes]
        hi = g.indptr[nodes + 1]
        lens = (hi - lo).astype(np.int64)
        total = int(lens.sum())
        seg_ptr = np.zeros(len(nodes) + 1, dtype=np.int64)
        np.cumsum(lens, out=seg_ptr[1:])
        if total:
            flat = np.repeat(lo - seg_ptr[:-1], lens) + np.arange(
                total, dtype=np.int64)
            nbr_flat = np.asarray(g.adj)[flat]
        else:
            nbr_flat = np.empty(0, dtype=np.int32)
        return nbr_flat, seg_ptr, lo, hi

    def _merge_buffered(self, nodes, nbr_flat, seg_ptr):
        """Splice buffered edge deltas into the flattened segments (in-memory,
        no extra block I/O): locate the dirty nodes vectorized and rebuild
        only their segments, so a handful of buffered updates costs
        O(|dirty|) Python work plus the unavoidable flat-array copy."""
        buffered = self.eng.buffered
        if buffered is None or not buffered._size:
            return nbr_flat, seg_ptr
        dirty = np.fromiter(
            buffered._ins.keys() | buffered._del.keys(), dtype=np.int64)
        hit = np.flatnonzero(np.isin(nodes, dirty))
        if not len(hit):
            return nbr_flat, seg_ptr
        merged = [
            np.asarray(
                buffered.merged_neighbors(
                    int(nodes[i]), nbr_flat[seg_ptr[i]: seg_ptr[i + 1]]
                ),
                dtype=np.int32,
            )
            for i in hit
        ]
        new_lens = np.diff(seg_ptr)
        new_lens[hit] = [len(s) for s in merged]
        new_ptr = np.zeros(len(nodes) + 1, dtype=np.int64)
        np.cumsum(new_lens, out=new_ptr[1:])
        out = np.empty(int(new_ptr[-1]), dtype=np.int32)
        prev_old = 0
        prev_new = 0
        for seg, i in zip(merged, hit):
            span = int(seg_ptr[i]) - prev_old  # untouched run before i
            out[prev_new: prev_new + span] = nbr_flat[prev_old: prev_old + span]
            prev_new += span
            out[prev_new: prev_new + len(seg)] = seg
            prev_new += len(seg)
            prev_old = int(seg_ptr[i + 1])
        out[prev_new:] = nbr_flat[prev_old:]
        return out, new_ptr

    def full_structure(self):
        """Merged flat adjacency of *all* nodes, charge-free: the backend's
        HBM-resident working set (disk I/O stays per-pass, planner-side)."""
        self.eng._sync()
        nodes = np.arange(self.n, dtype=np.int64)
        nbr_flat, seg_ptr, _, _ = self._segments(nodes)
        return self._merge_buffered(nodes, nbr_flat, seg_ptr)[:2]

    # ------------------------------------------------------------------ I/O
    def charge_blocks(self, lo: np.ndarray, hi: np.ndarray) -> None:
        """Charge one pass over the union of [lo//B, (hi-1)//B] block
        intervals, streamed through the reader's buffer pool in ascending
        order (single buffer when pool_blocks == 1, LRU page cache
        otherwise)."""
        reader = self.reader
        B = reader.block_edges
        lens = hi - lo
        nz = lens > 0
        if nz.any():
            first = (lo[nz] // B).astype(np.int64)
            last = ((hi[nz] - 1) // B).astype(np.int64)
            nb = reader.num_blocks
            diff = np.zeros(nb + 1, dtype=np.int64)
            np.add.at(diff, first, 1)
            np.add.at(diff, last + 1, -1)
            covered = np.cumsum(diff[:-1]) > 0
            reader.charge_pass(np.flatnonzero(covered))

    def gather(self, nodes: np.ndarray, core: np.ndarray):
        """Flattened adjacency of ``nodes`` + exact block-I/O accounting.

        Returns (neighbor core values, segment offsets, flat neighbor ids).
        """
        self.eng._sync()
        nbr_flat, seg_ptr, lo, hi = self._segments(nodes)
        self.charge_blocks(lo, hi)
        nbr_flat, seg_ptr = self._merge_buffered(nodes, nbr_flat, seg_ptr)
        return core[nbr_flat], seg_ptr, nbr_flat

    def charge_only(self, nodes: np.ndarray) -> None:
        """The I/O charge of :meth:`gather` without materializing the
        adjacency — for passes whose backend scans its own resident table
        and the driver needs nothing but the accounting."""
        self.eng._sync()
        g = self.eng.graph
        self.charge_blocks(g.indptr[nodes], g.indptr[nodes + 1])

    def gather_structure(self, nodes: np.ndarray):
        """Like :meth:`gather` (same I/O charge, same merged segments) but
        without the neighbor-value fancy-index — for full-table backends
        that need only frontier structure (propagation, push rule).

        Returns (seg_ptr, nbr_flat).
        """
        self.eng._sync()
        nbr_flat, seg_ptr, lo, hi = self._segments(nodes)
        self.charge_blocks(lo, hi)
        nbr_flat, seg_ptr = self._merge_buffered(nodes, nbr_flat, seg_ptr)
        return seg_ptr, nbr_flat

    def account_node_scan(self, v_lo: int, v_hi: int) -> None:
        self.reader.account_node_table_scan(v_lo, v_hi)


# ===========================================================================
# The generic batch superstep loop (Jacobi; one superstep == one pass)
# ===========================================================================
def run_batch(engine, algorithm: str, backend=None, *,
              core: np.ndarray | None = None,
              cnt: np.ndarray | None = None,
              rebind: bool = True,
              superstep_chunk: int | None = None) -> DecompResult:
    """Run a batch-schedule decomposition on ``engine`` with ``backend``.

    The three paper algorithms differ only in frontier policy:

    * ``semicore``   — every node, every pass (Alg. 3);
    * ``semicore+``  — neighbors of changed nodes (Alg. 4 / Lemma 4.1);
    * ``semicore*``  — cnt-gated: recompute v only while cnt(v) < core(v)
      (Alg. 5 / Lemma 4.2), with exact cnt maintenance under simultaneous
      updates (DESIGN.md §2).

    With (core, cnt) given for ``semicore*``, runs the warm-started settle
    loop (maintenance / recovery path).  ``rebind=False`` continues on a
    backend the caller already bound to this engine (:func:`warm_settle`'s
    extra cnt pass stays inside one bind scope, so the kernel-block report
    covers it just like the planner's read counters do).

    Device backends default to the device-resident fixpoint (resident.py):
    state and edge table upload once, many fused passes per host round-trip,
    planner accounting replayed bit-identically from the per-pass frontier
    summaries.  ``REPRO_DEVICE_RESIDENT=0`` selects the per-pass loop below.
    """
    backend = resolve_backend(backend)
    if backend.device_resident and rebind:
        from .resident import resident_enabled, run_resident

        if resident_enabled() or getattr(backend, "requires_resident", False):
            return run_resident(engine, algorithm, backend, core=core,
                                cnt=cnt, superstep_chunk=superstep_chunk)
    planner = engine.planner
    n = engine.n
    if rebind:
        backend.bind(planner)
    comp, iters = 0, 0
    upd_hist: list = []
    comp_hist: list = []

    if algorithm == "semicore":
        core = engine.degrees().astype(np.int64)
        all_nodes = np.arange(n, dtype=np.int64)
        om_p, om_f, om_u = _pass_obs("semicore", backend.name)
        while True:
            iters += 1
            with _trace.span("superstep", cat="engine", algorithm="semicore",
                             backend=backend.name, index=iters,
                             frontier=n) as sp:
                ka0, ks0 = _kernel_counts(backend)
                backend.begin_pass(all_nodes, core)
                if backend.consumes_gather:
                    vals, seg_ptr, _ = planner.gather(all_nodes, core)
                else:  # full-table backend; this driver only needs the charge
                    planner.charge_only(all_nodes)
                    vals = seg_ptr = None
                planner.account_node_scan(0, n - 1)
                h = backend.h_index(vals, seg_ptr, core)
                changed = int((h != core).sum())
                if sp.active:
                    _finish_pass_span(sp, backend, core, changed, ka0, ks0)
            om_p.inc()
            om_f.inc(n)
            om_u.inc(changed)
            upd_hist.append(changed)
            comp_hist.append(n)
            comp += n
            core = h
            if changed == 0:
                break
        return _result(planner, backend, core, None, iters, comp,
                       "semicore", upd_hist, comp_hist)

    if algorithm == "semicore+":
        core = engine.degrees().astype(np.int64)
        frontier = np.arange(n, dtype=np.int64)
        om_p, om_f, om_u = _pass_obs("semicore+", backend.name)
        while len(frontier):
            iters += 1
            with _trace.span("superstep", cat="engine", algorithm="semicore+",
                             backend=backend.name, index=iters,
                             frontier=len(frontier)) as sp:
                ka0, ks0 = _kernel_counts(backend)
                backend.begin_pass(frontier, core)
                if backend.consumes_gather:
                    vals, seg_ptr, nbr_flat = planner.gather(frontier, core)
                else:  # structure only: propagation needs nbr_flat, not values
                    seg_ptr, nbr_flat = planner.gather_structure(frontier)
                    vals = None
                planner.account_node_scan(int(frontier[0]), int(frontier[-1]))
                h = backend.h_index(vals, seg_ptr, core[frontier])
                changed_mask = h != core[frontier]
                if sp.active:
                    _finish_pass_span(sp, backend, core[frontier],
                                      changed_mask.sum(), ka0, ks0)
            om_p.inc()
            om_f.inc(len(frontier))
            om_u.inc(int(changed_mask.sum()))
            comp += len(frontier)
            comp_hist.append(len(frontier))
            upd_hist.append(int(changed_mask.sum()))
            core[frontier] = h
            # Lemma 4.1: only neighbors of changed nodes can change next pass
            lens = np.diff(seg_ptr)
            seg_changed = np.repeat(changed_mask, lens)
            frontier = np.unique(nbr_flat[seg_changed].astype(np.int64))
            frontier = frontier[core[frontier] > 0]
        return _result(planner, backend, core, None, iters, comp,
                       "semicore+", upd_hist, comp_hist)

    if algorithm == "semicore*":
        warm = core is not None
        if not warm:
            core = engine.degrees().astype(np.int64)
            cnt = np.zeros(n, dtype=np.int64)
        else:
            core = np.asarray(core, dtype=np.int64).copy()
            cnt = np.asarray(cnt, dtype=np.int64).copy()
        frontier = np.flatnonzero((cnt < core) & (core > 0))
        om_p, om_f, om_u = _pass_obs("semicore*", backend.name)
        while len(frontier):
            iters += 1
            with _trace.span("superstep", cat="engine", algorithm="semicore*",
                             backend=backend.name, index=iters,
                             frontier=len(frontier)) as sp:
                ka0, ks0 = _kernel_counts(backend)
                backend.begin_pass(frontier, core)
                if backend.consumes_gather:
                    vals_old, seg_ptr, nbr_flat = planner.gather(frontier, core)
                else:  # structure only: push rule needs nbr_flat, not values
                    seg_ptr, nbr_flat = planner.gather_structure(frontier)
                    vals_old = None
                planner.account_node_scan(int(frontier[0]), int(frontier[-1]))
                c_old_f = core[frontier].copy()
                h = backend.h_index(vals_old, seg_ptr, c_old_f)
                if sp.active:
                    _finish_pass_span(sp, backend, c_old_f,
                                      (h != c_old_f).sum(), ka0, ks0)
            om_p.inc()
            om_f.inc(len(frontier))
            om_u.inc(int((h != c_old_f).sum()))
            comp += len(frontier)
            comp_hist.append(len(frontier))
            upd_hist.append(int((h != c_old_f).sum()))
            core[frontier] = h
            # exact cnt under simultaneous updates (DESIGN.md §2):
            # (1) recompute cnt of frontier against pass-start neighbor values
            cnt[frontier] = backend.compute_cnt(vals_old, seg_ptr, h)
            # (2) push decrements: edge (v in F -> u) with
            #     core_now(u) in (h(v), c_old(v)]
            cnt -= backend.push_decrements(nbr_flat, seg_ptr, h, c_old_f,
                                           core, n)
            frontier = np.flatnonzero((cnt < core) & (core > 0))
        return _result(planner, backend, core, cnt, iters, comp,
                       "semicore*", upd_hist, comp_hist)

    raise ValueError(f"unknown algorithm {algorithm!r}")


def warm_settle(engine, core0: np.ndarray, applied_inserts: int,
                backend=None, *,
                superstep_chunk: int | None = None) -> DecompResult:
    """Settle to the exact decomposition from a stale ``core0`` after
    structural updates: the shared maintenance / recovery discipline
    (DESIGN.md §9, §11).

    ``min(core0 + I, deg)`` — I the number of applied insertions — is a
    pointwise upper bound of the new decomposition (one insertion raises any
    core by at most one, deletions never raise it; ``deg`` always bounds).
    One full scan recomputes cnt exactly w.r.t. the warm bounds (Eq. 2),
    then SemiCore* batch passes converge from above (Thm 4.1) to the exact
    fixpoint.
    """
    backend = resolve_backend(backend)
    n = engine.n
    warm = np.minimum(
        np.asarray(core0, dtype=np.int64) + int(applied_inserts),
        engine.degrees(),
    ).astype(np.int64)
    if backend.device_resident:
        from .resident import resident_enabled, run_resident

        if resident_enabled() or getattr(backend, "requires_resident", False):
            # same discipline, device-resident: the exact-cnt scan runs on
            # the bound structure (charged identically) and the settle
            # passes continue on device without re-downloading (core, cnt)
            return run_resident(engine, "semicore*", backend, core=warm,
                                initial_cnt_scan=True,
                                superstep_chunk=superstep_chunk)
    backend.bind(engine.planner)
    all_nodes = np.arange(n, dtype=np.int64)
    t0 = time.perf_counter()
    with _trace.span("cnt_prologue", cat="maintenance",
                     backend=backend.name, nodes=n):
        backend.begin_pass(all_nodes, warm)
        if backend.consumes_gather:
            vals, seg_ptr, _ = engine.planner.gather(all_nodes, warm)
        else:  # full-table backend scans its own resident copy
            engine.planner.charge_only(all_nodes)
            vals = seg_ptr = None
        engine.planner.account_node_scan(0, n - 1)
        cnt = backend.compute_cnt(vals, seg_ptr, warm)
    _MAINT_PROLOGUE.observe(time.perf_counter() - t0)
    return run_batch(engine, "semicore*", backend, core=warm, cnt=cnt,
                     rebind=False)


def _result(planner, backend, core, cnt, iters, comp, algo, upd, cph
            ) -> DecompResult:
    rep = backend.io_report()
    backend.unbind()
    return DecompResult(
        core=core,
        cnt=cnt,
        iterations=iters,
        node_computations=comp,
        edge_block_reads=planner.reader.reads,
        node_table_reads=planner.reader.node_table_reads,
        algorithm=algo,
        schedule="batch",
        updates_per_iter=upd,
        computations_per_iter=cph,
        backend=backend.name,
        kernel_blocks_active=rep.get("kernel_blocks_active", 0),
        kernel_blocks_skipped=rep.get("kernel_blocks_skipped", 0),
    )
