"""Pallas TPU kernels (interpret-validated on CPU; TPU is the target).

segsum         -- blocked one-hot-matmul segment sum (edge scans: LocalCore
                  counts, GNN aggregation, bag pooling)
embedding_bag  -- scalar-prefetch gather-pool (recsys tables)
flash_decode   -- blocked long-KV decode attention (long_500k cells)
fused_superstep-- the whole decomposition superstep as ONE pallas_call
                  (h-index histogram, cnt refresh, push rule, convergence
                  flag) with activity-masked block DMA (DESIGN.md §16)

``default_interpret`` is the single policy for the historical scattered
``interpret: bool = True`` kernel defaults: compiled lowering on real
accelerators, the Pallas interpreter elsewhere, ``REPRO_PALLAS_INTERPRET``
forcing either way.
"""
from __future__ import annotations

import os

__all__ = ["segment_sum", "segment_sum_active", "embedding_bag",
           "flash_decode", "default_interpret", "resolve_interpret",
           "INTERPRET_ENV_VAR"]

INTERPRET_ENV_VAR = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """Interpret-mode default for every kernel in this package.

    ``REPRO_PALLAS_INTERPRET`` (0/false vs anything else) wins when set;
    otherwise kernels lower for real on TPU/GPU hosts and fall back to the
    Pallas interpreter on CPU containers (the only option there).  This
    replaces the old per-signature ``interpret: bool = True`` defaults that
    silently emulated on real hardware.
    """
    from repro import runtime as _runtime

    resolved = _runtime.setting("pallas_interpret")
    if resolved is not None:
        return resolved
    import jax

    return jax.default_backend() not in ("tpu", "gpu")


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> :func:`default_interpret`; explicit values pass through."""
    return default_interpret() if interpret is None else bool(interpret)


# Bound eagerly (as the functions, not the same-named submodules — the
# function binding must shadow e.g. the embedding_bag module).  This import
# sits *below* resolve_interpret because the kernel modules resolve their
# ``interpret=None`` defaults through this package at call time.
from .ops import segment_sum, segment_sum_active, embedding_bag, flash_decode  # noqa: E402
