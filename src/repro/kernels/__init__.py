"""Pallas TPU kernels (interpret-validated on CPU; TPU is the target).

segsum       -- blocked one-hot-matmul segment sum (edge scans: LocalCore
                counts, GNN aggregation, bag pooling)
embedding_bag-- scalar-prefetch gather-pool (recsys tables)
flash_decode -- blocked long-KV decode attention (long_500k cells)
"""
from .ops import segment_sum, segment_sum_active, embedding_bag, flash_decode

__all__ = ["segment_sum", "segment_sum_active", "embedding_bag", "flash_decode"]
