"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(vals: jax.Array, rows: jax.Array, num_segments: int) -> jax.Array:
    """Oracle for segsum: plain jax.ops.segment_sum (rows need not be sorted)."""
    return jax.ops.segment_sum(vals, rows, num_segments=num_segments)


def embedding_bag_ref(
    table: jax.Array, indices: jax.Array, weights: jax.Array, mode: str = "sum"
) -> jax.Array:
    """Oracle for embedding_bag: gather + masked weighted sum/mean."""
    mask = (indices >= 0).astype(table.dtype)
    w = weights * mask
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)  # (B, L, D)
    out = jnp.einsum("bld,bl->bd", rows, w)
    if mode == "mean":
        denom = jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out


def fused_superstep_ref(core, cnt, active, nbr, rows, num_segments: int,
                        algorithm: str):
    """Oracle for fused_superstep: one batch superstep in plain jnp.

    Mirrors the resident reference pass (core/resident.py) formula for
    formula — hindex via eager binary search over segment counts, refreshed
    cnt via a >=-threshold segment sum, the semicore* push rule, the
    semicore+ touched rule.  Eager-only (num_probes is derived from the
    data); returns ``(core2, cnt2, active2, upd)`` as int/bool arrays.
    """
    core = jnp.asarray(core, jnp.int32)
    cnt = jnp.asarray(cnt, jnp.int32) if cnt is not None else None
    active = jnp.asarray(active, bool)
    nbr = jnp.asarray(nbr, jnp.int32)
    rows = jnp.asarray(rows, jnp.int32)
    n = int(num_segments)
    nbr_vals = jnp.take(core, nbr, mode="clip")
    c_old = jnp.where(active, core, 0)

    def count_ge(thresholds):
        ok = nbr_vals >= jnp.take(thresholds, rows, mode="clip")
        return segment_sum_ref(ok.astype(jnp.int32), rows, n)

    cmax = int(jnp.max(c_old)) if n else 0
    h = jnp.zeros(n, jnp.int32)
    step = 1
    while step <= cmax:
        step <<= 1
    step >>= 1
    while step >= 1:
        cand = jnp.minimum(h + step, c_old)
        h = jnp.where(count_ge(cand) >= cand, cand, h)
        step >>= 1

    core2 = jnp.where(active, h, core)
    upd = jnp.sum((active & (h != core)).astype(jnp.int32))
    if algorithm == "semicore":
        return core2, cnt, active, upd
    if algorithm == "semicore+":
        changed = active & (h != core)
        touched = segment_sum_ref(
            jnp.take(changed, nbr, mode="clip").astype(jnp.int32), rows, n)
        return core2, cnt, (touched > 0) & (core2 > 0), upd
    thr = jnp.where(active, h, 0)
    refreshed = count_ge(thr)
    c2_row = jnp.take(core2, rows, mode="clip")
    act_nbr = jnp.take(active, nbr, mode="clip")
    h_nbr = jnp.take(h, nbr, mode="clip")
    c_old_nbr = jnp.take(core, nbr, mode="clip")
    push = act_nbr & (c2_row > h_nbr) & (c2_row <= c_old_nbr)
    dec = segment_sum_ref(push.astype(jnp.int32), rows, n)
    cnt2 = jnp.where(active, refreshed, cnt) - dec
    return core2, cnt2, (cnt2 < core2) & (core2 > 0), upd


def flash_decode_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, cache_len: jax.Array
) -> jax.Array:
    """Oracle for flash_decode: full masked softmax attention, one query token."""
    H, d = q.shape
    Hkv, S, _ = k.shape
    G = H // Hkv
    qg = q.reshape(Hkv, G, d)
    scores = jnp.einsum("hgd,hsd->hgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    mask = jnp.arange(S)[None, None, :] < cache_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgs,hsd->hgd", p, v.astype(jnp.float32))
    return out.reshape(H, d).astype(q.dtype)
