"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(vals: jax.Array, rows: jax.Array, num_segments: int) -> jax.Array:
    """Oracle for segsum: plain jax.ops.segment_sum (rows need not be sorted)."""
    return jax.ops.segment_sum(vals, rows, num_segments=num_segments)


def embedding_bag_ref(
    table: jax.Array, indices: jax.Array, weights: jax.Array, mode: str = "sum"
) -> jax.Array:
    """Oracle for embedding_bag: gather + masked weighted sum/mean."""
    mask = (indices >= 0).astype(table.dtype)
    w = weights * mask
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)  # (B, L, D)
    out = jnp.einsum("bld,bl->bd", rows, w)
    if mode == "mean":
        denom = jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out


def flash_decode_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, cache_len: jax.Array
) -> jax.Array:
    """Oracle for flash_decode: full masked softmax attention, one query token."""
    H, d = q.shape
    Hkv, S, _ = k.shape
    G = H // Hkv
    qg = q.reshape(Hkv, G, d)
    scores = jnp.einsum("hgd,hsd->hgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    mask = jnp.arange(S)[None, None, :] < cache_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgs,hsd->hgd", p, v.astype(jnp.float32))
    return out.reshape(H, d).astype(q.dtype)
