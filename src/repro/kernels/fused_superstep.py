"""Fused single-kernel Pallas superstep (DESIGN.md §16).

One ``pallas_call`` per decomposition pass, replacing the per-probe
``segment_sum_active`` dispatch (~``log2(kmax)`` kernel launches per pass,
each paying per-grid-step interpreter overhead).  The whole superstep —
h-index, cnt refresh, push-decrement rule, convergence counter — runs in a
single grid over edge blocks:

  grid = (P, nbk)   P = 1 (semicore / hindex / counts) or 2 (semicore+/*)
                    nbk = ceil(E / block_edges); b iterates fastest, so all
                    phase-0 steps complete before any phase-1 step runs.

  phase 0  streams edge blocks and accumulates a per-row histogram of
           *capped* neighbor values (``min(nbr_core, cap_row)``) into a VMEM
           scratch; the last block finalizes h (monotone-predicate count over
           the suffix histogram), the refreshed cnt (suffix at h), the
           convergence counter, and parks core2 (or changed flags) in scratch
           for phase 1.
  phase 1  streams the same blocks and window-sums the push-decrement
           predicate (semicore*) or changed-neighbor indicator (semicore+)
           per row.

Activity masking happens *inside* the kernel's index maps: the scalar-
prefetched ``(3, nbk)`` table carries [row-activity, nbr-activity, firsts]
per block, and inactive blocks keep their index map pinned to block 0 so the
pipeline never issues their HBM->VMEM DMA (same trick as
``segsum_active.py``); compute for those steps is skipped with ``pl.when``.
Double buffering comes from the Pallas grid pipeline itself: streamed
BlockSpec fetches for step b+1 overlap step b's compute exactly as
``flash_decode.py`` overlaps KV-block DMA.

Compact rank space
------------------
Rows are addressed by the dense rank of their sorted position among rows
with >= 1 edge.  Consecutive edges' ranks differ by <= 1, so every block's
row span fits in ``block_edges`` rows — which makes the windowed
scratch read-modify-write (``hist[first:first+cbe] += counts``) well defined
even for graphs with many isolated nodes (in global row space, empty rows
could stretch a block's span arbitrarily).  Zero-degree rows can never be
active (core = deg = 0), so globalizing through ``rank``/``present`` is
exact.

Histograms have two lowerings picked by the ``interpret`` flag: a per-edge
scatter-add (O(cbe) per step — fastest on CPU/interpret, where XLA scatters
are cheap but scans are not) and a one-hot cumulative-sum + window-boundary
gather (O(cbe*K) per step — scatters don't lower in Mosaic, the cumsum form
vectorizes on the VPU).  Both produce bit-identical f32 counts: every
addend is 1.0 and ``dmax < 2**24`` is validated at structure-build time
(engine.py).

Everything the engine observes — core/cnt/iters traces, planner I/O
accounting, kernel_blocks_active/skipped — is bit-identical to the per-probe
path: frontiers are identical, and block accounting replays from the pinned
frontier masks at the *accounting* block size, decoupled from the kernel
tile size (``REPRO_FUSED_BLOCK_EDGES``).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "FusedArrays", "FusedTable", "build_fused_table", "fused_pass",
    "fused_hindex", "fused_counts", "fused_enabled", "fused_block_edges",
]

_FALSY = ("0", "false", "no", "off")


def fused_enabled() -> bool:
    """Hot-path switch: ``REPRO_PALLAS_FUSED=0`` reverts the pallas backend
    to the per-probe ``segment_sum_active`` dispatch (kept as the parity
    oracle for the differential tests).  Resolved through
    :func:`repro.runtime.setting`."""
    from repro import runtime as _runtime

    return _runtime.setting("pallas_fused")


def fused_block_edges(num_edges: int | None = None) -> int:
    """Kernel tile size in edges (``REPRO_FUSED_BLOCK_EDGES``); independent
    of the planner's accounting block size.

    Without an env override the tile adapts to the graph: ~24 grid steps
    per phase (next pow2 of ``num_edges / 24``, clamped to [512, 8192]).
    Per-step interpreter overhead dominates small tiles on big graphs, while
    oversized tiles waste the tail block on small ones.
    """
    from repro import runtime as _runtime

    v = _runtime.setting("fused_block_edges")
    if v is not None:
        if v < 8:
            raise ValueError(
                f"REPRO_FUSED_BLOCK_EDGES must be >= 8, got {v}")
        return v
    if not num_edges:
        return 512
    v = 512
    while v < min(num_edges // 24, 8192):
        v <<= 1
    return v


class FusedArrays(NamedTuple):
    """Device-resident kernel operands (a jit-friendly pytree of arrays).

    Shapes below use Ep = nbk * cbe (padded edges), R = max(U, 1) + cbe
    (padded compact rank space, sized so every windowed scratch access of
    length cbe starting at a valid rank stays in bounds).
    """
    nbr: jnp.ndarray      # (Ep,)  i32 neighbor node id per edge (pad: 0)
    ev: jnp.ndarray       # (Ep,)  bool edge-validity (False on pads)
    compact: jnp.ndarray  # (Ep,1) i32 compact rank of each edge's row
    nbrc: jnp.ndarray     # (Ep,1) i32 compact rank of each edge's neighbor
    cptr: jnp.ndarray     # (R+1,1) i32 compact CSR ptr, padded with E
    seg_of: jnp.ndarray   # (R,)   i32 node id of each rank (pad: 0)
    validc: jnp.ndarray   # (R,)   bool rank < U
    rank: jnp.ndarray     # (n,)   i32 rank of each node (0 if absent)
    present: jnp.ndarray  # (n,)   bool node has >= 1 edge
    firsts: jnp.ndarray   # (nbk,) i32 rank of first edge in block b
    lasts: jnp.ndarray    # (nbk,) i32 rank of last edge in block b


@dataclasses.dataclass(frozen=True)
class FusedTable:
    """Static dims + device arrays for one (structure, tile-size) pair."""
    dims: tuple  # (cbe, Ep, nbk, U, R, n, E) — all python ints, hashable
    arrays: FusedArrays


def build_fused_table(seg_ptr, nbr, n: int, block_edges: int) -> FusedTable:
    """Host-side build of the compact-rank edge table (once per structure
    per tile size; cached on ResidentStructure)."""
    seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
    nbr_h = np.asarray(nbr, dtype=np.int32)
    E = int(nbr_h.shape[0])
    cbe = int(block_edges)
    if cbe < 8:
        raise ValueError(f"block_edges must be >= 8, got {cbe}")
    lens = np.diff(seg_ptr)
    present = lens > 0
    pres_idx = np.flatnonzero(present)
    U = int(pres_idx.shape[0])
    nbk = max(1, -(-E // cbe))
    Ep = nbk * cbe
    R = max(U, 1) + cbe

    rank = np.zeros(n, dtype=np.int32)
    rank[pres_idx] = np.arange(U, dtype=np.int32)
    if E:
        rows = np.repeat(np.arange(n, dtype=np.int32), lens)
        compact = rank[rows]
        pad_rank = int(compact[-1])
    else:
        compact = np.zeros(0, dtype=np.int32)
        pad_rank = 0
    compact_p = np.full(Ep, pad_rank, dtype=np.int32)
    compact_p[:E] = compact
    nbr_p = np.zeros(Ep, dtype=np.int32)
    nbr_p[:E] = nbr_h
    nbrc_p = rank[nbr_p]  # neighbors have deg >= 1, so always present

    cptr = np.full(R + 1, E, dtype=np.int64)
    cptr[:U] = seg_ptr[pres_idx]
    seg_of = np.zeros(R, dtype=np.int32)
    seg_of[:U] = pres_idx
    validc = np.arange(R) < U
    ev = np.arange(Ep) < E
    firsts = compact_p[0::cbe].copy()
    lasts = compact_p[cbe - 1::cbe].copy()

    arrays = FusedArrays(
        nbr=jnp.asarray(nbr_p),
        ev=jnp.asarray(ev),
        compact=jnp.asarray(compact_p)[:, None],
        nbrc=jnp.asarray(nbrc_p)[:, None],
        cptr=jnp.asarray(cptr.astype(np.int32))[:, None],
        seg_of=jnp.asarray(seg_of),
        validc=jnp.asarray(validc),
        rank=jnp.asarray(rank),
        present=jnp.asarray(present),
        firsts=jnp.asarray(firsts),
        lasts=jnp.asarray(lasts),
    )
    return FusedTable(dims=(cbe, Ep, nbk, U, R, int(n), E), arrays=arrays)


_N_OUT = {"semicore": 2, "semicore+": 3, "semicore*": 4,
          "hindex": 3, "counts": 1}


def _superstep_kernel(scal_ref, core0_ref, active_ref, cptr_ref,
                      compact_ref, nbrc_ref, nval_ref, *refs,
                      cbe: int, K: int, nbk: int, R: int, E: int,
                      mode: str, scatter: bool):
    n_out = _N_OUT[mode]
    outs = refs[:n_out]
    hist_ref, acc_ref, core2s_ref = refs[n_out:]
    p = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when((p == 0) & (b == 0))
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # phase 0 reads this as the per-row probe cap (pass-start core for
        # active rows, 0 — i.e. "frozen" — otherwise); the phase-0 finalize
        # overwrites it with core2 / changed flags for phase 1.
        core2s_ref[...] = jnp.where(active_ref[...] > 0, core0_ref[...], 0)

    first = scal_ref[2, b]
    bstart = b * cbe

    def windows():
        # per-row edge windows: cptr bounds are <= E, so pad edges in the
        # tail block fall outside every [lo, hi) and contribute nothing
        cw = cptr_ref[pl.ds(first, cbe + 1), :][:, 0]
        lo = jnp.clip(cw[:-1] - bstart, 0, cbe)
        hi = jnp.clip(cw[1:] - bstart, 0, cbe)
        return lo, hi

    @pl.when((p == 0) & (scal_ref[0, b] > 0))
    def _histogram():
        local = jnp.clip(compact_ref[...][:, 0] - first, 0, cbe - 1)
        cap = jnp.take(core2s_ref[pl.ds(first, cbe), :][:, 0], local)
        vals = jnp.minimum(nval_ref[...][:, 0], cap)
        if scatter:
            # interpret / CPU path: one scatter-add per edge, O(cbe) work.
            # Pad edges in the tail block get weight 0 (caps are < K, so
            # every valid capped value lands in-range without clipping).
            valid = (bstart + jax.lax.iota(jnp.int32, cbe)) < E
            idx = local * K + jnp.clip(vals, 0, K - 1)
            win = hist_ref[pl.ds(first, cbe), :].reshape(-1)
            win = win.at[idx].add(valid.astype(jnp.float32))
            hist_ref[pl.ds(first, cbe), :] = win.reshape(cbe, K)
        else:
            # compiled TPU path: scatters don't lower in Mosaic, so build
            # the same counts from a one-hot cumsum + boundary gathers
            # (O(cbe*K), vectorizes on the VPU)
            lo, hi = windows()
            onehot = (vals[:, None] ==
                      jax.lax.broadcasted_iota(jnp.int32, (cbe, K), 1))
            pc = jnp.concatenate(
                [jnp.zeros((1, K), jnp.float32),
                 jnp.cumsum(onehot.astype(jnp.float32), axis=0)], axis=0)
            counts = jnp.take(pc, hi, axis=0) - jnp.take(pc, lo, axis=0)
            hist_ref[pl.ds(first, cbe), :] += counts

    @pl.when((p == 0) & (b == nbk - 1))
    def _finalize_h():
        histU = hist_ref[...]                       # (R, K)
        incl = jnp.cumsum(histU, axis=1)
        total = incl[:, K - 1:K]
        suffix = total - incl + histU               # suffix[:, k] = #vals>=k
        ks = jax.lax.broadcasted_iota(jnp.float32, (R, K), 1)
        act = active_ref[...] > 0
        core0 = core0_ref[...]
        if mode == "counts":
            # cnt at an arbitrary threshold: #(min(v, thr) >= thr)
            # == #(v >= thr); core2s still holds the caps here.
            capf = core2s_ref[...].astype(jnp.float32)
            cnt = jnp.sum(histU * (ks >= capf), axis=1, keepdims=True)
            outs[0][...] = jnp.rint(cnt).astype(jnp.int32)
            return
        # h = max feasible k: the predicate suffix[k] >= k is monotone in k,
        # so the count of feasible k in [1, K) *is* the max.  Caps make this
        # min(h_true, cap) — exactly hindex_bsearch's bounded answer.
        feas = (suffix >= ks) & (ks >= 1.0)
        h = jnp.sum(feas.astype(jnp.float32), axis=1, keepdims=True)
        h32 = jnp.rint(h).astype(jnp.int32)
        outs[0][...] = h32
        if mode in ("hindex", "semicore*"):
            # refreshed cnt: #(v >= h) == suffix at h (h <= cap)
            refr = jnp.sum(histU * (ks >= h), axis=1, keepdims=True)
            outs[1][...] = jnp.rint(refr).astype(jnp.int32)
        outs[-1][0, 0] = jnp.sum((act & (h32 != core0)).astype(jnp.int32))
        if mode == "semicore*":
            core2s_ref[...] = jnp.where(act, h32, core0)
        elif mode == "semicore+":
            core2s_ref[...] = (act & (h32 != core0)).astype(jnp.int32)

    if mode in ("semicore+", "semicore*"):
        @pl.when((p == 1) & (scal_ref[1, b] > 0))
        def _accum_phase1():
            nbrc = nbrc_ref[...][:, 0]
            c2n = jnp.take(core2s_ref[...][:, 0], nbrc)
            if mode == "semicore*":
                local = jnp.clip(compact_ref[...][:, 0] - first, 0, cbe - 1)
                c2r = jnp.take(core2s_ref[pl.ds(first, cbe), :][:, 0], local)
                nv = nval_ref[...][:, 0]
                # == act_nbr & (core2_row > h_nbr) & (core2_row <= c_old_nbr):
                # an inactive neighbor has c2n == nv, an empty interval.
                contrib = ((c2r > c2n) & (c2r <= nv)).astype(jnp.float32)
            else:
                contrib = c2n.astype(jnp.float32)   # changed-neighbor flag
            lo, hi = windows()
            pc = jnp.concatenate(
                [jnp.zeros((1,), jnp.float32), jnp.cumsum(contrib)])
            acc_ref[pl.ds(first, cbe), :] += (
                jnp.take(pc, hi) - jnp.take(pc, lo))[:, None]

        @pl.when((p == 1) & (b == nbk - 1))
        def _finalize_phase1():
            out = jnp.rint(acc_ref[...]).astype(jnp.int32)
            if mode == "semicore*":
                outs[2][...] = out                  # push decrements
            else:
                outs[1][...] = out                  # touched counts


def _check_vmem(R: int, K: int, limit: int = 1 << 26):
    if R * K > limit:
        raise ValueError(
            f"fused superstep histogram scratch {R}x{K} exceeds the VMEM "
            f"budget ({R * K} > {limit} f32 elems); this graph's kmax/size "
            "wants the xla backend (or a smaller REPRO_FUSED_BLOCK_EDGES)")


@functools.lru_cache(maxsize=None)
def _fused_call(dims, num_probes: int, mode: str, interpret: bool):
    cbe, Ep, nbk, U, R, n, E = dims
    K = max(8, 1 << int(num_probes))
    _check_vmem(R, K)
    kernel = functools.partial(_superstep_kernel, cbe=cbe, K=K, nbk=nbk,
                               R=R, E=E, mode=mode, scatter=interpret)

    def const(p, b, scal):
        return (0, 0)

    def stream(p, b, scal):
        # activity-masked DMA: an inactive (p, b) step re-points its block
        # fetch at block 0, so the pipeline never pulls its bytes from HBM
        return (jnp.where(scal[p, b] > 0, b, 0), 0)

    in_specs = [
        pl.BlockSpec((R, 1), const),            # core0 (or thresholds)
        pl.BlockSpec((R, 1), const),            # active mask
        pl.BlockSpec((R + 1, 1), const),        # compact csr ptr
        pl.BlockSpec((cbe, 1), stream),         # compact row ranks
        pl.BlockSpec((cbe, 1), stream),         # neighbor ranks
        pl.BlockSpec((cbe, 1), stream),         # neighbor core values
    ]
    out_defs = {
        "semicore": [(R, 1), (1, 1)],
        "semicore+": [(R, 1), (R, 1), (1, 1)],
        "semicore*": [(R, 1), (R, 1), (R, 1), (1, 1)],
        "hindex": [(R, 1), (R, 1), (1, 1)],
        "counts": [(R, 1)],
    }[mode]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(2 if mode in ("semicore+", "semicore*") else 1, nbk),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec(s, const) for s in out_defs],
        scratch_shapes=[
            pltpu.VMEM((R, K), jnp.float32),    # capped-value histogram
            pltpu.VMEM((R, 1), jnp.float32),    # phase-1 row accumulator
            pltpu.VMEM((R, 1), jnp.int32),      # cap, then core2 / changed
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(s, jnp.int32) for s in out_defs],
        interpret=interpret)


def _compactify(x, arrs: FusedArrays):
    c = jnp.where(arrs.validc,
                  jnp.take(x, arrs.seg_of, mode="clip"), 0)
    return c.astype(jnp.int32)[:, None]


def _globalize(xc, arrs: FusedArrays):
    return jnp.where(arrs.present,
                     jnp.take(xc[:, 0], arrs.rank, mode="clip"), 0)


def _scal_table(activec_b, arrs: FusedArrays, dims, phase1: bool):
    cbe, Ep, nbk, U, R, n, E = dims
    # act0[b]: any active rank in [firsts[b], lasts[b]] — exact, because
    # every rank in a block's span has >= 1 edge in that block
    s = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                         jnp.cumsum(activec_b.astype(jnp.int32))])
    act0 = (jnp.take(s, arrs.lasts + 1) - jnp.take(s, arrs.firsts)) > 0
    if phase1:
        # act1[b]: any active *neighbor* in block b (sound superset for the
        # changed-neighbor sweep: changed ⊆ active)
        nbr_act = jnp.take(activec_b, arrs.nbrc[:, 0], mode="clip") & arrs.ev
        act1 = jnp.any(nbr_act.reshape(nbk, cbe), axis=1)
    else:
        act1 = jnp.zeros((nbk,), dtype=bool)
    return jnp.stack([act0.astype(jnp.int32), act1.astype(jnp.int32),
                      arrs.firsts.astype(jnp.int32)])


def _invoke(mode, core, capsrc, active, arrs, dims, num_probes, interpret):
    core_i = core.astype(jnp.int32)
    nval = jnp.take(core_i, arrs.nbr, mode="clip")[:, None]
    core0c = _compactify(capsrc, arrs)
    activec_b = arrs.validc & jnp.take(active, arrs.seg_of, mode="clip")
    activec = activec_b.astype(jnp.int32)[:, None]
    scal = _scal_table(activec_b, arrs, dims,
                       phase1=mode in ("semicore+", "semicore*"))
    fn = _fused_call(dims, int(num_probes), mode, bool(interpret))
    return fn(scal, core0c, activec, arrs.cptr, arrs.compact, arrs.nbrc,
              nval)


def fused_pass(core, cnt, active, arrs: FusedArrays, *, dims, num_probes,
               algorithm: str, interpret: bool):
    """One engine superstep as ONE pallas_call; traceable (jit-safe).

    Args match the resident reference pass: ``core``/``cnt`` int32 (n,),
    ``active`` bool (n,).  Returns ``(core2, cnt2, active2, upd)`` with the
    exact semantics of the per-probe reference (resident.py) — including
    ``cnt``/``active`` passthrough for algorithms that don't track them.
    """
    core = core.astype(jnp.int32)
    outs = _invoke(algorithm, core, core, active, arrs, dims, num_probes,
                   interpret)
    if algorithm == "semicore":
        h_c, upd = outs
        core2 = jnp.where(active, _globalize(h_c, arrs), core)
        return core2, cnt, active, upd[0, 0]
    if algorithm == "semicore+":
        h_c, touched_c, upd = outs
        h = _globalize(h_c, arrs)
        core2 = jnp.where(active, h, core)
        touched = _globalize(touched_c, arrs)
        active2 = (touched > 0) & (core2 > 0)
        return core2, cnt, active2, upd[0, 0]
    if algorithm == "semicore*":
        h_c, refr_c, dec_c, upd = outs
        h = _globalize(h_c, arrs)
        core2 = jnp.where(active, h, core)
        cnt2 = jnp.where(active, _globalize(refr_c, arrs), cnt) \
            - _globalize(dec_c, arrs)
        active2 = (cnt2 < core2) & (core2 > 0)
        return core2, cnt2, active2, upd[0, 0]
    raise ValueError(f"unknown algorithm {algorithm!r}")


@functools.partial(jax.jit, static_argnames=("dims", "num_probes",
                                             "interpret"))
def fused_hindex(core, active, arrs: FusedArrays, *, dims, num_probes,
                 interpret):
    """Legacy per-pass path: (h, cnt_at_h) for the frontier in one call.

    ``h`` is the cap-bounded h-index of pass-start neighbor values and
    ``cnt_at_h`` the refreshed #(nbr_core >= h) — both global (n,), zero
    off-frontier.  PallasBackend serves ``compute_cnt(thresholds == h)``
    from the second output without another kernel launch.
    """
    outs = _invoke("hindex", core, core, active, arrs, dims, num_probes,
                   interpret)
    h_c, refr_c, _upd = outs
    return _globalize(h_c, arrs), _globalize(refr_c, arrs)


@functools.partial(jax.jit, static_argnames=("dims", "num_probes",
                                             "interpret"))
def fused_counts(core, thresholds, active, arrs: FusedArrays, *, dims,
                 num_probes, interpret):
    """#(nbr pass-start core >= threshold) per active row, one call.

    ``num_probes`` must satisfy ``2**num_probes >= max(thresholds) + 2``.
    Used by the warm-settle prologue and by ``compute_cnt`` calls whose
    thresholds differ from the pass's h (cache miss).
    """
    (cnt_c,) = _invoke("counts", core, thresholds, active, arrs, dims,
                       num_probes, interpret)
    return _globalize(cnt_c, arrs)
