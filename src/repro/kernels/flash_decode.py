"""Pallas TPU kernel: blocked single-token decode attention (flash-decode).

The building block of the ``long_500k`` cells: one query token attends a long
KV cache with running (max, sum, acc) softmax state carried in VMEM scratch
across KV blocks — O(L·d) streaming, never materializing the (L,) score row
in HBM.  GQA layout: the G query heads of one KV head share each KV block
fetch.  The cache length is scalar-prefetched for tail masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(
    len_ref, q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr,
    *, block_kv: int, scale: float,
):
    s = pl.program_id(1)
    num_s = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]          # (G, d)
    k = k_ref[0]            # (BS, d)
    v = v_ref[0]            # (BS, d)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale               # (G, BS)
    pos = s * block_kv + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < len_ref[0], scores, NEG_INF)

    m_prev = m_scr[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                          # (G, BS)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(s == num_s - 1)
    def _finish():
        out_ref[...] = (acc_scr[...] / l_scr[...]).astype(out_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,        # (H, d)   H = Hkv * G query heads
    k: jax.Array,        # (Hkv, S, d)
    v: jax.Array,        # (Hkv, S, d)
    cache_len: jax.Array,  # () int32 — valid prefix of S
    *,
    block_kv: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    from . import resolve_interpret
    interpret = resolve_interpret(interpret)
    H, d = q.shape
    Hkv, S, _ = k.shape
    assert H % Hkv == 0 and S % block_kv == 0
    G = H // Hkv
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_decode_kernel, block_kv=block_kv, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Hkv, S // block_kv),
        in_specs=[
            pl.BlockSpec((G, d), lambda h, s, ln: (h, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, s, ln: (h, s, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, s, ln: (h, s, 0)),
        ],
        out_specs=pl.BlockSpec((G, d), lambda h, s, ln: (h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((H, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k, v)
