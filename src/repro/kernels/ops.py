"""jit'd public wrappers around the Pallas kernels (+ XLA fallbacks).

``use_pallas`` selects the kernel path; on this CPU container kernels run in
interpret mode (the TPU lowering is the target, exercised by the dry-run).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .segsum import segsum_pallas_partials
from .segsum_active import segsum_active_partials
from .embedding_bag import embedding_bag_pallas
from .flash_decode import flash_decode_pallas
from . import ref

__all__ = ["segment_sum", "segment_sum_active", "make_superstep_segsum",
           "embedding_bag", "flash_decode"]


@partial(jax.jit, static_argnames=("num_segments", "block_edges", "use_pallas", "interpret"))
def segment_sum(
    vals: jax.Array,
    rows: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 512,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Segment-sum over *sorted* rows; (E,) or (E, D) values -> (n[, D]).

    Pallas path: compact ranks -> blocked one-hot-matmul kernel -> window
    scatter-add epilogue (see segsum.py).
    """
    if not use_pallas:
        return ref.segment_sum_ref(vals, rows, num_segments)
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    E, D = vals.shape
    in_dtype = vals.dtype
    Ep = -(-max(E, 1) // block_edges) * block_edges
    pad = Ep - E
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        rows = jnp.pad(rows, (0, pad), mode="edge")
    rows = rows.astype(jnp.int32)
    # dense compact ranks of the sorted segment ids
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (rows[1:] != rows[:-1]).astype(jnp.int32)]
    )
    compact = jnp.cumsum(boundary) - 1  # (Ep,), in [0, Ep)
    partials = segsum_pallas_partials(
        vals.astype(jnp.float32), compact[:, None], block_edges=block_edges,
        interpret=interpret,
    )  # (nb, BE, D)
    nb = Ep // block_edges
    firsts = compact[:: block_edges]  # (nb,) first compact rank per block
    # epilogue: windows overlap by at most the boundary row -> scatter-add
    win = firsts[:, None] + jnp.arange(block_edges)[None, :]  # (nb, BE)
    r_cap = Ep + block_edges
    dense = jnp.zeros((r_cap, D), jnp.float32).at[win.reshape(-1)].add(
        partials.reshape(-1, D)
    )
    # compact rank -> global segment id
    seg_of = jnp.zeros((r_cap,), jnp.int32).at[compact].set(rows)
    out = jnp.zeros((num_segments, D), jnp.float32).at[seg_of[: Ep]].add(dense[: Ep])
    # rank 0..U-1 used; unused slots are zero contributions to segment 0
    if jnp.issubdtype(in_dtype, jnp.integer):
        out = jnp.rint(out)
    out = out.astype(in_dtype)
    return out[:, 0] if squeeze else out


def make_superstep_segsum(
    rows: jax.Array,
    node_active: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 512,
    interpret: bool | None = None,
):
    """Superstep-granular entry to the block-skipping segment-sum.

    One superstep (pass) runs several reductions over the *same* sorted
    ``rows`` with the *same* frontier mask — log2(kmax) h-index probes plus
    the cnt refresh.  This precomputes everything that depends only on
    (rows, node_active) — padding, dense compact ranks, the on-device
    block-activity mask, the window scatter targets — once, and returns an
    ``apply(vals)`` closure for the per-probe sums.  Traceable: intended to
    be called *inside* a jit (the device-resident superstep, resident.py).

    Requires ``rows.shape[0] >= 1`` (edgeless graphs never reach the kernel
    layer — the engine resolves them host-side).
    """
    E = rows.shape[0]
    Ep = -(-E // block_edges) * block_edges
    pad = Ep - E
    if pad:
        rows = jnp.pad(rows, (0, pad), mode="edge")
    rows = rows.astype(jnp.int32)
    boundary = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (rows[1:] != rows[:-1]).astype(jnp.int32)])
    compact = jnp.cumsum(boundary) - 1
    nb = Ep // block_edges
    # per-block activity from the per-node mask — derived on-device, so the
    # resident superstep's frontier never round-trips to the host for it
    row_active = jnp.take(node_active, rows, mode="clip").astype(jnp.int32)
    block_active = jnp.max(row_active.reshape(nb, block_edges), axis=1)
    firsts = compact[::block_edges]
    win = firsts[:, None] + jnp.arange(block_edges)[None, :]
    r_cap = Ep + block_edges
    seg_of = jnp.zeros((r_cap,), jnp.int32).at[compact].set(rows)

    def apply(vals: jax.Array) -> jax.Array:
        squeeze = vals.ndim == 1
        if squeeze:
            vals = vals[:, None]
        in_dtype = vals.dtype
        if pad:
            vals = jnp.pad(vals, ((0, pad), (0, 0)))
        D = vals.shape[1]
        partials = segsum_active_partials(
            vals.astype(jnp.float32), compact[:, None], block_active,
            block_edges=block_edges, interpret=interpret)
        dense = jnp.zeros((r_cap, D), jnp.float32).at[win.reshape(-1)].add(
            partials.reshape(-1, D))
        out = jnp.zeros((num_segments, D), jnp.float32).at[seg_of[:Ep]].add(
            dense[:Ep])
        if jnp.issubdtype(in_dtype, jnp.integer):
            out = jnp.rint(out)
        out = out.astype(in_dtype)
        return out[:, 0] if squeeze else out

    return apply


@partial(jax.jit, static_argnames=("num_segments", "block_edges", "interpret"))
def segment_sum_active(
    vals: jax.Array,
    rows: jax.Array,
    node_active: jax.Array,
    num_segments: int,
    *,
    block_edges: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Block-skipping segment-sum (SemiCore*'s saved I/O on TPU).

    Blocks whose rows are all inactive are neither fetched nor computed;
    their contributions are exactly zero (the caller's invariant — Lemma
    4.2 — guarantees no needed update lives in a skipped block).  One-shot
    wrapper over :func:`make_superstep_segsum`; supersteps issuing several
    sums per frontier should build the closure once instead.
    """
    return make_superstep_segsum(
        rows, node_active, num_segments,
        block_edges=block_edges, interpret=interpret)(vals)


@partial(jax.jit, static_argnames=("mode", "use_pallas", "interpret"))
def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    weights: jax.Array | None = None,
    *,
    mode: str = "sum",
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """EmbeddingBag: out[b] = pool_l w[b,l] * table[idx[b,l]]; idx<0 masked."""
    B, L = indices.shape
    if weights is None:
        weights = jnp.ones((B, L), table.dtype)
    if not use_pallas:
        return ref.embedding_bag_ref(table, indices, weights, mode)
    mask = (indices >= 0).astype(table.dtype)
    w = weights * mask
    out = embedding_bag_pallas(table, indices.astype(jnp.int32), w, interpret=interpret)
    if mode == "mean":
        denom = jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out


@partial(jax.jit, static_argnames=("block_kv", "use_pallas", "interpret"))
def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cache_len: jax.Array,
    *,
    block_kv: int = 512,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token GQA decode attention over a long KV cache."""
    if not use_pallas:
        return ref.flash_decode_ref(q, k, v, cache_len)
    return flash_decode_pallas(
        q, k, v, cache_len, block_kv=block_kv, interpret=interpret
    )
