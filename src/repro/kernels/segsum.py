"""Pallas TPU kernel: blocked segment-sum over sorted segment ids.

The universal edge-scan primitive of this framework (DESIGN.md §6): LocalCore
neighbor counts, GNN message aggregation, and embedding-bag pooling are all
segment-sums over a CSR-sorted edge axis.

TPU-native design: the grid marches fixed-size edge blocks HBM->VMEM
(``BlockSpec`` tiles — the semi-external "sequential block scan"), and the
scatter within a block is expressed as a one-hot x values **matmul** so the
MXU does the reduction.  Because segment ids are *compacted* (dense ranks),
a block of BE edges touches at most BE consecutive compact rows, so each
block's partial result is a (BE, D) window starting at the block's first
compact row; windows are combined by a cheap scatter-add epilogue in the
jit'd wrapper (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_block_kernel(compact_ref, vals_ref, out_ref, *, block_edges: int):
    """One grid step: (BE, D) values -> (BE, D) window partial via MXU."""
    c = compact_ref[...]  # (BE, 1) int32 compact segment ids (sorted)
    vals = vals_ref[...]  # (BE, D)
    first = c[0, 0]
    # one-hot of (compact - first) against the BE-wide local window
    local = c - first  # (BE, 1), values in [0, BE)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_edges, block_edges), 1)
    onehot = (local == iota).astype(jnp.float32)  # (BE, W=BE)
    # MXU: window partial = onehot^T @ vals
    out_ref[0] = jax.lax.dot_general(
        onehot, vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def segsum_pallas_partials(
    vals: jax.Array, compact: jax.Array, *, block_edges: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the blocked kernel; returns (num_blocks, BE, D) window partials.

    ``vals``    -- (E, D) float32, E a multiple of block_edges.
    ``compact`` -- (E, 1) int32 dense sorted segment ranks.
    ``interpret`` -- None defers to ``kernels.default_interpret()``.
    """
    from . import resolve_interpret
    interpret = resolve_interpret(interpret)
    E, D = vals.shape
    assert E % block_edges == 0, (E, block_edges)
    nb = E // block_edges
    kernel = functools.partial(_segsum_block_kernel, block_edges=block_edges)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_edges, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_edges, D), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_edges, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_edges, D), jnp.float32),
        interpret=interpret,
    )(compact, vals)
