"""Pallas TPU kernel: segment-sum with *block skipping* — the semi-external
I/O saving (SemiCore+/SemiCore*, §IV-B/C) expressed at the HBM->VMEM level.

The paper skips disk blocks whose nodes cannot update; here a scalar-prefetched
per-block activity flag drives the BlockSpec ``index_map``: inactive blocks
map to block 0, which is already VMEM-resident after the first step, so the
pipeline issues **no DMA** for them — skipped I/O on TPU, block-for-block the
paper's discipline.  The kernel body is additionally predicated with
``pl.when`` so skipped blocks cost neither bandwidth nor MXU cycles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(active_ref, compact_ref, vals_ref, out_ref, *, block_edges: int):
    b = pl.program_id(0)

    @pl.when(active_ref[b] > 0)
    def _compute():
        c = compact_ref[...]                    # (BE, 1)
        vals = vals_ref[...]                    # (BE, D)
        first = c[0, 0]
        iota = jax.lax.broadcasted_iota(
            jnp.int32, (block_edges, block_edges), 1)
        onehot = ((c - first) == iota).astype(jnp.float32)
        out_ref[0] = jax.lax.dot_general(
            onehot, vals, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(active_ref[b] == 0)
    def _skip():
        out_ref[0] = jnp.zeros_like(out_ref[0])


def segsum_active_partials(
    vals: jax.Array,          # (E, D) float32, E % block_edges == 0
    compact: jax.Array,       # (E, 1) int32 dense sorted segment ranks
    block_active: jax.Array,  # (num_blocks,) int32 — 0 skips the block
    *,
    block_edges: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Window partials like segsum, but inactive blocks are never fetched.

    ``interpret`` -- None defers to ``kernels.default_interpret()``.
    """
    from . import resolve_interpret
    interpret = resolve_interpret(interpret)
    E, D = vals.shape
    assert E % block_edges == 0
    nb = E // block_edges
    kernel = functools.partial(_kernel, block_edges=block_edges)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            # inactive blocks re-map to block 0: no new DMA is issued
            pl.BlockSpec((block_edges, 1),
                         lambda b, act: (jnp.where(act[b] > 0, b, 0), 0)),
            pl.BlockSpec((block_edges, D),
                         lambda b, act: (jnp.where(act[b] > 0, b, 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_edges, D), lambda b, act: (b, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, block_edges, D), jnp.float32),
        interpret=interpret,
    )(block_active.astype(jnp.int32), compact, vals)
