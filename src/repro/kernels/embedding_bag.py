"""Pallas TPU kernel: EmbeddingBag (gather + pooled reduce) via scalar prefetch.

JAX has no native EmbeddingBag; this is the TPU-native one (DESIGN.md §6):
the bag indices are *scalar-prefetched* so the input ``index_map`` can DMA
exactly the needed table rows HBM->VMEM (the canonical Pallas block-sparse
pattern) while the output block stays resident in VMEM across the bag axis
and accumulates.  The embedding table itself never materializes in VMEM —
only ``bag_size`` rows per output row, mirroring the paper's semi-external
contract (O(state) fast memory, stream the big table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_row_ref, weight_ref, out_ref):
    l = pl.program_id(1)
    row = table_row_ref[...]          # (1, D) — the index-mapped table row
    w = weight_ref[...]               # (1, 1) — per-slot weight (0 = masked)
    contrib = row * w

    @pl.when(l == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(l > 0)
    def _acc():
        out_ref[...] += contrib


def embedding_bag_pallas(
    table: jax.Array,      # (N, D)
    indices: jax.Array,    # (B, L) int32; negative = masked slot
    weights: jax.Array,    # (B, L) float32 per-slot weights
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum-pooled bags: out[b] = sum_l weights[b,l] * table[indices[b,l]].

    ``interpret`` -- None defers to ``kernels.default_interpret()``.
    """
    from . import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, L = indices.shape
    N, D = table.shape
    safe_idx = jnp.maximum(indices, 0).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, l, idx: (idx[b, l], 0)),
            pl.BlockSpec((1, 1), lambda b, l, idx: (b, l)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, l, idx: (b, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(safe_idx, table, weights.astype(table.dtype))
