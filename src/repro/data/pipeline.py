"""Host data pipelines: deterministic synthetic sources + bounded prefetch.

Straggler-mitigation story at pod scale: all sources are *indexable by step*
(stateless), so any host can produce any step's batch — a restarted/replaced
host resumes from the step counter alone, and the prefetch queue bounds how
far a slow producer can fall behind before backpressure.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["TokenSource", "GNNFullGraphSource", "SampledGraphSource",
           "RecsysSource", "Prefetcher"]


class TokenSource:
    """Synthetic LM token stream: batch(step) is a pure function of step.

    Tokens follow a noisy deterministic bigram process (t+1 = a*t+c mod V with
    p=0.9) so the loss has learnable structure — train loops demonstrably
    descend toward the process entropy.
    """

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0,
                 noise: float = 0.1):
        self.batch, self.seq, self.vocab, self.seed = batch, seq, vocab, seed
        self.noise = noise

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.batch, self.seq + 1, self.vocab
        toks = np.empty((B, S), dtype=np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        flip = rng.random((B, S)) < self.noise
        rand = rng.integers(0, V, (B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1] * 31 + 7) % V
            toks[:, t] = np.where(flip[:, t], rand[:, t], nxt)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class GNNFullGraphSource:
    """Full-graph batch (same graph every step) with synthetic features."""

    def __init__(self, graph, d_feat: int, num_classes: int, arch: str,
                 seed: int = 0, core_order: bool = False, pad_nodes: int = 0):
        rng = np.random.default_rng(seed)
        if core_order:
            # degeneracy-order relabeling (the paper's ordering as a
            # locality-improving preprocessing step; DESIGN.md §8)
            from ..core.imcore import imcore_peel
            order = np.argsort(-imcore_peel(graph), kind="stable")
            perm = np.empty(graph.n, dtype=np.int64)
            perm[order] = np.arange(graph.n)
            graph = graph.relabel(perm)
        self.graph = graph
        src, dst = graph.directed_pairs()
        self.batch = {"src": src.astype(np.int32), "dst": np.asarray(dst, np.int32)}
        n = graph.n
        if arch == "schnet":
            self.batch |= {"z": rng.integers(1, 90, n).astype(np.int32),
                           "pos": rng.normal(size=(n, 3)).astype(np.float32),
                           "y": rng.normal(size=n).astype(np.float32)}
        elif arch == "egnn":
            self.batch |= {"x": rng.normal(size=(n, d_feat)).astype(np.float32),
                           "pos": rng.normal(size=(n, 3)).astype(np.float32),
                           "y": rng.normal(size=n).astype(np.float32)}
        else:
            self.batch |= {"x": rng.normal(size=(n, d_feat)).astype(np.float32),
                           "labels": rng.integers(0, num_classes, n).astype(np.int32)}
        if pad_nodes:  # specs reserve dummy sink rows
            for k in ("x", "z", "pos", "y"):
                if k in self.batch:
                    pad = np.zeros((pad_nodes,) + self.batch[k].shape[1:],
                                   self.batch[k].dtype)
                    self.batch[k] = np.concatenate([self.batch[k], pad])

    def __call__(self, step: int) -> dict:
        return self.batch


class SampledGraphSource:
    """minibatch_lg: real two-hop neighbor sampling -> flattened subgraph."""

    def __init__(self, graph, d_feat: int, num_classes: int, batch_nodes: int,
                 fanout=(15, 10), seed: int = 0):
        from ..graph.sampler import NeighborSampler

        self.graph = graph
        self.sampler = NeighborSampler(graph, seed)
        self.d_feat, self.num_classes = d_feat, num_classes
        self.batch_nodes, self.fanout = batch_nodes, fanout
        rng = np.random.default_rng(seed)
        self.features = rng.normal(size=(graph.n, d_feat)).astype(np.float32)
        self.labels = rng.integers(0, num_classes, graph.n).astype(np.int32)

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((17, step))
        seeds = rng.integers(0, self.graph.n, self.batch_nodes)
        blocks = self.sampler.sample_batch(seeds, self.fanout)
        b1, b2 = blocks
        B, f1 = b1.neighbors.shape
        f2 = b2.neighbors.shape[1]
        # flattened node set: [seeds | hop1 | hop2], seeds first
        nodes = np.concatenate(
            [seeds, b1.neighbors.reshape(-1), b2.neighbors.reshape(-1)])
        # local edges: hop1 -> seed, hop2 -> hop1 (both directions)
        h1 = B + np.arange(B * f1)
        h2 = B + B * f1 + np.arange(B * f1 * f2)
        s1 = np.repeat(np.arange(B), f1)
        s2 = np.repeat(h1, f2)
        src = np.concatenate([h1, s1, h2, s2]).astype(np.int32)
        dst = np.concatenate([s1, h1, s2, h2]).astype(np.int32)
        return {
            "x": self.features[nodes],
            "src": src, "dst": dst,
            "labels": self.labels[seeds],
        }


class RecsysSource:
    """Synthetic MIND batches: history, profile bags, target + negatives."""

    def __init__(self, cfg, batch: int, seed: int = 0):
        self.cfg, self.batch, self.seed = cfg, batch, seed

    def __call__(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((self.seed, step))
        return {
            "hist_ids": rng.integers(-1, c.n_items, (self.batch, c.hist_len)).astype(np.int32),
            "profile_ids": rng.integers(
                0, c.profile_vocab,
                (self.batch, c.n_profile_fields, c.profile_bag)).astype(np.int32),
            "target_id": rng.integers(0, c.n_items, self.batch).astype(np.int32),
            "negative_ids": rng.integers(
                0, c.n_items, (self.batch, c.num_sampled_negatives)).astype(np.int32),
        }


class Prefetcher:
    """Bounded background prefetch of step-indexed batches."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
