from .pipeline import (TokenSource, GNNFullGraphSource, SampledGraphSource,
                       RecsysSource, Prefetcher)

__all__ = ["TokenSource", "GNNFullGraphSource", "SampledGraphSource",
           "RecsysSource", "Prefetcher"]
