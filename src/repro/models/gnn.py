"""GNN zoo: GraphSAGE, GCN, SchNet, EGNN on the segment-sum substrate.

Two execution modes shared by all four archs:

* **full-graph** — edge-list message passing via ``segment_sum`` over a
  (possibly device-sharded) edge axis with replicated node state — structurally
  the same superstep as the decomposition engine (DESIGN.md §5).  JAX has no
  EmbeddingBag/CSR: the scatter substrate *is* part of this system.
* **sampled blocks** — dense (B, fanout, ...) two-hop batches from the real
  neighbor sampler (``minibatch_lg``), fully dense ops.

SchNet/EGNN consume stub modality frontends (positions / atomic numbers are
inputs, per the assignment note).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import GNNConfig
from .params import Spec

F32 = jnp.float32


# ------------------------------------------------------------------ helpers
def _mlp_specs(d_in, d_hidden, d_out, name_dims=("embed", "mlp", "embed")):
    return {
        "w1": Spec((d_in, d_hidden), F32, (name_dims[0], name_dims[1])),
        "b1": Spec((d_hidden,), F32, (name_dims[1],), init="zeros"),
        "w2": Spec((d_hidden, d_out), F32, (name_dims[1], name_dims[2])),
        "b2": Spec((d_out,), F32, (name_dims[2],), init="zeros"),
    }


def _mlp(p, x, act=jax.nn.silu):
    return act(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _segsum(vals, idx, n):
    return jax.ops.segment_sum(vals, idx, num_segments=n)


def _degree(dst, n):
    return jnp.maximum(_segsum(jnp.ones_like(dst, F32), dst, n), 1.0)


# ================================================================= GraphSAGE
def graphsage_param_specs(cfg: GNNConfig, d_in: int) -> dict:
    d = cfg.d_hidden
    dims = [d_in] + [d] * cfg.n_layers
    layers = {}
    for i in range(cfg.n_layers):
        layers[f"l{i}"] = {
            "w_self": Spec((dims[i], d), F32, ("embed", "mlp")),
            "w_nbr": Spec((dims[i], d), F32, ("embed", "mlp")),
            "b": Spec((d,), F32, ("mlp",), init="zeros"),
        }
    layers["head"] = Spec((d, cfg.num_classes), F32, ("mlp", None))
    return layers


def graphsage_forward(params, cfg: GNNConfig, x, src, dst, n):
    deg = _degree(dst, n)[:, None]
    h = x
    for i in range(cfg.n_layers):
        p = params[f"l{i}"]
        agg = _segsum(jnp.take(h, src, axis=0), dst, n) / deg
        h = jax.nn.relu(h @ p["w_self"] + agg @ p["w_nbr"] + p["b"])
    return h @ params["head"]


# ====================================================================== GCN
def gcn_param_specs(cfg: GNNConfig, d_in: int) -> dict:
    d = cfg.d_hidden
    dims = [d_in] + [d] * cfg.n_layers
    layers = {
        f"l{i}": {"w": Spec((dims[i], d), F32, ("embed", "mlp")),
                  "b": Spec((d,), F32, ("mlp",), init="zeros")}
        for i in range(cfg.n_layers)
    }
    layers["head"] = Spec((d, cfg.num_classes), F32, ("mlp", None))
    return layers


def gcn_forward(params, cfg: GNNConfig, x, src, dst, n):
    deg = _degree(dst, n)
    coef = (1.0 / jnp.sqrt(jnp.take(deg, src) * jnp.take(deg, dst)))[:, None]
    h = x
    for i in range(cfg.n_layers):
        p = params[f"l{i}"]
        msg = _segsum(jnp.take(h, src, axis=0) * coef, dst, n)
        h = jax.nn.relu(msg @ p["w"] + p["b"])
    return h @ params["head"]


# =================================================================== SchNet
def schnet_param_specs(cfg: GNNConfig, d_in: int = 0) -> dict:
    d, R = cfg.d_hidden, cfg.n_rbf
    sp = {"embed": Spec((100, d), F32, (None, "embed"), scale=1.0)}  # z <= 100
    for i in range(cfg.n_layers):
        sp[f"int{i}"] = {
            "filter": _mlp_specs(R, d, d, (None, "mlp", "embed")),
            "w_in": Spec((d, d), F32, ("embed", "mlp")),
            "out": _mlp_specs(d, d, d),
        }
    sp["readout"] = _mlp_specs(d, d // 2, 1, ("embed", "mlp", None))
    return sp


def _rbf_expand(dist, n_rbf, cutoff):
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2)


def schnet_forward(params, cfg: GNNConfig, z, pos, src, dst, n):
    """Returns per-atom energies (n,); pooling happens in the loss."""
    h = jnp.take(params["embed"], jnp.clip(z, 0, 99), axis=0)
    dist = jnp.linalg.norm(jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0) + 1e-9,
                           axis=-1)
    rbf = _rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    for i in range(cfg.n_layers):
        p = params[f"int{i}"]
        w = _mlp(p["filter"], rbf)                        # (E, d) cfconv filter
        msg = _segsum(jnp.take(h @ p["w_in"], src, axis=0) * w, dst, n)
        h = h + _mlp(p["out"], msg)
    return _mlp(params["readout"], h)[:, 0]


# ===================================================================== EGNN
def egnn_param_specs(cfg: GNNConfig, d_in: int) -> dict:
    d = cfg.d_hidden
    sp = {"embed_in": Spec((d_in, d), F32, ("embed", "mlp"))}
    for i in range(cfg.n_layers):
        sp[f"l{i}"] = {
            "edge": _mlp_specs(2 * d + 1, d, d, (None, "mlp", "embed")),
            "coord": _mlp_specs(d, d, 1, ("embed", "mlp", None)),
            "node": _mlp_specs(2 * d, d, d, (None, "mlp", "embed")),
        }
    sp["head"] = _mlp_specs(d, d, 1, ("embed", "mlp", None))
    return sp


def egnn_forward(params, cfg: GNNConfig, x, pos, src, dst, n):
    """Returns (per-node energies (n,), updated positions)."""
    h = x @ params["embed_in"]
    deg = _degree(dst, n)[:, None]
    for i in range(cfg.n_layers):
        p = params[f"l{i}"]
        hs, hd = jnp.take(h, src, axis=0), jnp.take(h, dst, axis=0)
        rel = jnp.take(pos, dst, axis=0) - jnp.take(pos, src, axis=0)
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = _mlp(p["edge"], jnp.concatenate([hd, hs, d2], axis=-1))   # (E, d)
        # E(n)-equivariant coordinate update
        cw = _mlp(p["coord"], m)                                      # (E, 1)
        pos = pos + _segsum(rel * cw, dst, n) / deg
        agg = _segsum(m, dst, n)
        h = h + _mlp(p["node"], jnp.concatenate([h, agg], axis=-1))
    return _mlp(params["head"], h)[:, 0], pos


# ------------------------------------------------------------------- losses
def gnn_param_specs(cfg: GNNConfig, d_in: int) -> dict:
    return {
        "graphsage": graphsage_param_specs,
        "gcn": gcn_param_specs,
        "schnet": lambda c, d: schnet_param_specs(c),
        "egnn": egnn_param_specs,
    }[cfg.arch](cfg, d_in)


def gnn_loss(params, cfg: GNNConfig, batch: dict) -> jax.Array:
    """Unified train loss across archs, modes, and shape cells.

    Every mode is an edge list over a (padded, static-size) node set:
    full-graph cells use the whole graph; ``minibatch_lg`` uses the flattened
    sampled subgraph with the B seed nodes first (loss over seeds only);
    ``molecule`` uses a batched disjoint union with ``graph_ids`` pooling.
    """
    n = batch["num_nodes"]
    src, dst = batch["src"], batch["dst"]
    if cfg.arch == "graphsage":
        logits = graphsage_forward(params, cfg, batch["x"], src, dst, n)
    elif cfg.arch == "gcn":
        logits = gcn_forward(params, cfg, batch["x"], src, dst, n)
    elif cfg.arch == "schnet":
        node_out = schnet_forward(params, cfg, batch["z"], batch["pos"], src, dst, n)
    elif cfg.arch == "egnn":
        node_out, _ = egnn_forward(params, cfg, batch["x"], batch["pos"], src, dst, n)
    else:
        raise ValueError(cfg.arch)

    if cfg.arch in ("graphsage", "gcn"):
        labels = batch["labels"]
        B = labels.shape[0]
        return _xent(logits[:B], labels)  # seeds-first (or all nodes)
    # energy regression
    y = batch["y"]
    if "graph_ids" in batch:  # molecule: pool per graph
        e = _segsum(node_out, batch["graph_ids"], y.shape[0])
    else:
        e = node_out[: y.shape[0]]
    return jnp.mean((e - y) ** 2)


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
