from . import params, layers, transformer, moe, gnn, recsys

__all__ = ["params", "layers", "transformer", "moe", "gnn", "recsys"]
