"""Shared neural layers: RMSNorm, RoPE, SwiGLU, chunked flash-style attention."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _unroll_scans() -> bool:
    """Dry-run metric mode: unroll internal scans so XLA's cost analysis sees
    every iteration (HloCostAnalysis counts a `while` body once)."""
    return os.environ.get("REPRO_UNROLL_SCANS") == "1"


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding; x (..., S, H, d), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def chunked_attention(q, k, v, *, chunk: int = 1024, causal: bool = True,
                      q_offset=0, kv_len=None):
    """Flash-style streaming attention in pure JAX (lax.scan over KV chunks).

    q (B, S, H, d); k/v (B, T, Hkv, d) with GQA groups G = H // Hkv.
    Never materializes the (S, T) score matrix — per-chunk (S, chunk) only —
    so 32k prefill fits per-device memory (DESIGN.md §5).
    """
    B, S, H, d = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # MLA: value head dim may differ from q/k head dim
    G = H // Hkv
    scale = 1.0 / (d ** 0.5)
    nchunks = -(-T // chunk)
    Tp = nchunks * chunk
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qg = q.reshape(B, S, Hkv, G, d)
    kc = k.reshape(B, nchunks, chunk, Hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, Hkv, dv).transpose(1, 0, 2, 3, 4)
    q_pos = (jnp.arange(S) + q_offset)[:, None]
    valid_len = T if kv_len is None else kv_len

    def body(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        base = ci * chunk
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = base + jnp.arange(chunk)[None, :]
        mask = kpos < valid_len
        if causal:
            mask = mask & (kpos <= q_pos)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nchunks), kc, vc),
        unroll=nchunks if _unroll_scans() else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len):
    """One-token attention over a (possibly sequence-sharded) KV cache.

    q (B, 1, H, d); caches (B, T, Hkv, d).  Plain einsum + masked softmax:
    under SPMD with the cache sequence axis sharded, XLA lowers the reduction
    to per-shard partials + psum (the flash-combine of DESIGN.md §5); the
    Pallas flash_decode kernel is the single-chip optimized variant.
    """
    B, _, H, d = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, d)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32) / (d ** 0.5)
    mask = jnp.arange(T)[None, None, None, :] < jnp.reshape(cache_len, (-1, 1, 1, 1))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, d).astype(q.dtype)
