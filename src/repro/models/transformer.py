"""LM transformer: GQA (+qk_norm), MLA (DeepSeek), MoE, MTP; train & serve steps.

Layers are stacked (leading dim = group depth) and run under ``jax.lax.scan``
with per-layer remat — compile time is O(1) in depth; memory saves only layer
inputs.  DeepSeek's first-k-dense prefix is a second stacked group.  MLA decode
uses the *compressed latent cache* (kv_lora + rope dims per token — 576 B not
64 KiB) with the weight-absorption trick, which is what makes the long_500k
cell feasible.  Logical parameter axes: embed / heads / kv_heads / mlp / vocab
/ expert (mapped to mesh axes per shape cell; see launch/dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from .params import Spec
from .layers import (rms_norm, rope, chunked_attention, decode_attention,
                     NEG_INF, _unroll_scans)
from .moe import moe_param_specs, moe_apply

F32 = jnp.float32


# ---------------------------------------------------------------- param specs
def _attn_specs(cfg: LMConfig, L: int) -> dict:
    E, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "wq_a": Spec((L, E, m.q_lora), dt, (None, "embed", None)),
            "q_norm": Spec((L, m.q_lora), F32, (None, None), init="ones"),
            "wq_b": Spec((L, m.q_lora, H * (m.dh_nope + m.dh_rope)), dt,
                         (None, None, "heads")),
            "wkv_a": Spec((L, E, m.kv_lora + m.dh_rope), dt, (None, "embed", None)),
            "kv_norm": Spec((L, m.kv_lora), F32, (None, None), init="ones"),
            "wk_b": Spec((L, m.kv_lora, H * m.dh_nope), dt, (None, None, "heads")),
            "wv_b": Spec((L, m.kv_lora, H * m.dh_v), dt, (None, None, "heads")),
            "wo": Spec((L, H * m.dh_v, E), dt, (None, "heads", "embed")),
        }
    sp = {
        "wq": Spec((L, E, H * dh), dt, (None, "embed", "heads")),
        "wk": Spec((L, E, Hkv * dh), dt, (None, "embed", "kv_heads")),
        "wv": Spec((L, E, Hkv * dh), dt, (None, "embed", "kv_heads")),
        "wo": Spec((L, H * dh, E), dt, (None, "heads", "embed")),
    }
    if cfg.qk_norm:
        sp["q_norm"] = Spec((L, dh), F32, (None, None), init="ones")
        sp["k_norm"] = Spec((L, dh), F32, (None, None), init="ones")
    return sp


def _dense_mlp_specs(cfg: LMConfig, L: int) -> dict:
    E, dt = cfg.d_model, cfg.dtype
    return {
        "w_gate": Spec((L, E, cfg.d_ff), dt, (None, "embed", "mlp")),
        "w_up": Spec((L, E, cfg.d_ff), dt, (None, "embed", "mlp")),
        "w_down": Spec((L, cfg.d_ff, E), dt, (None, "mlp", "embed")),
    }


def _layer_group_specs(cfg: LMConfig, L: int, use_moe: bool) -> dict:
    E = cfg.d_model
    g = {
        "attn": _attn_specs(cfg, L),
        "ln_attn": Spec((L, E), F32, (None, "embed"), init="ones"),
        "ln_mlp": Spec((L, E), F32, (None, "embed"), init="ones"),
    }
    if use_moe:
        g["moe"] = moe_param_specs(cfg, L)
    else:
        g["mlp"] = _dense_mlp_specs(cfg, L)
    return g


def layer_groups(cfg: LMConfig) -> list[tuple[str, int, bool]]:
    """[(group name, depth, uses_moe)]; DeepSeek has a dense prefix group."""
    kd = cfg.moe.first_k_dense if cfg.moe is not None else 0
    groups = []
    if kd:
        groups.append(("layers0", kd, False))
    groups.append(("layers", cfg.n_layers - kd, cfg.moe is not None))
    return groups


def lm_param_specs(cfg: LMConfig) -> dict:
    E, dt = cfg.d_model, cfg.dtype
    specs = {
        "embed": Spec((cfg.vocab, E), dt, ("vocab", "embed"), scale=1.0),
        "ln_f": Spec((E,), F32, ("embed",), init="ones"),
        "lm_head": Spec((E, cfg.vocab), dt, ("embed", "vocab")),
    }
    for name, depth, use_moe in layer_groups(cfg):
        specs[name] = _layer_group_specs(cfg, depth, use_moe)
    if cfg.mtp_depth > 0:
        D = cfg.mtp_depth
        specs["mtp"] = {
            "proj": Spec((D, 2 * E, E), dt, (None, "embed", None)),
            "ln_in": Spec((D, E), F32, (None, "embed"), init="ones"),
            "ln_prev": Spec((D, E), F32, (None, "embed"), init="ones"),
            "mlp": _dense_mlp_specs(cfg, D),
        }
    return specs


# ------------------------------------------------------------------- attention
def _gqa_qkv(p, cfg: LMConfig, x, positions):
    B, S, E = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_qkv_full(p, cfg: LMConfig, x, positions):
    """MLA decompressed form (train/prefill: full per-head k, v)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.dh_nope + m.dh_rope)
    q_nope, q_rope = q[..., : m.dh_nope], q[..., m.dh_nope:]
    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., : m.kv_lora], p["kv_norm"])
    k_rope = kv_a[..., m.kv_lora:][:, :, None, :]
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, m.dh_nope)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, m.dh_v)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.dh_rope))], axis=-1)
    return q, k, v


def _mla_decode(p, cfg: LMConfig, x, positions, cache):
    """Latent-cache decode with weight absorption: cache is (ckv, kr) only."""
    m = cfg.mla
    B, S, _ = x.shape            # S == new tokens (1 for decode)
    H = cfg.n_heads
    ckv_c, kr_c, length = cache
    T = ckv_c.shape[1]
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.dh_nope + m.dh_rope)
    q_nope, q_rope = q[..., : m.dh_nope], q[..., m.dh_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., : m.kv_lora], p["kv_norm"])       # (B,S,kvl)
    k_rope = rope(kv_a[:, :, None, m.kv_lora:], positions, cfg.rope_theta)[:, :, 0]
    ckv_c = jax.lax.dynamic_update_slice_in_dim(
        ckv_c, c_kv.astype(ckv_c.dtype), length, axis=1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(
        kr_c, k_rope.astype(kr_c.dtype), length, axis=1)
    # absorb wk_b into q: q_abs (B,S,H,kvl)
    wk = p["wk_b"].reshape(m.kv_lora, H, m.dh_nope)
    q_abs = jnp.einsum("bshn,khn->bshk", q_nope, wk)
    scale = 1.0 / ((m.dh_nope + m.dh_rope) ** 0.5)
    s = (jnp.einsum("bshk,btk->bhst", q_abs.astype(F32), ckv_c.astype(F32))
         + jnp.einsum("bshr,btr->bhst", q_rope.astype(F32), kr_c.astype(F32))) * scale
    mask = jnp.arange(T)[None, None, None, :] < jnp.reshape(length + S, (-1, 1, 1, 1))
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btk->bshk", pr, ckv_c.astype(F32))   # latent context
    wv = p["wv_b"].reshape(m.kv_lora, H, m.dh_v)
    out = jnp.einsum("bshk,khv->bshv", ctx, wv.astype(F32))
    out = out.reshape(B, S, H * m.dh_v).astype(x.dtype)
    return out @ p["wo"], (ckv_c, kr_c)


def attention_block(p, cfg: LMConfig, x, positions, cache=None):
    """Returns (out, new cache arrays or None)."""
    B, S, _ = x.shape
    if cache is not None and cfg.mla is not None:
        return _mla_decode(p, cfg, x, positions, cache)
    qkv = _mla_qkv_full if cfg.mla is not None else _gqa_qkv
    q, k, v = qkv(p, cfg, x, positions)
    if cache is None:
        return chunked_attention(q, k, v, causal=True).reshape(B, S, -1) @ p["wo"], None
    k_cache, v_cache, length = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), length, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), length, axis=1)
    out = decode_attention(q, k_cache, v_cache, length + S)
    return out.reshape(B, S, -1) @ p["wo"], (k_cache, v_cache)


# ------------------------------------------------------------------- layers
def _dense_mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def _layer(cfg: LMConfig, x, lp, positions, use_moe, cache=None):
    a, new_kv = attention_block(lp["attn"], cfg, rms_norm(x, lp["ln_attn"]),
                                positions, cache)
    x = x + a
    h = rms_norm(x, lp["ln_mlp"])
    f = moe_apply(lp["moe"], cfg, h) if use_moe else _dense_mlp(lp["mlp"], h)
    return x + f, new_kv


def lm_forward(params, cfg: LMConfig, tokens, positions=None, caches=None):
    """tokens (B, S) -> (hidden (B, S, E), new caches or None)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)

    groups = layer_groups(cfg)
    new_cache_parts = {}
    offset = 0
    for name, depth, use_moe in groups:
        gp = params[name]
        if caches is None:
            def body(carry, lp, _moe=use_moe):
                y, _ = _layer(cfg, carry, lp, positions, _moe)
                return y, None

            x, _ = jax.lax.scan(jax.checkpoint(body), x, gp,
                                unroll=depth if _unroll_scans() else 1)
        else:
            length = caches["len"]
            cache_keys = [k for k in caches if k != "len"]
            slices = tuple(caches[k][offset:offset + depth] for k in cache_keys)

            def body(carry, inp, _moe=use_moe):
                lp = inp[0]
                y, new_kv = _layer(cfg, carry, lp, positions, _moe,
                                   cache=(*inp[1:], length))
                return y, new_kv

            x, kvs = jax.lax.scan(body, x, (gp, *slices))
            for k, arr in zip(cache_keys, kvs):
                new_cache_parts.setdefault(k, []).append(arr)
        offset += depth

    if caches is None:
        new_caches = None
    else:
        new_caches = {
            k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
            for k, v in new_cache_parts.items()
        }
        new_caches["len"] = caches["len"] + S
    return rms_norm(x, params["ln_f"]), new_caches


def lm_logits(params, cfg: LMConfig, hidden):
    return hidden @ params["lm_head"]


# ---------------------------------------------------------------------- steps
def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


def lm_loss(params, cfg: LMConfig, tokens, labels):
    hidden, _ = lm_forward(params, cfg, tokens)
    loss = softmax_xent(lm_logits(params, cfg, hidden), labels)
    if cfg.mtp_depth > 0:
        loss = loss + 0.3 * _mtp_loss(params, cfg, hidden, tokens, labels)
    return loss


def _mtp_loss(params, cfg: LMConfig, hidden, tokens, labels):
    """DeepSeek-V3 multi-token prediction: chained extra-depth predictions."""
    mtp = params["mtp"]
    h = hidden
    total = 0.0
    for d in range(cfg.mtp_depth):
        nxt = jnp.roll(tokens, -(d + 1), axis=1)
        e = jnp.take(params["embed"], nxt, axis=0).astype(cfg.dtype)
        h = jnp.concatenate(
            [rms_norm(h, mtp["ln_prev"][d]), rms_norm(e, mtp["ln_in"][d])], axis=-1
        ) @ mtp["proj"][d]
        h = h + _dense_mlp(jax.tree.map(lambda a: a[d], mtp["mlp"]), h)
        total = total + softmax_xent(
            lm_logits(params, cfg, h), jnp.roll(labels, -(d + 1), axis=1))
    return total / cfg.mtp_depth


def make_kv_cache_specs(cfg: LMConfig, batch: int, max_len: int):
    """Decode-cache avals; MLA uses the compressed latent cache."""
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((L, batch, max_len, m.kv_lora), cfg.dtype),
            "kr": jax.ShapeDtypeStruct((L, batch, max_len, m.dh_rope), cfg.dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, cfg.n_kv, cfg.head_dim), cfg.dtype),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, cfg.n_kv, cfg.head_dim), cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def serve_prefill(params, cfg: LMConfig, tokens):
    hidden, _ = lm_forward(params, cfg, tokens)
    return lm_logits(params, cfg, hidden[:, -1:, :])


def serve_decode(params, cfg: LMConfig, tokens, caches):
    """One decode step: tokens (B, 1) + caches -> (logits, new caches)."""
    B = tokens.shape[0]
    positions = jnp.broadcast_to(caches["len"][None, None], (B, 1))
    hidden, new_caches = lm_forward(params, cfg, tokens, positions, caches)
    return lm_logits(params, cfg, hidden), new_caches
