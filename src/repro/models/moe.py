"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch is the production "dropping" scheme: flatten (token, k) assignments,
sort by expert, take the first C per expert (capacity factor), scatter into an
(experts, C, E) buffer sharded expert->model / capacity->data — the scatter
and the combine-gather are where SPMD inserts the all-to-all traffic that the
roofline's collective term measures.  Expert FFNs are a single batched einsum
over the expert axis (local to each model shard).

Supports DeepSeek-style shared experts + first-k-dense layers and Arctic's
parallel dense residual MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import Spec

F32 = jnp.float32


def moe_param_specs(cfg, L: int) -> dict:
    m, E, dt = cfg.moe, cfg.d_model, cfg.dtype
    X, F = m.num_experts, m.d_ff_expert
    sp = {
        "router": Spec((L, E, X), F32, (None, "embed", None)),
        # experts ride the model axis; their embed dim is sharded over the
        # batch axes (2D expert sharding — 480B/671B would not fit TP-only)
        "w_gate": Spec((L, X, E, F), dt, (None, "expert", "expert_embed", None)),
        "w_up": Spec((L, X, E, F), dt, (None, "expert", "expert_embed", None)),
        "w_down": Spec((L, X, F, E), dt, (None, "expert", None, "expert_embed")),
    }
    if m.num_shared:
        Fs = F * m.num_shared
        sp["shared"] = {
            "w_gate": Spec((L, E, Fs), dt, (None, "embed", "mlp")),
            "w_up": Spec((L, E, Fs), dt, (None, "embed", "mlp")),
            "w_down": Spec((L, Fs, E), dt, (None, "mlp", "embed")),
        }
    if m.dense_parallel:
        sp["dense"] = {
            "w_gate": Spec((L, E, cfg.d_ff), dt, (None, "embed", "mlp")),
            "w_up": Spec((L, E, cfg.d_ff), dt, (None, "embed", "mlp")),
            "w_down": Spec((L, cfg.d_ff, E), dt, (None, "mlp", "embed")),
        }
    return sp


def _swiglu(x, g, u, d):
    return (jax.nn.silu(x @ g) * (x @ u)) @ d


def moe_apply(p, cfg, x, layer_idx=None, aux=None):
    """x (B, S, E) -> (B, S, E).  Dropping top-k dispatch (see module doc)."""
    m = cfg.moe
    B, S, E = x.shape
    T = B * S
    X, k = m.num_experts, m.top_k
    xt = x.reshape(T, E)

    logits = xt.astype(F32) @ p["router"].astype(F32)          # (T, X)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    C = max(8, int(T * k / X * m.capacity_factor))
    flat_e = top_e.reshape(-1).astype(jnp.int32)               # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(X, dtype=jnp.int32))
    pos = jnp.arange(T * k, dtype=jnp.int32) - jnp.take(start, sorted_e)
    keep = pos < C
    slot_e = jnp.where(keep, sorted_e, X)                      # drop -> dummy
    slot_p = jnp.where(keep, pos, 0)
    tok = order // k

    buf = jnp.zeros((X + 1, C, E), x.dtype).at[slot_e, slot_p].set(
        jnp.take(xt, tok, axis=0))
    h = buf[:X]                                                # (X, C, E)
    h = jax.nn.silu(jnp.einsum("xce,xef->xcf", h, p["w_gate"])) * jnp.einsum(
        "xce,xef->xcf", h, p["w_up"])
    out_buf = jnp.einsum("xcf,xfe->xce", h, p["w_down"])       # (X, C, E)

    gathered = out_buf[jnp.minimum(slot_e, X - 1), slot_p]     # (T*k, E)
    gate = jnp.take(top_p.reshape(-1), order) * keep
    y = jnp.zeros((T, E), x.dtype).at[tok].add(
        (gathered.astype(F32) * gate[:, None]).astype(x.dtype))

    if m.num_shared:
        s = p["shared"]
        y = y + _swiglu(xt, s["w_gate"], s["w_up"], s["w_down"])
    if m.dense_parallel:
        d = p["dense"]
        y = y + _swiglu(xt, d["w_gate"], d["w_up"], d["w_down"])
    if aux is not None:
        # Switch-style load-balance loss terms
        me = probs.mean(axis=0)
        ce = jnp.zeros(X, F32).at[flat_e].add(1.0) / (T * k)
        aux["load_balance"] = X * jnp.sum(me * ce)
    return y.reshape(B, S, E)
