"""MIND: Multi-Interest Network with Dynamic Routing (Li et al., CIKM'19).

User behavior sequence -> B2I dynamic-routing capsules (n_interests) ->
label-aware attention training with sampled-softmax negatives; serving scores
candidates by max-over-interests dot product (``retrieval_cand`` = one user
vs 10^6 candidates as a batched matmul + top-k, never a loop).

The item table is the semi-external object here: rows sharded over the model
axis, O(batch) activation state; the user-profile multi-hot fields go through
the EmbeddingBag primitive (Pallas kernel on TPU, XLA fallback otherwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import RecsysConfig
from .params import Spec
from ..kernels import ops as kops

F32 = jnp.float32


def mind_param_specs(cfg: RecsysConfig) -> dict:
    D = cfg.embed_dim
    return {
        "item_embed": Spec((cfg.n_items, D), F32, ("rows", "embed"), scale=0.1),
        "profile_embed": Spec((cfg.profile_vocab, D), F32, ("rows", "embed"),
                              scale=0.1),
        "bilinear": Spec((D, D), F32, ("embed", "embed2")),  # routing S matrix
        "profile_proj": Spec((cfg.n_profile_fields * D, D), F32, (None, "embed")),
        "mlp": {
            "w1": Spec((2 * D, cfg.mlp_dim), F32, ("embed", "mlp")),
            "b1": Spec((cfg.mlp_dim,), F32, ("mlp",), init="zeros"),
            "w2": Spec((cfg.mlp_dim, D), F32, ("mlp", "embed")),
            "b2": Spec((D,), F32, ("embed",), init="zeros"),
        },
    }


def _squash(z, axis=-1):
    n2 = jnp.sum(z * z, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + 1e-9)


def dynamic_routing(e, mask, n_interests: int, iters: int):
    """B2I routing: behaviors e (B, L, D) -> interest capsules (B, K, D)."""
    B, L, D = e.shape
    K = n_interests
    logits = jnp.zeros((B, K, L), F32)
    caps = jnp.zeros((B, K, D), F32)
    neg = jnp.asarray(-1e30, F32)
    for _ in range(iters):
        w = jax.nn.softmax(jnp.where(mask[:, None, :], logits, neg), axis=1)
        z = jnp.einsum("bkl,bld->bkd", w * mask[:, None, :], e)
        caps = _squash(z)
        logits = logits + jnp.einsum("bkd,bld->bkl", caps, e)
    return caps


def user_interests(params, cfg: RecsysConfig, hist_ids, profile_ids,
                   use_pallas_bag: bool = False):
    """(B, hist_len) history + (B, fields, bag) profile -> (B, K, D)."""
    B = hist_ids.shape[0]
    D = cfg.embed_dim
    mask = hist_ids >= 0
    e = jnp.take(params["item_embed"], jnp.maximum(hist_ids, 0), axis=0)
    e = e @ params["bilinear"]  # shared bilinear map (B2I)
    caps = dynamic_routing(e, mask, cfg.n_interests, cfg.capsule_iters)
    # profile: one EmbeddingBag per multi-hot field
    flat = profile_ids.reshape(B * cfg.n_profile_fields, -1)
    bags = kops.embedding_bag(
        params["profile_embed"], flat, mode="mean",
        use_pallas=use_pallas_bag, interpret=use_pallas_bag,
    ).reshape(B, cfg.n_profile_fields * D)
    prof = bags @ params["profile_proj"]  # (B, D)
    h = jnp.concatenate(
        [caps, jnp.broadcast_to(prof[:, None, :], caps.shape)], axis=-1)
    m = params["mlp"]
    out = jax.nn.relu(h @ m["w1"] + m["b1"]) @ m["w2"] + m["b2"]
    return out  # (B, K, D)


def label_aware_attention(caps, target_e, p: float = 2.0):
    """MIND eq. (6): soft attention of the label over interests."""
    s = jnp.einsum("bkd,bd->bk", caps, target_e)
    w = jax.nn.softmax((jnp.abs(s) + 1e-9) ** p * jnp.sign(s), axis=-1)
    return jnp.einsum("bk,bkd->bd", w, caps)


def mind_train_loss(params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    """Sampled softmax: target vs `num_sampled_negatives` uniform negatives."""
    caps = user_interests(params, cfg, batch["hist_ids"], batch["profile_ids"])
    tgt = jnp.take(params["item_embed"], batch["target_id"], axis=0)  # (B, D)
    user = label_aware_attention(caps, tgt)
    negs = jnp.take(params["item_embed"], batch["negative_ids"], axis=0)  # (B,M,D)
    pos_logit = jnp.einsum("bd,bd->b", user, tgt)[:, None]
    neg_logit = jnp.einsum("bd,bmd->bm", user, negs)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -logp[:, 0].mean()


def mind_serve(params, cfg: RecsysConfig, batch: dict) -> jax.Array:
    """Online inference: user interest vectors (serve_p99 / serve_bulk)."""
    return user_interests(params, cfg, batch["hist_ids"], batch["profile_ids"])


def mind_retrieval(params, cfg: RecsysConfig, batch: dict, top_k: int = 100):
    """Score one user's interests against `n_candidates` items (batched dot)."""
    caps = user_interests(params, cfg, batch["hist_ids"], batch["profile_ids"])
    cand = jnp.take(params["item_embed"], batch["candidate_ids"], axis=0)  # (C,D)
    scores = jnp.einsum("bkd,cd->bkc", caps, cand).max(axis=1)  # (B, C)
    vals, idx = jax.lax.top_k(scores, min(top_k, scores.shape[-1]))
    return vals, idx
