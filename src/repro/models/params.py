"""Parameter spec trees: one definition drives init, dry-run avals & sharding.

A model's parameters are declared once as a nested dict of :class:`Spec`
leaves carrying (shape, dtype, logical axes, init).  From that single tree we
derive: real initialization (small configs), ShapeDtypeStructs (dry-run — no
allocation), and NamedShardings (logical axes -> mesh axes via per-cell rules).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclass(frozen=True)
class Spec:
    shape: tuple
    dtype: Any = jnp.float32
    axes: tuple = ()          # logical axis names (len == ndim; None = unsharded)
    init: str = "normal"      # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")


def _is_spec(x):
    return isinstance(x, Spec)


def tree_avals(spec_tree):
    """ShapeDtypeStruct tree (the dry-run parameter stand-ins)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def tree_init(spec_tree, key):
    """Materialize parameters (reduced/smoke configs only)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            out.append((jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def tree_shardings(spec_tree, mesh: Mesh, rules: dict):
    """Logical-axis names -> mesh axes; unknown/None axes stay replicated."""

    def one(s: Spec):
        axes = s.axes if s.axes else (None,) * len(s.shape)
        pspec = PartitionSpec(*[rules.get(a) if a is not None else None for a in axes])
        return NamedSharding(mesh, pspec)

    return jax.tree.map(one, spec_tree, is_leaf=_is_spec)


def tree_num_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def tree_sharded_bytes(spec_tree, mesh, rules: dict) -> int:
    """Per-chip parameter bytes under the given logical->mesh rules."""
    def frac(s: Spec) -> float:
        f = 1.0
        axes = s.axes if s.axes else (None,) * len(s.shape)
        for a in axes:
            m = rules.get(a) if a is not None else None
            if m is None:
                continue
            names = m if isinstance(m, tuple) else (m,)
            for nm in names:
                if nm in mesh.shape:
                    f *= mesh.shape[nm]
        return f

    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize / frac(s)
                   for s in leaves))


def tree_bytes(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves))
