"""Synthetic mixed update streams for benchmarks, examples, and tests.

Generates an always-valid insert/delete stream against the *evolving* edge
set (deletes pick a live edge, inserts pick a fresh non-edge), deterministic
in ``seed``.  Deleted-edge selection uses swap-remove over a mirrored edge
list, so generation is O(1) per op.
"""
from __future__ import annotations

import numpy as np

__all__ = ["mixed_stream"]


def mixed_stream(g, num_updates: int, seed: int = 0, p_delete: float = 0.45):
    """Return ``(ops, final_edges)``: the op list and the resulting edge set."""
    rng = np.random.default_rng(seed)
    present = {tuple(e) for e in g.edge_list().tolist()}
    ordered = sorted(present)
    ops = []
    for _ in range(num_updates):
        if ordered and rng.random() < p_delete:
            i = rng.integers(len(ordered))
            u, v = ordered[i]
            ordered[i] = ordered[-1]
            ordered.pop()
            present.discard((u, v))
            ops.append(("-", u, v))
        else:
            while True:
                u, v = int(rng.integers(g.n)), int(rng.integers(g.n))
                lo, hi = min(u, v), max(u, v)
                if u != v and (lo, hi) not in present:
                    break
            present.add((lo, hi))
            ordered.append((lo, hi))
            ops.append(("+", lo, hi))
    return ops, present
