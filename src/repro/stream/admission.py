"""Batch admission for the streaming core service.

An edge-update stream arrives as ``("+"/"-", u, v)`` operations.  Before a
micro-batch touches the maintenance algorithms it is *admitted*:

* operations are normalized (self loops dropped, endpoints canonicalized to
  ``u < v``),
* per edge, only the **last** operation in stream order survives — an
  insert+delete pair inside one batch cancels to whatever the final state
  asks for, duplicates collapse (the maintenance pass later resolves the
  surviving op against the actual graph, so "insert an edge that already
  exists" degrades to a counted no-op, never an error), and
* deletions are ordered before insertions.  Deletions only lower cores
  (SemiDelete* settles them with cheap SemiCore* passes); applying them
  first keeps every intermediate ``core`` an upper bound of the final
  decomposition and avoids paying SemiInsert* expansion for nodes a later
  delete would pull back down.

After coalescing, the surviving operations touch distinct edges, so the
delete-first reordering cannot change the batch's net effect.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.update import UpdateBatch

__all__ = ["AdmittedBatch", "admit_batch"]

INSERT = "+"
DELETE = "-"


@dataclass
class AdmittedBatch:
    """A coalesced, reordered micro-batch ready for ``CoreMaintainer.apply``
    (via the :attr:`batch` projection)."""

    deletes: list = field(default_factory=list)  # [(u, v)], u < v
    inserts: list = field(default_factory=list)  # [(u, v)], u < v
    num_requested: int = 0  # raw ops in the incoming batch
    num_dropped: int = 0  # self loops / malformed ops
    num_coalesced: int = 0  # ops superseded by a later op on the same edge

    @property
    def num_admitted(self) -> int:
        return len(self.deletes) + len(self.inserts)

    @property
    def batch(self) -> UpdateBatch:
        """The admitted ops as a typed :class:`UpdateBatch` (deletes first —
        the coalesced order admission decided on)."""
        return UpdateBatch.from_pairs(self.deletes, self.inserts)


def admit_batch(ops, n: int | None = None) -> AdmittedBatch:
    """Normalize, coalesce (last op per edge wins) and reorder a batch.

    With ``n`` given, ops naming nodes outside ``[0, n)`` are dropped (and
    counted) — the node table is fixed-size O(n) state, so an out-of-range
    id can never be applied and must not reach the update buffer.
    """
    last: dict[tuple[int, int], str] = {}
    requested = dropped = 0
    for op in ops:
        requested += 1
        try:
            kind, u, v = op
            u, v = int(u), int(v)
        except (TypeError, ValueError):
            dropped += 1
            continue
        if u == v or kind not in (INSERT, DELETE):
            dropped += 1
            continue
        if n is not None and not (0 <= u < n and 0 <= v < n):
            dropped += 1
            continue
        if u > v:
            u, v = v, u
        last[(u, v)] = kind  # first-seen key order is kept: deterministic
    batch = AdmittedBatch(
        num_requested=requested,
        num_dropped=dropped,
        num_coalesced=requested - dropped - len(last),
    )
    for edge, kind in last.items():
        (batch.deletes if kind == DELETE else batch.inserts).append(edge)
    return batch
