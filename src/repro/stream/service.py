"""CoreWriter: the write side of the CQRS-split streaming core service.

The paper's semi-external contract — O(n) node state in memory, edge table on
disk — is exactly the shape of a long-lived serving process, and §V's
maintenance algorithms are built for continuous updates.  The service is
split CQRS-style (DESIGN.md §15):

* **writes** (``CoreWriter``, this module) — an edge-update stream ingested
  in micro-batches.  Each batch is admitted (normalized / coalesced /
  deletes-first, see admission.py), logged to the write-ahead log as a
  typed op record, then applied through ``CoreMaintainer.apply`` (the
  parallel grouped settle, or SemiDelete*/SemiInsert* when disabled),
  keeping ``core``/``cnt`` exact after every batch;
* **reads** (``QueryAPI``, shared) — ``coreness``, k-core membership, top-k
  by coreness and the degeneracy, answered from an immutable *epoch view*:
  a frozen copy of the O(n) node arrays published atomically after each
  batch commit.  Readers never observe a half-applied batch, and the query
  path performs **zero edge-table I/O** — it never touches the BlockReader.
  Set queries are memoized in an LRU cache that is invalidated on every
  epoch publish.  The same query surface is served by ``CoreReplica``
  (replica.py) from its own WAL-tailed epoch views, which is what lets
  reads scale independently of the single writer;
* **durability** — the WAL records a batch before it is applied; periodic
  snapshots dump (epoch, CSR, core, cnt) atomically and rotate the WAL past
  the snapshot epoch.  Recovery replays the WAL tail structurally and
  warm-restarts SemiCore* from a provable upper bound instead of
  recomputing from scratch (DESIGN.md §9).

``CoreService`` remains as the established name of the writer (it serves
both roles in a single-process deployment).
"""
from __future__ import annotations

import os
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..core.engine import warm_settle
from ..core.maintenance import CoreMaintainer
from ..core.semicore import HostEngine
from ..core.update import Delete, UpdateBatch
from ..graph.storage import CSRGraph, DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from ..obs import metrics as _metrics, trace as _trace
from .admission import AdmittedBatch, admit_batch
from .backpressure import AdmissionController, Overloaded
from .wal import CorruptionError, SnapshotStore, WriteAheadLog

__all__ = [
    "EpochView", "BatchStats", "RecoveryStats", "QueryAPI",
    "CoreWriter", "CoreService", "Overloaded",
    "Watermarked", "WatermarkedArray",
]

# Service-level instrumentation (DESIGN.md §14).  Per-kind query series are
# hoisted once at import so the hot query path pays one perf_counter pair and
# two attribute bumps, nothing else.
_INGEST_SECONDS = _metrics.histogram(
    "repro_service_ingest_seconds",
    "End-to-end micro-batch ingest latency (admit + WAL + apply + publish)",
)
_INGESTS = _metrics.counter(
    "repro_service_batches_total", "Micro-batches ingested").labels()
_QUERY_SECONDS = _metrics.histogram(
    "repro_service_query_seconds", "Query latency by kind")
_QUERIES = _metrics.counter(
    "repro_service_queries_total", "Queries served by kind")
_EPOCH_GAUGE = _metrics.gauge(
    "repro_service_epoch", "Committed epoch watermark").labels()
_BUFFERED_GAUGE = _metrics.gauge(
    "repro_service_buffered_updates",
    "Structural updates buffered in the BufferedGraph awaiting flush").labels()
_QUERY_KINDS = ("coreness", "in_kcore", "kcore_members", "top_k", "degeneracy")
_QOBS = {
    k: (_QUERIES.labels(kind=k), _QUERY_SECONDS.labels(kind=k))
    for k in _QUERY_KINDS
}


# ======================================================= watermarked replies
class Watermarked(int):
    """An int query reply carrying the epoch watermark it was answered at.

    Behaves exactly like ``int`` (equality, hashing, arithmetic) so existing
    callers never notice; readers that care about staleness check ``.epoch``.
    """

    def __new__(cls, value, epoch: int):
        self = super().__new__(cls, value)
        self.epoch = int(epoch)
        return self


class WatermarkedArray(np.ndarray):
    """ndarray view subclass whose ``.epoch`` is the reply's watermark.

    Created as a zero-copy view, so readonly flags and values are exactly the
    wrapped array's — cached replies stay shared and immutable.

    Watermark propagation semantics (pinned by tests/test_stream.py):

    * **derived arrays keep the source epoch** — slices, views, reshapes,
      copies and single-source ufunc results (``members + 1``) answer for
      the same epoch their data came from;
    * **mixed-epoch operands drop to ``None``** — combining replies from
      different epochs produces data that answers for *no* well-defined
      epoch, and a ``None`` watermark says so instead of silently inheriting
      whichever operand numpy templated the result from (the pre-fix
      behavior).  Operands without a watermark (plain ndarrays, scalars, or
      an unstamped ``WatermarkedArray``) don't constrain the epoch: mixing
      a reply with constants keeps the reply's epoch.
    """

    #: class-level default: an array that never got stamped has no watermark.
    epoch = None

    def __array_finalize__(self, obj):
        if obj is not None:
            self.epoch = getattr(obj, "epoch", None)

    def __array_ufunc__(self, ufunc, method, *inputs, **kwargs):
        epochs = {
            x.epoch for x in inputs
            if isinstance(x, WatermarkedArray) and x.epoch is not None
        }
        epoch = epochs.pop() if len(epochs) == 1 else None
        # compute on the plain ndarray views so numpy's subclass templating
        # (which would copy one arbitrary operand's epoch) never runs.
        plain = tuple(
            x.view(np.ndarray) if isinstance(x, WatermarkedArray) else x
            for x in inputs
        )
        out = kwargs.get("out")
        if out is not None:
            kwargs["out"] = tuple(
                o.view(np.ndarray) if isinstance(o, WatermarkedArray) else o
                for o in out
            )
        result = getattr(ufunc, method)(*plain, **kwargs)
        if result is NotImplemented:
            return NotImplemented

        def stamp(r, o):
            if o is not None and isinstance(o, WatermarkedArray):
                o.epoch = epoch  # in-place result: restamp the caller's array
                return o
            if isinstance(r, np.ndarray):
                r = r.view(WatermarkedArray)
                r.epoch = epoch
                return r
            return r  # scalar reductions stay plain python/numpy scalars

        outs = out if out is not None else (None,) * (
            len(result) if isinstance(result, tuple) else 1)
        if isinstance(result, tuple):
            return tuple(stamp(r, o) for r, o in zip(result, outs))
        return stamp(result, outs[0])


def _watermark(value, epoch: int):
    """Stamp a query reply with its epoch watermark (satellite: every
    CoreService reply must carry the epoch it was answered at)."""
    if isinstance(value, np.ndarray):
        out = value.view(WatermarkedArray)
        out.epoch = int(epoch)
        return out
    return Watermarked(int(value), epoch)


# ===================================================================== views
@dataclass(frozen=True)
class EpochView:
    """Immutable snapshot of the node state at one epoch.

    Holds only the O(n) in-memory arrays (read-only); every query below is a
    pure vectorized lookup with no edge-table I/O.
    """

    epoch: int
    core: np.ndarray  # (n,) int64, writeable=False
    deg: np.ndarray  # (n,) int64, writeable=False

    @property
    def n(self) -> int:
        return len(self.core)

    def coreness(self, v):
        """Core number of node ``v`` (int) or of an array of nodes."""
        if np.isscalar(v) or isinstance(v, (int, np.integer)):
            return int(self.core[int(v)])
        return self.core[np.asarray(v, dtype=np.int64)]

    def in_kcore(self, v, k: int):
        """Membership of ``v`` (scalar or array) in the k-core."""
        if np.isscalar(v) or isinstance(v, (int, np.integer)):
            return bool(self.core[int(v)] >= k)
        return self.core[np.asarray(v, dtype=np.int64)] >= k

    def kcore_members(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.core >= k)

    def kcore_size(self, k: int) -> int:
        return int((self.core >= k).sum())

    def top_k(self, k: int) -> np.ndarray:
        """Node ids of the k highest-coreness nodes (ties: lower id first)."""
        n = self.n
        k = min(int(k), n)
        if k <= 0:
            return np.empty(0, dtype=np.int64)
        # partial-select then order, on a tie-free composite key
        # (coreness desc, node id asc): O(n + k log k)
        key = self.core * np.int64(n) - np.arange(n, dtype=np.int64)
        idx = np.argpartition(-key, k - 1)[:k]
        return idx[np.argsort(-key[idx])].astype(np.int64)

    def degeneracy(self) -> int:
        return int(self.core.max()) if self.n else 0

    def core_histogram(self) -> np.ndarray:
        """hist[c] = number of nodes with coreness exactly c."""
        return np.bincount(self.core, minlength=self.degeneracy() + 1)


# ===================================================================== stats
@dataclass
class BatchStats:
    """Per-batch admission + maintenance + I/O stats (DecompResult style)."""

    epoch: int
    num_requested: int
    num_dropped: int
    num_coalesced: int
    num_applied_deletes: int
    num_applied_inserts: int
    num_noops: int
    node_computations: int
    edge_block_reads: int
    node_table_reads: int
    iterations: int
    num_changed: int
    flushes: int
    wall_time_s: float
    # backpressure fields (stage-1 degradation, DESIGN.md §17): a deferred
    # batch was WAL-logged (durable) but coalesced into the pending pool
    # instead of applied; ``pending_updates`` is the pool size afterwards.
    deferred: bool = False
    pending_updates: int = 0


@dataclass
class RecoveryStats:
    """What recovery did, and what it cost vs. a cold decomposition."""

    snapshot_epoch: int
    recovered_epoch: int
    replayed_batches: int
    replayed_updates: int
    applied_deletes: int
    applied_inserts: int
    warm_restart: bool  # False => no WAL tail, snapshot state used as-is
    settle_node_computations: int = 0
    settle_iterations: int = 0
    settle_edge_block_reads: int = 0


class _LRUCache:
    """Tiny LRU for set-valued queries; cleared on every epoch publish."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, value) -> None:
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def clear(self) -> None:
        self._d.clear()


# ============================================================== query surface
class QueryAPI:
    """The read side of the CQRS split: epoch-view queries + LRU memoization.

    Shared verbatim by the writer (``CoreWriter``) and the read replicas
    (``CoreReplica``): both publish immutable :class:`EpochView`s of their
    own O(n) node state and answer every query from the committed view, with
    every reply watermarked by the epoch it was answered at.  Requires the
    host object to provide ``self.epoch``, ``self.maintainer``, ``self.bg``
    and ``self.cache``; publishing calls :meth:`_publish_metrics` so each
    side exports its own gauges (writer epoch vs. replica epoch/lag).
    """

    def _publish(self) -> None:
        """Commit the current node state as the readable epoch view."""
        core = self.maintainer.core.copy()
        core.setflags(write=False)
        deg = np.asarray(self.bg.degrees(), dtype=np.int64)
        deg.setflags(write=False)
        self._view = EpochView(self.epoch, core, deg)
        self.cache.clear()
        self._publish_metrics()

    def _publish_metrics(self) -> None:  # pragma: no cover - overridden
        raise NotImplementedError

    # -------------------------------------------------------------- queries
    def view(self) -> EpochView:
        """The current committed epoch view (stable across later ingests)."""
        return self._view

    def coreness(self, v):
        t0 = time.perf_counter()
        view = self._view
        out = _watermark(view.coreness(v), view.epoch)
        self._query_done("coreness", t0)
        return out

    def in_kcore(self, v, k: int):
        t0 = time.perf_counter()
        view = self._view
        out = _watermark(view.in_kcore(v, k), view.epoch)
        self._query_done("in_kcore", t0)
        return out

    def kcore_members(self, k: int) -> np.ndarray:
        t0 = time.perf_counter()
        view = self._view
        key = (view.epoch, "kcore", int(k))
        out = self.cache.get(key)
        if out is None:
            out = view.kcore_members(k)
            out.setflags(write=False)  # hits are shared across callers
            self.cache.put(key, out)
        out = _watermark(out, view.epoch)
        self._query_done("kcore_members", t0)
        return out

    def top_k(self, k: int) -> np.ndarray:
        t0 = time.perf_counter()
        view = self._view
        key = (view.epoch, "topk", int(k))
        out = self.cache.get(key)
        if out is None:
            out = view.top_k(k)
            out.setflags(write=False)  # hits are shared across callers
            self.cache.put(key, out)
        out = _watermark(out, view.epoch)
        self._query_done("top_k", t0)
        return out

    def degeneracy(self) -> int:
        t0 = time.perf_counter()
        view = self._view
        out = _watermark(view.degeneracy(), view.epoch)
        self._query_done("degeneracy", t0)
        return out

    @staticmethod
    def _query_done(kind: str, t0: float) -> None:
        cnt, hist = _QOBS[kind]
        cnt.inc()
        hist.observe(time.perf_counter() - t0)

    def metrics(self) -> dict:
        """Observability endpoint: the process registry in both formats.

        ``json`` is the full structured dump (families, series, histogram
        buckets); ``prometheus`` is text exposition 0.0.4 ready to serve on a
        ``/metrics`` route.  Stamped with the committed epoch watermark so a
        scraper can correlate metric values with query replies.
        """
        reg = _metrics.get_registry()
        return {
            "epoch": self.epoch,
            "json": reg.to_dict(),
            "prometheus": reg.to_prometheus(),
        }


# ==================================================================== writer
class CoreWriter(QueryAPI):
    """Owns the semi-external node state and serves it under a live stream.

    ``backend`` selects the batch-settle compute substrate ("numpy" | "xla"
    | "pallas" | "shard", DESIGN.md §11/§13); the numpy default keeps the
    paper's per-edge seq maintenance, any other backend ingests each batch
    through one warm-started SemiCore* batch settle on that backend —
    device-resident by default (DESIGN.md §12): the settle's node state
    stays on device across its passes, and the uploaded edge table (sharded
    over the mesh for ``"shard"``) is version-keyed on the long-lived
    maintainer, so a batch that turns out structure-free (all no-ops)
    re-uploads nothing.
    """

    def __init__(
        self,
        graph,
        *,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
        insert_algorithm: str = "semiinsert*",
        wal_path: str | None = None,
        wal_fsync: bool = False,
        snapshot_dir: str | None = None,
        snapshot_every: int = 0,
        snapshot_keep: int = 1,
        cache_size: int = 256,
        state: tuple[np.ndarray, np.ndarray] | None = None,
        epoch: int = 0,
        backend=None,
        superstep_chunk: int | None = None,
        retry=None,
        admission_budget: int = 0,
        admission_soft_ratio: float = 0.5,
        admission_max_defer: int = 4,
        settings=None,
    ):
        # ``settings`` is a repro.runtime.Settings snapshot: one object that
        # carries every REPRO_* knob through the service into the maintainer
        # (env vars still win per the env > override > default order).
        self.maintainer = CoreMaintainer(
            graph, block_edges, state=state, pool_blocks=pool_blocks,
            backend=backend, superstep_chunk=superstep_chunk, retry=retry,
            settings=settings,
        )
        self.bg: BufferedGraph = self.maintainer.bg
        self.insert_algorithm = insert_algorithm
        self.epoch = int(epoch)
        #: the last WAL-durable epoch.  Without backpressure it equals
        #: ``epoch``; with an admission budget it can run ahead while
        #: accepted-but-deferred batches sit in the pending pool.
        self._wal_tip = int(epoch)
        self.wal = WriteAheadLog(wal_path, fsync=wal_fsync) if wal_path else None
        self.snapshots = (
            SnapshotStore(snapshot_dir, keep=snapshot_keep)
            if snapshot_dir else None)
        self.snapshot_every = int(snapshot_every)
        self.admission = (
            AdmissionController(
                admission_budget, soft_ratio=admission_soft_ratio,
                max_defer=admission_max_defer)
            if admission_budget > 0 else None)
        self._batches_since_snapshot = 0
        self.cache = _LRUCache(cache_size)
        self.batch_log: list[BatchStats] = []
        self._flush_events = 0
        self.bg.add_flush_hook(self._on_flush)
        self._publish()

    # ------------------------------------------------------------ internals
    def _on_flush(self, bg: BufferedGraph) -> None:
        # storage epoch turned over: the CSR was rewritten under the engine.
        # HostEngine re-points lazily on the next read, but the buffer pool
        # holds blocks of the *old* edge table — drop them now so a pooled
        # reader never serves stale hits across the rewrite.
        self._flush_events += 1
        self.maintainer.engine.reader.invalidate()

    def _publish_metrics(self) -> None:
        _EPOCH_GAUGE.set(self.epoch)
        _BUFFERED_GAUGE.set(self.bg._size)

    # --------------------------------------------------------------- writes
    def ingest(self, ops) -> BatchStats:
        """Admit + log + apply one micro-batch; commit a new epoch view.

        With an ``admission_budget`` configured, ingest degrades under load
        instead of queueing without bound: accepted batches are always
        WAL-logged (durable on accept) but may be *deferred* — coalesced
        into a bounded pending pool and applied as one settle later — and a
        batch that cannot fit even after a full drain is rejected with a
        typed :class:`Overloaded` (see backpressure.py for the state
        machine).
        """
        if self.admission is not None:
            return self._ingest_backpressure(ops)
        t0 = time.perf_counter()
        with _trace.span("service.ingest", cat="stream") as sp:
            admitted: AdmittedBatch = admit_batch(ops, n=self.bg.n)
            next_epoch = self.epoch + 1
            if self.wal is not None:  # write-ahead: log before touching state
                self.wal.append(next_epoch, admitted.batch)
            self._wal_tip = next_epoch
            flushes0 = self._flush_events
            m = self.maintainer.apply(
                admitted.batch, insert_algorithm=self.insert_algorithm
            )
            self.epoch = next_epoch
            self._publish()
            if sp.active:
                sp.set(epoch=next_epoch, requested=admitted.num_requested,
                       applied=m.num_deletes + m.num_inserts, noops=m.num_noops)
        _INGEST_SECONDS.observe(time.perf_counter() - t0)
        _INGESTS.inc()
        stats = BatchStats(
            epoch=self.epoch,
            num_requested=admitted.num_requested,
            num_dropped=admitted.num_dropped,
            num_coalesced=admitted.num_coalesced,
            num_applied_deletes=m.num_deletes,
            num_applied_inserts=m.num_inserts,
            num_noops=m.num_noops,
            node_computations=m.node_computations,
            edge_block_reads=m.edge_block_reads,
            node_table_reads=m.node_table_reads,
            iterations=m.iterations,
            num_changed=m.num_changed,
            flushes=self._flush_events - flushes0,
            wall_time_s=time.perf_counter() - t0,
        )
        self.batch_log.append(stats)
        self._batches_since_snapshot += 1
        if (
            self.snapshots is not None
            and self.snapshot_every > 0
            and self._batches_since_snapshot >= self.snapshot_every
        ):
            self.snapshot()
        return stats

    def _ingest_backpressure(self, ops) -> BatchStats:
        """Budgeted ingest: accept-durably, coalesce, defer, drain or shed.

        Order of operations per offer: (1) a batch larger than the whole
        budget can never fit and is shed immediately; (2) if the pool plus
        the incoming batch overflows, the pool is drained first — after
        which the batch fits by (1); (3) the accepted batch is WAL-appended
        at ``_wal_tip + 1`` (durable even if deferred) and merged into the
        pool; (4) the controller decides apply-now vs. defer (bounded by
        ``max_defer`` consecutive deferrals).
        """
        adm = self.admission
        t0 = time.perf_counter()
        with _trace.span("service.ingest", cat="stream") as sp:
            admitted: AdmittedBatch = admit_batch(ops, n=self.bg.n)
            incoming = admitted.num_admitted
            if incoming > adm.budget:
                raise adm.reject(incoming)
            if not adm.fits(incoming):
                self._apply_pending()  # stage-2 pressure: drain restores room
            next_tip = self._wal_tip + 1
            if self.wal is not None:  # durable on accept, even when deferred
                self.wal.append(next_tip, admitted.batch)
            self._wal_tip = next_tip
            adm.merge(admitted.deletes, admitted.inserts)
            if adm.should_apply():
                stats = self._apply_pending(admitted=admitted, t0=t0)
            else:
                adm.note_deferred()
                stats = BatchStats(
                    epoch=self.epoch,
                    num_requested=admitted.num_requested,
                    num_dropped=admitted.num_dropped,
                    num_coalesced=admitted.num_coalesced,
                    num_applied_deletes=0, num_applied_inserts=0,
                    num_noops=0, node_computations=0, edge_block_reads=0,
                    node_table_reads=0, iterations=0, num_changed=0,
                    flushes=0, wall_time_s=time.perf_counter() - t0,
                    deferred=True, pending_updates=adm.pending_updates,
                )
            if sp.active:
                sp.set(epoch=self.epoch, wal_tip=self._wal_tip,
                       requested=admitted.num_requested,
                       deferred=stats.deferred,
                       pending=adm.pending_updates)
        _INGEST_SECONDS.observe(time.perf_counter() - t0)
        _INGESTS.inc()
        self.batch_log.append(stats)
        self._batches_since_snapshot += 1
        if (
            self.snapshots is not None
            and self.snapshot_every > 0
            and self._batches_since_snapshot >= self.snapshot_every
        ):
            self.snapshot()
        return stats

    def _apply_pending(self, admitted: AdmittedBatch | None = None,
                       t0: float | None = None) -> BatchStats:
        """Drain the whole pending pool into one settle at ``_wal_tip``.

        All-or-nothing by design: the published view must equal the state a
        replica reaches by replaying WAL records 1..``_wal_tip`` one at a
        time, which coalesced last-op-per-edge application guarantees (see
        backpressure.py).  Safe to call with an empty pool (publishes the
        current state at the tip epoch).
        """
        adm = self.admission
        t0 = time.perf_counter() if t0 is None else t0
        deletes, inserts = adm.take()
        pending = UpdateBatch.from_pairs(deletes, inserts)
        flushes0 = self._flush_events
        ta = time.perf_counter()
        m = self.maintainer.apply(pending,
                                  insert_algorithm=self.insert_algorithm)
        adm.note_applied(len(pending), time.perf_counter() - ta)
        self.epoch = self._wal_tip
        self._publish()
        stats = BatchStats(
            epoch=self.epoch,
            num_requested=admitted.num_requested if admitted else 0,
            num_dropped=admitted.num_dropped if admitted else 0,
            num_coalesced=admitted.num_coalesced if admitted else 0,
            num_applied_deletes=m.num_deletes,
            num_applied_inserts=m.num_inserts,
            num_noops=m.num_noops,
            node_computations=m.node_computations,
            edge_block_reads=m.edge_block_reads,
            node_table_reads=m.node_table_reads,
            iterations=m.iterations,
            num_changed=m.num_changed,
            flushes=self._flush_events - flushes0,
            wall_time_s=time.perf_counter() - t0,
            pending_updates=0,
        )
        return stats

    # --------------------------------------------------------------- health
    def health(self) -> dict:
        """Liveness/degradation summary: {status, epoch, wal lag, admission}.

        ``status`` is "ok", "degraded" (deferred batches pending — readers
        see a bounded-stale epoch) or "overloaded" (recent shedding with a
        still-saturated pool).
        """
        status = "ok"
        out = {
            "epoch": int(self.epoch),
            "wal_tip": int(self._wal_tip),
            "wal_lag": int(self._wal_tip - self.epoch),
            "wal_appends": self.wal.appends if self.wal else 0,
        }
        if self.admission is not None:
            adm_state = self.admission.state()
            out["admission"] = adm_state
            if adm_state["stage"] == "overloaded" or (
                    adm_state["stage"] == "degraded"
                    and adm_state["pending_updates"] >= self.admission.budget):
                status = "overloaded"
            elif adm_state["stage"] == "degraded" or out["wal_lag"] > 0:
                status = "degraded"
        out["status"] = status
        return out

    def snapshot(self) -> None:
        """Flush the update buffer and atomically dump the durable state.

        Snapshot publish also rotates the WAL: records at or below the
        *rotation floor* are superseded and would otherwise grow the log
        without bound.  The floor is the oldest **retained** snapshot's
        epoch (``SnapshotStore.oldest_retained_epoch``): with the default
        ``keep=1`` that is the snapshot just published (the historical
        behavior), while ``keep >= 2`` keeps enough WAL tail to roll forward
        from the older fallback snapshots, making recover-from-previous-
        snapshot sound when the newest one turns out corrupt.  Rotation is
        atomic (stream the tail to a temp file + rename + dir fsync) and
        ordered *after* the snapshot publish, so a crash between the two
        leaves a WAL that is merely longer than necessary, never one missing
        records the latest snapshot doesn't cover.
        """
        if self.snapshots is None:
            raise RuntimeError("CoreService was built without a snapshot_dir")
        if self.admission is not None and (
                self.admission.pending or self.epoch != self._wal_tip):
            # the snapshot must capture a state that equals a WAL prefix
            self._apply_pending()
        g = self.bg.materialize()
        self.snapshots.save(self.epoch, g, self.maintainer.core, self.maintainer.cnt)
        if self.wal is not None:
            floor = self.snapshots.oldest_retained_epoch()
            self.wal.rotate(self.epoch if floor is None else floor)
        self._batches_since_snapshot = 0

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()

    # ---------------------------------------------------------------- stats
    def service_stats(self) -> dict:
        reader = self.maintainer.engine.reader
        return {
            "epoch": self.epoch,
            "backend": self.maintainer.backend.name,
            "n": self.bg.n,
            "m": self.bg.m,
            "degeneracy": self.degeneracy(),
            "batches": len(self.batch_log),
            "updates_applied": sum(
                s.num_applied_deletes + s.num_applied_inserts for s in self.batch_log
            ),
            "edge_block_reads_total": reader.reads,
            "edge_block_hits_total": reader.hits,
            "pool_blocks": reader.pool_blocks,
            "node_table_reads_total": reader.node_table_reads,
            "flush_events": self._flush_events,
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "wal_appends": self.wal.appends if self.wal else 0,
            # device-backend settles only: edge-table uploads (cache misses
            # of the version-keyed resident structure, DESIGN.md §12)
            "backend_structure_builds": getattr(
                self.maintainer.backend, "structure_builds", 0),
        }

    # ------------------------------------------------------------- recovery
    @classmethod
    def recover(
        cls,
        *,
        wal_path: str | None = None,
        snapshot_dir: str | None = None,
        base_graph: CSRGraph | None = None,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
        snapshot_keep: int = 1,
        **service_kwargs,
    ) -> tuple["CoreService", RecoveryStats]:
        """Rebuild a service from snapshot + WAL tail, without full recompute.

        The warm restart leans on convergence-from-above (Thm 4.1): with the
        WAL tail replayed structurally, ``min(snapshot_core + I, deg)`` — I
        the number of net-inserted tail edges, since one insertion raises any
        core by at most one and deletions never raise it — is a pointwise
        upper bound of the true decomposition, so SemiCore* passes from it
        (with ``cnt`` recomputed exactly once) settle to the exact fixpoint.

        Corruption handling (DESIGN.md §17): a corrupt snapshot falls back
        to an older retained one inside ``SnapshotStore.latest``; a framed
        WAL record that fails its checksum ends the replay at that record —
        the intact prefix is kept, the log is truncated at the corruption
        offset (those batches are lost, exactly as if the crash had happened
        before them), and the writer resumes from the last good epoch.
        """
        snap = (SnapshotStore(snapshot_dir, keep=snapshot_keep).latest()
                if snapshot_dir else None)
        if snap is not None:
            epoch0, g, core0, cnt0 = snap
        elif base_graph is not None:
            epoch0, g, core0, cnt0 = 0, base_graph, None, None
        else:
            raise ValueError("recover() needs a snapshot_dir with a snapshot "
                             "or a base_graph")

        bg = BufferedGraph(g)
        applied_d = applied_i = batches = updates = 0
        last_epoch = epoch0
        if wal_path is not None:
            replay = WriteAheadLog.replay(wal_path, after_epoch=epoch0)
            while True:
                try:
                    e, batch = next(replay)
                except StopIteration:
                    break
                except CorruptionError as err:
                    # keep the intact prefix; amputate the log at the bad
                    # record so the reopened WAL appends after good data.
                    if err.offset is not None and os.path.exists(wal_path):
                        with open(wal_path, "rb+") as f:
                            f.truncate(err.offset)
                    break
                batches += 1
                updates += len(batch)
                for op in batch:  # structural replay, in WAL op order
                    if isinstance(op, Delete):
                        applied_d += bool(bg.delete_edge(int(op.u), int(op.v)))
                    else:
                        applied_i += bool(bg.insert_edge(int(op.u), int(op.v)))
                last_epoch = max(last_epoch, e)

        state = None
        settle = None
        warm_restart = False
        if core0 is not None:
            if applied_d or applied_i:
                warm_restart = True
                bg.flush()  # one CSR rewrite so the settle scans exact lists
                eng = HostEngine(bg, block_edges, pool_blocks=pool_blocks,
                                 retry=service_kwargs.get("retry"))
                settle = warm_settle(
                    eng, core0, applied_i, service_kwargs.get("backend"),
                    superstep_chunk=service_kwargs.get("superstep_chunk"))
                state = (settle.core, settle.cnt)
            else:
                state = (core0, cnt0)

        svc = cls(
            bg,
            block_edges=block_edges,
            pool_blocks=pool_blocks,
            wal_path=wal_path,
            snapshot_dir=snapshot_dir,
            snapshot_keep=snapshot_keep,
            state=state,
            epoch=last_epoch,
            **service_kwargs,
        )
        stats = RecoveryStats(
            snapshot_epoch=epoch0,
            recovered_epoch=last_epoch,
            replayed_batches=batches,
            replayed_updates=updates,
            applied_deletes=applied_d,
            applied_inserts=applied_i,
            warm_restart=warm_restart,
            settle_node_computations=settle.node_computations if settle else 0,
            settle_iterations=settle.iterations if settle else 0,
            settle_edge_block_reads=settle.edge_block_reads if settle else 0,
        )
        return svc, stats


#: Established name of the writer.  In a single-process deployment the
#: writer serves both roles of the CQRS split, so the historical service
#: name stays bound to it; replicated deployments pair one ``CoreWriter``
#: with N ``CoreReplica``s (replica.py, DESIGN.md §15).
CoreService = CoreWriter
