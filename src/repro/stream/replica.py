"""CoreReplica: the read side of the CQRS split — WAL-tailing read replicas.

The paper's semi-external contract makes the serving process cheap to
replicate: a replica needs only the O(n) node arrays and the writer's WAL
tail, never a second copy of the edge-table machinery's write path.  A
``CoreReplica``

* **bootstraps** from ``SnapshotStore.latest()`` + a *structural* replay of
  the WAL tail, settled with one warm SemiCore* pass — exactly the
  recovery discipline of ``CoreService.recover`` (DESIGN.md §9), so the
  replica's ``(core, cnt)`` lands on the writer's exact fixpoint;
* **tails** the WAL incrementally with :class:`~.wal.WalTailer` (byte-offset
  cursor, complete-records-only, rotation-aware), replaying each admitted
  batch through its own ``CoreMaintainer.apply`` — the same exact
  maintenance the writer ran — and publishing an :class:`EpochView` per
  batch.  Per-node core views converge correctly under asynchronous,
  replayed update orders (Montresor et al., arXiv 1103.5320); here the
  replay order *is* the writer's commit order, so every replica epoch is
  bit-identical to the writer's state at that epoch;
* **serves** the full ``QueryAPI`` (coreness / in_kcore / kcore_members /
  top_k / degeneracy) from its own epoch views, every reply watermarked
  with the replica's committed epoch, with ``lag()`` exposing staleness as
  (writer WAL tip epoch − replica epoch);
* **catches up restartably**: if a rotation outruns the tailer
  (:class:`~.wal.WalGap`), the replica re-bootstraps from the latest
  snapshot — the same snapshot + tail path, incremental and restartable.

Replica-side telemetry (DESIGN.md §14/§15): ``repro_replica_epoch`` /
``repro_replica_lag`` gauges and a lag histogram per replica id; the
per-kind query series of service.py are reused, so a dashboard sees one
query-latency family across writer and replicas.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from ..core.engine import warm_settle
from ..core.maintenance import CoreMaintainer
from ..core.semicore import HostEngine
from ..core.update import Delete
from ..faults import CircuitBreaker
from ..graph.storage import DEFAULT_BLOCK_EDGES
from ..graph.updates import BufferedGraph
from ..obs import metrics as _metrics, trace as _trace
from .service import EpochView, QueryAPI, _LRUCache
from .wal import CorruptionError, SnapshotStore, WalGap, WalTailer, WriteAheadLog

__all__ = ["CoreReplica", "BootstrapStats"]

_REPLICA_EPOCH = _metrics.gauge(
    "repro_replica_epoch", "Replica committed epoch watermark")
_REPLICA_LAG = _metrics.gauge(
    "repro_replica_lag",
    "Replica staleness: writer WAL tip epoch minus replica epoch")
_REPLICA_LAG_EPOCHS = _metrics.histogram(
    "repro_replica_lag_epochs",
    "Observed replica lag (epochs) at each lag() probe",
    buckets=_metrics.DEFAULT_COUNT_BUCKETS)
_REPLICA_BATCHES = _metrics.counter(
    "repro_replica_batches_applied_total",
    "WAL batches replayed into replica epoch views")
_REPLICA_SYNC_SECONDS = _metrics.histogram(
    "repro_replica_sync_seconds", "Replica sync() latency (tail + apply)")
_REPLICA_BOOTSTRAPS = _metrics.counter(
    "repro_replica_bootstraps_total",
    "Replica bootstraps (snapshot + structural tail replay + warm settle); "
    "first one is construction, later ones are WalGap catch-ups")
_REPLICA_SYNC_FAILURES = _metrics.counter(
    "repro_replica_sync_failures_total",
    "sync() attempts that failed (transient I/O, gap, or corruption) and "
    "left the replica serving its last good epoch")


@dataclass
class BootstrapStats:
    """What one bootstrap (construction or WalGap catch-up) did."""

    snapshot_epoch: int
    bootstrapped_epoch: int
    replayed_batches: int
    replayed_updates: int
    applied_deletes: int
    applied_inserts: int
    warm_restart: bool  # False => no WAL tail, snapshot state used as-is
    settle_node_computations: int = 0
    settle_iterations: int = 0


class CoreReplica(QueryAPI):
    """Serves the query surface from WAL-replayed epoch views (DESIGN.md §15).

    A replica never writes: it owns no WAL handle and no snapshot publisher,
    only a :class:`WalTailer` cursor over the writer's log and its own
    ``CoreMaintainer`` holding the O(n) node state.  ``sync()`` drains newly
    durable batches; every committed batch publishes a fresh immutable
    ``EpochView`` (the last ``keep_views`` are retained so a reader can pin
    a recent epoch), and queries answer from the newest one, watermarked.
    """

    def __init__(
        self,
        *,
        snapshot_dir: str,
        wal_path: str,
        block_edges: int = DEFAULT_BLOCK_EDGES,
        pool_blocks: int = 1,
        insert_algorithm: str = "semiinsert*",
        backend=None,
        superstep_chunk: int | None = None,
        cache_size: int = 256,
        replica_id: int = 0,
        keep_views: int = 4,
        retry=None,
        breaker_trip_after: int = 3,
    ):
        self.snapshots = SnapshotStore(snapshot_dir)
        self.wal_path = wal_path
        self.block_edges = int(block_edges)
        self.pool_blocks = int(pool_blocks)
        self.insert_algorithm = insert_algorithm
        self._backend = backend
        self._superstep_chunk = superstep_chunk
        self.replica_id = int(replica_id)
        self.keep_views = max(int(keep_views), 1)
        self.retry = retry  # optional faults.RetryPolicy for polls/loads
        self.breaker = CircuitBreaker(trip_after=breaker_trip_after)
        self.stale_serving = False  # last sync/bootstrap failed: views frozen
        self.sync_failures = 0
        self.bootstrap_failures = 0
        self.cache = _LRUCache(cache_size)
        self.views: list[EpochView] = []  # newest last, bounded chain
        self.bootstraps = 0
        self.batches_applied = 0
        self.last_bootstrap: BootstrapStats | None = None
        _lbl = {"replica": str(self.replica_id)}
        self._epoch_gauge = _REPLICA_EPOCH.labels(**_lbl)
        self._lag_gauge = _REPLICA_LAG.labels(**_lbl)
        self._lag_hist = _REPLICA_LAG_EPOCHS.labels(**_lbl)
        self._batches_ctr = _REPLICA_BATCHES.labels(**_lbl)
        self._sync_hist = _REPLICA_SYNC_SECONDS.labels(**_lbl)
        self._bootstraps_ctr = _REPLICA_BOOTSTRAPS.labels(**_lbl)
        self._sync_failures_ctr = _REPLICA_SYNC_FAILURES.labels(**_lbl)
        self._bootstrap()

    # ------------------------------------------------------------ bootstrap
    def _bootstrap(self) -> None:
        """Snapshot + structural WAL-tail replay + warm settle (restartable).

        This *is* the catch-up protocol: a fresh replica, a replica that
        fell behind a rotation, and writer crash recovery all walk the same
        path.  A rotation racing the bootstrap (newer snapshot published
        between ``latest()`` and the tail replay) surfaces as a
        :class:`WalGap` and simply restarts the bootstrap against the newer
        snapshot.
        """
        with _trace.span("replica.bootstrap", cat="stream",
                         replica=self.replica_id):
            for _ in range(3):  # rotation races are resolved by retrying
                try:
                    self._bootstrap_once()
                    break
                except WalGap:
                    continue
            else:
                raise RuntimeError(
                    "replica bootstrap kept losing rotation races; "
                    "is the writer snapshotting every batch?")
        self.bootstraps += 1
        self._bootstraps_ctr.inc()
        self._publish()
        self.lag()

    def _bootstrap_once(self) -> None:
        if self.retry is None:
            snap = self.snapshots.latest()
        else:  # transient load failures retry; CorruptionError falls through
            snap = self.retry.call(self.snapshots.latest, op="snapshot.load",
                                   retry_on=(OSError,))
        if snap is None:
            raise RuntimeError(
                "CoreReplica needs a published snapshot to bootstrap from; "
                "call writer.snapshot() first")
        epoch0, g, core0, cnt0 = snap
        bg = BufferedGraph(g)
        tailer = WalTailer(self.wal_path, after_epoch=epoch0)
        applied_d = applied_i = batches = updates = 0
        last_epoch = epoch0
        try:
            for e, batch in tailer.poll():
                batches += 1
                updates += len(batch)
                for op in batch:  # structural replay, in WAL op order
                    if isinstance(op, Delete):
                        applied_d += bool(bg.delete_edge(int(op.u), int(op.v)))
                    else:
                        applied_i += bool(bg.insert_edge(int(op.u), int(op.v)))
                last_epoch = e
        except CorruptionError:
            # a corrupt record past the snapshot: bring the replica up on
            # the intact prefix instead of failing construction.  The
            # cursor is pinned before the bad record, so the next sync()
            # re-detects it and escalates (bootstrap / wait for the
            # writer's rotation to repair the log).
            pass
        settle = None
        if applied_d or applied_i:
            bg.flush()  # one CSR rewrite so the settle scans exact lists
            eng = HostEngine(bg, self.block_edges, pool_blocks=self.pool_blocks,
                             retry=self.retry)
            settle = warm_settle(eng, core0, applied_i, self._backend,
                                 superstep_chunk=self._superstep_chunk)
            state = (settle.core, settle.cnt)
        else:
            state = (core0, cnt0)
        self.maintainer = CoreMaintainer(
            bg, self.block_edges, state=state, pool_blocks=self.pool_blocks,
            backend=self._backend, superstep_chunk=self._superstep_chunk,
            retry=self.retry,
        )
        self.bg = self.maintainer.bg
        self.epoch = last_epoch
        self.tailer = tailer
        self.last_bootstrap = BootstrapStats(
            snapshot_epoch=epoch0,
            bootstrapped_epoch=last_epoch,
            replayed_batches=batches,
            replayed_updates=updates,
            applied_deletes=applied_d,
            applied_inserts=applied_i,
            warm_restart=settle is not None,
            settle_node_computations=settle.node_computations if settle else 0,
            settle_iterations=settle.iterations if settle else 0,
        )

    # ----------------------------------------------------------- publishing
    def _publish(self) -> None:
        super()._publish()
        self.views.append(self._view)
        del self.views[:-self.keep_views]

    def _publish_metrics(self) -> None:
        self._epoch_gauge.set(self.epoch)

    def view_at(self, epoch: int) -> EpochView:
        """A retained view at exactly ``epoch`` (KeyError when evicted)."""
        for v in self.views:
            if v.epoch == epoch:
                return v
        raise KeyError(
            f"epoch {epoch} not retained (have "
            f"{[v.epoch for v in self.views]})")

    # ----------------------------------------------------------------- sync
    def _drain(self, max_batches: int | None) -> int:
        """One tailing pass: apply newly durable records from the cursor.

        Idempotent under retry: the cursor (byte offset + last epoch)
        advances only past records that were fully applied, so re-calling
        after a transient failure resumes exactly where the failure struck.
        """
        applied = 0
        for e, batch in self.tailer.poll():
            self.maintainer.apply(batch,
                                  insert_algorithm=self.insert_algorithm)
            self.epoch = e
            self.batches_applied += 1
            self._batches_ctr.inc()
            applied += 1
            self._publish()
            if max_batches is not None and applied >= max_batches:
                break
        return applied

    def _recover_by_bootstrap(self) -> int:
        """Full snapshot catch-up after tailing broke (gap/corruption/trip).

        On failure the replica *keeps serving* its last good epoch views
        (``stale_serving`` flips on, the failure is counted) instead of
        raising into the read path — staleness is visible through
        ``health()``/``lag()``, availability is preserved.
        """
        try:
            if self.retry is None:
                self._bootstrap()
            else:
                self.retry.call(self._bootstrap, op="replica.bootstrap",
                                retry_on=(OSError,))
        except (OSError, CorruptionError, RuntimeError):
            self.stale_serving = True
            self.bootstrap_failures += 1
            self._sync_failures_ctr.inc()
            return 0
        self.breaker.record_success()
        self.stale_serving = False
        return 1

    def sync(self, max_batches: int | None = None) -> int:
        """Drain newly durable WAL records into the epoch-view chain.

        Replays each batch through ``CoreMaintainer.apply`` — the
        writer's own maintenance path, so the settled ``(core, cnt)`` is
        bit-identical to the writer's at the same epoch — and publishes one
        ``EpochView`` per batch.  Returns the number of batches applied
        (bootstrap counts as one).

        Failure policy (DESIGN.md §17): falling behind a rotation
        (:class:`WalGap`) or hitting a checksum failure
        (:class:`CorruptionError`) abandons incremental tailing for a full
        snapshot bootstrap; transient I/O errors are retried by the
        configured ``RetryPolicy`` and, when they persist, counted by the
        circuit breaker — ``breaker_trip_after`` consecutive failed syncs
        trip straight to bootstrap.  Every failure path degrades to serving
        the last good epoch rather than raising into the read path.
        """
        t0 = time.perf_counter()
        applied = 0
        with _trace.span("replica.sync", cat="stream",
                         replica=self.replica_id) as sp:
            try:
                if self.retry is None:
                    applied = self._drain(max_batches)
                else:
                    applied = self.retry.call(
                        self._drain, max_batches, op="replica.sync",
                        retry_on=(OSError,))
                self.breaker.record_success()
                self.stale_serving = False
                if applied == 0:
                    # an empty drain with a newer snapshot published means
                    # the log has nothing left for this cursor (a rotation
                    # repaired records away, or emptied the log entirely):
                    # the snapshot store is the only way forward.
                    floor = self.snapshots.latest_epoch()
                    if floor is not None and floor > self.epoch:
                        applied += self._recover_by_bootstrap()
            except (WalGap, CorruptionError):
                # non-transient: the log no longer works for this cursor
                self.sync_failures += 1
                applied += self._recover_by_bootstrap()
            except OSError:
                # transient (possibly injected): serve stale, let the
                # breaker decide when banging on the WAL stops being useful
                self.sync_failures += 1
                self._sync_failures_ctr.inc()
                self.stale_serving = True
                if self.breaker.record_failure():
                    applied += self._recover_by_bootstrap()
            if sp.active:
                sp.set(applied=applied, epoch=self.epoch,
                       stale=self.stale_serving)
        self._sync_hist.observe(time.perf_counter() - t0)
        self.lag()
        return applied

    # --------------------------------------------------------------- health
    def health(self) -> dict:
        """Replica liveness summary: {status, epoch, lag, breaker state}.

        ``status`` is "ok" when tailing normally and "degraded" while the
        replica serves stale views (failed sync/bootstrap or a tripped
        breaker); a replica is never "overloaded" — it sheds nothing.
        """
        lag = self.lag()
        degraded = self.stale_serving or self.breaker.tripped
        return {
            "status": "degraded" if degraded else "ok",
            "replica_id": self.replica_id,
            "epoch": int(self.epoch),
            "lag": int(lag),
            "stale_serving": self.stale_serving,
            "breaker": {
                "tripped": self.breaker.tripped,
                "consecutive_failures": self.breaker.consecutive_failures,
                "trips": self.breaker.trips,
            },
            "sync_failures": self.sync_failures,
            "bootstrap_failures": self.bootstrap_failures,
            "bootstraps": self.bootstraps,
        }

    # ------------------------------------------------------------ staleness
    def lag(self, writer_epoch: int | None = None) -> int:
        """Epochs this replica trails the writer (0 = fully caught up).

        With ``writer_epoch`` given, that is the authority; otherwise the
        writer's committed tip is read from the WAL's last complete record
        (an O(record) backwards peek — the WAL is append-before-apply, so
        its tip bounds the writer's committed epoch from above by at most
        the one in-flight batch), floored by the latest snapshot's epoch:
        right after a rotation the WAL can be empty, but the snapshot that
        triggered the rotation pins the writer's epoch from below.
        """
        if writer_epoch is None:
            tip = WriteAheadLog.tip_epoch(self.wal_path)
            snap = self.snapshots.latest_epoch()
            writer_epoch = max(
                x for x in (tip, snap, self.epoch) if x is not None)
        out = max(0, int(writer_epoch) - int(self.epoch))
        self._lag_gauge.set(out)
        self._lag_hist.observe(out)
        return out

    # ---------------------------------------------------------------- stats
    def replica_stats(self) -> dict:
        return {
            "replica_id": self.replica_id,
            "epoch": self.epoch,
            "lag": self.lag(),
            "n": self.bg.n,
            "m": self.bg.m,
            "batches_applied": self.batches_applied,
            "bootstraps": self.bootstraps,
            "sync_failures": self.sync_failures,
            "bootstrap_failures": self.bootstrap_failures,
            "stale_serving": self.stale_serving,
            "rotations_detected": self.tailer.rotations_detected,
            "wal_records_read": self.tailer.records_read,
            "retained_views": [v.epoch for v in self.views],
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "backend": self.maintainer.backend.name,
        }
