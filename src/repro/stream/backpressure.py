"""Admission backpressure for the CoreWriter (DESIGN.md §17, ROADMAP item 3).

The writer's ingest is synchronous, so overload does not show up as a full
queue — it shows up as callers outrunning the settle rate.  The
:class:`AdmissionController` bounds the damage with a pending-updates
budget and three-stage degradation:

* **ok** (stage 0, ``pending <= soft``) — every accepted batch is applied
  immediately; normal operation;
* **degraded** (stage 1, ``soft < pending <= budget``) — accepted batches
  are WAL-logged (durable on accept) but *deferred*: they coalesce into the
  pending pool (last-op-per-edge wins, so N batches against the same hot
  edges collapse) and are applied as one settle.  Staleness is bounded: at
  most ``max_defer`` consecutive ingests defer before a forced drain;
* **overloaded** (stage 2) — an incoming batch that cannot fit even after a
  full drain is rejected with a typed :class:`Overloaded` carrying a
  ``retry_after_s`` estimated from the recent apply throughput.

Why coalesced deferral is *safe*: per-edge last-op-wins makes the pending
pool's net structural effect identical to applying the same records one at
a time, and the exact decomposition is a pure function of the graph — so
when the writer drains at WAL epoch k its (core, cnt) is bit-identical to a
replica that replayed records 1..k individually (Li & Yu's bounded
per-update change sets are what keep the drained settle cheap).
"""
from __future__ import annotations

from ..obs import metrics as _metrics

__all__ = ["Overloaded", "AdmissionController"]

_BP_STATE = _metrics.gauge(
    "repro_backpressure_state",
    "Admission degradation stage: 0=ok, 1=degraded, 2=overloaded").labels()
_BP_PENDING = _metrics.gauge(
    "repro_backpressure_pending_updates",
    "Coalesced structural updates accepted but not yet applied").labels()
_BP_REJECTED = _metrics.counter(
    "repro_backpressure_rejected_total",
    "Update offers rejected with Overloaded").labels()
_BP_DEFERRED = _metrics.counter(
    "repro_backpressure_deferred_batches_total",
    "Accepted batches deferred into the pending pool (bounded staleness)"
).labels()
_BP_COALESCED = _metrics.counter(
    "repro_backpressure_coalesced_total",
    "Pending-pool merges where an edge already had a pending op").labels()

_STAGES = ("ok", "degraded", "overloaded")


class Overloaded(RuntimeError):
    """The writer shed an update batch: the admission budget is exhausted.

    ``retry_after_s`` is the controller's estimate (from recent apply
    throughput) of when enough budget will have drained; callers should
    back off at least that long before re-offering.
    """

    def __init__(self, *, requested: int, pending: int, budget: int,
                 retry_after_s: float):
        super().__init__(
            f"admission budget exhausted: {requested} offered, {pending} "
            f"pending of {budget} budget; retry after {retry_after_s:.3f}s")
        self.requested = requested
        self.pending = pending
        self.budget = budget
        self.retry_after_s = retry_after_s


class AdmissionController:
    """Bounded pending-updates pool with staged degradation (module doc).

    ``budget`` is the hard cap on coalesced pending updates; ``soft_ratio``
    sets the degraded-stage threshold; ``max_defer`` bounds how many
    consecutive ingests may defer before the owner must drain (the
    bounded-staleness knob).
    """

    def __init__(self, budget: int, *, soft_ratio: float = 0.5,
                 max_defer: int = 4):
        if budget < 1:
            raise ValueError("admission budget must be >= 1")
        self.budget = int(budget)
        self.soft = max(1, int(self.budget * float(soft_ratio)))
        self.max_defer = max(1, int(max_defer))
        self.pending: dict[tuple[int, int], str] = {}  # edge -> "+" | "-"
        self.deferred_batches = 0  # consecutive, reset on drain
        self.rejected_batches = 0
        self.rejected_updates = 0
        self.coalesced = 0
        self._rate_ewma = 0.0  # applied updates / second
        self._sync_gauges()

    # ------------------------------------------------------------------ state
    @property
    def pending_updates(self) -> int:
        return len(self.pending)

    def stage(self) -> str:
        if len(self.pending) > self.budget:
            return "overloaded"
        if len(self.pending) > self.soft:
            return "degraded"
        return "ok"

    def _sync_gauges(self) -> None:
        _BP_STATE.set(_STAGES.index(self.stage()))
        _BP_PENDING.set(len(self.pending))

    # ------------------------------------------------------------- decisions
    def fits(self, incoming: int) -> bool:
        """Can ``incoming`` coalesced updates join the pool right now?"""
        return len(self.pending) + incoming <= self.budget

    def should_apply(self) -> bool:
        """Drain now?  Stage 0 applies immediately; stage 1 defers until the
        bounded-staleness window (``max_defer`` consecutive deferrals) is
        spent."""
        return (len(self.pending) <= self.soft
                or self.deferred_batches >= self.max_defer)

    # ------------------------------------------------------------ transitions
    def merge(self, deletes, inserts) -> int:
        """Coalesce one admitted batch into the pool; returns new merges."""
        pending = self.pending
        coalesced = 0
        for u, v in deletes:
            key = (int(u), int(v))
            coalesced += key in pending
            pending[key] = "-"
        for u, v in inserts:
            key = (int(u), int(v))
            coalesced += key in pending
            pending[key] = "+"
        if coalesced:
            self.coalesced += coalesced
            _BP_COALESCED.inc(coalesced)
        self._sync_gauges()
        return coalesced

    def note_deferred(self) -> None:
        self.deferred_batches += 1
        _BP_DEFERRED.inc()
        self._sync_gauges()

    def take(self) -> tuple[list, list]:
        """All-or-nothing drain: the whole pool becomes one applied batch.

        Partial drains would publish an epoch whose state matches no WAL
        prefix; taking everything keeps every published epoch bit-identical
        to a replica that replayed the same records individually.
        """
        deletes = [e for e, kind in self.pending.items() if kind == "-"]
        inserts = [e for e, kind in self.pending.items() if kind == "+"]
        self.pending.clear()
        self.deferred_batches = 0
        self._sync_gauges()
        return deletes, inserts

    def note_applied(self, count: int, seconds: float) -> None:
        """Feed the apply-throughput EWMA that prices ``retry_after_s``."""
        if count <= 0:
            return
        rate = count / max(seconds, 1e-6)
        self._rate_ewma = (
            rate if self._rate_ewma == 0.0
            else 0.3 * rate + 0.7 * self._rate_ewma)

    def reject(self, requested: int) -> Overloaded:
        """Record a shed batch and build the typed rejection to raise."""
        self.rejected_batches += 1
        self.rejected_updates += requested
        _BP_REJECTED.inc()
        exc = Overloaded(
            requested=requested, pending=len(self.pending),
            budget=self.budget, retry_after_s=self.retry_after(requested))
        self._sync_gauges()
        return exc

    def retry_after(self, incoming: int) -> float:
        """Seconds until ``incoming`` should fit, from the apply EWMA."""
        backlog = max(0, len(self.pending) + incoming - self.soft)
        if self._rate_ewma <= 0.0:
            return 0.05  # no throughput signal yet: a polite default
        return min(60.0, max(0.01, backlog / self._rate_ewma))

    # ------------------------------------------------------------------ intro
    def state(self) -> dict:
        return {
            "stage": self.stage(),
            "pending_updates": len(self.pending),
            "budget": self.budget,
            "soft_budget": self.soft,
            "deferred_batches": self.deferred_batches,
            "max_defer": self.max_defer,
            "rejected_batches": self.rejected_batches,
            "rejected_updates": self.rejected_updates,
            "coalesced": self.coalesced,
            "apply_rate_ewma": self._rate_ewma,
        }
