"""Streaming core-graph service: online coreness queries over an edge stream.

Built on the paper's §V maintenance algorithms: ``CoreService`` owns the
semi-external node state, ingests insert/delete micro-batches through
``CoreMaintainer``/``BufferedGraph``, and serves epoch-versioned reads with
zero edge-table I/O.  WAL + snapshots give crash recovery via warm restart
(DESIGN.md §9).
"""
from .admission import AdmittedBatch, admit_batch
from .service import BatchStats, CoreService, EpochView, RecoveryStats
from .wal import SnapshotStore, WriteAheadLog
from .workload import mixed_stream

__all__ = [
    "AdmittedBatch", "admit_batch",
    "BatchStats", "CoreService", "EpochView", "RecoveryStats",
    "SnapshotStore", "WriteAheadLog",
    "mixed_stream",
]
