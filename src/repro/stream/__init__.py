"""Streaming core-graph service: online coreness queries over an edge stream.

Built on the paper's §V maintenance algorithms and split CQRS-style
(DESIGN.md §15): ``CoreWriter`` (the established ``CoreService``) owns the
semi-external node state, ingests insert/delete micro-batches through
``CoreMaintainer``/``BufferedGraph`` and appends them to the WAL before
applying; ``CoreReplica`` read replicas bootstrap from the latest snapshot,
tail the WAL incrementally (``WalTailer``) and serve the same epoch-versioned
query surface from their own views with per-reply staleness watermarks.
WAL + snapshots give crash recovery via warm restart (DESIGN.md §9); the WAL
rotates on snapshot publish so the log size tracks the snapshot interval.
"""
from ..core.update import Delete, Insert, UpdateBatch
from .admission import AdmittedBatch, admit_batch
from .backpressure import AdmissionController, Overloaded
from .integrity import CorruptionError, crc32c
from .replica import BootstrapStats, CoreReplica
from .service import (BatchStats, CoreService, CoreWriter, EpochView,
                      QueryAPI, RecoveryStats, Watermarked, WatermarkedArray)
from .wal import SnapshotStore, WalGap, WalTailer, WriteAheadLog
from .workload import mixed_stream

__all__ = [
    "Insert", "Delete", "UpdateBatch",
    "AdmittedBatch", "admit_batch",
    "AdmissionController", "Overloaded",
    "CorruptionError", "crc32c",
    "BatchStats", "CoreService", "CoreWriter", "CoreReplica", "EpochView",
    "QueryAPI", "RecoveryStats", "BootstrapStats",
    "Watermarked", "WatermarkedArray",
    "SnapshotStore", "WriteAheadLog", "WalTailer", "WalGap",
    "mixed_stream",
]
