"""Checksummed record framing for the WAL + snapshot manifest (DESIGN.md §17).

Frame format (one line, still greppable text)::

    c1 <len> <crc32c-hex8> <payload>\\n

``c1`` is the version byte pair (``c`` = checksummed, ``1`` = format
version); ``len`` is the payload byte length in decimal; the CRC is CRC32C
(Castagnoli, poly 0x82F63B78 reflected — the checksum hardware-accelerated
on every modern disk path, here a 256-entry table since we cannot add
dependencies) over the payload bytes only.  Legacy WAL lines start with
``{`` and are still replayed unframed, so pre-existing logs keep working;
the version prefix leaves room for a ``c2`` frame later.

Framing turns the two silent failure modes into *typed* ones:

* the length catches torn/short writes that happen to end at a newline;
* the CRC catches bit rot anywhere in the payload.

Both raise :class:`CorruptionError` carrying the layer, path and byte
offset, which the WAL maps onto its torn-tail-vs-interior policy.
"""
from __future__ import annotations

__all__ = ["CorruptionError", "crc32c", "frame_record", "is_framed",
           "unframe", "FRAME_VERSION"]

FRAME_VERSION = b"c1"

# CRC32C (Castagnoli), reflected polynomial 0x82F63B78, table-driven.
_POLY = 0x82F63B78
_TABLE = []
for _i in range(256):
    _c = _i
    for _ in range(8):
        _c = (_c >> 1) ^ _POLY if _c & 1 else _c >> 1
    _TABLE.append(_c)
_TABLE = tuple(_TABLE)


def crc32c(data: bytes, crc: int = 0) -> int:
    """CRC32C of ``data``; pass a previous result as ``crc`` to stream."""
    c = crc ^ 0xFFFFFFFF
    for byte in data:
        c = _TABLE[(c ^ byte) & 0xFF] ^ (c >> 8)
    return c ^ 0xFFFFFFFF


class CorruptionError(RuntimeError):
    """Persistent data failed an integrity check (checksum/length/structure).

    Unlike an ``IOError`` this is *not* transient — retrying the read
    returns the same corrupt bytes.  ``layer`` is ``"wal"`` or
    ``"snapshot"``; ``offset`` is the byte offset of the corrupt record
    when known (the writer uses it to truncate a corrupt WAL tail).
    """

    def __init__(self, detail: str, *, layer: str = "wal",
                 path: str | None = None, offset: int | None = None):
        where = f" in {path}" if path else ""
        at = f" at byte {offset}" if offset is not None else ""
        super().__init__(f"{layer} corruption{where}{at}: {detail}")
        self.layer = layer
        self.path = path
        self.offset = offset
        self.detail = detail


def frame_record(payload: bytes) -> bytes:
    """Wrap one payload into a ``c1``-framed line (includes the newline)."""
    return b"%s %d %08x %s\n" % (
        FRAME_VERSION, len(payload), crc32c(payload), payload)


def is_framed(line: bytes) -> bool:
    """True when ``line`` claims to be a versioned checksummed frame."""
    return line.startswith(FRAME_VERSION + b" ")


def unframe(line: bytes, *, path: str | None = None,
            offset: int | None = None) -> bytes:
    """Validate one framed line (sans trailing newline ok) -> payload bytes.

    Raises :class:`CorruptionError` on any mismatch: bad header structure,
    length mismatch (torn write), or CRC mismatch (bit rot).
    """
    line = line.rstrip(b"\n")
    parts = line.split(b" ", 3)
    if len(parts) != 4 or parts[0] != FRAME_VERSION:
        raise CorruptionError("malformed frame header",
                              path=path, offset=offset)
    try:
        length = int(parts[1])
        expect = int(parts[2], 16)
    except ValueError:
        raise CorruptionError("unparseable frame length/crc",
                              path=path, offset=offset) from None
    payload = parts[3]
    if len(payload) != length:
        raise CorruptionError(
            f"length mismatch: frame says {length}, got {len(payload)} "
            "(torn write?)", path=path, offset=offset)
    actual = crc32c(payload)
    if actual != expect:
        raise CorruptionError(
            f"crc mismatch: frame says {expect:08x}, payload is {actual:08x}",
            path=path, offset=offset)
    return payload
