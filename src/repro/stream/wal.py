"""Durability for the streaming core service: write-ahead log + snapshots.

The service's durable state is tiny — the O(n) node arrays (``core``,
``cnt``) plus the graph itself — which the paper's semi-external contract
already forces through a disk-resident edge table.  Crash recovery therefore
needs only:

* a **write-ahead log**: one JSON line per admitted micro-batch, appended
  (and optionally fsynced) *before* the batch is applied.  A crash mid-append
  leaves a torn final line, which replay ignores — that batch was never
  acknowledged;
* a **snapshot store**: periodic atomic dumps of (epoch, CSR graph, core,
  cnt).  Snapshots are written to a temp directory and published with
  ``os.replace`` so a crash never exposes a half-written snapshot.

Recovery = latest snapshot + structural replay of the WAL tail + a warm
SemiCore* settle (see service.recover; DESIGN.md §9 for the upper-bound
argument).
"""
from __future__ import annotations

import json
import os
import shutil
import time

import numpy as np

from ..graph.storage import CSRGraph
from ..obs import metrics as _metrics, trace as _trace

__all__ = ["WriteAheadLog", "SnapshotStore"]

_WAL_APPENDS = _metrics.counter(
    "repro_wal_appends_total", "WAL records appended").labels()
_WAL_BYTES = _metrics.counter(
    "repro_wal_bytes_total", "Bytes written to the WAL (incl. newline)").labels()
_WAL_FSYNCS = _metrics.counter(
    "repro_wal_fsyncs_total", "fsync() calls issued by the WAL").labels()
_WAL_APPEND_SECONDS = _metrics.histogram(
    "repro_wal_append_seconds", "WAL append latency (write+flush+fsync)")
_SNAP_WRITES = _metrics.counter(
    "repro_snapshot_writes_total", "Snapshots published atomically").labels()
_SNAP_SECONDS = _metrics.histogram(
    "repro_snapshot_seconds", "Snapshot save latency (write + rename + GC)")


class WriteAheadLog:
    """Append-only JSONL of admitted micro-batches, keyed by epoch."""

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._truncate_torn_tail(path)
        self._f = open(path, "a", encoding="utf-8")
        self.appends = 0

    @staticmethod
    def _truncate_torn_tail(path: str) -> None:
        """Drop a crash-torn final line so new appends never concatenate
        onto it (a merged line would corrupt the *next* recovery)."""
        if not os.path.exists(path):
            return
        with open(path, "rb+") as f:
            data = f.read()
            if not data or data.endswith(b"\n"):
                return
            cut = data.rfind(b"\n") + 1  # 0 when the only line is torn
            f.truncate(cut)

    def append(self, epoch: int, deletes, inserts) -> None:
        rec = {
            "epoch": int(epoch),
            "del": [[int(u), int(v)] for u, v in deletes],
            "ins": [[int(u), int(v)] for u, v in inserts],
        }
        line = json.dumps(rec, separators=(",", ":")) + "\n"
        t0 = time.perf_counter()
        with _trace.span("wal.append", cat="stream", epoch=int(epoch),
                         bytes=len(line), fsync=self.fsync):
            self._f.write(line)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
                _WAL_FSYNCS.inc()
        _WAL_APPEND_SECONDS.observe(time.perf_counter() - t0)
        _WAL_APPENDS.inc()
        _WAL_BYTES.inc(len(line.encode("utf-8")))
        self.appends += 1

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def replay(path: str, after_epoch: int = -1):
        """Yield ``(epoch, deletes, inserts)`` for batches past ``after_epoch``.

        A torn (crash-interrupted) final line is skipped; corruption anywhere
        else is a real error and raises.
        """
        if not os.path.exists(path):
            return
        with open(path, "r", encoding="utf-8") as f:
            lines = f.readlines()
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    return  # torn tail: the batch was never acknowledged
                raise
            if rec["epoch"] <= after_epoch:
                continue
            yield (
                rec["epoch"],
                [tuple(e) for e in rec["del"]],
                [tuple(e) for e in rec["ins"]],
            )


class SnapshotStore:
    """Atomic (epoch, graph, core, cnt) snapshots; only the latest is kept."""

    PREFIX = "snap_"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, epoch: int) -> str:
        return os.path.join(self.root, f"{self.PREFIX}{epoch:012d}")

    def save(self, epoch: int, graph: CSRGraph, core: np.ndarray, cnt: np.ndarray) -> str:
        t0 = time.perf_counter()
        with _trace.span("snapshot.save", cat="stream", epoch=int(epoch),
                         nodes=int(graph.n), edges=int(graph.m)):
            tmp = os.path.join(self.root, ".snap_tmp")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            graph.save(tmp)
            np.save(os.path.join(tmp, "core.npy"), np.asarray(core, dtype=np.int64))
            np.save(os.path.join(tmp, "cnt.npy"), np.asarray(cnt, dtype=np.int64))
            with open(os.path.join(tmp, "epoch.json"), "w") as f:
                json.dump({"epoch": int(epoch)}, f)
            final = self._dir(epoch)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # publish atomically
            for name in os.listdir(self.root):  # GC superseded snapshots
                if name.startswith(self.PREFIX) and os.path.join(self.root, name) != final:
                    shutil.rmtree(os.path.join(self.root, name))
        _SNAP_SECONDS.observe(time.perf_counter() - t0)
        _SNAP_WRITES.inc()
        return final

    def latest(self):
        """Return ``(epoch, graph, core, cnt)`` or None when no snapshot."""
        snaps = sorted(
            n for n in os.listdir(self.root) if n.startswith(self.PREFIX)
        )
        if not snaps:
            return None
        d = os.path.join(self.root, snaps[-1])
        with open(os.path.join(d, "epoch.json")) as f:
            epoch = json.load(f)["epoch"]
        graph = CSRGraph.load(d, mmap=False)
        core = np.load(os.path.join(d, "core.npy"))
        cnt = np.load(os.path.join(d, "cnt.npy"))
        return epoch, graph, core, cnt
