"""Durability for the streaming core service: write-ahead log + snapshots.

The service's durable state is tiny — the O(n) node arrays (``core``,
``cnt``) plus the graph itself — which the paper's semi-external contract
already forces through a disk-resident edge table.  Crash recovery therefore
needs only:

* a **write-ahead log**: one record per admitted micro-batch, appended
  (and optionally fsynced) *before* the batch is applied.  A crash mid-append
  leaves a torn final record, which replay ignores — that batch was never
  acknowledged;
* a **snapshot store**: periodic atomic dumps of (epoch, CSR graph, core,
  cnt).  Snapshots are written to a temp directory and published with an
  atomic rename (plus a directory fsync) so a crash never exposes a
  half-written snapshot.

Recovery = latest snapshot + structural replay of the WAL tail + a warm
SemiCore* settle (see service.recover; DESIGN.md §9 for the upper-bound
argument).

**Integrity** (DESIGN.md §17): every record appended by this version is
framed ``c1 <len> <crc32c> <payload>\\n`` (:mod:`repro.stream.integrity`),
and snapshots carry a checksummed ``manifest.json``.  Legacy unframed JSON
lines still replay.  A corrupt *final* record is handled like a torn tail
(truncated / skipped — the batch was never acknowledged); a corrupt
*interior* record raises a typed :class:`CorruptionError` (legacy lines keep
raising ``json.JSONDecodeError``) which the replica converts into a
snapshot catch-up and the writer converts into recover-from-snapshot.
Rotation doubles as log *repair*: unparseable records are dropped (and
counted), so after any snapshot+rotation the live log is clean again.
Filesystem side effects route through :mod:`repro.faults.fs`, which is a
no-op unless a test installs a :class:`~repro.faults.FaultPlan`.

The WAL is also the **replication stream** (DESIGN.md §15): read replicas
tail it with :class:`WalTailer` — a stat/offset cursor that consumes only
complete (newline-terminated) records, tolerates the writer's in-flight
tail, and re-seeks after a rotation.  Rotation (``rotate(after_epoch)``,
invoked on snapshot publish) atomically drops records a published snapshot
supersedes, so the log's size tracks the snapshot interval rather than the
stream's lifetime.

Memory discipline: replay, torn-tail truncation, tailing and rotation are
all O(record) — the log is streamed line-by-line (the torn tail is found by
scanning *backwards* in bounded chunks), never slurped, so a multi-GB WAL
recovers in constant memory.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import warnings

import numpy as np

from ..core.update import UpdateBatch
from ..faults import fs as _faults
from ..graph.storage import CSRGraph
from ..obs import metrics as _metrics, trace as _trace
from .integrity import CorruptionError, crc32c, frame_record, is_framed, unframe

__all__ = ["WriteAheadLog", "SnapshotStore", "WalTailer", "WalGap",
           "CorruptionError"]

_WAL_APPENDS = _metrics.counter(
    "repro_wal_appends_total", "WAL records appended").labels()
_WAL_BYTES = _metrics.counter(
    "repro_wal_bytes_total", "Bytes written to the WAL (incl. newline)").labels()
_WAL_FSYNCS = _metrics.counter(
    "repro_wal_fsyncs_total", "fsync() calls issued by the WAL").labels()
_WAL_APPEND_SECONDS = _metrics.histogram(
    "repro_wal_append_seconds", "WAL append latency (write+flush+fsync)")
_WAL_ROTATIONS = _metrics.counter(
    "repro_wal_rotations_total", "WAL rotations (snapshot-superseded prefix "
    "dropped atomically)").labels()
_WAL_ROTATED_RECORDS = _metrics.counter(
    "repro_wal_rotated_records_total",
    "WAL records dropped by rotation (epoch <= snapshot epoch)").labels()
_WAL_REPAIRED_RECORDS = _metrics.counter(
    "repro_wal_repaired_records_total",
    "Unparseable WAL records dropped by rotation (log repair)").labels()
_SNAP_WRITES = _metrics.counter(
    "repro_snapshot_writes_total", "Snapshots published atomically").labels()
_SNAP_SECONDS = _metrics.histogram(
    "repro_snapshot_seconds", "Snapshot save latency (write + rename + GC)")
_SNAP_FALLBACKS = _metrics.counter(
    "repro_snapshot_fallbacks_total",
    "Snapshot loads that fell back past a corrupt/unreadable snapshot").labels()

#: backwards-scan chunk for torn-tail detection / tip peeking (bytes).
_SCAN_CHUNK = 1 << 16


class WalGap(RuntimeError):
    """A tailer fell behind a rotation: the WAL no longer contains the next
    record it needs (first surviving epoch > last applied + 1).  The tailer's
    owner must catch up through the snapshot store instead (DESIGN.md §15)."""


def _find_tail_start(f, size: int, chunk: int = _SCAN_CHUNK) -> int:
    """Byte offset where the final (possibly torn) line begins.

    Scans *backwards* in bounded chunks from ``size`` for the last newline
    strictly before the final byte, so memory stays O(chunk) no matter how
    large the log is.  ``size`` must not include a trailing newline byte at
    ``size-1`` (callers strip it first when they want the last *complete*
    line).
    """
    pos = size
    while pos > 0:
        lo = max(0, pos - chunk)
        f.seek(lo)
        buf = f.read(pos - lo)
        nl = buf.rfind(b"\n")
        if nl != -1:
            return lo + nl + 1
        pos = lo
    return 0


def _parse_record(raw: bytes, *, path: str | None = None,
                  offset: int | None = None) -> dict:
    """Parse one stripped, non-empty WAL line into its record dict.

    Framed (``c1 ...``) lines are checksum-verified and raise
    :class:`CorruptionError` on any mismatch; legacy unframed JSON lines
    parse as before and keep raising ``json.JSONDecodeError`` on damage
    (pre-framing callers depend on that type).
    """
    if is_framed(raw):
        payload = unframe(raw, path=path, offset=offset)
        try:
            return json.loads(payload.decode("utf-8"))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            # the CRC matched but the payload is garbage: a writer bug or a
            # collision — either way typed corruption, not a parse quirk.
            raise CorruptionError(f"framed payload is not valid JSON: {e}",
                                  path=path, offset=offset) from None
    return json.loads(raw.decode("utf-8", errors="replace"))


def _record_batch(rec: dict) -> UpdateBatch:
    """Decode a WAL record dict into its :class:`UpdateBatch`.

    Current records carry the typed op vocabulary (``"ops"``: ordered
    ``[kind, u, v]`` triples).  Legacy ``"del"``/``"ins"`` pair records
    decode as deletes-then-inserts — the canonical coalesced order the
    writer applied them in, so replay stays bit-identical.
    """
    if "ops" in rec:
        return UpdateBatch.from_wire(rec["ops"])
    return UpdateBatch.from_pairs(rec.get("del", ()), rec.get("ins", ()))


class WriteAheadLog:
    """Append-only log of admitted micro-batches, keyed by epoch.

    Records are checksum-framed (see module docstring); appends self-heal:
    if the write or fsync fails (real or injected), the file is rolled back
    to the pre-append offset so a caller's retry never lands after a torn
    fragment.
    """

    ROTATE_TMP_SUFFIX = ".rotate_tmp"

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        # a crash mid-rotation leaves the filtered temp file behind with the
        # original log intact (os.replace never ran): discard the temp.
        tmp = path + self.ROTATE_TMP_SUFFIX
        if os.path.exists(tmp):
            os.remove(tmp)
        self._truncate_torn_tail(path)
        self._f = open(path, "ab")
        self.appends = 0
        self.rotations = 0
        self.repaired = 0

    @staticmethod
    def _truncate_torn_tail(path: str) -> None:
        """Drop a crash-torn final line so new appends never concatenate
        onto it (a merged line would corrupt the *next* recovery).  A final
        *complete* framed record that fails its checksum is dropped the same
        way — it was never acknowledged-and-applied by a clean writer, and
        leaving it would turn into interior corruption at the next append.

        The last newline is found by scanning backwards in bounded chunks —
        peak memory is O(chunk), not O(log)."""
        if not os.path.exists(path):
            return
        with open(path, "rb+") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            if size == 0:
                return
            f.seek(size - 1)
            if f.read(1) != b"\n":
                size = _find_tail_start(f, size - 1)
                f.truncate(size)
            while size > 0:
                start = _find_tail_start(f, size - 1)
                f.seek(start)
                line = f.read(size - start).strip()
                if not line or not is_framed(line):
                    return  # legacy tail records keep the replay-time policy
                try:
                    unframe(line)
                    return  # healthy framed tail: nothing to heal
                except CorruptionError:
                    size = start
                    f.truncate(size)

    def append(self, epoch: int, batch, inserts=None) -> None:
        """Append one admitted micro-batch as a typed op record.

        ``batch`` is an :class:`UpdateBatch` (any iterable of
        ``Insert``/``Delete`` ops is promoted).  The historical
        ``append(epoch, deletes, inserts)`` pair form still works as a
        deprecated shim — it encodes deletes-then-inserts, which is the
        order the writer applied them in, so nothing changes on replay.
        """
        if inserts is not None:
            warnings.warn(
                "WriteAheadLog.append(epoch, deletes, inserts) is "
                "deprecated; pass an UpdateBatch",
                DeprecationWarning, stacklevel=2)
            batch = UpdateBatch.from_pairs(batch, inserts)
        elif not isinstance(batch, UpdateBatch):
            batch = UpdateBatch(tuple(batch))
        rec = {"epoch": int(epoch), "ops": batch.to_wire()}
        payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
        line = frame_record(payload)
        t0 = time.perf_counter()
        with _trace.span("wal.append", cat="stream", epoch=int(epoch),
                         bytes=len(line), fsync=self.fsync):
            self._f.seek(0, os.SEEK_END)
            start = self._f.tell()
            try:
                _faults.write(self._f, "wal.append", line, path=self.path)
                self._f.flush()
                if self.fsync:
                    _faults.fsync(self._f, "wal.fsync", path=self.path)
                    _WAL_FSYNCS.inc()
            except Exception:
                # self-heal: a failed append must leave no torn fragment for
                # the retry to concatenate onto.
                try:
                    self._f.flush()
                except OSError:
                    pass
                try:
                    os.ftruncate(self._f.fileno(), start)
                    self._f.seek(0, os.SEEK_END)
                except OSError:
                    pass
                raise
        _WAL_APPEND_SECONDS.observe(time.perf_counter() - t0)
        _WAL_APPENDS.inc()
        _WAL_BYTES.inc(len(line))
        self.appends += 1

    def rotate(self, after_epoch: int) -> int:
        """Atomically drop records with ``epoch <= after_epoch``.

        Invoked on snapshot publish: a record at or below the snapshot epoch
        is superseded (recovery and replicas bootstrap from the snapshot) and
        only bloats replay.  The surviving tail is *streamed* to a temp file
        and published with an atomic rename — a crash at any point leaves
        either the old complete log or the new complete log, never a
        half-rotated one.  Tailers notice the inode change and re-seek
        (:class:`WalTailer`).

        Rotation is also the log's *repair* path: records that fail their
        checksum (or do not parse at all) are dropped and counted in
        ``repaired`` — the snapshot that triggered this rotation supersedes
        them, so dropping is safe and unwedges any replica stuck behind the
        corruption.  Surviving legacy records are re-framed.  Returns the
        number of superseded records dropped.
        """
        self._f.flush()
        tmp = self.path + self.ROTATE_TMP_SUFFIX
        dropped = 0
        repaired = 0
        with _trace.span("wal.rotate", cat="stream",
                         after_epoch=int(after_epoch)):
            with open(self.path, "rb") as src, open(tmp, "wb") as out:
                offset = 0
                while True:
                    line = src.readline()
                    if not line:
                        break
                    next_offset = src.tell()
                    stripped = line.strip()
                    if stripped:
                        try:
                            rec = _parse_record(stripped, path=self.path,
                                                offset=offset)
                        except (CorruptionError, json.JSONDecodeError):
                            repaired += 1
                            rec = None
                        if rec is not None:
                            if rec["epoch"] <= after_epoch:
                                dropped += 1
                            else:
                                body = json.dumps(
                                    rec, separators=(",", ":")).encode("utf-8")
                                out.write(frame_record(body))
                    offset = next_offset
                out.flush()
                if self.fsync:
                    _faults.fsync(out, "wal.rotate.fsync", path=tmp)
            _faults.replace(tmp, self.path, op="wal.rotate.replace")
            # durability satellite: the rename is atomic but its directory
            # entry is not durable until the directory inode is synced.
            _faults.fsync_dir(
                os.path.dirname(os.path.abspath(self.path)), "wal.dirsync")
            # the open append handle points at the replaced (now anonymous)
            # inode — reopen so later appends land in the published log.
            self._f.close()
            self._f = open(self.path, "ab")
        self.rotations += 1
        self.repaired += repaired
        _WAL_ROTATIONS.inc()
        _WAL_ROTATED_RECORDS.inc(dropped)
        if repaired:
            _WAL_REPAIRED_RECORDS.inc(repaired)
        return dropped

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def replay(path: str, after_epoch: int = -1):
        """Yield ``(epoch, UpdateBatch)`` for batches past ``after_epoch``.

        Both record generations decode — typed ``"ops"`` records in op
        order, legacy ``"del"``/``"ins"`` records as deletes-then-inserts
        (see :func:`_record_batch`).

        Streams the log line-by-line (O(record) memory, never ``readlines``).
        A torn or checksum-corrupt *final* record is skipped (that batch was
        never acknowledged); damage anywhere else is real corruption and
        raises — :class:`CorruptionError` with the byte offset for framed
        records, ``json.JSONDecodeError`` for legacy lines.
        """
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            offset = 0
            while True:
                line = f.readline()
                if not line:
                    return
                start = offset
                offset = f.tell()
                stripped = line.strip()
                if not stripped:
                    continue
                try:
                    rec = _parse_record(stripped, path=path, offset=start)
                except (CorruptionError, json.JSONDecodeError):
                    # only a *final* bad record is a torn/corrupt tail (the
                    # batch was never acknowledged); anything after it means
                    # mid-log corruption, which must not be silently skipped.
                    if f.read(_SCAN_CHUNK).strip():
                        raise
                    return
                if rec["epoch"] <= after_epoch:
                    continue
                yield rec["epoch"], _record_batch(rec)

    @staticmethod
    def tip_epoch(path: str):
        """Epoch of the last *complete, intact* record, or ``None``.

        Reads only the final line(s) (backwards chunk scan + one parse), so
        a replica's ``lag()`` probe costs O(record) regardless of log size.
        One corrupt final record is stepped over (torn-tail policy); a
        second bad record in a row is interior corruption and raises.
        """
        if not os.path.exists(path):
            return None
        corrupt_skipped = False
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            end = f.tell()
            if end:
                f.seek(end - 1)
                if f.read(1) != b"\n":  # torn final line: step back past it
                    end = _find_tail_start(f, end - 1)
            while end > 0:
                # ``end`` sits just past a newline: the line ending there is
                # complete.  Blank lines are skipped by scanning further back.
                start = _find_tail_start(f, end - 1)
                f.seek(start)
                line = f.read(end - start).strip()
                if line:
                    try:
                        return int(_parse_record(
                            line, path=path, offset=start)["epoch"])
                    except (CorruptionError, json.JSONDecodeError):
                        if corrupt_skipped:
                            raise
                        corrupt_skipped = True
                end = start
        return None


class WalTailer:
    """Incremental, restartable WAL cursor for read replicas (DESIGN.md §15).

    Resumes from a byte offset, consumes only **complete** records (a final
    line without its newline is the writer's in-flight append — or a torn
    crash remnant — and is left for the next poll), deduplicates by epoch,
    and re-verifies its position after a rotation: the atomic rename swaps
    the inode, so a changed inode (or a size below the cursor) forces a
    re-seek from the start, where the epoch filter drops already-applied
    records.

    If the first surviving record after a re-seek skips past
    ``last_epoch + 1``, the rotation outran this tailer and :class:`WalGap`
    is raised — the owner must catch up from the snapshot store.  A record
    that fails its checksum raises :class:`CorruptionError` *without
    advancing the cursor*: the owner bootstraps from a snapshot and the
    writer's next rotation repairs the log.
    """

    def __init__(self, path: str, after_epoch: int = -1):
        self.path = path
        self.offset = 0
        self.last_epoch = int(after_epoch)
        self._ino = None
        self.rotations_detected = 0
        self.records_read = 0

    def poll(self):
        """Yield ``(epoch, UpdateBatch)`` newly durable since last poll."""
        _faults.on_op("wal.poll")
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            st = os.fstat(f.fileno())  # fstat the fd: no stat/open race
            if self._ino is not None and (
                    st.st_ino != self._ino or st.st_size < self.offset):
                # rotated (new inode) or truncated under us: re-scan from the
                # start; the epoch filter below deduplicates.
                self.offset = 0
                self.rotations_detected += 1
            self._ino = st.st_ino
            f.seek(self.offset)
            while True:
                start = f.tell()
                line = f.readline()
                if not line or not line.endswith(b"\n"):
                    return  # in-flight / torn tail: not yet durable
                stripped = line.strip()
                if not stripped:
                    self.offset = f.tell()
                    continue
                try:
                    rec = _parse_record(stripped, path=self.path, offset=start)
                except CorruptionError:
                    raise  # cursor NOT advanced: re-polls see it until repair
                except json.JSONDecodeError as e:
                    raise CorruptionError(
                        f"unparseable legacy record: {e}",
                        path=self.path, offset=start) from None
                self.offset = f.tell()
                epoch = int(rec["epoch"])
                if epoch <= self.last_epoch:
                    continue
                # epochs are consecutive; a skip means rotation already
                # dropped records this tailer still needs.  (A cursor born
                # at after_epoch<0 tails from the log's own first record.)
                if self.last_epoch >= 0 and epoch > self.last_epoch + 1:
                    raise WalGap(
                        f"WAL at {self.path!r} resumes at epoch {epoch} but "
                        f"tailer last applied {self.last_epoch}: rotation "
                        "outran this replica; bootstrap from a snapshot"
                    )
                self.last_epoch = epoch
                self.records_read += 1
                yield epoch, _record_batch(rec)


class SnapshotStore:
    """Atomic (epoch, graph, core, cnt) snapshots with checksummed manifests.

    ``keep`` retains the newest N snapshots (default 1 = the historical
    behavior).  ``keep >= 2`` makes *recover-from-previous-snapshot* sound:
    when the latest snapshot is corrupt, ``latest()`` falls back to an older
    one, and the writer's rotation floor (``oldest_retained_epoch``) keeps
    the WAL records needed to roll forward from it.
    """

    PREFIX = "snap_"
    MANIFEST = "manifest.json"
    _CRC_CHUNK = 1 << 20

    def __init__(self, root: str, keep: int = 1):
        self.root = root
        self.keep = max(1, int(keep))
        self.fallbacks = 0
        os.makedirs(root, exist_ok=True)

    def _dir(self, epoch: int) -> str:
        return os.path.join(self.root, f"{self.PREFIX}{epoch:012d}")

    def _names(self):
        return sorted(
            n for n in os.listdir(self.root) if n.startswith(self.PREFIX))

    @classmethod
    def _file_crc(cls, path: str) -> tuple[int, int]:
        crc = 0
        size = 0
        with open(path, "rb") as f:
            while True:
                chunk = f.read(cls._CRC_CHUNK)
                if not chunk:
                    return crc, size
                crc = crc32c(chunk, crc)
                size += len(chunk)

    def save(self, epoch: int, graph: CSRGraph, core: np.ndarray,
             cnt: np.ndarray) -> str:
        t0 = time.perf_counter()
        with _trace.span("snapshot.save", cat="stream", epoch=int(epoch),
                         nodes=int(graph.n), edges=int(graph.m)):
            _faults.on_op("snapshot.save")
            tmp = os.path.join(self.root, ".snap_tmp")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            graph.save(tmp)
            np.save(os.path.join(tmp, "core.npy"),
                    np.asarray(core, dtype=np.int64))
            np.save(os.path.join(tmp, "cnt.npy"),
                    np.asarray(cnt, dtype=np.int64))
            with open(os.path.join(tmp, "epoch.json"), "w") as f:
                json.dump({"epoch": int(epoch)}, f)
            self._write_manifest(tmp, epoch)
            # durability satellite: fsync every payload file, then the temp
            # directory, then publish, then the parent directory — without
            # the dir fsyncs a power loss can lose the published entry even
            # though every byte of content was synced.
            for name in os.listdir(tmp):
                p = os.path.join(tmp, name)
                with open(p, "rb") as f:
                    _faults.fsync(f, "snapshot.fsync", path=p)
            _faults.fsync_dir(tmp, "snapshot.dirsync")
            final = self._dir(epoch)
            if os.path.exists(final):
                shutil.rmtree(final)
            _faults.replace(tmp, final, op="snapshot.publish")
            _faults.fsync_dir(self.root, "snapshot.dirsync")
            for name in self._names()[:-self.keep]:  # keep-N GC
                full = os.path.join(self.root, name)
                if full != final:
                    shutil.rmtree(full)
        _SNAP_SECONDS.observe(time.perf_counter() - t0)
        _SNAP_WRITES.inc()
        return final

    def _write_manifest(self, d: str, epoch: int) -> None:
        files = {}
        for name in sorted(os.listdir(d)):
            if name == self.MANIFEST:
                continue
            crc, size = self._file_crc(os.path.join(d, name))
            files[name] = {"bytes": size, "crc32c": f"{crc:08x}"}
        body = {"version": 1, "epoch": int(epoch), "files": files}
        blob = json.dumps(body, sort_keys=True,
                          separators=(",", ":")).encode("utf-8")
        manifest = dict(body, crc32c=f"{crc32c(blob):08x}")
        with open(os.path.join(d, self.MANIFEST), "w") as f:
            json.dump(manifest, f, sort_keys=True, separators=(",", ":"))

    def verify(self, d: str) -> None:
        """Integrity-check one snapshot directory against its manifest.

        Raises :class:`CorruptionError` on any mismatch.  Snapshots written
        before manifests existed (no ``manifest.json``) pass unverified.
        """
        mpath = os.path.join(d, self.MANIFEST)
        if not os.path.exists(mpath):
            return  # legacy snapshot: nothing to check against
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CorruptionError(f"unreadable manifest: {e}",
                                  layer="snapshot", path=mpath) from None
        claimed = manifest.pop("crc32c", None)
        blob = json.dumps(manifest, sort_keys=True,
                          separators=(",", ":")).encode("utf-8")
        if claimed != f"{crc32c(blob):08x}":
            raise CorruptionError("manifest checksum mismatch",
                                  layer="snapshot", path=mpath)
        for name, meta in manifest.get("files", {}).items():
            p = os.path.join(d, name)
            if not os.path.exists(p):
                raise CorruptionError(f"manifest lists missing file {name}",
                                      layer="snapshot", path=p)
            crc, size = self._file_crc(p)
            if size != meta["bytes"] or f"{crc:08x}" != meta["crc32c"]:
                raise CorruptionError(
                    f"file {name}: manifest says {meta['bytes']}B/"
                    f"{meta['crc32c']}, found {size}B/{crc:08x}",
                    layer="snapshot", path=p)

    def latest_epoch(self):
        """Epoch of the latest snapshot (directory-name parse only), or None.

        Cheap staleness floor for replicas: right after a rotation the WAL
        can be empty, but the snapshot that triggered it pins the writer's
        committed epoch from below.
        """
        names = self._names()
        return int(names[-1][len(self.PREFIX):]) if names else None

    def oldest_retained_epoch(self):
        """Epoch of the oldest retained snapshot, or None.

        The writer's WAL rotation floor: dropping records above this epoch
        would strand the fallback snapshots ``keep >= 2`` exists to provide.
        With the default ``keep=1`` this equals ``latest_epoch()``.
        """
        names = self._names()
        return int(names[0][len(self.PREFIX):]) if names else None

    def latest(self):
        """Return ``(epoch, graph, core, cnt)`` or None when no snapshot.

        Verifies the manifest before trusting a snapshot; a corrupt or
        unreadable snapshot falls back to the next-older one (counted in
        ``repro_snapshot_fallbacks_total``).  Raises :class:`CorruptionError`
        only when *every* retained snapshot fails.
        """
        _faults.on_op("snapshot.load")  # transient faults propagate: retryable
        names = self._names()
        last_err = None
        for i, name in enumerate(reversed(names)):
            d = os.path.join(self.root, name)
            try:
                self.verify(d)
                with open(os.path.join(d, "epoch.json")) as f:
                    epoch = json.load(f)["epoch"]
                graph = CSRGraph.load(d, mmap=False)
                core = np.load(os.path.join(d, "core.npy"))
                cnt = np.load(os.path.join(d, "cnt.npy"))
            except (CorruptionError, OSError, ValueError, KeyError,
                    json.JSONDecodeError) as e:
                last_err = e
                continue
            if i:
                self.fallbacks += 1
                _SNAP_FALLBACKS.inc(i)
            return epoch, graph, core, cnt
        if names:
            raise CorruptionError(
                f"all {len(names)} retained snapshots failed to load "
                f"(last error: {last_err})", layer="snapshot", path=self.root)
        return None
