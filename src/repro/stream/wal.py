"""Durability for the streaming core service: write-ahead log + snapshots.

The service's durable state is tiny — the O(n) node arrays (``core``,
``cnt``) plus the graph itself — which the paper's semi-external contract
already forces through a disk-resident edge table.  Crash recovery therefore
needs only:

* a **write-ahead log**: one JSON line per admitted micro-batch, appended
  (and optionally fsynced) *before* the batch is applied.  A crash mid-append
  leaves a torn final line, which replay ignores — that batch was never
  acknowledged;
* a **snapshot store**: periodic atomic dumps of (epoch, CSR graph, core,
  cnt).  Snapshots are written to a temp directory and published with
  ``os.replace`` so a crash never exposes a half-written snapshot.

Recovery = latest snapshot + structural replay of the WAL tail + a warm
SemiCore* settle (see service.recover; DESIGN.md §9 for the upper-bound
argument).

The WAL is also the **replication stream** (DESIGN.md §15): read replicas
tail it with :class:`WalTailer` — a stat/offset cursor that consumes only
complete (newline-terminated) records, tolerates the writer's in-flight
tail, and re-seeks after a rotation.  Rotation (``rotate(after_epoch)``,
invoked on snapshot publish) atomically drops records a published snapshot
supersedes, so the log's size tracks the snapshot interval rather than the
stream's lifetime.

Memory discipline: replay, torn-tail truncation, tailing and rotation are
all O(record) — the log is streamed line-by-line (the torn tail is found by
scanning *backwards* in bounded chunks), never slurped, so a multi-GB WAL
recovers in constant memory.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import numpy as np

from ..graph.storage import CSRGraph
from ..obs import metrics as _metrics, trace as _trace

__all__ = ["WriteAheadLog", "SnapshotStore", "WalTailer", "WalGap"]

_WAL_APPENDS = _metrics.counter(
    "repro_wal_appends_total", "WAL records appended").labels()
_WAL_BYTES = _metrics.counter(
    "repro_wal_bytes_total", "Bytes written to the WAL (incl. newline)").labels()
_WAL_FSYNCS = _metrics.counter(
    "repro_wal_fsyncs_total", "fsync() calls issued by the WAL").labels()
_WAL_APPEND_SECONDS = _metrics.histogram(
    "repro_wal_append_seconds", "WAL append latency (write+flush+fsync)")
_WAL_ROTATIONS = _metrics.counter(
    "repro_wal_rotations_total", "WAL rotations (snapshot-superseded prefix "
    "dropped atomically)").labels()
_WAL_ROTATED_RECORDS = _metrics.counter(
    "repro_wal_rotated_records_total",
    "WAL records dropped by rotation (epoch <= snapshot epoch)").labels()
_SNAP_WRITES = _metrics.counter(
    "repro_snapshot_writes_total", "Snapshots published atomically").labels()
_SNAP_SECONDS = _metrics.histogram(
    "repro_snapshot_seconds", "Snapshot save latency (write + rename + GC)")

#: backwards-scan chunk for torn-tail detection / tip peeking (bytes).
_SCAN_CHUNK = 1 << 16


class WalGap(RuntimeError):
    """A tailer fell behind a rotation: the WAL no longer contains the next
    record it needs (first surviving epoch > last applied + 1).  The tailer's
    owner must catch up through the snapshot store instead (DESIGN.md §15)."""


def _find_tail_start(f, size: int, chunk: int = _SCAN_CHUNK) -> int:
    """Byte offset where the final (possibly torn) line begins.

    Scans *backwards* in bounded chunks from ``size`` for the last newline
    strictly before the final byte, so memory stays O(chunk) no matter how
    large the log is.  ``size`` must not include a trailing newline byte at
    ``size-1`` (callers strip it first when they want the last *complete*
    line).
    """
    pos = size
    while pos > 0:
        lo = max(0, pos - chunk)
        f.seek(lo)
        buf = f.read(pos - lo)
        nl = buf.rfind(b"\n")
        if nl != -1:
            return lo + nl + 1
        pos = lo
    return 0


class WriteAheadLog:
    """Append-only JSONL of admitted micro-batches, keyed by epoch."""

    ROTATE_TMP_SUFFIX = ".rotate_tmp"

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        # a crash mid-rotation leaves the filtered temp file behind with the
        # original log intact (os.replace never ran): discard the temp.
        tmp = path + self.ROTATE_TMP_SUFFIX
        if os.path.exists(tmp):
            os.remove(tmp)
        self._truncate_torn_tail(path)
        self._f = open(path, "a", encoding="utf-8")
        self.appends = 0
        self.rotations = 0

    @staticmethod
    def _truncate_torn_tail(path: str) -> None:
        """Drop a crash-torn final line so new appends never concatenate
        onto it (a merged line would corrupt the *next* recovery).

        The last newline is found by scanning backwards in bounded chunks —
        peak memory is O(chunk), not O(log)."""
        if not os.path.exists(path):
            return
        with open(path, "rb+") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            if size == 0:
                return
            f.seek(size - 1)
            if f.read(1) == b"\n":
                return
            f.truncate(_find_tail_start(f, size - 1))

    def append(self, epoch: int, deletes, inserts) -> None:
        rec = {
            "epoch": int(epoch),
            "del": [[int(u), int(v)] for u, v in deletes],
            "ins": [[int(u), int(v)] for u, v in inserts],
        }
        line = json.dumps(rec, separators=(",", ":")) + "\n"
        t0 = time.perf_counter()
        with _trace.span("wal.append", cat="stream", epoch=int(epoch),
                         bytes=len(line), fsync=self.fsync):
            self._f.write(line)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
                _WAL_FSYNCS.inc()
        _WAL_APPEND_SECONDS.observe(time.perf_counter() - t0)
        _WAL_APPENDS.inc()
        _WAL_BYTES.inc(len(line.encode("utf-8")))
        self.appends += 1

    def rotate(self, after_epoch: int) -> int:
        """Atomically drop records with ``epoch <= after_epoch``.

        Invoked on snapshot publish: a record at or below the snapshot epoch
        is superseded (recovery and replicas bootstrap from the snapshot) and
        only bloats replay.  The surviving tail is *streamed* to a temp file
        and published with ``os.replace`` — a crash at any point leaves
        either the old complete log or the new complete log, never a
        half-rotated one.  Tailers notice the inode change and re-seek
        (:class:`WalTailer`).  Returns the number of records dropped.
        """
        self._f.flush()
        tmp = self.path + self.ROTATE_TMP_SUFFIX
        dropped = 0
        with _trace.span("wal.rotate", cat="stream",
                         after_epoch=int(after_epoch)):
            with open(self.path, "r", encoding="utf-8") as src, \
                    open(tmp, "w", encoding="utf-8") as out:
                for line in src:  # streamed: O(record) memory
                    stripped = line.strip()
                    if not stripped:
                        continue
                    if json.loads(stripped)["epoch"] <= after_epoch:
                        dropped += 1
                    else:
                        out.write(stripped + "\n")
                out.flush()
                if self.fsync:
                    os.fsync(out.fileno())
            os.replace(tmp, self.path)
            # the open append handle points at the replaced (now anonymous)
            # inode — reopen so later appends land in the published log.
            self._f.close()
            self._f = open(self.path, "a", encoding="utf-8")
        self.rotations += 1
        _WAL_ROTATIONS.inc()
        _WAL_ROTATED_RECORDS.inc(dropped)
        return dropped

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def replay(path: str, after_epoch: int = -1):
        """Yield ``(epoch, deletes, inserts)`` for batches past ``after_epoch``.

        Streams the log line-by-line (O(record) memory, never ``readlines``).
        A torn (crash-interrupted) final line is skipped; corruption anywhere
        else is a real error and raises.
        """
        if not os.path.exists(path):
            return
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                stripped = line.strip()
                if not stripped:
                    continue
                try:
                    rec = json.loads(stripped)
                except json.JSONDecodeError:
                    # only a *final* bad line is a torn tail (the batch was
                    # never acknowledged); anything after it means mid-log
                    # corruption, which must not be silently skipped.
                    if f.read(_SCAN_CHUNK).strip():
                        raise
                    return
                if rec["epoch"] <= after_epoch:
                    continue
                yield (
                    rec["epoch"],
                    [tuple(e) for e in rec["del"]],
                    [tuple(e) for e in rec["ins"]],
                )

    @staticmethod
    def tip_epoch(path: str):
        """Epoch of the last *complete* record, or ``None`` for no record.

        Reads only the final line (backwards chunk scan + one parse), so a
        replica's ``lag()`` probe costs O(record) regardless of log size.
        """
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            end = f.tell()
            if end:
                f.seek(end - 1)
                if f.read(1) != b"\n":  # torn final line: step back past it
                    end = _find_tail_start(f, end - 1)
            while end > 0:
                # ``end`` sits just past a newline: the line ending there is
                # complete.  Blank lines are skipped by scanning further back.
                start = _find_tail_start(f, end - 1)
                f.seek(start)
                line = f.read(end - start).strip()
                if line:
                    return int(json.loads(line)["epoch"])
                end = start
        return None


class WalTailer:
    """Incremental, restartable WAL cursor for read replicas (DESIGN.md §15).

    Resumes from a byte offset, consumes only **complete** records (a final
    line without its newline is the writer's in-flight append — or a torn
    crash remnant — and is left for the next poll), deduplicates by epoch,
    and re-verifies its position after a rotation: ``os.replace`` swaps the
    inode, so a changed inode (or a size below the cursor) forces a re-seek
    from the start, where the epoch filter drops already-applied records.

    If the first surviving record after a re-seek skips past
    ``last_epoch + 1``, the rotation outran this tailer and :class:`WalGap`
    is raised — the owner must catch up from the snapshot store.
    """

    def __init__(self, path: str, after_epoch: int = -1):
        self.path = path
        self.offset = 0
        self.last_epoch = int(after_epoch)
        self._ino = None
        self.rotations_detected = 0
        self.records_read = 0

    def poll(self):
        """Yield ``(epoch, deletes, inserts)`` newly durable since last poll."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            st = os.fstat(f.fileno())  # fstat the fd: no stat/open race
            if self._ino is not None and (
                    st.st_ino != self._ino or st.st_size < self.offset):
                # rotated (new inode) or truncated under us: re-scan from the
                # start; the epoch filter below deduplicates.
                self.offset = 0
                self.rotations_detected += 1
            self._ino = st.st_ino
            f.seek(self.offset)
            while True:
                line = f.readline()
                if not line or not line.endswith(b"\n"):
                    return  # in-flight / torn tail: not yet durable
                self.offset = f.tell()
                stripped = line.strip()
                if not stripped:
                    continue
                rec = json.loads(stripped)
                epoch = int(rec["epoch"])
                if epoch <= self.last_epoch:
                    continue
                # epochs are consecutive; a skip means rotation already
                # dropped records this tailer still needs.  (A cursor born
                # at after_epoch<0 tails from the log's own first record.)
                if self.last_epoch >= 0 and epoch > self.last_epoch + 1:
                    raise WalGap(
                        f"WAL at {self.path!r} resumes at epoch {epoch} but "
                        f"tailer last applied {self.last_epoch}: rotation "
                        "outran this replica; bootstrap from a snapshot"
                    )
                self.last_epoch = epoch
                self.records_read += 1
                yield (
                    epoch,
                    [tuple(e) for e in rec["del"]],
                    [tuple(e) for e in rec["ins"]],
                )


class SnapshotStore:
    """Atomic (epoch, graph, core, cnt) snapshots; only the latest is kept."""

    PREFIX = "snap_"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, epoch: int) -> str:
        return os.path.join(self.root, f"{self.PREFIX}{epoch:012d}")

    def save(self, epoch: int, graph: CSRGraph, core: np.ndarray, cnt: np.ndarray) -> str:
        t0 = time.perf_counter()
        with _trace.span("snapshot.save", cat="stream", epoch=int(epoch),
                         nodes=int(graph.n), edges=int(graph.m)):
            tmp = os.path.join(self.root, ".snap_tmp")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            graph.save(tmp)
            np.save(os.path.join(tmp, "core.npy"), np.asarray(core, dtype=np.int64))
            np.save(os.path.join(tmp, "cnt.npy"), np.asarray(cnt, dtype=np.int64))
            with open(os.path.join(tmp, "epoch.json"), "w") as f:
                json.dump({"epoch": int(epoch)}, f)
            final = self._dir(epoch)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # publish atomically
            for name in os.listdir(self.root):  # GC superseded snapshots
                if name.startswith(self.PREFIX) and os.path.join(self.root, name) != final:
                    shutil.rmtree(os.path.join(self.root, name))
        _SNAP_SECONDS.observe(time.perf_counter() - t0)
        _SNAP_WRITES.inc()
        return final

    def latest_epoch(self):
        """Epoch of the latest snapshot (directory-name parse only), or None.

        Cheap staleness floor for replicas: right after a rotation the WAL
        can be empty, but the snapshot that triggered it pins the writer's
        committed epoch from below.
        """
        snaps = sorted(
            n for n in os.listdir(self.root) if n.startswith(self.PREFIX)
        )
        return int(snaps[-1][len(self.PREFIX):]) if snaps else None

    def latest(self):
        """Return ``(epoch, graph, core, cnt)`` or None when no snapshot."""
        snaps = sorted(
            n for n in os.listdir(self.root) if n.startswith(self.PREFIX)
        )
        if not snaps:
            return None
        d = os.path.join(self.root, snaps[-1])
        with open(os.path.join(d, "epoch.json")) as f:
            epoch = json.load(f)["epoch"]
        graph = CSRGraph.load(d, mmap=False)
        core = np.load(os.path.join(d, "core.npy"))
        cnt = np.load(os.path.join(d, "cnt.npy"))
        return epoch, graph, core, cnt
