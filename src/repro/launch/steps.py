"""Cell assembly: (arch x shape x mesh) -> jit-ready step fn + avals + shardings.

Sharding policy per cell family (DESIGN.md §5):

* LM train      — batch over (pod, data); Megatron TP over `model` (heads /
                  mlp / vocab / expert); FSDP over `data` on the embed dim
                  (2D param sharding); optimizer state mirrors params
                  (int8-moment state shards its block dim over data).
* LM prefill    — batch over (pod, data), heads over model.
* LM decode_32k — cache batch over (pod, data), cache sequence over model.
* LM long_500k  — batch=1: cache sequence over *all* axes (flash-combine);
                  weights TP over model.
* GNN           — edge arrays over all axes flattened; node state replicated
                  (the decomposition engine's semi-external layout).
* RecSys        — embedding-table rows over model; batch over (pod, data).
* CoreGraph     — the paper's engine: shards over all axes, core replicated.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config, input_specs
from ..configs.base import LMConfig, GNNConfig, RecsysConfig, CoreGraphConfig
from ..models import transformer as tfm
from ..models import gnn as gnn_m
from ..models import recsys as rec_m
from ..models.params import tree_avals, tree_shardings, Spec, tree_num_params
from ..optim import AdamWConfig, adamw_update, adamw_state_avals


@dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple                 # avals, positional
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    num_params: int = 0
    static: dict | None = None


def _batch_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


def _all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _ba_rule(mesh: Mesh):
    ba = _batch_axes(mesh)
    return ba if len(ba) > 1 else ba[0]


def _lm_rules(mesh: Mesh, step_kind: str) -> dict:
    """TP over model; experts 2D (expert x embed-over-batch-axes); weights
    otherwise replicated over batch axes.  (Full FSDP on dense weights is
    opt-in via REPRO_FSDP=1: XLA's partitioner currently resolves it with
    involuntary remat, inflating per-layer flops ~8x — see EXPERIMENTS §Perf.)
    """
    rules = {"heads": "model", "kv_heads": "model", "mlp": "model",
             "vocab": "model", "expert": "model", "rows": "model",
             "embed": None, "expert_embed": _ba_rule(mesh)}
    if step_kind == "train" and os.environ.get("REPRO_FSDP") == "1":
        rules["embed"] = _ba_rule(mesh)
    return rules


def _zero1_rules(rules: dict, mesh: Mesh) -> dict:
    """Optimizer-state rules: additionally shard the embed dim over batch
    axes (ZeRO-1) — states live 2D even where weights stay replicated."""
    return {**rules, "embed": _ba_rule(mesh)}


def _opt_shardings(param_specs, mesh, rules, opt: AdamWConfig):
    param_sh = tree_shardings(param_specs, mesh, rules)
    if not opt.quantize_moments:
        mu = jax.tree.map(lambda s: {"m": s, "v": s}, param_sh,
                          is_leaf=lambda x: isinstance(x, NamedSharding))
    else:
        ba = _batch_axes(mesh)
        q = _ns(mesh, ba, None)
        s = _ns(mesh, ba)
        mu = jax.tree.map(lambda _: {"m_q": q, "m_s": s, "v_q": q, "v_s": s},
                          param_sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    return {"step": _ns(mesh), "mu": mu}


# ===================================================================== LM
def _build_lm(cfg: LMConfig, shape_name, step_kind, avals, mesh, opt, reduced):
    ba = _batch_axes(mesh)
    rules = _lm_rules(mesh, step_kind)
    pspecs = tfm.lm_param_specs(cfg)
    p_avals = tree_avals(pspecs)
    p_shard = tree_shardings(pspecs, mesh, rules)
    n_params = tree_num_params(pspecs)

    if step_kind == "train":
        o_avals = adamw_state_avals(p_avals, opt)
        o_shard = _opt_shardings(pspecs, mesh, _zero1_rules(rules, mesh), opt)
        # gradient accumulation: bound per-chip live tokens per microbatch
        B, S = avals["tokens"].shape
        data_shards = int(np.prod([mesh.shape[a] for a in ba]))
        tokens_per_chip = B * S // max(data_shards, 1)
        budget = int(os.environ.get("REPRO_ACCUM_TOKENS", 8192))
        want = max(1, -(-tokens_per_chip // budget))
        accum = 1
        for cand in range(min(want, B), 0, -1):  # microbatch stays shardable
            if B % cand == 0 and (B // cand) % data_shards == 0:
                accum = cand
                break

        def step(params, opt_state, tokens, labels):
            if accum == 1:
                loss, grads = jax.value_and_grad(tfm.lm_loss)(
                    params, cfg, tokens, labels)
            else:
                # keep each microbatch batch-sharded over the data axes
                mb_spec = P(None, ba if len(ba) > 1 else ba[0], None)
                mb_tok = jax.lax.with_sharding_constraint(
                    tokens.reshape(accum, B // accum, S), mb_spec)
                mb_lbl = jax.lax.with_sharding_constraint(
                    labels.reshape(accum, B // accum, S), mb_spec)

                def micro(carry, mb):
                    t, l = mb
                    loss, g = jax.value_and_grad(tfm.lm_loss)(params, cfg, t, l)
                    return jax.tree.map(jnp.add, carry[0], g), carry[1] + loss

                from ..models.layers import _unroll_scans
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    lambda c, mb: (micro(c, mb), None),
                    (zeros, jnp.float32(0)), (mb_tok, mb_lbl),
                    unroll=accum if _unroll_scans() else 1)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
            params, opt_state = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, loss

        tok_sh = _ns(mesh, ba, None)
        return StepBundle(
            name="train_step", fn=step,
            args=(p_avals, o_avals, avals["tokens"], avals["labels"]),
            in_shardings=(p_shard, o_shard, tok_sh, tok_sh),
            out_shardings=(p_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 1), num_params=n_params,
            static={"opt": opt, "cfg": cfg, "accum": accum, "rules": rules,
                    "pspecs": pspecs},
        )

    if step_kind == "prefill":
        def step(params, tokens):
            return tfm.serve_prefill(params, cfg, tokens)

        return StepBundle(
            name="serve_prefill", fn=step,
            args=(p_avals, avals["tokens"]),
            in_shardings=(p_shard, _ns(mesh, ba, None)),
            out_shardings=_ns(mesh, ba, None, "model"),
            num_params=n_params,
        )

    # decode
    long_ctx = shape_name == "long_500k"
    if long_ctx:
        seq_axes = _all_axes(mesh)
        cache_b, cache_t = None, seq_axes
    else:
        cache_b, cache_t = ba, "model"

    def cache_sharding(aval_key):
        if aval_key == "len":
            return _ns(mesh)
        # (L, B, T, ...) — rank 4 (MLA: ckv/kr) or 5 (k/v)
        rank = 5 if cfg.mla is None else 4
        trailing = (None,) * (rank - 3)
        return _ns(mesh, None, cache_b, cache_t, *trailing)

    c_shard = {k: cache_sharding(k) for k in avals["caches"]}

    def step(params, tokens, caches):
        return tfm.serve_decode(params, cfg, tokens, caches)

    return StepBundle(
        name="serve_decode", fn=step,
        args=(p_avals, avals["tokens"], avals["caches"]),
        in_shardings=(p_shard, _ns(mesh, cache_b, None), c_shard),
        out_shardings=(_ns(mesh, cache_b, None, "model"),
                       {**c_shard}),
        donate_argnums=(2,), num_params=n_params,
    )


# ===================================================================== GNN
def _build_gnn(cfg: GNNConfig, shape_name, step_kind, avals, mesh, opt, reduced):
    batch_avals = avals["batch"]
    N = avals["num_nodes"]
    sh = SHAPE_FEAT_DIM = batch_avals.get("x")
    d_in = batch_avals["x"].shape[-1] if "x" in batch_avals else 0
    pspecs = gnn_m.gnn_param_specs(cfg, d_in)
    p_avals = tree_avals(pspecs)
    p_shard = tree_shardings(pspecs, mesh, {})  # replicated (small models)
    n_params = tree_num_params(pspecs)
    o_avals = adamw_state_avals(p_avals, opt)
    o_shard = _opt_shardings(pspecs, mesh, {}, opt)

    edge_sh = _ns(mesh, _all_axes(mesh))
    repl = _ns(mesh)
    b_shard = {
        k: edge_sh if k in ("src", "dst") else repl for k in batch_avals
    }

    def step(params, opt_state, batch):
        def loss_fn(p):
            return gnn_m.gnn_loss(p, cfg, {**batch, "num_nodes": N})

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, loss

    return StepBundle(
        name="train_step", fn=step,
        args=(p_avals, o_avals, batch_avals),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, repl),
        donate_argnums=(0, 1), num_params=n_params,
        static={"opt": opt, "cfg": cfg},
    )


# ================================================================== recsys
def _build_recsys(cfg: RecsysConfig, shape_name, step_kind, avals, mesh, opt,
                  reduced):
    ba = _batch_axes(mesh)
    rules = {"rows": "model", "embed": None, "mlp": "model", "embed2": None}
    pspecs = rec_m.mind_param_specs(cfg)
    p_avals = tree_avals(pspecs)
    p_shard = tree_shardings(pspecs, mesh, rules)
    n_params = tree_num_params(pspecs)

    def batch_shard(k, aval):
        if k == "candidate_ids":
            return _ns(mesh, ba)
        if aval.shape[0] == 1:  # retrieval: a single user, replicated
            return _ns(mesh)
        return _ns(mesh, ba, *([None] * (len(aval.shape) - 1)))

    b_shard = {k: batch_shard(k, v) for k, v in avals.items()}

    if step_kind == "train":
        o_avals = adamw_state_avals(p_avals, opt)
        o_shard = _opt_shardings(pspecs, mesh, rules, opt)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(rec_m.mind_train_loss)(
                params, cfg, batch)
            params, opt_state = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, loss

        return StepBundle(
            name="train_step", fn=step,
            args=(p_avals, o_avals, avals),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, _ns(mesh)),
            donate_argnums=(0, 1), num_params=n_params,
            static={"opt": opt, "cfg": cfg},
        )

    if step_kind == "serve":
        def step(params, batch):
            return rec_m.mind_serve(params, cfg, batch)

        return StepBundle(
            name="serve_step", fn=step, args=(p_avals, avals),
            in_shardings=(p_shard, b_shard),
            out_shardings=_ns(mesh, ba, None, None), num_params=n_params,
        )

    def step(params, batch):
        return rec_m.mind_retrieval(params, cfg, batch)

    return StepBundle(
        name="retrieval_step", fn=step, args=(p_avals, avals),
        in_shardings=(p_shard, b_shard),
        out_shardings=(_ns(mesh), _ns(mesh)), num_params=n_params,
    )


# =============================================================== coregraph
def _build_coregraph(cfg: CoreGraphConfig, shape_name, step_kind, avals, mesh,
                     opt, reduced):
    # one cond-gated SemiCore* superstep of the shard backend (chunk=1), the
    # §Perf measurement unit: its HLO contains exactly the per-superstep
    # collectives (one all_gather of owned core slices + the scalar psum)
    from ..core.resident import build_shard_chunk_fn

    specs = avals["specs"]
    num_probes = avals["num_probes"]
    fn = build_shard_chunk_fn(mesh, "semicore*", cfg.n, num_probes, chunk=1)
    args = (specs["core0"], specs["cnt"], specs["active"], specs["nactive"],
            specs["dst"], specs["rows"], specs["edge_mask"],
            specs["lsegptr"], specs["owned_ids"], specs["owned_mask"])
    return StepBundle(
        name="decompose", fn=fn, args=args,
        in_shardings=None,  # already a jit-wrapped fn with shardings
        out_shardings=None, num_params=0,
    )


def build_step(arch_id: str, shape_name: str, mesh: Mesh, *,
               reduced: bool = False, opt: AdamWConfig | None = None,
               quantize_moments: bool | None = None,
               depth_override: int | None = None) -> StepBundle:
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced()
    if depth_override is not None and cfg.kind == "lm":
        from dataclasses import replace as _replace
        cfg = _replace(cfg, n_layers=depth_override)
    if opt is None:
        big = cfg.kind == "lm" and cfg.d_model >= 7000
        opt = AdamWConfig(quantize_moments=big if quantize_moments is None
                          else quantize_moments)
    num_shards = int(np.prod(mesh.devices.shape))
    step_kind, avals = input_specs(cfg, shape_name, num_shards=num_shards,
                                   reduced=reduced)
    builder = {"lm": _build_lm, "gnn": _build_gnn, "recsys": _build_recsys,
               "coregraph": _build_coregraph}[cfg.kind]
    return builder(cfg, shape_name, step_kind, avals, mesh, opt, reduced)
