"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""
from __future__ import annotations

import jax


def use_mesh(mesh):
    """Activate ``mesh`` for a ``with`` block, across jax versions.

    ``jax.set_mesh`` only exists on newer jax; on the pinned 0.4.x line the
    ``jax.sharding.Mesh`` object is itself the context manager that installs
    the resource environment.  Both return a context manager, so call sites
    are uniformly ``with use_mesh(mesh): ...``.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """256-chip pod mesh (data, model), or 512-chip 2-pod (pod, data, model).

    A function (not a module constant) so importing never touches device
    state; the dry-run sets XLA_FLAGS for 512 host devices before any import.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(max_data: int | None = 1):
    """A (data, model) host mesh for tests / reduced runs.

    ``max_data`` caps the data axis (default 1): the CI matrix forces up to
    8 virtual host devices (ci.yml, DESIGN.md §7) and reduced-cell batch
    sizes need not divide the forced device count, so the smoke meshes stay
    single-shard unless a caller opts into more.  ``None`` spans every
    visible device.
    """
    import numpy as np

    dev = np.array(jax.devices())
    if max_data is not None:
        dev = dev[: max(1, int(max_data))]
    return jax.sharding.Mesh(dev.reshape(-1, 1), ("data", "model"))
